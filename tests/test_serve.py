"""repro.serve: served results must be bit-identical to direct facade
calls — batching, caching, warm executables, and incremental repair are
throughput machinery, never semantics.

The digest/parity tests here are the serving analogue of the engine
digest-parity matrix in test_resident.py and are named so the CI serve
gate (`-k "digest or parity"`) picks them up.
"""
import importlib
import sys

import numpy as np
import pytest

import repro
from repro.api.backend import (
    Backend,
    default_mis2_engine,
    default_multilevel_engine,
)
from repro.core.mis2 import Mis2Options
from repro.graphs import er_laplacian, laplace3d, random_uniform_graph
from repro.serve import (
    Batcher,
    CacheParityError,
    PendingRequest,
    ResultCache,
    Server,
    ServerConfig,
    StreamSession,
    warm_buckets_for,
)

from conftest import verify_mis2


def _fleet():
    """Mixed-size workload: three bucket shapes, structure + matrix."""
    return [repro.Graph(laplace3d(4)),
            repro.Graph(laplace3d(5)),
            repro.Graph(random_uniform_graph(200, 5.0, seed=1)),
            repro.Graph(random_uniform_graph(150, 4.0, seed=2)),
            repro.Graph(random_uniform_graph(60, 3.0, seed=3))]


@pytest.fixture(scope="module")
def fleet():
    return _fleet()


# ---------------------------------------------------------------------------
# served digest == direct facade digest (the acceptance gate)
# ---------------------------------------------------------------------------

def test_served_mis2_digest_matches_facade_mixed_workload(fleet):
    cfg = ServerConfig(max_batch=4, warm_buckets=warm_buckets_for(fleet))
    srv = Server(cfg)
    futs = [srv.submit("mis2", g) for g in fleet]
    assert srv.flush() > 0
    for g, fut in zip(fleet, futs):
        served = fut.result()
        direct = repro.mis2(g)
        assert served.digest == direct.digest
        verify_mis2(g.csr, np.asarray(served.payload))


def test_served_color_coarsen_digest_matches_facade(fleet):
    srv = Server(ServerConfig(max_batch=4))
    color_futs = [srv.submit("color", g) for g in fleet]
    coarsen_futs = [srv.submit("coarsen", g) for g in fleet]
    srv.flush()
    for g, fut in zip(fleet, color_futs):
        assert fut.result().digest == repro.color(g).digest
    for g, fut in zip(fleet, coarsen_futs):
        assert fut.result().digest == repro.coarsen(g).digest


def test_served_amg_setup_digest_matches_facade():
    m = repro.Graph(er_laplacian(300, 6.0, seed=4))
    srv = Server(ServerConfig())
    served = srv.request("amg_setup", m, coarse_size=50)
    direct = repro.amg_setup(repro.Graph(er_laplacian(300, 6.0, seed=4)),
                             coarse_size=50)
    assert served.digest == direct.digest
    assert served.level_digests == direct.level_digests


def test_single_fast_path_digest_matches_facade(fleet):
    g = fleet[2]
    srv = Server(ServerConfig(single_fast_path=True))
    served = srv.request("mis2", g)
    assert srv.server_stats()["single_dispatches"] == 1
    assert served.digest == repro.mis2(g).digest


def test_explicit_engine_honored_digest(fleet):
    g = fleet[0]
    srv = Server(ServerConfig())
    served = srv.request("mis2", g, engine="dense")
    assert served.engine == "dense"
    assert served.digest == repro.mis2(g, engine="dense").digest


# ---------------------------------------------------------------------------
# cache: bitwise hits, parity assertions, byte-budget LRU
# ---------------------------------------------------------------------------

def test_cache_hit_returns_same_payload_bitwise(fleet):
    g = fleet[1]
    srv = Server(ServerConfig())
    first = srv.request("mis2", g)
    # a fresh handle over the same structure shares the canonical digest
    clone = repro.Graph(laplace3d(5))
    fut = srv.submit("mis2", clone)
    assert fut.done(), "identical resubmission must hit the cache"
    hit = fut.result()
    assert hit.digest == first.digest
    assert np.array_equal(np.asarray(hit.payload),
                          np.asarray(first.payload))
    assert hit.payload.tobytes() == first.payload.tobytes()
    stats = srv.server_stats()["cache"]
    assert stats["hits"] == 1 and stats["misses"] >= 1


def test_cache_parity_mode_recomputes_and_asserts(fleet):
    g = fleet[0]
    srv = Server(ServerConfig(parity_fraction=1.0))
    srv.request("mis2", g)
    srv.request("mis2", g)          # hit -> parity recompute
    stats = srv.server_stats()["cache"]
    assert stats["parity_checks"] == 1
    assert stats["parity_failures"] == 0


def test_cache_parity_failure_raises():
    cache = ResultCache(max_bytes=1 << 20, parity_fraction=1.0)

    class FakeResult:
        def __init__(self, digest):
            self.digest = digest
            self.payload = np.zeros(4)

    cache.insert(("k",), FakeResult("aaaa"))
    with pytest.raises(CacheParityError):
        cache.lookup(("k",), recompute=lambda: FakeResult("bbbb"))
    assert cache.stats.parity_failures == 1


def test_cache_eviction_respects_byte_budget():
    cache = ResultCache(max_bytes=2000)

    class R:
        def __init__(self, i):
            self.digest = f"{i:016x}"
            self.payload = np.zeros(100, dtype=np.float64)  # 800 B each

    for i in range(5):
        cache.insert(("g", i), R(i))
    assert cache.stats.bytes_used <= 2000
    assert cache.stats.evictions >= 3
    assert cache.lookup(("g", 0)) is None       # LRU: oldest evicted
    assert cache.lookup(("g", 4)) is not None   # newest survives


def test_cache_disabled_by_zero_budget(fleet):
    srv = Server(ServerConfig(cache_bytes=0))
    srv.request("mis2", fleet[0])
    fut = srv.submit("mis2", fleet[0])
    assert not fut.done()           # no cache -> queued, not resolved
    srv.flush()
    assert fut.result().digest == repro.mis2(fleet[0]).digest


# ---------------------------------------------------------------------------
# batcher: deadline-or-full dispatch with a manual clock
# ---------------------------------------------------------------------------

def _req(g, kind="mis2"):
    return PendingRequest(kind=kind, graph=repro.Graph(g),
                          params={"options": Mis2Options()}, engine=None,
                          backend=None, cache_key=(kind, id(g)))


def test_batcher_full_group_dispatches_immediately():
    b = Batcher(max_batch=3, max_delay_s=10.0)
    for _ in range(3):
        b.add(_req(laplace3d(3)), now=0.0)
    groups = b.due(now=0.0)
    assert len(groups) == 1 and len(groups[0][1]) == 3
    assert len(b) == 0


def test_batcher_partial_group_waits_for_deadline():
    b = Batcher(max_batch=8, max_delay_s=0.5)
    b.add(_req(laplace3d(3)), now=0.0)
    b.add(_req(laplace3d(3)), now=0.1)
    assert b.due(now=0.2) == []                 # budget not exhausted
    assert b.next_deadline(now=0.2) == pytest.approx(0.3)
    groups = b.due(now=0.5)                     # oldest waited 0.5s
    assert len(groups) == 1 and len(groups[0][1]) == 2


def test_batcher_force_flush_dispatches_everything():
    b = Batcher(max_batch=8, max_delay_s=100.0)
    b.add(_req(laplace3d(3)), now=0.0)
    b.add(_req(laplace3d(3), kind="color"), now=0.0)
    groups = b.due(now=0.0, force=True)
    assert len(groups) == 2                     # kinds never coalesce
    assert len(b) == 0


# ---------------------------------------------------------------------------
# per-request engine auto-selection (Backend honored at dispatch time)
# ---------------------------------------------------------------------------

class _FakeDevice:
    def __init__(self, platform):
        self.platform = platform


def test_engine_resolution_honors_request_backend_platform():
    cpu_req = Backend(device=_FakeDevice("cpu"))
    tpu_req = Backend(device=_FakeDevice("tpu"))
    assert default_mis2_engine(cpu_req) == "compacted"
    assert default_mis2_engine(tpu_req) == "compacted_resident"
    assert default_mis2_engine(tpu_req.with_(pallas=True)) == \
        "pallas_resident"
    assert default_multilevel_engine(cpu_req) == "host"
    assert default_multilevel_engine(tpu_req) == "resident"
    # the worklists=False ablation still forces the host-driven driver
    assert default_mis2_engine(
        tpu_req, Mis2Options(worklists=False)) == "compacted"


def test_server_resolves_engine_per_request(fleet):
    srv = Server(ServerConfig())
    req = PendingRequest(kind="mis2", graph=fleet[0],
                         params={"options": Mis2Options()}, engine=None,
                         backend=Backend(device=_FakeDevice("tpu")),
                         cache_key=())
    assert srv._resolve_engine(req) == "compacted_resident"
    req.backend = Backend(device=_FakeDevice("cpu"))
    assert srv._resolve_engine(req) == "compacted"
    req.engine = "dense"
    assert srv._resolve_engine(req) == "dense"


# ---------------------------------------------------------------------------
# warm-executable registry: jit churn accounting
# ---------------------------------------------------------------------------

def test_warm_registry_configured_shapes_cost_no_runtime_compiles(fleet):
    cfg = ServerConfig(max_batch=4, warm_buckets=warm_buckets_for(fleet))
    srv = Server(cfg)
    comp = srv.server_stats()["compiles"]
    assert comp["startup_aot"] == len(cfg.warm_buckets)
    for g in fleet:
        srv.submit("mis2", g)
    srv.flush()
    comp = srv.server_stats()["compiles"]
    assert comp["runtime_cold"] == 0


def test_warm_registry_counts_cold_shapes_once():
    # dedup=False: the pairs below are digest-equal on purpose (they must
    # form a real batch of 2 to exercise the warm-bucket path; with dedup
    # they would coalesce to a single fast-path dispatch)
    srv = Server(ServerConfig(max_batch=2, warm_buckets=(), dedup=False))
    g = repro.Graph(laplace3d(4))
    for _ in range(2):
        srv.submit("mis2", g)
        srv.submit("mis2", repro.Graph(laplace3d(4)))
        srv.flush()
        srv.cache.clear()           # force recomputation next round
    comp = srv.server_stats()["compiles"]
    assert comp["runtime_cold"] == 1        # same cold shape, counted once
    srv.reset_window()
    assert srv.server_stats()["compiles"]["runtime_cold_window"] == 0


# ---------------------------------------------------------------------------
# streaming: incremental-repair digest == from-scratch digest
# ---------------------------------------------------------------------------

def _random_delta(session, rng, n=3):
    v = session.graph.num_vertices
    adds = rng.integers(0, v, size=(n, 2))
    adds = adds[adds[:, 0] != adds[:, 1]]
    rows, cols = session._rows, session._cols
    offd = np.flatnonzero(rows != cols)
    pick = rng.choice(offd, size=min(n, len(offd)), replace=False)
    removes = np.stack([rows[pick], cols[pick]], axis=1)
    return adds, removes


@pytest.mark.parametrize("maker", [
    lambda: laplace3d(6),
    lambda: random_uniform_graph(300, 5.0, seed=11),
], ids=["laplace3d", "er"])
def test_incremental_repair_digest_matches_scratch(maker):
    rng = np.random.default_rng(5)
    session = StreamSession(maker(), check_fraction=1.0)
    v = session.graph.num_vertices
    localized = 0
    for _ in range(3):              # >= 3 delta sequences per graph family
        adds, removes = _random_delta(session, rng)
        repaired = session.apply_delta(adds, removes)
        scratch = repro.mis2(session.graph,
                             options=Mis2Options(priority="fixed"))
        assert repaired.digest == scratch.digest
        verify_mis2(session.graph.csr, np.asarray(repaired.payload))
        st = session.last_repair
        assert st.mode == "repair" and st.checked
        assert st.reactivated <= v
        localized += st.reactivated < v
    assert localized >= 1, "repair never localized below full recompute"


def test_streaming_nonfixed_priority_falls_back_to_recompute():
    session = StreamSession(laplace3d(4), options=Mis2Options())
    r = session.apply_delta([[0, 7]], None)
    assert session.last_repair.mode == "recompute"
    assert r.digest == repro.mis2(session.graph).digest


def test_server_open_stream_uses_config_check_fraction():
    srv = Server(ServerConfig(delta_check_fraction=1.0))
    session = srv.open_stream(laplace3d(4))
    session.apply_delta([[0, 9]], None)
    assert session.last_repair.checked


# ---------------------------------------------------------------------------
# threaded pump + shim + graph digest plumbing
# ---------------------------------------------------------------------------

def test_threaded_server_serves_without_explicit_flush(fleet):
    cfg = ServerConfig(max_batch=4, max_delay_s=0.005)
    with Server(cfg) as srv:
        futs = [srv.submit("mis2", g) for g in fleet]
        results = [f.result(timeout=60) for f in futs]
    for g, r in zip(fleet, results):
        assert r.digest == repro.mis2(g).digest


def test_launch_serve_shim_warns_and_reexports():
    sys.modules.pop("repro.launch.serve", None)
    with pytest.warns(DeprecationWarning, match="repro.serve"):
        mod = importlib.import_module("repro.launch.serve")
    assert mod.Server is Server
    assert mod.ServerConfig is ServerConfig


def test_graph_digest_canonical_and_cached():
    g1 = repro.Graph(laplace3d(4))
    g2 = repro.Graph(laplace3d(4))
    g3 = repro.Graph(laplace3d(5))
    assert g1.digest == g2.digest
    assert g1.digest != g3.digest
    _ = g1.digest
    assert g1.conversions.get("digest") == 1    # second access is cached
    # structure-only vs matrix handles differ (values are hashed)
    s = repro.Graph(laplace3d(4).graph)
    assert s.digest != g1.digest
