"""Dry-run machinery tests: production mesh, input specs, HLO analyzer,
and one real lower+compile cell via subprocess (512 host devices)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def test_hlo_analyzer_scales_while_loops():
    """Synthetic HLO: a dot inside a while body must be scaled by the
    known_trip_count."""
    from repro.launch.hlo_analysis import analyze
    hlo = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %dot.1 = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %dot.1)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %while.1 = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[8,8] get-tuple-element(%while.1), index=1
}
"""
    res = analyze(hlo, 1)
    # one 8x8x8 dot = 2*8*8*8 = 1024 flops, x7 trips
    assert res["flops"] == pytest.approx(7 * 1024)


def test_collective_wire_model():
    from repro.launch.hlo_analysis import analyze
    hlo = """
ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024] parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%a), replica_groups=[32,16]<=[512], to_apply=%add
}

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}
"""
    res = analyze(hlo, 512)
    rec = res["collectives"]["all-reduce"]
    assert rec["count"] == 1
    # ring all-reduce of 4096 bytes over groups of 16: 2*4096*15/16
    assert rec["wire_bytes"] == pytest.approx(2 * 4096 * 15 / 16)


def test_cell_applicability_rules():
    from repro.configs import get_config
    from repro.models.config import LM_SHAPES, cell_applicable
    long = next(c for c in LM_SHAPES if c.shape_name == "long_500k")
    ok, _ = cell_applicable(get_config("mamba2-780m"), long)
    assert ok
    ok, why = cell_applicable(get_config("granite-8b"), long)
    assert not ok and "512k" in why


@pytest.mark.slow
def test_dryrun_cell_compiles_on_512_devices(tmp_path):
    """Real lower+compile of one cell on the production mesh (subprocess
    because the dry-run forces 512 host devices)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "decode_32k", "--out", str(tmp_path), "--force"],
        capture_output=True, text=True, timeout=580,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"}, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads((tmp_path / "smollm-135m__decode_32k__16x16.json")
                     .read_text())
    assert rec["ok"] and rec["fits_hbm"]
    assert rec["num_devices"] == 256
    assert rec["roofline"]["dominant"] in ("memory", "collective", "compute")
