"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tuples import id_bits
from repro.graphs import csr_to_ell_graph, csr_to_ell_matrix, laplace3d, \
    random_skewed_graph, random_uniform_graph
from repro.kernels.hash_priority.kernel import hash_pack_pallas
from repro.kernels.hash_priority.ref import hash_pack_ref
from repro.kernels.minprop_ell.kernel import decide_pallas, refresh_columns_pallas
from repro.kernels.minprop_ell.ref import decide_ref, refresh_columns_ref
from repro.kernels.spmv_ell.kernel import spmv_ell_pallas
from repro.kernels.spmv_ell.ref import spmv_ell_ref

OUT = np.uint32(0xFFFFFFFF)


@pytest.mark.parametrize("v,deg,seed", [(257, 4.0, 0), (1024, 8.0, 1),
                                        (333, 12.0, 2), (4096, 3.0, 3)])
@pytest.mark.parametrize("count_frac", [1.0, 0.5, 0.1])
def test_minprop_refresh_columns_sweep(v, deg, seed, count_frac):
    g = random_uniform_graph(v, deg, seed=seed)
    ell = csr_to_ell_graph(g)
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.integers(1, 2**32 - 2, size=v, dtype=np.uint32))
    w = 1 << int(np.ceil(np.log2(max(2, int(v * 0.7)))))
    wl = rng.permutation(v)[:w].astype(np.int32)
    wl = np.pad(wl[:min(w, v)], (0, max(0, w - v)), constant_values=0)
    wl_nbrs = np.asarray(ell.neighbors)[wl]
    count = max(1, int(len(wl) * count_frac))
    out_k = refresh_columns_pallas(t, jnp.asarray(wl_nbrs),
                                   jnp.asarray(count, jnp.int32))
    out_r = refresh_columns_ref(t, jnp.asarray(wl_nbrs), count)
    # bitwise equality on the live region
    assert (np.asarray(out_k)[:count] == np.asarray(out_r)[:count]).all()


@pytest.mark.parametrize("v,deg", [(512, 6.0), (777, 10.0)])
def test_minprop_decide_sweep(v, deg):
    g = random_uniform_graph(v, deg, seed=7)
    ell = csr_to_ell_graph(g)
    rng = np.random.default_rng(7)
    t = rng.integers(0, 2**32 - 1, size=v, dtype=np.uint32)
    t[rng.random(v) < 0.1] = 0            # some IN
    t[rng.random(v) < 0.1] = OUT          # some OUT
    m = rng.integers(0, 2**32 - 1, size=v, dtype=np.uint32)
    m[rng.random(v) < 0.2] = OUT
    active = rng.random(v) < 0.9
    w = 512
    wl = rng.permutation(v)[:w].astype(np.int32)
    wl_nbrs = np.asarray(ell.neighbors)[wl]
    t_rows = t[wl]
    count = 300
    out_k = decide_pallas(jnp.asarray(t_rows), jnp.asarray(m),
                          jnp.asarray(active), jnp.asarray(wl_nbrs),
                          jnp.asarray(count, jnp.int32))
    out_r = decide_ref(jnp.asarray(t_rows), jnp.asarray(m),
                       jnp.asarray(active), jnp.asarray(wl_nbrs), count)
    assert (np.asarray(out_k)[:count] == np.asarray(out_r)[:count]).all()


@pytest.mark.parametrize("maker,dtype", [
    (lambda: laplace3d(8), jnp.float32),
    (lambda: laplace3d(12), jnp.float32),
])
def test_spmv_sweep(maker, dtype):
    a = maker()
    ell = csr_to_ell_matrix(a)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(a.num_rows).astype(dtype))
    y_k = spmv_ell_pallas(ell.cols, ell.vals, x)
    y_r = spmv_ell_ref(ell.cols, ell.vals, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-5)


def test_spmv_skewed_degrees():
    g = random_skewed_graph(2000, 6.0, seed=5)
    from repro.graphs import ell_to_csr_graph
    csr = ell_to_csr_graph(csr_to_ell_graph(g))
    vals = np.random.default_rng(1).standard_normal(
        csr.num_entries).astype(np.float32)
    from repro.graphs.csr import CSRMatrix
    import jax.numpy as jnp
    a = CSRMatrix(csr.indptr, csr.indices, jnp.asarray(vals))
    ell = csr_to_ell_matrix(a)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(2000)
                    .astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(spmv_ell_pallas(ell.cols, ell.vals, x)),
        np.asarray(spmv_ell_ref(ell.cols, ell.vals, x)),
        rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [64, 1000, 4097])
@pytest.mark.parametrize("iteration", [0, 17])
def test_hash_pack_bit_exact(n, iteration):
    ids = jnp.arange(n, dtype=jnp.uint32)
    b = id_bits(n)
    out_k = hash_pack_pallas(iteration, ids, b)
    out_r = hash_pack_ref(iteration, ids, b)
    assert (np.asarray(out_k) == np.asarray(out_r)).all()
