"""AMG edge cases: rectangular ELL padding, degenerate hierarchies, and the
v-cycle's actual job (reducing the residual)."""
import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.api import Graph, amg  # noqa: E402
from repro.graphs import laplace3d  # noqa: E402
from repro.graphs.ops import spmv_ell  # noqa: E402
from repro.solvers.amg import _build_hierarchy_impl, _rect_ell, v_cycle  # noqa: E402


# ---------------------------------------------------------------------------
# _rect_ell: a row with zero entries must pad cleanly, not corrupt slots
# ---------------------------------------------------------------------------

def test_rect_ell_zero_entry_row():
    rows = np.array([0, 0, 2])
    cols = np.array([0, 1, 1])
    vals = np.array([1.0, 2.0, 3.0])
    ell = _rect_ell(rows, cols, vals, nrows=3)   # row 1 is empty
    assert ell.cols.shape == (3, 2)
    mask = np.asarray(ell.mask)
    assert mask[0].tolist() == [True, True]
    assert not mask[1].any()                      # empty row: all padding
    assert mask[2].tolist() == [True, False]
    # padding slots are (col 0, val 0): SpMV through the empty row yields 0
    x = jnp.asarray(np.array([5.0, 7.0], dtype=np.float32))
    y = np.asarray(jnp.sum(ell.vals * x[ell.cols], axis=1))
    np.testing.assert_allclose(y, [5.0 + 14.0, 0.0, 21.0])


def test_rect_ell_all_rows_empty_min_width():
    ell = _rect_ell(np.array([], dtype=np.int64), np.array([], dtype=np.int64),
                    np.array([], dtype=np.float64), nrows=2)
    assert ell.cols.shape == (2, 1)               # d = max(1, ...) floor
    assert not np.asarray(ell.mask).any()


# ---------------------------------------------------------------------------
# hierarchy build on a graph already below coarse_size: single level
# ---------------------------------------------------------------------------

def test_hierarchy_below_coarse_size_is_single_level():
    a = laplace3d(4)                              # 64 rows < coarse_size
    h = _build_hierarchy_impl(a, aggregation="two_phase", coarse_size=200)
    assert len(h.levels) == 1
    assert h.levels[0].p_ell is None and h.levels[0].r_ell is None
    assert h.level_sizes == [(64, a.num_entries)]
    # the v-cycle degenerates to the cached direct solve
    b = jnp.asarray(np.random.default_rng(1).standard_normal(64)
                    .astype(np.float32))
    x = v_cycle(h, b)
    r = b - spmv_ell(Graph(a).ell_matrix, x)
    assert float(jnp.linalg.norm(r)) <= 1e-4 * float(jnp.linalg.norm(b))


def test_facade_amg_single_level():
    setup = amg(Graph(laplace3d(4)), coarse_size=200)
    assert setup.num_levels == 1
    assert setup.converged
    assert setup.level_sizes[0][0] == 64


# ---------------------------------------------------------------------------
# v-cycle residual reduction (the Table V property, asserted not eyeballed)
# ---------------------------------------------------------------------------

def test_v_cycle_reduces_residual():
    a = laplace3d(8)                              # 512 rows, 3 levels
    h = _build_hierarchy_impl(a, aggregation="two_phase", coarse_size=64)
    assert len(h.levels) >= 2
    b = jnp.asarray(np.random.default_rng(0).standard_normal(a.num_rows)
                    .astype(np.float32))
    x = v_cycle(h, b)
    rel = float(jnp.linalg.norm(b - spmv_ell(Graph(a).ell_matrix, x))
                / jnp.linalg.norm(b))
    # one V(2,2) cycle on Laplace3D contracts the residual well below 0.3
    # (measured ~0.06); a regression in smoothing/transfer breaks this
    assert rel < 0.3, rel
