"""Fault tolerance: the persistent serve-cache tier (atomic writes,
digest-verified restart, retention) and multi-device MIS-2.

The seed-era version of this file exercised the legacy LM checkpoint
modules; the atomic-write / retention / bit-exact-restart patterns it
pioneered now gate the repo's real fault-tolerance surface — the
``repro.serve`` persistent cache tier (``src/repro/serve/persist.py``),
which reuses the same tmp+fsync+rename commit discipline.
"""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import repro
from repro.graphs import laplace3d, random_uniform_graph
from repro.serve import Fault, FaultPlan, PersistTier, Server, ServerConfig


def _graph(seed=0, n=100, deg=4.0):
    return repro.Graph(random_uniform_graph(n, deg, seed=seed))


def _key(kind, g, engine="auto"):
    return (kind, g.digest, engine, ())


# ---------------------------------------------------------------------------
# atomic commit: an entry either exists whole or not at all
# ---------------------------------------------------------------------------

def test_persist_store_leaves_no_tmp(tmp_path):
    tier = PersistTier(str(tmp_path))
    g = _graph(1)
    assert tier.store(_key("mis2", g), repro.mis2(g))
    names = [p.name for p in tmp_path.iterdir()]
    assert not any(n.endswith(".tmp") for n in names)
    assert len(names) == 1 and names[0].startswith("entry_")


def test_persist_crash_mid_commit_leaves_old_or_nothing(tmp_path):
    g = _graph(2)
    res = repro.mis2(g)
    plan = FaultPlan(seed=1, sites={"persist_write": Fault("error", count=1)})
    tier = PersistTier(str(tmp_path), faults=plan)
    assert not tier.store(_key("mis2", g), res)     # simulated crash
    assert tier.load(_key("mis2", g)) is None       # nothing half-written
    # next open sweeps the orphaned tmp and the retry commits cleanly
    tier2 = PersistTier(str(tmp_path), faults=plan)
    assert tier2.stats.torn_cleaned == 1
    assert tier2.store(_key("mis2", g), res)        # fault budget spent
    assert tier2.load(_key("mis2", g)).digest == res.digest


def test_persist_overwrite_same_key_stays_consistent(tmp_path):
    tier = PersistTier(str(tmp_path))
    g = _graph(3)
    res = repro.mis2(g)
    key = _key("mis2", g)
    assert tier.store(key, res)
    assert tier.store(key, res)                     # idempotent re-commit
    assert len(tier) == 1
    assert tier.load(key).digest == res.digest
    assert tier.stats.corrupt == 0


# ---------------------------------------------------------------------------
# digest re-verification: bit rot and tampering are dropped, never served
# ---------------------------------------------------------------------------

def test_persist_bit_rot_on_disk_is_detected_and_dropped(tmp_path):
    tier = PersistTier(str(tmp_path))
    g = _graph(4)
    res = repro.mis2(g)
    key = _key("mis2", g)
    assert tier.store(key, res)
    npz = next(tmp_path.glob("entry_*/arrays.npz"))
    raw = bytearray(npz.read_bytes())
    raw[len(raw) // 2] ^= 0xFF                      # one flipped bit, mid-file
    npz.write_bytes(bytes(raw))
    assert tier.load(key) is None                   # dropped, not served
    assert tier.stats.corrupt == 1
    assert len(tier) == 0                           # entry removed from disk


def test_persist_tampered_manifest_is_rejected(tmp_path):
    tier = PersistTier(str(tmp_path))
    g = _graph(5)
    key = _key("mis2", g)
    assert tier.store(key, repro.mis2(g))
    mpath = next(tmp_path.glob("entry_*/manifest.json"))
    manifest = json.loads(mpath.read_text())
    manifest["array_digests"]["payload"] = "0" * 16
    mpath.write_text(json.dumps(manifest))
    assert tier.load(key) is None
    assert tier.stats.corrupt == 1


# ---------------------------------------------------------------------------
# restart: a new server rehydrates from disk, serving 0 corrupt entries
# ---------------------------------------------------------------------------

def test_server_restart_rehydrates_with_zero_corrupt_served(tmp_path):
    d = str(tmp_path / "tier")
    graphs = [_graph(10 + s) for s in range(3)] + [repro.Graph(laplace3d(4))]
    srv = Server(ServerConfig(persist_dir=d))
    refs = [srv.request("mis2", g) for g in graphs]
    refs.append(srv.request("coarsen", graphs[-1]))
    srv.stop()

    srv2 = Server(ServerConfig(persist_dir=d))      # "restarted process"
    got = [srv2.request("mis2", g) for g in graphs]
    got.append(srv2.request("coarsen", graphs[-1]))
    for a, b in zip(refs, got):
        assert a.digest == b.digest
        np.testing.assert_array_equal(np.asarray(a.payload),
                                      np.asarray(b.payload))
    assert srv2.stats.dispatches == 0               # all served from disk
    assert srv2.persist.stats.hits == len(got)
    assert srv2.persist.stats.corrupt == 0
    srv2.stop()


def test_server_restart_recomputes_corrupted_entry(tmp_path):
    d = str(tmp_path / "tier")
    g = _graph(20)
    srv = Server(ServerConfig(persist_dir=d))
    ref = srv.request("mis2", g)
    srv.stop()
    npz = next(Path(d).glob("entry_*/arrays.npz"))
    npz.write_bytes(b"not an npz at all")           # catastrophic corruption

    srv2 = Server(ServerConfig(persist_dir=d))
    res = srv2.request("mis2", g)
    assert res.digest == ref.digest                 # honest recompute
    assert srv2.persist.stats.corrupt == 1
    assert srv2.stats.dispatches == 1
    srv2.stop()


# ---------------------------------------------------------------------------
# retention: byte budget enforced oldest-first, loads refresh recency
# ---------------------------------------------------------------------------

def test_persist_retention_evicts_to_budget(tmp_path):
    import time as _time

    tier = PersistTier(str(tmp_path), max_bytes=1 << 40)
    graphs = [_graph(30 + s, n=150) for s in range(5)]
    results = [repro.mis2(g) for g in graphs]
    keys = [_key("mis2", g) for g in graphs]
    sizes = []
    for k, r in zip(keys, results):
        before = tier.stats.bytes_used
        assert tier.store(k, r)
        sizes.append(tier.stats.bytes_used - before)
    budget = sum(sizes[-2:]) + sizes[0] // 2        # fits ~2 entries
    tier2 = PersistTier(str(tmp_path / "b"), max_bytes=budget)
    for k, r in zip(keys, results):
        assert tier2.store(k, r)
        _time.sleep(0.01)                           # strictly ordered mtimes
    assert tier2.stats.bytes_used <= budget
    assert tier2.stats.evictions >= 1
    assert tier2.load(keys[-1]) is not None         # newest survives
    assert tier2.load(keys[0]) is None              # oldest went first
    assert tier2.stats.corrupt == 0


# ---------------------------------------------------------------------------
# multi-device MIS-2 (the distributed engine's own fault surface)
# ---------------------------------------------------------------------------

REPO = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_distributed_mis2_multi_device():
    """shard_map MIS-2 over 8 host devices == single-device result."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%s")
import numpy as np
from repro.graphs import laplace3d, random_uniform_graph
from repro.core.dist import mis2_distributed
from repro.core.mis2 import mis2
for g in [laplace3d(10).graph, random_uniform_graph(997, 5.0, seed=6)]:
    single = mis2(g, engine="dense")
    in_set, iters = mis2_distributed(g)
    assert (in_set == single.in_set).all()
    assert iters == single.iterations
print("DIST_OK")
""" % (REPO / "src")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert "DIST_OK" in out.stdout, out.stderr[-2000:]
