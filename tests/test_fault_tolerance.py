"""Fault-tolerance substrate: checkpoint atomicity/retention, bit-exact
restart, elastic re-shard, deterministic seekable data."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticTokens

REPO = Path(__file__).resolve().parents[1]


def _state(seed):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "opt": {"m": jnp.zeros((8, 8)), "step": jnp.asarray(3)}}


def test_checkpoint_roundtrip(tmp_path):
    s = _state(0)
    save_checkpoint(tmp_path, 10, s)
    assert latest_step(tmp_path) == 10
    step, restored, manifest = restore_checkpoint(tmp_path, s)
    assert step == 10
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 10


def test_checkpoint_retention_and_latest(tmp_path):
    s = _state(1)
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, step, s, keep=2)
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_4", "step_5"]
    assert latest_step(tmp_path) == 5


def test_checkpoint_no_torn_tmp(tmp_path):
    s = _state(2)
    save_checkpoint(tmp_path, 7, s)
    assert not list(tmp_path.glob("*.tmp"))


def test_data_pipeline_seekable_deterministic():
    cfg = DataConfig(vocab_size=1000, global_batch=8, seq_len=32, seed=5)
    p1 = SyntheticTokens(cfg)
    p2 = SyntheticTokens(cfg)
    a = p1.batch_at(17)["tokens"]
    b = p2.batch_at(17)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = p1.batch_at(18)["tokens"]
    assert not np.array_equal(a, c)
    # host slicing partitions the global batch exactly
    full = p1.batch_at(3)
    parts = [p1.host_slice(full, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])


@pytest.mark.slow
def test_train_restart_bit_exact(tmp_path):
    """Training N steps straight == training with a kill/restart in the
    middle (checkpoint + seekable data = bit-exact resume)."""
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root"}
    common = [sys.executable, "-m", "repro.launch.train", "--arch",
              "smollm-135m", "--reduced", "--batch", "4", "--seq", "32",
              "--ckpt-every", "5", "--log-every", "100",
              "--total-steps", "10"]

    def run(steps, ckpt):
        out = subprocess.run(
            common + ["--steps", str(steps), "--ckpt-dir", str(ckpt)],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        last = [l for l in out.stdout.splitlines() if l.startswith("step")][-1]
        return float(last.split("loss")[1].split()[0])

    loss_straight = run(10, tmp_path / "a")
    run(5, tmp_path / "b")             # first half
    loss_resumed = run(10, tmp_path / "b")   # resumes from step 5
    assert abs(loss_straight - loss_resumed) < 1e-5


@pytest.mark.slow
def test_elastic_reshard_across_meshes(tmp_path):
    """Checkpoint written under a (4,2) mesh restores onto (2,4) and (8,1)
    meshes with identical values (elastic scaling contract)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%s")
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint

path = r"%s"
state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
sh_a = {"w": NamedSharding(mesh_a, P("data", "model"))}
state_a = jax.tree.map(jax.device_put, state, sh_a)
save_checkpoint(path, 1, state_a)
for shape in ((2, 4), (8, 1), (1, 1)):
    mesh_b = jax.make_mesh(shape, ("data", "model"))
    sh_b = {"w": NamedSharding(mesh_b, P("data", "model"))}
    _, restored, _ = restore_checkpoint(path, state, shardings=sh_b)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
print("ELASTIC_OK")
""" % (REPO / "src", tmp_path)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_distributed_mis2_multi_device():
    """shard_map MIS-2 over 8 host devices == single-device result."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%s")
import numpy as np
from repro.graphs import laplace3d, random_uniform_graph
from repro.core.dist import mis2_distributed
from repro.core.mis2 import mis2
for g in [laplace3d(10).graph, random_uniform_graph(997, 5.0, seed=6)]:
    single = mis2(g, engine="dense")
    in_set, iters = mis2_distributed(g)
    assert (in_set == single.in_set).all()
    assert iters == single.iterations
print("DIST_OK")
""" % (REPO / "src")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert "DIST_OK" in out.stdout, out.stderr[-2000:]
