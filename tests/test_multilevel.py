"""``repro.multilevel`` — device-resident multilevel setup.

The acceptance surface of the ``multilevel: host | resident`` engine
pair:

* digest parity: per-level ``A_l`` ELL digests, aggregation labels and
  coarse colors bit-identical across engines, over laplace3d + an ER
  Laplacian x all three priorities x >= 3 levels;
* execution shape: the resident setup performs **zero** matrix-sized
  host syncs (``obs.capture()`` counter-asserted) and a bounded number
  of jitted dispatches (7 per built level);
* the device Galerkin product agrees with the scipy reference
  (``graphs.ops.galerkin_coarse_matrix``) on random CSR matrices
  including empty rows, singleton aggregates and rectangular P
  (property-style, hypothesis with the deterministic fallback);
* the ``misk`` engine pair (dense | resident) is bit-identical;
* coarse-solver dtype defaults + the ``dense_coarse_cap`` fallback.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # image has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

jnp = pytest.importorskip("jax.numpy")

from repro.api import (  # noqa: E402
    Graph,
    Mis2Options,
    amg_setup,
    amg_setup_batch,
    cluster_gs_setup,
    list_engines,
    misk,
)
from repro import obs  # noqa: E402
from repro.graphs import er_laplacian, laplace3d  # noqa: E402
from repro.graphs.csr import CSRMatrix, csr_from_coo  # noqa: E402
from repro.graphs.ops import galerkin_coarse_matrix  # noqa: E402
from repro.multilevel import galerkin  # noqa: E402
from repro.multilevel.packing import (  # noqa: E402
    pack_clusters_device,
    pack_clusters_host,
)

LEVEL_KW = dict(coarse_size=24, max_levels=6)


@pytest.fixture(scope="module")
def matrices():
    return {
        "laplace3d": Graph(laplace3d(8)),            # V = 512
        "er": Graph(er_laplacian(600, 6.0, seed=3)),
    }


# ---------------------------------------------------------------------------
# digest parity (the acceptance gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("priority", ["fixed", "xorshift", "xorshift_star"])
def test_amg_setup_digest_parity(matrices, priority):
    opts = Mis2Options(priority=priority)
    for name, a in matrices.items():
        host = amg_setup(a, engine="host", options=opts, **LEVEL_KW)
        with obs.capture() as cap:
            res = amg_setup(a, engine="resident", options=opts, **LEVEL_KW)
        assert host.num_levels >= 3, (name, host.level_sizes)
        assert host.num_levels == res.num_levels
        assert host.level_sizes == res.level_sizes
        # per-level A_l ELL digests bit-identical (cols + vals + mask)
        assert host.level_digests == res.level_digests, (name, priority)
        # zero matrix-sized host syncs in the resident setup path,
        # 7 dispatches per built (non-coarsest) level
        assert cap.value("multilevel.host_syncs") == 0
        assert res.dispatches == 7 * (res.num_levels - 1)


def test_amg_setup_engine_dispatch_and_result_fields(matrices):
    a = matrices["laplace3d"]
    assert list_engines("multilevel") == {"multilevel": ["host", "resident"]}
    host = amg_setup(a, engine="host", **LEVEL_KW)
    res = amg_setup(a, engine="resident", **LEVEL_KW)
    assert host.engine == "host" and res.engine == "resident"
    assert host.dispatches == 0
    for setup in (host, res):
        assert set(setup.timings) >= {"aggregate", "prolongator",
                                      "galerkin", "pack"}
    with pytest.raises(ValueError):
        amg_setup(a, engine="nope")


def test_amg_setup_vcycle_equivalence(matrices):
    """Digest-identical hierarchies must solve identically: one V-cycle
    from either engine produces the same iterate bit for bit."""
    from repro.solvers.amg import v_cycle

    a = matrices["laplace3d"]
    host = amg_setup(a, engine="host", **LEVEL_KW)
    res = amg_setup(a, engine="resident", **LEVEL_KW)
    b = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(a.num_vertices).astype(np.float32))
    xh = np.asarray(v_cycle(host.hierarchy, b))
    xr = np.asarray(v_cycle(res.hierarchy, b))
    np.testing.assert_array_equal(xh, xr)


def test_host_syncs_counted_on_host_engine(matrices):
    with obs.capture() as cap:
        host = amg_setup(matrices["laplace3d"], engine="host", **LEVEL_KW)
    # 3 matrix-sized round-trips per built level (the one-time coarsest
    # densify is boundary work, counted by neither engine)
    assert cap.value("multilevel.host_syncs") == 3 * (host.num_levels - 1)
    assert cap.value("multilevel.resident_dispatches") == 0


# ---------------------------------------------------------------------------
# cluster-GS setup parity (labels, colors, packed rows, timings)
# ---------------------------------------------------------------------------

def test_cluster_gs_setup_parity(matrices):
    for name, a in matrices.items():
        host = cluster_gs_setup(a, engine="host")
        with obs.capture() as cap:
            res = cluster_gs_setup(a, engine="resident")
        assert cap.value("multilevel.host_syncs") == 0
        assert host.digest == res.digest, name            # labels
        assert host.colors_digest == res.colors_digest    # coarse colors
        assert host.num_colors == res.num_colors
        assert host.num_clusters == res.num_clusters
        hr, rr = host.preconditioner.color_rows, res.preconditioner.color_rows
        assert len(hr) == len(rr)
        for x, y in zip(hr, rr):                          # packed rows
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_cluster_gs_structured_timings(matrices):
    res = cluster_gs_setup(matrices["laplace3d"], engine="host")
    assert set(res.timings) == {"aggregate", "color", "pack"}
    assert all(t >= 0.0 for t in res.timings.values())
    assert set(res.preconditioner.timings) == {"aggregate", "color", "pack"}
    # the legacy solver entry point reports the same structure
    from repro.solvers.multicolor_gs import setup_cluster_gs, setup_point_gs

    pre = setup_cluster_gs(matrices["laplace3d"].csr_matrix)
    assert set(pre.timings) == {"aggregate", "color", "pack"}
    ppt = setup_point_gs(matrices["laplace3d"].csr_matrix)
    assert set(ppt.timings) == {"aggregate", "color", "pack"}


def test_cluster_gs_apply_parity(matrices):
    """Bit-identical packings must precondition identically."""
    a = matrices["er"]
    b = jnp.asarray(np.random.default_rng(1)
                    .standard_normal(a.num_vertices).astype(np.float32))
    xs = [np.asarray(cluster_gs_setup(a, engine=e).preconditioner.apply(b))
          for e in ("host", "resident")]
    np.testing.assert_array_equal(xs[0], xs[1])


@pytest.mark.parametrize("ncolors", [5, 70])   # 70 > coloring.MAX_COLORS
def test_pack_clusters_device_matches_host_random(ncolors):
    rng = np.random.default_rng(7)
    v, nclusters = 257, 101
    labels = rng.integers(0, nclusters, v).astype(np.int32)
    labels[:nclusters] = np.arange(nclusters)     # every cluster non-empty
    colors = rng.integers(0, ncolors, nclusters).astype(np.int32)
    host = pack_clusters_host(labels, colors, ncolors, v)
    dev = pack_clusters_device(labels, colors, ncolors, v)
    assert len(host) == len(dev)
    for x, y in zip(host, dev):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# device Galerkin vs the scipy reference (property-style)
# ---------------------------------------------------------------------------

def _dense_of(csr: CSRMatrix, shape) -> np.ndarray:
    out = np.zeros(shape, dtype=np.float64)
    ip = np.asarray(csr.indptr)
    rows = np.repeat(np.arange(len(ip) - 1), np.diff(ip))
    np.add.at(out, (rows, np.asarray(csr.indices)),
              np.asarray(csr.values, dtype=np.float64))
    return out


def _random_case(seed: int, v: int, nagg: int, density: float):
    rng = np.random.default_rng(seed)
    # random symmetric-pattern CSR with empty rows possible
    e = max(0, int(density * v * 4))
    r = rng.integers(0, v, e)
    c = rng.integers(0, v, e)
    vals = rng.standard_normal(e).astype(np.float32)
    a = csr_from_coo(np.concatenate([r, c]), np.concatenate([c, r]), v,
                     np.concatenate([vals, vals]))
    # rectangular P: one entry per fine row (tentative-style) plus noise;
    # some aggregates end up singleton or empty
    labels = rng.integers(0, nagg, v)
    extra = rng.integers(0, 4)
    pr = np.concatenate([np.arange(v), rng.integers(0, v, extra)])
    pc = np.concatenate([labels, rng.integers(0, nagg, extra)])
    pv = rng.standard_normal(len(pr))
    return a, pr, pc, pv, nagg


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(5, 60), st.integers(1, 20),
       st.floats(0.0, 1.5))
def test_galerkin_matches_scipy(seed, v, nagg, density):
    a, pr, pc, pv, nagg = _random_case(seed, v, nagg, density)
    want = galerkin_coarse_matrix(a, pr, pc, pv, nagg)      # scipy (f64)
    got = galerkin(a, pr, pc, pv, nagg)                     # device
    np.testing.assert_allclose(_dense_of(got, (nagg, nagg)),
                               _dense_of(want, (nagg, nagg)),
                               rtol=1e-5, atol=1e-6)


def test_galerkin_empty_rows_and_singletons():
    # 5x5 with two empty rows; P rectangular 5x3 with a singleton column
    a = csr_from_coo(np.array([0, 0, 3]), np.array([0, 3, 0]), 5,
                     np.array([2.0, -1.0, -1.0]))
    pr = np.array([0, 3, 4])
    pc = np.array([0, 1, 2])                      # aggregate 2 is a singleton
    pv = np.array([1.0, 0.5, 2.0])
    want = galerkin_coarse_matrix(a, pr, pc, pv, 3)
    got = galerkin(a, pr, pc, pv, 3)
    np.testing.assert_allclose(_dense_of(got, (3, 3)),
                               _dense_of(want, (3, 3)), rtol=1e-6, atol=0)


# ---------------------------------------------------------------------------
# misk engine pair
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 3])
def test_misk_engines_bit_identical(k):
    g = Graph(laplace3d(8).graph)
    dense = misk(g, k=k, engine="dense")
    with obs.capture() as cap:
        res = misk(g, k=k, engine="resident")
    assert dense.digest == res.digest
    assert dense.iterations == res.iterations
    assert res.num_compiles == 1
    assert cap.value("mis2.resident_dispatches") == 1
    assert cap.value("mis2.host_syncs") == 0


def test_misk_registry_and_default():
    assert list_engines("misk") == {"misk": ["dense", "resident"]}
    r = misk(Graph(laplace3d(6).graph), k=2)      # engine=None auto-selects
    assert r.engine.startswith("misk2_")


# ---------------------------------------------------------------------------
# coarse solver: dtype threading + densification cap
# ---------------------------------------------------------------------------

def test_coarse_dtype_default_and_override(matrices):
    from repro.api import accelerator_present

    h = amg_setup(matrices["laplace3d"], **LEVEL_KW)
    want = "float32" if accelerator_present() else "float64"
    assert h.hierarchy.coarse_dtype == want
    h32 = amg_setup(matrices["laplace3d"], coarse_dtype="float32", **LEVEL_KW)
    assert h32.hierarchy.coarse_dtype == "float32"
    # both coarse solves actually solve (residual-reducing V-cycle)
    from repro.graphs.ops import spmv_ell
    from repro.solvers.amg import v_cycle

    a = matrices["laplace3d"]
    b = jnp.asarray(np.random.default_rng(2)
                    .standard_normal(a.num_vertices).astype(np.float32))
    for h_ in (h, h32):
        x = v_cycle(h_.hierarchy, b)
        rel = float(jnp.linalg.norm(b - spmv_ell(a.ell_matrix, x))
                    / jnp.linalg.norm(b))
        assert rel < 0.3, rel


def test_dense_coarse_cap_falls_back_to_jacobi(matrices):
    a = matrices["laplace3d"]
    h = amg_setup(a, max_levels=1, dense_coarse_cap=64)   # coarsest = 512
    assert h.hierarchy.coarse_kind == "jacobi"
    # the cap defaults to coarse_size: a max_levels cut that leaves the
    # coarsest above what was asked for must not densify it
    hd = amg_setup(a, max_levels=1, coarse_size=200)
    assert hd.hierarchy.coarse_kind == "jacobi"
    h2 = amg_setup(a, **LEVEL_KW)
    assert h2.hierarchy.coarse_kind == "lu"


# ---------------------------------------------------------------------------
# batched setup
# ---------------------------------------------------------------------------

def test_amg_setup_batch_digest_parity(matrices):
    mats = [matrices["laplace3d"], matrices["er"]]
    batch = amg_setup_batch(mats, engine="host", **LEVEL_KW)
    assert len(batch) == 2
    singles = [amg_setup(m, engine="host", **LEVEL_KW) for m in mats]
    for got, want in zip(batch, singles):
        assert got.level_digests == want.level_digests
        assert got.level_sizes == want.level_sizes


# ---------------------------------------------------------------------------
# transposed ELL SpMV (matrix-free restriction)
# ---------------------------------------------------------------------------

def test_spmv_t_kernel_matches_ref():
    from repro.kernels.spmv_ell.kernel import spmv_ell_t_pallas
    from repro.kernels.spmv_ell.ref import spmv_ell_t_ref
    from repro.multilevel.prolongator import rect_ell

    rng = np.random.default_rng(5)
    rows = rng.integers(0, 300, 900)
    cols = rng.integers(0, 40, 900)
    vals = rng.standard_normal(900)
    m = rect_ell(rows, cols, vals.astype(np.float32), 300)
    x = jnp.asarray(rng.standard_normal(300).astype(np.float32))
    want = spmv_ell_t_ref(m.cols, m.vals, x, 40)
    got = spmv_ell_t_pallas(m.cols, m.vals, x, num_out=40, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_vcycle_matrix_free_restriction(matrices):
    """explicit_restriction=False drops R; the V-cycle restricts through
    the transposed SpMV and still contracts the residual."""
    from repro.graphs.ops import spmv_ell
    from repro.solvers.amg import v_cycle

    a = matrices["laplace3d"]
    h = amg_setup(a, engine="host", explicit_restriction=False,
                  **LEVEL_KW).hierarchy
    assert all(lvl.r_ell is None for lvl in h.levels)
    b = jnp.asarray(np.random.default_rng(3)
                    .standard_normal(a.num_vertices).astype(np.float32))
    x = v_cycle(h, b)
    rel = float(jnp.linalg.norm(b - spmv_ell(a.ell_matrix, x))
                / jnp.linalg.norm(b))
    assert rel < 0.3, rel
