"""Per-architecture smoke tests: reduced config of the same family runs one
forward/train step on CPU (output shapes + no NaNs), and the serving paths
are consistent with the training forward (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import get_model
from repro.train import AdamWConfig, adamw_init, make_train_step

DECODELESS = ()   # all ten archs have a decode path (whisper via decoder)


def _batch_for(cfg, b=2, s=24):
    rng = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size)}
    if cfg.family in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (b, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    if cfg.family in ("encdec", "audio"):
        logits, aux = jax.jit(model.forward)(params, batch)
    else:
        logits, aux = jax.jit(model.forward)(params, batch["tokens"])
    assert logits.shape == (2, 24, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    step = make_train_step(model, cfg, AdamWConfig(lr=1e-3, total_steps=10))
    opt = adamw_init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed


@pytest.mark.parametrize("arch", ["smollm-135m", "granite-moe-1b-a400m",
                                  "mamba2-780m", "recurrentgemma-2b",
                                  "whisper-tiny"])
def test_reduced_decode_consistency(arch):
    """prefill + decode_step logits match full forward on extended seq."""
    cfg = get_config(arch).reduced(moe_capacity_factor=8.0)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch_for(cfg, b=2, s=16)
    tokens = batch["tokens"]
    if cfg.family in ("encdec", "audio"):
        fwd = lambda p, t: model.forward(p, {"frames": batch["frames"],  # noqa: E731
                                             "tokens": t})
        pre = lambda p: model.prefill(p, {"frames": batch["frames"],  # noqa: E731
                                          "tokens": tokens}, 32)
    else:
        fwd = lambda p, t: model.forward(p, t)  # noqa: E731
        pre = lambda p: model.prefill(p, tokens, 32)  # noqa: E731
    logits, _ = jax.jit(fwd)(params, tokens)
    plog, cache = jax.jit(pre)(params)
    np.testing.assert_allclose(np.asarray(plog, np.float32),
                               np.asarray(logits[:, -1], np.float32),
                               rtol=4e-2, atol=4e-2)
    nt = jnp.argmax(plog, -1)[:, None]
    dlog, _ = jax.jit(model.decode_step)(params, cache, nt)
    flog, _ = jax.jit(fwd)(params, jnp.concatenate([tokens, nt], 1))
    np.testing.assert_allclose(np.asarray(dlog, np.float32),
                               np.asarray(flog[:, -1], np.float32),
                               rtol=7e-2, atol=7e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_congruent(arch):
    """param_axes() must be congruent with init() output (dry-run contract)."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes = model.param_axes()
    jax.tree.map(lambda sds, ax: None, params, axes,
                 is_leaf=lambda x: isinstance(x, tuple) and not
                 isinstance(x, jax.ShapeDtypeStruct))
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_p) == len(flat_a)
    for sds, ax in zip(flat_p, flat_a):
        assert len(ax) == len(sds.shape), f"{arch}: {ax} vs {sds.shape}"
