"""Distributed (shard_map) MIS-2 and coarsening — the tests promised by
``core/dist.py``.

The multi-device cases run in ONE subprocess forced to 8 host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must precede jax
init), shared by every assertion through a module-scoped fixture.  Sizes
cover V divisible by the device count (1000), non-divisible (997), and the
power-of-two id_bits crossing (1022 pads to 1024) that the padded-V packing
bug silently broke.  The cheap plumbing (engine registration, one-device
mesh, the analytic collective model, dry-run records) runs in-process.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

SIZES = (1000, 1022, 997)   # divisible | pow2-crossing | non-divisible

_CHILD = """
import json
import numpy as np
import jax
import repro
from repro.api import Backend
from repro.graphs import laplace3d, random_uniform_graph

out = {"num_devices": len(jax.devices()), "cases": {}}
for v in (1000, 1022, 997):
    g = repro.Graph(laplace3d(10).graph) if v == 1000 else \\
        repro.Graph(random_uniform_graph(v, 6.0, seed=v))
    dense = repro.mis2(g, engine="dense")
    case = {"dense_digest": dense.digest, "dense_iterations": dense.iterations,
            "engines": {}}
    for eng in ("distributed", "distributed_single_gather"):
        r = repro.mis2(g, engine=eng)
        case["engines"][eng] = {
            "digest": r.digest, "iterations": r.iterations,
            "converged": r.converged, "collectives": r.collectives,
        }
    a1 = repro.coarsen(g, method="two_phase", mis2_engine="dense")
    a2 = repro.coarsen(g, method="two_phase_distributed")
    case["coarsen"] = {
        "single_digest": a1.digest, "dist_digest": a2.digest,
        "labels_equal": bool((a1.labels == a2.labels).all()),
        "roots_equal": bool((a1.roots == a2.roots).all()),
        "phase_equal": bool((a1.phase == a2.phase).all()),
        "num_aggregates": (a1.num_aggregates, a2.num_aggregates),
    }
    out["cases"][str(v)] = case

# a 2x4 mesh with axis=None must flatten both axes into the partition
mesh = jax.make_mesh((2, 4), ("a", "b"))
g = repro.Graph(random_uniform_graph(997, 6.0, seed=997))
out["multi_axis"] = {
    "digest": repro.mis2(g, engine="distributed",
                         backend=Backend(mesh=mesh)).digest,
    "dense_digest": repro.mis2(g, engine="dense").digest,
}

# adversarial id_bits regression: V=6 pads to 8 on 8 devices, so the buggy
# padded-V packing used b=4 instead of b=3.  The crafted priority (8 on
# vertex 0, 0 elsewhere) makes the b=3 and b=4 packings order vertices 0/1
# oppositely, so any padded-width packing flips the resulting set.
import jax.numpy as jnp
from repro.core import hashing
from repro.core.mis2 import Mis2Options

hashing.PRIORITY_FNS["adversarial"] = lambda it, vids: jnp.where(
    vids == 0, jnp.uint32(8), jnp.uint32(0))
path = repro.Graph.from_coo([0, 1, 1, 2, 2, 3, 3, 4, 4, 5],
                            [1, 0, 2, 1, 3, 2, 4, 3, 5, 4], 6)
opts = Mis2Options(priority="adversarial")
da = repro.mis2(path, engine="dense", options=opts)
out["adversarial"] = {"dense_digest": da.digest, "engines": {}}
for eng in ("distributed", "distributed_single_gather"):
    out["adversarial"]["engines"][eng] = \
        repro.mis2(path, engine=eng, options=opts).digest
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist8():
    # inherit the parent env (venv paths, HOME, tool caches) and override
    # only what the forced-device child needs
    env = dict(os.environ)
    env.update({"PYTHONPATH": str(REPO / "src"), "JAX_PLATFORMS": "cpu",
                "XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
    out = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True, text=True, timeout=580, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.rsplit("RESULT:", 1)[1])


@pytest.mark.slow
def test_runs_on_eight_devices(dist8):
    assert dist8["num_devices"] == 8


@pytest.mark.slow
@pytest.mark.parametrize("v", SIZES)
@pytest.mark.parametrize("engine",
                         ["distributed", "distributed_single_gather"])
def test_digest_matches_dense(dist8, v, engine):
    """The headline determinism claim: bit-identical to the single-device
    dense engine for any device count — including V=1022, where device
    padding (-> 1024) used to change the id_bits packing width."""
    case = dist8["cases"][str(v)]
    assert case["engines"][engine]["digest"] == case["dense_digest"]
    assert case["engines"][engine]["converged"]


@pytest.mark.slow
@pytest.mark.parametrize("v", SIZES)
def test_iterations_match_dense(dist8, v):
    case = dist8["cases"][str(v)]
    for eng in ("distributed", "distributed_single_gather"):
        assert case["engines"][eng]["iterations"] == case["dense_iterations"]


@pytest.mark.slow
@pytest.mark.parametrize("v", SIZES)
def test_distributed_coarsening_bitwise(dist8, v):
    """Alg. 3 labels (and roots/phase provenance) from the sharded rounds
    match the single-device two_phase engine bit-for-bit."""
    c = dist8["cases"][str(v)]["coarsen"]
    assert c["single_digest"] == c["dist_digest"]
    assert c["labels_equal"] and c["roots_equal"] and c["phase_equal"]
    assert c["num_aggregates"][0] == c["num_aggregates"][1]


@pytest.mark.slow
def test_multi_axis_mesh_flattens(dist8):
    assert dist8["multi_axis"]["digest"] == dist8["multi_axis"]["dense_digest"]


def test_adversarial_case_is_b_sensitive():
    """Sanity for the regression below: the crafted priorities order
    vertices 0/1 oppositely under b=id_bits(6)=3 vs b=id_bits(8)=4, so a
    padded-width packing provably changes the MIS."""
    from repro.core.tuples import id_bits

    assert id_bits(6) == 3 and id_bits(8) == 4

    def pack(p, i, b):
        return ((p >> b) << b) | (i + 1)

    assert pack(8, 0, 3) > pack(0, 1, 3)   # b=3: vertex 1 wins
    assert pack(8, 0, 4) < pack(0, 1, 4)   # b=4: vertex 0 wins


@pytest.mark.slow
def test_padded_v_id_bits_regression(dist8):
    """V=6 on 8 devices pads to 8; packing with id_bits of the PADDED
    count (the old bug) flips the adversarial set — the fix packs with
    id_bits(V_real) and must match dense bit-for-bit."""
    adv = dist8["adversarial"]
    for eng, digest in adv["engines"].items():
        assert digest == adv["dense_digest"], eng


@pytest.mark.slow
@pytest.mark.parametrize("v", SIZES)
def test_collective_accounting(dist8, v):
    """wire bytes = per-iteration model x iterations; single_gather halves
    the per-iteration volume of two_gather."""
    engines = dist8["cases"][str(v)]["engines"]
    two = engines["distributed"]["collectives"]
    single = engines["distributed_single_gather"]["collectives"]
    for rec in (two, single):
        assert rec["wire_bytes_per_device"] == pytest.approx(
            rec["wire_bytes_per_device_per_iteration"] * rec["iterations"])
    assert single["result_bytes_per_iteration"] * 2 == \
        two["result_bytes_per_iteration"]
    assert two["gathers_per_iteration"] == 2
    assert single["gathers_per_iteration"] == 1


# ---------------------------------------------------------------------------
# in-process (single device): plumbing, model, artifacts
# ---------------------------------------------------------------------------

def test_engines_registered():
    from repro.api import list_engines

    engines = list_engines()
    assert "distributed" in engines["mis2"]
    assert "distributed_single_gather" in engines["mis2"]
    assert "two_phase_distributed" in engines["aggregation"]


def test_single_device_mesh_matches_dense():
    """The sharded fixed point degenerates cleanly to one device (no
    XLA_FLAGS forcing needed) — same digest, same iterations."""
    import repro
    from repro.graphs import random_uniform_graph

    g = repro.Graph(random_uniform_graph(301, 5.0, seed=7))
    dense = repro.mis2(g, engine="dense")
    for eng in ("distributed", "distributed_single_gather"):
        r = repro.mis2(g, engine=eng)
        assert r.digest == dense.digest
        assert r.iterations == dense.iterations
        assert r.collectives["num_devices"] >= 1


def test_single_device_distributed_coarsening_matches():
    import repro
    from repro.graphs import random_uniform_graph

    g = repro.Graph(random_uniform_graph(301, 5.0, seed=7))
    a1 = repro.coarsen(g, method="two_phase", mis2_engine="dense")
    a2 = repro.coarsen(g, method="two_phase_distributed")
    assert a1.digest == a2.digest


def test_collective_model():
    from repro.core.dist import collective_bytes_per_iteration

    two = collective_bytes_per_iteration(1000, 8, single_gather=False)
    single = collective_bytes_per_iteration(1000, 8, single_gather=True)
    # Vp = 1000 (divisible): 2 gathers x 4000 B, ring factor 7/8
    assert two["result_bytes_per_iteration"] == 2 * 4000
    assert two["wire_bytes_per_device_per_iteration"] == \
        pytest.approx(2 * 4000 * 7 / 8)
    assert single["result_bytes_per_iteration"] == 4000
    # padding rounds V up before the byte count
    padded = collective_bytes_per_iteration(1022, 8, single_gather=False)
    assert padded["result_bytes_per_iteration"] == 2 * 4096


def test_dryrun_record_feeds_figs4_5(tmp_path):
    """write_mis2_dryrun_record emits the exact schema figs4_5_scaling
    axis B consumes."""
    from repro.core.dist import write_mis2_dryrun_record

    path = write_mis2_dryrun_record(10_000, 7, 16, single_gather=True,
                                    out_dir=tmp_path)
    rec = json.loads(path.read_text())
    for key in ("V", "wire_bytes_per_device", "variant", "num_devices"):
        assert key in rec
    assert rec["variant"] == "single_gather"
    assert rec["num_devices"] == 16
    assert rec["wire_bytes_per_device"] == pytest.approx(
        rec["per_iteration"]["wire_bytes_per_device_per_iteration"]
        * rec["max_iters"])


def test_backend_resolve_mesh_default():
    from repro.api import Backend

    mesh, axis = Backend().resolve_mesh()
    assert axis == "x"
    assert mesh.axis_names == ("x",)
