"""Deterministic stand-in for the ``hypothesis`` API surface these tests
use (``given`` / ``settings`` / ``strategies.integers`` / ``.floats``).

The container image does not ship ``hypothesis`` (the seed suite died at
collection on it).  When the real library is importable the test modules
use it; otherwise this fallback runs each property test over a fixed,
seeded sample set — boundary values first, then pseudo-random draws — so
the properties still get exercised instead of the module erroring out.
"""
from __future__ import annotations

import random

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    """Draws boundary examples first, then seeded pseudo-random ones."""

    def __init__(self, boundaries, draw):
        self._boundaries = list(boundaries)
        self._random_draw = draw

    def draw(self, index: int, rng: random.Random):
        if index < len(self._boundaries):
            return self._boundaries[index]
        return self._random_draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        mid = (min_value + max_value) // 2
        return _Strategy([min_value, max_value, mid],
                         lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        mid = (min_value + max_value) / 2
        return _Strategy([min_value, max_value, mid],
                         lambda rng: rng.uniform(min_value, max_value))


st = _Strategies()


def given(*strategies):
    def deco(fn):
        # zero-arg wrapper (no functools.wraps): pytest must not mistake the
        # property's drawn parameters for fixtures
        def runner():
            n = getattr(runner, "_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0)
            for i in range(n):
                fn(*(s.draw(i, rng) for s in strategies))

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner._is_fallback_property = True
        return runner

    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        # cap the fallback at a sane count: it is a smoke net, not a fuzzer
        fn._max_examples = min(max_examples, 12)
        return fn

    return deco
