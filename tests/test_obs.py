"""repro.obs (ISSUE 7): metrics registry semantics, context-scoped
capture, cardinality bounding, exporters, span tracing, facade
provenance, legacy-stats shims, clock monotonicity, and the one-snapshot
whole-process view a mixed workload must produce."""
import json
import os
import re
import sys
import time

import numpy as np
import pytest

from repro import obs
from repro.api import Graph, amg_setup, coarsen, color, mis2
from repro.graphs import laplace3d, random_uniform_graph
from repro.obs import CardinalityError, MetricsRegistry, Snapshot

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("x.calls").inc()
    reg.counter("x.calls").inc(2)
    reg.gauge("x.level").set(7)
    reg.gauge("x.level").add(-2)
    h = reg.histogram("x.seconds", labels={"phase": "a"})
    h.observe(0.5)
    h.observe(1.5)
    snap = reg.snapshot()
    assert snap.value("x.calls") == 3
    assert snap.value("x.level") == 5
    assert snap.value("x.seconds", {"phase": "a"}) == 2.0   # sum
    assert snap.count("x.seconds", {"phase": "a"}) == 2
    assert h.stats["mean"] == 1.0
    assert h.stats["min"] == 0.5 and h.stats["max"] == 1.5


def test_registry_kind_conflict_rejected():
    reg = MetricsRegistry()
    reg.counter("x.thing")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x.thing")


def test_registry_labels_order_insensitive():
    reg = MetricsRegistry()
    reg.counter("x.c", labels={"a": 1, "b": 2}).inc()
    reg.counter("x.c", labels={"b": 2, "a": 1}).inc()
    assert reg.snapshot().value("x.c", {"a": 1, "b": 2}) == 2


def test_registry_total_sums_across_label_sets():
    reg = MetricsRegistry()
    reg.counter("x.c", labels={"k": "a"}).inc(2)
    reg.counter("x.c", labels={"k": "b"}).inc(3)
    assert reg.snapshot().total("x.c") == 5


def test_reset_zeros_in_place_and_handles_stay_valid():
    reg = MetricsRegistry()
    c = reg.counter("x.calls")
    c.inc(5)
    reg.reset()
    assert reg.snapshot().value("x.calls") == 0
    c.inc()                                  # cached handle still writes
    assert reg.snapshot().value("x.calls") == 1


def test_reset_prefix_scopes():
    reg = MetricsRegistry()
    reg.counter("a.one").inc()
    reg.counter("b.two").inc()
    reg.reset("a.")
    snap = reg.snapshot()
    assert snap.value("a.one") == 0
    assert snap.value("b.two") == 1


def test_capture_is_delta_scoped_not_global():
    reg = MetricsRegistry()
    reg.counter("x.calls").inc(100)          # pre-existing traffic
    with reg.capture() as outer:
        reg.counter("x.calls").inc()
        with reg.capture() as inner:         # concurrent capture: no clobber
            reg.counter("x.calls").inc(2)
        reg.counter("x.calls").inc(4)
    assert inner.value("x.calls") == 2
    assert outer.value("x.calls") == 7
    assert reg.snapshot().value("x.calls") == 107


def test_snapshot_delta_drops_zero_series_keeps_gauges():
    reg = MetricsRegistry()
    reg.counter("x.a").inc(5)
    reg.gauge("x.g").set(3)
    before = reg.snapshot()
    reg.counter("x.b").inc()
    after = reg.snapshot()
    d = after.delta(before)
    assert d.value("x.b") == 1
    assert d.value("x.a") == 0               # unchanged counter dropped
    assert d.value("x.g") == 3               # gauge keeps current reading
    assert all(s.name != "x.a" for s in d)


# ---------------------------------------------------------------------------
# cardinality bounding (satellite: reject unbounded label values)
# ---------------------------------------------------------------------------

def test_label_cardinality_rejects_long_values():
    reg = MetricsRegistry()
    digest64 = "a" * 64                      # a raw sha256 hexdigest
    with pytest.raises(CardinalityError, match="span attrs"):
        reg.counter("x.c", labels={"digest": digest64})


def test_label_cardinality_rejects_unboundedly_many_series():
    reg = MetricsRegistry()
    with pytest.raises(CardinalityError, match="label sets"):
        for i in range(reg.max_series_per_metric + 1):
            reg.counter("x.c", labels={"i": i}).inc()
    # other metrics are unaffected by one metric hitting its cap
    reg.counter("y.ok").inc()


def test_label_value_token_charset():
    reg = MetricsRegistry()
    reg.counter("x.c", labels={"k": "csr_to_ell"}).inc()     # fine
    reg.counter("x.c", labels={"k": "a/b:c+d-e.f"}).inc()    # fine
    with pytest.raises(CardinalityError):
        reg.counter("x.c", labels={"k": "has spaces"})


# ---------------------------------------------------------------------------
# exporters (satellite: Prometheus parses, JSON round-trips)
# ---------------------------------------------------------------------------

_PROM_LINE = re.compile(
    r"^(# TYPE [a-zA-Z_][a-zA-Z0-9_]* (counter|gauge|summary)"
    r"|[a-zA-Z_][a-zA-Z0-9_]*(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""
    r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})? -?[0-9.e+-]+)$")


def test_prometheus_exposition_parses_line_by_line():
    reg = MetricsRegistry()
    reg.counter("mis2.resident_dispatches").inc(3)
    reg.gauge("serve.cache.bytes_used").set(1024)
    reg.histogram("span.seconds", labels={"span": "api.mis2"}).observe(0.25)
    text = obs.to_prometheus(reg.snapshot())
    lines = text.strip().split("\n")
    assert lines, "empty exposition"
    for line in lines:
        assert _PROM_LINE.match(line), f"unparseable line: {line!r}"
    assert "# TYPE repro_mis2_resident_dispatches counter" in lines
    assert "repro_mis2_resident_dispatches 3" in lines
    assert 'repro_span_seconds_count{span="api.mis2"} 1' in lines


def test_json_export_round_trips_exactly():
    reg = MetricsRegistry()
    reg.counter("x.c", labels={"k": "v"}).inc(2)
    reg.gauge("x.g").set(1.5)
    reg.histogram("x.h").observe(3.0)
    snap = reg.snapshot()
    back = obs.from_json(obs.to_json(snap))
    assert isinstance(back, Snapshot)
    assert back.to_json() == snap.to_json()
    assert back.flat() == snap.flat()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_and_metric_attribution():
    with obs.span("outer", job="t") as outer:
        obs.metrics.counter("spantest.outer_work").inc()
        assert obs.current_span() is outer
        with obs.span("inner") as inner:
            obs.metrics.counter("spantest.inner_work").inc(2)
    assert obs.current_span() is None
    assert [c.name for c in outer.children] == ["inner"]
    assert outer.metrics["spantest.outer_work"] == 1
    assert outer.metrics["spantest.inner_work"] == 2     # nested included
    # gauges report level (not delta) in span metrics, so ambient gauges
    # set by earlier tests may appear — assert on counters only
    inner_counters = {k: v for k, v in inner.metrics.items()
                      if k.startswith("spantest.")}
    assert inner_counters == {"spantest.inner_work": 2.0}
    assert outer.duration_s >= inner.duration_s >= 0.0
    d = outer.to_dict()
    json.dumps(d)                                        # serializable
    assert d["attrs"] == {"job": "t"}
    assert obs.snapshot().count("span.seconds", {"span": "inner"}) >= 1
    assert outer in obs.recent_spans(5)


def test_span_annotate_coerces_non_scalars():
    with obs.span("anno", arr=np.arange(3)) as sp:
        sp.annotate(n=np.int64(7))
    assert isinstance(sp.attrs["arr"], str)
    assert isinstance(sp.attrs["n"], str)
    json.dumps(sp.to_dict())


# ---------------------------------------------------------------------------
# facade provenance (acceptance: every facade Result carries it)
# ---------------------------------------------------------------------------

def test_every_facade_result_carries_provenance():
    g = Graph(laplace3d(6).graph)
    m = Graph(laplace3d(6))
    calls = {
        "mis2": lambda: mis2(g),
        "color": lambda: color(g),
        "coarsen": lambda: coarsen(g),
        "amg_setup": lambda: amg_setup(m, coarse_size=24, max_levels=3),
    }
    for kind, call in calls.items():
        r = call()
        p = r.provenance
        assert p is not None, kind
        assert p.kind == kind
        assert p.digest == r.digest
        assert p.backend in ("cpu", "gpu", "tpu")
        assert p.span["name"] == f"api.{kind}"
        assert p.wall_time_s > 0.0
        # round-trips through JSON without loss
        back = obs.Provenance.from_json(p.to_json())
        assert back.as_dict() == p.as_dict()


def test_mis2_provenance_metrics_show_execution_shape():
    g = Graph(random_uniform_graph(500, 5.0, seed=4))
    mis2(g, engine="compacted_resident")     # warm
    r = mis2(g, engine="compacted_resident")
    assert r.provenance.metrics.get("mis2.resident_dispatches") == 1
    assert "mis2.host_syncs" not in r.provenance.metrics   # zero => dropped


def test_batch_results_share_batch_provenance():
    from repro.api import mis2_batch

    gs = [Graph(random_uniform_graph(200, 4.0, seed=s)) for s in (1, 2, 3)]
    batch = mis2_batch(gs)
    assert batch.provenance is not None
    assert batch.provenance.kind == "mis2_batch"
    for r in batch.results:
        assert r.provenance is batch.provenance


def test_streaming_repair_provenance_and_counters():
    g = Graph(random_uniform_graph(300, 5.0, seed=6))
    from repro.serve import StreamSession

    sess = StreamSession(g)
    with obs.capture() as cap:
        r = sess.apply_delta(edge_adds=[(0, 7), (11, 23)])
    assert r.provenance is not None
    assert r.provenance.span["name"] == "serve.repair"
    assert r.provenance.engine == "stream_repair"
    assert cap.value("serve.repair.deltas", {"mode": "repair"}) == 1
    assert cap.value("serve.repair.reactivated") == sess.last_repair.reactivated


# ---------------------------------------------------------------------------
# legacy shims: same numbers on both surfaces
# ---------------------------------------------------------------------------

def test_hotloop_stats_is_a_registry_view():
    from repro.core.mis2 import HOTLOOP_STATS

    base = obs.snapshot().value("mis2.resident_dispatches")
    assert HOTLOOP_STATS.resident_dispatches == base
    HOTLOOP_STATS.resident_dispatches += 1
    assert obs.snapshot().value("mis2.resident_dispatches") == base + 1
    obs.metrics.counter("mis2.resident_dispatches").inc()
    assert HOTLOOP_STATS.resident_dispatches == base + 2


def test_setup_stats_is_a_registry_view():
    from repro.multilevel import SETUP_STATS

    base = obs.snapshot().value("multilevel.host_syncs")
    SETUP_STATS.host_syncs += 3
    assert obs.snapshot().value("multilevel.host_syncs") == base + 3


def test_cache_stats_mirror_into_registry():
    from repro.serve.cache import ResultCache

    cache = ResultCache(max_bytes=1 << 20)
    with obs.capture() as cap:
        assert cache.lookup(("k",)) is None
        r = mis2(Graph(laplace3d(4).graph))
        cache.insert(("k",), r)
        assert cache.lookup(("k",)) is r
    assert cap.value("serve.cache.misses") == 1
    assert cap.value("serve.cache.hits") == 1
    assert cap.value("serve.cache.inserts") == 1
    # per-instance truth preserved alongside the process aggregate
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    assert obs.snapshot().value("serve.cache.bytes_used") >= \
        cache.stats.bytes_used


# ---------------------------------------------------------------------------
# clock monotonicity (satellite: one clock, perf_counter, everywhere)
# ---------------------------------------------------------------------------

def test_server_intervals_are_perf_counter_monotone():
    from repro.serve import Server, ServerConfig

    srv = Server(ServerConfig(max_batch=2, max_delay_s=0.0))
    s1 = srv.server_stats()
    time.sleep(0.01)
    s2 = srv.server_stats()
    assert 0.0 <= s1["uptime_s"] <= s2["uptime_s"]
    assert s2["compiles"]["window_s"] <= s2["uptime_s"]
    srv.reset_window()
    s3 = srv.server_stats()
    assert s3["compiles"]["window_s"] <= s2["compiles"]["window_s"]
    assert s3["uptime_s"] >= s2["uptime_s"]              # never jumps back


def test_serve_stats_clock_is_perf_counter():
    import inspect

    from repro.serve import server as server_mod

    src = inspect.getsource(server_mod)
    assert "time.monotonic" not in src
    src = inspect.getsource(sys.modules["repro.serve.cache"])
    assert "time.monotonic" not in src


# ---------------------------------------------------------------------------
# the acceptance snapshot: one obs.snapshot() sees every subsystem
# ---------------------------------------------------------------------------

def test_mixed_workload_single_snapshot_covers_all_subsystems():
    from repro.serve import Server, ServerConfig

    with obs.capture() as cap:
        g = Graph(random_uniform_graph(400, 5.0, seed=8))
        mis2(g, engine="compacted_resident")             # device-resident
        mis2(g, engine="compacted")                      # host-driven syncs
        amg_setup(Graph(laplace3d(6)), engine="resident",
                  coarse_size=24, max_levels=3)          # multilevel
        mis2(g, engine="distributed")                    # collective bytes
        srv = Server(ServerConfig(max_batch=2, max_delay_s=0.0))
        f1 = srv.submit("mis2", g)
        f2 = srv.submit("mis2", g)                       # same digest
        srv.flush()
        f1.result(timeout=60)
        f2.result(timeout=60)
    d = cap.delta()
    assert d.value("mis2.resident_dispatches") >= 1
    assert d.value("mis2.host_syncs") >= 1
    assert d.value("multilevel.resident_dispatches") >= 1
    assert d.total("dist.collective_bytes") > 0
    assert d.value("serve.requests") == 2
    assert d.value("serve.cache.misses") + d.value("serve.cache.hits") == 2
    assert d.total("graph.conversions") >= 1
    assert d.total("span.seconds") > 0
    # and the whole thing exports cleanly
    text = obs.to_prometheus(d)
    assert "repro_serve_requests 2" in text.split("\n")


def test_graph_conversion_timings_via_snapshot():
    with obs.capture() as cap:
        g = Graph(laplace3d(5).graph)
        _ = g.ell
        _ = g.ell                                        # cache hit
        _ = g.digest
    assert cap.value("graph.conversions", {"kind": "csr_to_ell"}) == 1
    assert cap.count("graph.conversion_seconds", {"kind": "csr_to_ell"}) == 1
    assert cap.value("graph.conversions", {"kind": "digest"}) == 1
    assert g.conversion_timings["csr_to_ell"] >= 0.0


# ---------------------------------------------------------------------------
# benchmark trajectory contract (satellite: records embed the snapshot)
# ---------------------------------------------------------------------------

def test_emit_trajectory_embeds_metrics_snapshot(tmp_path, monkeypatch):
    from benchmarks import common

    monkeypatch.setattr(common, "REPO_ROOT", tmp_path)
    monkeypatch.setattr(common, "ARTIFACTS", tmp_path / "bench")
    obs.metrics.counter("benchtest.calls").inc()
    common.emit_trajectory("obs_unit", {"graphs_per_s": 12.5})
    history = json.loads((tmp_path / "BENCH_obs_unit.json").read_text())
    rec = history[-1]
    assert rec["graphs_per_s"] == 12.5
    assert "metrics" in rec
    assert rec["metrics"]["benchtest.calls"] >= 1
    # caller-supplied snapshots are respected, not overwritten
    common.emit_trajectory("obs_unit", {"graphs_per_s": 1.0,
                                        "metrics": {"mine": 1}})
    history = json.loads((tmp_path / "BENCH_obs_unit.json").read_text())
    assert history[-1]["metrics"] == {"mine": 1}
