"""repro.lint (ISSUE 8): the AST-level determinism & execution-shape
analyzer.  Per-rule positive/negative/suppressed fixtures, baseline
semantics (grandfathering, monotonic shrinkage, mandatory reasons), the
self-run gate (src/repro lints clean modulo the committed baseline), and
the historical-bug reconstructions: RL102 must fire on the PR 3
``id_bits(vp_total)`` bug re-introduced into the real core/dist.py code
shape, and the facade must never alias a shared options default (PR 2)."""
import inspect
import json
import textwrap
from pathlib import Path

from repro.lint import Baseline, BaselineEntry, check, lint_paths
from repro.lint.findings import parse_legacy_tag, parse_suppressions

REPO_ROOT = Path(__file__).resolve().parents[1]
SRC_REPRO = REPO_ROOT / "src" / "repro"
BASELINE = REPO_ROOT / "tools" / "lint_baseline.json"


def run_lint(tmp_path, source, name="mod.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return lint_paths([p], repo_root=tmp_path, roots=[])


def rules_of(findings):
    return sorted({f.rule for f in findings})


def live(findings):
    return [f for f in findings if not f.suppressed]


# ---------------------------------------------------------------------------
# RL101 trace-purity
# ---------------------------------------------------------------------------

def test_rl101_positive_branch_and_item_in_jit(tmp_path):
    findings = run_lint(tmp_path, """\
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return x.item()
        """)
    rl101 = [f for f in findings if f.rule == "RL101"]
    assert len(rl101) >= 2          # the Python branch AND the .item() sync
    assert all(f.symbol == "f" for f in rl101)


def test_rl101_positive_loop_body(tmp_path):
    findings = run_lint(tmp_path, """\
        import jax.lax as lax

        def solve(x0):
            def body(carry):
                return carry + int(carry)
            return lax.while_loop(lambda c: True, body, x0)
        """)
    assert any(f.rule == "RL101" and "int" in f.message for f in findings)


def test_rl101_negative_without_jit(tmp_path):
    findings = run_lint(tmp_path, """\
        def f(x):
            if x > 0:
                return x.item()
            return x
        """)
    assert not [f for f in findings if f.rule == "RL101"]


def test_rl101_negative_static_bool_param(tmp_path):
    # the _mis2_local_fixpoint shape: a bool-annotated kwarg of a
    # shard_map-seeded function is host control flow, not a traced branch
    findings = run_lint(tmp_path, """\
        import jax

        @jax.jit
        def f(x, single_gather: bool = False):
            if single_gather:
                return x + 1
            return x
        """)
    assert not [f for f in findings if f.rule == "RL101"]


def test_rl101_negative_shape_is_static(tmp_path):
    findings = run_lint(tmp_path, """\
        import jax

        @jax.jit
        def f(x):
            if x.shape[0] > 4:
                return x + 1
            return x
        """)
    assert not [f for f in findings if f.rule == "RL101"]


def test_rl101_respects_static_argnames(tmp_path):
    findings = run_lint(tmp_path, """\
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x, mode):
            if mode:
                return x + 1
            return x
        """)
    assert not [f for f in findings if f.rule == "RL101"]


# ---------------------------------------------------------------------------
# RL102 priority-provenance
# ---------------------------------------------------------------------------

def test_rl102_positive_padded_name(tmp_path):
    findings = run_lint(tmp_path, """\
        from repro.core.tuples import id_bits

        def pack_width(padded_graph):
            vp_total = padded_graph.num_vertices
            return id_bits(vp_total)
        """)
    rl102 = [f for f in findings if f.rule == "RL102"]
    assert len(rl102) == 1
    assert "vp_total" in rl102[0].message


def test_rl102_positive_bucketing_call(tmp_path):
    findings = run_lint(tmp_path, """\
        from repro.core.tuples import id_bits

        def _bucket(n):
            return 1 << (n - 1).bit_length()

        def pack_width(n):
            size = _bucket(n)
            return id_bits(size)
        """)
    assert any(f.rule == "RL102" for f in findings)


def test_rl102_negative_real_count(tmp_path):
    findings = run_lint(tmp_path, """\
        from repro.core.tuples import id_bits

        def pack_width(num_vertices):
            return id_bits(num_vertices)
        """)
    assert not [f for f in findings if f.rule == "RL102"]


def test_rl102_fires_on_reintroduced_pr3_bug(tmp_path):
    """Reconstruct the PR 3 determinism bug on the REAL core/dist.py code
    shape: swap the (fixed) ``id_bits(num_vertices)`` back to the padded
    count and RL102 must fire inside the sharded fixed point."""
    real = (SRC_REPRO / "core" / "dist.py").read_text()
    assert "id_bits(num_vertices)" in real    # today's fixed shape
    bugged = real.replace("id_bits(num_vertices)", "id_bits(vp_total)")
    assert bugged != real
    findings = run_lint(tmp_path, bugged, name="dist.py")
    rl102 = [f for f in findings if f.rule == "RL102"]
    assert rl102, "RL102 must catch the reconstructed PR 3 bug"
    assert any("vp_total" in f.message for f in rl102)


def test_rl102_clean_on_current_dist(tmp_path):
    findings = run_lint(tmp_path, (SRC_REPRO / "core" / "dist.py").read_text(),
                        name="dist.py")
    assert not [f for f in findings if f.rule == "RL102"]


# ---------------------------------------------------------------------------
# RL103 timing
# ---------------------------------------------------------------------------

def test_rl103_positive(tmp_path):
    findings = run_lint(tmp_path, """\
        import time

        def bench():
            t0 = time.time()
            return time.time() - t0
        """)
    assert len([f for f in findings if f.rule == "RL103"]) >= 1


def test_rl103_negative_perf_counter(tmp_path):
    findings = run_lint(tmp_path, """\
        import time

        def bench():
            t0 = time.perf_counter()
            return time.perf_counter() - t0
        """)
    assert not [f for f in findings if f.rule == "RL103"]


def test_rl103_suppressed_epoch_alias(tmp_path):
    findings = run_lint(tmp_path, """\
        import time

        _EPOCH_NOW = time.time  # repro-lint: ignore[RL103] epoch stamp, not a duration
        """)
    rl103 = [f for f in findings if f.rule == "RL103"]
    assert rl103 and all(f.suppressed for f in rl103)


def test_rl103_suppression_without_reason_stays_live(tmp_path):
    findings = run_lint(tmp_path, """\
        import time

        _EPOCH_NOW = time.time  # repro-lint: ignore[RL103]
        """)
    rl103 = [f for f in findings if f.rule == "RL103"]
    assert rl103 and not any(f.suppressed for f in rl103)
    assert any("reason is mandatory" in f.message for f in rl103)


# ---------------------------------------------------------------------------
# RL104 obs hygiene
# ---------------------------------------------------------------------------

def test_rl104_positive_bad_name_and_legacy_write(tmp_path):
    findings = run_lint(tmp_path, """\
        from repro.obs import metrics
        from repro.core.mis2 import HOTLOOP_STATS

        def record(n):
            metrics.counter("BadName").inc()
            HOTLOOP_STATS.host_syncs += n
        """)
    msgs = [f.message for f in findings if f.rule == "RL104"]
    assert any("scheme" in m for m in msgs)
    assert any("legacy stats view" in m for m in msgs)


def test_rl104_positive_fstring_name_and_digest_label(tmp_path):
    findings = run_lint(tmp_path, """\
        from repro.obs import metrics

        def record(name, digest):
            metrics.counter(f"{name}.calls").inc()
            metrics.gauge("serve.cache.entries",
                          labels={"graph": digest}).set(1)
        """)
    msgs = [f.message for f in findings if f.rule == "RL104"]
    assert any("prefix" in m for m in msgs)
    assert any("digest" in m for m in msgs)


def test_rl104_negative_scheme_names(tmp_path):
    findings = run_lint(tmp_path, """\
        from repro.obs import metrics

        def record(name):
            metrics.counter("mis2.host_syncs").inc(2)
            metrics.counter(f"serve.cache.{name}").inc()
            metrics.histogram("serve.batch.size_vertices").observe(4)
        """)
    assert not [f for f in findings if f.rule == "RL104"]


# ---------------------------------------------------------------------------
# RL105 options aliasing
# ---------------------------------------------------------------------------

def test_rl105_positive_call_default(tmp_path):
    findings = run_lint(tmp_path, """\
        class Options:
            pass

        def solve(graph, options=Options(), sizes=[]):
            return graph, options, sizes
        """)
    rl105 = [f for f in findings if f.rule == "RL105"]
    assert len(rl105) >= 2          # the Options() call AND the [] literal


def test_rl105_negative_none_sentinel(tmp_path):
    findings = run_lint(tmp_path, """\
        class Options:
            pass

        def solve(graph, options=None):
            options = Options() if options is None else options
            return graph, options
        """)
    assert not [f for f in findings if f.rule == "RL105"]


def test_facade_calls_do_not_alias_options():
    """PR 2 regression: two facade invocations must never share one
    options object — every public facade signature uses the None
    sentinel, and the resolver mints a fresh Mis2Options per call."""
    from repro.api import engines, facade

    a, b = engines._opts(None), engines._opts(None)
    assert a is not b

    for name, fn in inspect.getmembers(facade, inspect.isfunction):
        if name.startswith("_"):
            continue
        for p in inspect.signature(fn).parameters.values():
            if p.default is inspect.Parameter.empty or p.default is None:
                continue
            assert isinstance(
                p.default, (int, float, str, bool, bytes, tuple, frozenset)
            ) or p.default is Ellipsis, (
                f"{name}({p.name}=...) has a shared mutable default "
                f"{p.default!r} — the PR 2 aliasing bug class")


# ---------------------------------------------------------------------------
# RL106 kernel masking
# ---------------------------------------------------------------------------

def test_rl106_positive_unguarded_kernel(tmp_path):
    findings = run_lint(tmp_path, """\
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _gather_kernel(cols_ref, x_ref, y_ref):
            cols = cols_ref[...]
            y_ref[...] = jnp.take(x_ref[...], cols)
        """)
    rl106 = [f for f in findings if f.rule == "RL106"]
    assert len(rl106) == 1
    assert rl106[0].symbol == "_gather_kernel"


def test_rl106_negative_pl_when_guard(tmp_path):
    findings = run_lint(tmp_path, """\
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _guarded_kernel(cols_ref, y_ref, *, count):
            i = pl.program_id(0)

            @pl.when(i * 8 < count)
            def _():
                y_ref[...] = cols_ref[...] * 2
        """)
    assert not [f for f in findings if f.rule == "RL106"]


def test_rl106_negative_validity_mask(tmp_path):
    findings = run_lint(tmp_path, """\
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _masked_kernel(cols_ref, y_ref, *, num_rows):
            i = pl.program_id(0)
            block = cols_ref.shape[0]
            valid = i * block + jnp.arange(block) < num_rows
            y_ref[...] = jnp.where(valid, cols_ref[...], 0)
        """)
    assert not [f for f in findings if f.rule == "RL106"]


def test_rl106_negative_non_pallas_file(tmp_path):
    # "_ref" params without a pallas import are not kernel bodies
    findings = run_lint(tmp_path, """\
        def update(x_ref, y_ref):
            y_ref[...] = x_ref[...]
        """)
    assert not [f for f in findings if f.rule == "RL106"]


# ---------------------------------------------------------------------------
# suppression / legacy pragma parsing
# ---------------------------------------------------------------------------

def test_suppression_parsing_trailing_and_standalone():
    sups = parse_suppressions(
        "x = 1  # repro-lint: ignore[RL103] trailing reason\n"
        "# repro-lint: ignore[RL101,RL104] standalone reason\n"
        "y = 2\n")
    assert sups[1].codes == ("RL103",)
    assert sups[2].codes == ("RL101", "RL104")   # the pragma line itself
    assert sups[3].codes == ("RL101", "RL104")   # ...and the guarded line
    assert sups[3].reason == "standalone reason"


def test_pragmas_inside_strings_do_not_count():
    text = '"""docs show `# repro-lint: legacy example` usage"""\nx = 1\n'
    assert parse_legacy_tag(text) is None
    assert parse_suppressions(
        's = "# repro-lint: ignore[RL103] not a comment"\n') == {}


def test_legacy_tag_real_comment():
    assert parse_legacy_tag(
        "# repro-lint: legacy seed-era module\nx = 1\n") \
        == "seed-era module"


def test_legacy_findings_are_nonfatal(tmp_path):
    (tmp_path / "old.py").write_text(
        "# repro-lint: legacy retired module\n"
        "import time\n"
        "t0 = time.time()\n")
    result = check([tmp_path / "old.py"], repo_root=tmp_path, roots=[])
    assert result.ok
    assert any(f.rule == "RL103" for f in result.legacy)


# ---------------------------------------------------------------------------
# baseline semantics
# ---------------------------------------------------------------------------

def _one_rl103(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text("import time\nt0 = time.time()\n")
    return p


def test_baseline_grandfathers_matching_finding(tmp_path):
    p = _one_rl103(tmp_path)
    bl = Baseline(entries=[BaselineEntry(
        rule="RL103", path="mod.py", symbol="<module>",
        reason="seed-era stamp, scheduled cleanup")])
    result = check([p], baseline=bl, repo_root=tmp_path, roots=[])
    assert result.ok
    assert len(result.grandfathered) == 1
    assert not result.findings


def test_stale_baseline_entry_fails(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text("import time\nt0 = time.perf_counter()\n")
    bl = Baseline(entries=[BaselineEntry(
        rule="RL103", path="mod.py", symbol="<module>", reason="paid off")])
    result = check([p], baseline=bl, repo_root=tmp_path, roots=[])
    assert not result.ok
    assert any("stale" in m for m in result.baseline_problems)


def test_baseline_placeholder_reason_fails(tmp_path):
    p = _one_rl103(tmp_path)
    bl = Baseline(entries=[BaselineEntry(
        rule="RL103", path="mod.py", symbol="<module>", reason="FILLME")])
    result = check([p], baseline=bl, repo_root=tmp_path, roots=[])
    assert not result.ok
    assert any("reason" in m for m in result.baseline_problems)


def test_committed_baseline_is_small_and_reasoned():
    data = json.loads(BASELINE.read_text())
    entries = data["entries"]
    assert len(entries) <= 10
    for e in entries:
        assert e["reason"].strip().lower() not in ("", "fillme", "todo", "tbd")


# ---------------------------------------------------------------------------
# the self-run gate + quarantine
# ---------------------------------------------------------------------------

def test_src_repro_lints_clean_modulo_baseline():
    """The CI gate, as a test: the whole tree must be free of live
    findings and baseline problems."""
    result = check([SRC_REPRO], baseline=BASELINE, repo_root=REPO_ROOT)
    assert result.ok, (
        "repro-lint regressions:\n  "
        + "\n  ".join(f.render() for f in result.findings)
        + "\n  ".join(result.baseline_problems))


def test_quarantined_modules_stay_unreachable():
    result = check([SRC_REPRO], baseline=BASELINE, repo_root=REPO_ROOT)
    # the seed-era LM stack is quarantined, and no RL001 violation means
    # nothing live imports it
    assert "repro.models" in result.quarantined
    assert "repro.configs" in result.quarantined
    assert not any(f.rule == "RL001" for f in result.findings)
    # parity/reference kernels are test-only, not dead
    assert "repro.kernels.minprop_ell.ref" in result.test_only


def test_rl001_fires_when_quarantine_violated(tmp_path):
    src = tmp_path / "src" / "repro"
    src.mkdir(parents=True)
    (src / "__init__.py").write_text("")
    (src / "api.py").write_text("from repro.old import f\n")
    (src / "old.py").write_text(
        "# repro-lint: legacy retired\ndef f():\n    return 1\n")
    result = check([src], repo_root=tmp_path, roots=[])
    assert any(f.rule == "RL001" for f in result.findings)
    assert not result.ok
