"""The `repro.api` facade: cached-format Graph handle, engine registry,
cross-engine determinism, the common Result protocol, and the deprecation
shims at the legacy entry points."""
import warnings

import numpy as np
import pytest

from conftest import verify_mis2
from repro import obs
from repro.api import (
    Backend,
    Graph,
    Mis2Options,
    amg,
    coarsen,
    color,
    get_engine,
    list_engines,
    mis2,
    misk,
    partition,
)
from repro.graphs import laplace3d, random_uniform_graph

ENGINES = ("dense", "compacted", "pallas")
PRIORITIES = ("fixed", "xorshift", "xorshift_star")


def graph_cases():
    return {
        "laplace3d": Graph(laplace3d(8).graph),
        "er_random": Graph(random_uniform_graph(1200, 6.0, seed=7)),
    }


# ---------------------------------------------------------------------------
# cross-engine determinism (the paper's portability claim, per engine pair)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("priority", PRIORITIES)
@pytest.mark.parametrize("gname", ["laplace3d", "er_random"])
def test_cross_engine_determinism(gname, priority):
    g = graph_cases()[gname]
    opts = Mis2Options(priority=priority)
    results = {e: mis2(g, options=opts, engine=e) for e in ENGINES}
    ref = results["compacted"]
    verify_mis2(g.csr, ref.in_set)
    for name, r in results.items():
        assert (r.in_set == ref.in_set).all(), (gname, priority, name)
        assert r.digest == ref.digest, (gname, priority, name)
        assert r.iterations == ref.iterations, (gname, priority, name)


# ---------------------------------------------------------------------------
# Graph handle: conversion caching
# ---------------------------------------------------------------------------

def test_graph_ell_conversion_runs_exactly_once():
    g = Graph(laplace3d(6).graph)
    with obs.capture() as cap:
        a = g.ell
        b = g.ell
        assert a is b
        assert cap.value("graph.conversions", {"kind": "csr_to_ell"}) == 1
        # three engines + coloring + coarsening share that single conversion
        mis2(g)
        mis2(g, engine="dense")
        mis2(g, engine="pallas")
        color(g)
        coarsen(g)
    assert cap.value("graph.conversions", {"kind": "csr_to_ell"}) == 1
    # the per-handle view agrees with the registry, and the work was timed
    assert g.conversions["csr_to_ell"] == 1
    assert g.conversion_timings["csr_to_ell"] >= 0.0


def test_graph_handle_of_handle_shares_cache():
    g = Graph(laplace3d(5).graph)
    g2 = Graph(g)
    _ = g.ell
    assert g2.conversions["csr_to_ell"] == 1
    assert g2.ell is g.ell


def test_graph_round_trip_and_stats():
    m = laplace3d(5)
    g = Graph(m)
    assert g.has_values
    assert g.num_vertices == m.num_rows
    assert g.ell_matrix.num_rows == m.num_rows
    s = g.stats()
    assert s["max_degree"] == 7 and s["has_values"]
    # ELL-seeded handles can go back to CSR
    h = Graph(g.ell)
    assert h.csr.num_vertices == g.num_vertices
    assert h.conversions["ell_to_csr"] == 1


def test_graph_structure_only_rejects_matrix_access():
    g = Graph(laplace3d(4).graph)
    with pytest.raises(ValueError):
        _ = g.csr_matrix


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

def test_registry_lists_and_aliases():
    eng = list_engines()
    assert set(ENGINES) <= set(eng["mis2"])
    assert {"basic", "two_phase", "serial"} <= set(eng["aggregation"])
    # legacy AGGREGATORS spellings stay routable as aliases
    assert get_engine("aggregation", "mis2_agg") is get_engine(
        "aggregation", "two_phase")
    assert get_engine("aggregation", "mis2_basic") is get_engine(
        "aggregation", "basic")


def test_registry_unknown_engine_lists_available():
    with pytest.raises(ValueError, match="compacted"):
        get_engine("mis2", "warp")
    with pytest.raises(ValueError, match="unknown"):
        mis2(Graph(laplace3d(4).graph), engine="nope")


# ---------------------------------------------------------------------------
# Result protocol: host-numpy payloads, digests, wall time
# ---------------------------------------------------------------------------

def test_result_protocol_payloads_are_host_numpy():
    g = graph_cases()["er_random"]
    results = [mis2(g), mis2(g, engine="dense"), color(g), coarsen(g),
               partition(g, 4), misk(g, k=2)]
    for r in results:
        assert type(r.payload) is np.ndarray, type(r.payload)
        assert r.digest and len(r.digest) == 16
        assert r.wall_time_s >= 0.0
    assert results[0].payload.dtype == np.bool_
    assert results[2].payload.dtype == np.int32


def test_digest_distinguishes_different_outputs():
    g = graph_cases()["er_random"]
    a = mis2(g, options=Mis2Options(priority="fixed"))
    b = mis2(g, options=Mis2Options(priority="xorshift_star"))
    assert a.digest != b.digest  # different priorities, different sets


def test_amg_setup_result():
    h = amg(Graph(laplace3d(10)), aggregation="two_phase", coarse_size=64)
    assert h.num_levels >= 2
    assert h.level_sizes[0][0] == 1000
    assert h.converged and h.hierarchy is not None
    from repro.solvers import cg
    from repro.graphs.ops import spmv_ell

    g = Graph(laplace3d(10))
    b = np.random.default_rng(0).standard_normal(1000).astype(np.float32)
    import jax.numpy as jnp

    res = cg(lambda x: spmv_ell(g.ell_matrix, x), jnp.asarray(b),
             precond=h.as_precond(), tol=1e-8, maxiter=100)
    assert res.converged


# ---------------------------------------------------------------------------
# Backend policy
# ---------------------------------------------------------------------------

def test_backend_interpret_auto_matches_device():
    import jax

    auto = Backend()
    assert auto.resolve_interpret() == (jax.default_backend() == "cpu")
    assert Backend(interpret=True).resolve_interpret() is True
    assert Backend(interpret=False).resolve_interpret() is False


def test_backend_threads_through_pallas_engine():
    g = graph_cases()["laplace3d"]
    base = mis2(g)
    pal = mis2(g, engine="pallas", backend=Backend(interpret=True))
    assert pal.digest == base.digest


# ---------------------------------------------------------------------------
# deprecation shims: old names work, but warn
# ---------------------------------------------------------------------------

def _assert_warns_deprecated(fn):
    with warnings.catch_warnings(record=True) as log:
        warnings.simplefilter("always")
        out = fn()
    assert any(issubclass(w.category, DeprecationWarning) for w in log)
    return out


def test_legacy_entry_points_warn_and_agree():
    from repro.core.aggregation import aggregate_two_phase
    from repro.core.coloring import color_graph
    from repro.core.mis2 import mis2 as old_mis2

    g = laplace3d(6).graph
    old = _assert_warns_deprecated(lambda: old_mis2(g))
    assert (old.in_set == mis2(Graph(g)).in_set).all()
    oldc = _assert_warns_deprecated(lambda: color_graph(g))
    assert (oldc.colors == color(Graph(g)).colors).all()
    olda = _assert_warns_deprecated(lambda: aggregate_two_phase(g))
    assert (olda.labels == coarsen(Graph(g)).labels).all()


def test_legacy_use_pallas_flag_warns_and_matches_pallas_engine():
    g = laplace3d(6).graph
    opts = _assert_warns_deprecated(lambda: Mis2Options(use_pallas=True))
    from repro.core.mis2 import _mis2_compacted_impl

    r = _mis2_compacted_impl(Graph(g), options=opts)
    assert (r.in_set == mis2(Graph(g), engine="pallas").in_set).all()


def test_legacy_aggregators_mapping_warns():
    from repro.solvers.amg import AGGREGATORS

    fn = _assert_warns_deprecated(lambda: AGGREGATORS["mis2_agg"])
    out = fn(laplace3d(5).graph)
    assert out.num_aggregates > 0
