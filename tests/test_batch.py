"""`repro.batch`: size-bucketed, vmapped multi-graph pipelines.

The load-bearing invariant under test: every per-graph result from the
batched path is **bit-identical** (equal determinism digest) to the
single-graph ``dense`` engine, and invariant to the composition of the
batch it rode in — batching is purely a throughput optimization.
"""
import numpy as np
import pytest

from conftest import verify_mis2
from repro.api import (
    BatchResult,
    Graph,
    GraphBatch,
    Mis2Options,
    coarsen,
    coarsen_batch,
    color,
    color_batch,
    list_engines,
    mis2,
    mis2_batch,
)
from repro.batch.container import bucket_shape
from repro.graphs import laplace3d, pad_ell_graph, random_uniform_graph


def mixed_graphs():
    """laplace3d + ER random, varied sizes, spanning several buckets."""
    return [
        Graph(laplace3d(5).graph),                       # V=125
        Graph(laplace3d(6).graph),                       # V=216
        Graph(laplace3d(8).graph),                       # V=512
        Graph(random_uniform_graph(300, 4.0, seed=3)),
        Graph(random_uniform_graph(500, 5.0, seed=1)),
        Graph(random_uniform_graph(800, 6.0, seed=5)),
        Graph(random_uniform_graph(1200, 6.0, seed=7)),
        Graph(laplace3d(4).graph),                       # V=64
    ]


@pytest.fixture(scope="module")
def graphs():
    return mixed_graphs()


@pytest.fixture(scope="module")
def batch(graphs):
    return GraphBatch(graphs)


# ---------------------------------------------------------------------------
# the acceptance invariant: batched digest == single-graph dense digest
# ---------------------------------------------------------------------------

def test_batch_spans_multiple_buckets(batch):
    assert len(batch) >= 8
    assert batch.num_buckets >= 2
    # bucket dims are powers of two and cover every graph exactly once
    members = []
    for rows, width, count in batch.bucket_shapes:
        assert rows & (rows - 1) == 0 and width & (width - 1) == 0
        members.append(count)
    assert sum(members) == len(batch)


def test_mis2_batch_digests_match_dense_engine(graphs, batch):
    br = mis2_batch(batch)
    assert isinstance(br, BatchResult) and len(br) == len(graphs)
    for g, r in zip(graphs, br):
        single = mis2(g, engine="dense")
        assert r.digest == single.digest
        assert r.iterations == single.iterations
        assert r.converged and single.converged
        verify_mis2(g.csr, r.in_set)


@pytest.mark.parametrize("priority", ["fixed", "xorshift_star"])
def test_mis2_batch_digests_match_across_priorities(graphs, batch, priority):
    opts = Mis2Options(priority=priority)
    br = mis2_batch(batch, options=opts)
    for g, r in zip(graphs, br):
        assert r.digest == mis2(g, options=opts, engine="dense").digest


def test_mis2_batch_invariant_to_batch_composition(graphs):
    full = mis2_batch(GraphBatch(graphs))
    # same graph in a different batch (different mates, different order,
    # different bucket occupancy) -> same digest
    shuffled = [graphs[6], graphs[0], graphs[3]]
    small = mis2_batch(shuffled)
    assert small[0].digest == full[6].digest
    assert small[1].digest == full[0].digest
    assert small[2].digest == full[3].digest
    solo = mis2_batch([graphs[6]])
    assert solo[0].digest == full[6].digest


def test_color_batch_matches_single_graph(graphs, batch):
    cb = color_batch(batch)
    for g, r in zip(graphs, cb):
        single = color(g)
        assert r.digest == single.digest
        assert r.num_colors == single.num_colors
        assert r.iterations == single.iterations


@pytest.mark.parametrize("method", ["two_phase", "basic"])
def test_coarsen_batch_matches_single_graph(graphs, batch, method):
    ab = coarsen_batch(batch, method=method)
    for g, r in zip(graphs, ab):
        single = coarsen(g, method=method, mis2_engine="dense")
        assert r.digest == single.digest
        assert r.num_aggregates == single.num_aggregates
        assert (r.roots == single.roots).all()
        assert (r.phase == single.phase).all()
        assert r.iterations == single.iterations


# ---------------------------------------------------------------------------
# registry integration: mis2 engine "dense_batched" (batch of one)
# ---------------------------------------------------------------------------

def test_dense_batched_engine_registered_and_bit_identical():
    assert "dense_batched" in list_engines("mis2")["mis2"]
    g = Graph(random_uniform_graph(700, 5.0, seed=11))
    assert mis2(g, engine="dense_batched").digest == \
        mis2(g, engine="dense").digest


def test_dense_batched_engine_respects_active_mask():
    g = Graph(laplace3d(6).graph)
    active = np.arange(g.num_vertices) % 3 != 0
    a = mis2(g, active=active, engine="dense_batched")
    b = mis2(g, active=active, engine="dense")
    assert a.digest == b.digest and a.iterations == b.iterations


# ---------------------------------------------------------------------------
# container: bucketing, padding, caching
# ---------------------------------------------------------------------------

def test_bucket_policy_power_of_two():
    g = Graph(laplace3d(5).graph)           # V=125, max degree 7
    rows, width = bucket_shape(g)
    assert rows == 128 and width == 8


def test_pad_ell_graph_convention_and_validation():
    ell = Graph(laplace3d(4).graph).ell
    padded = pad_ell_graph(ell, 128, 16)
    assert padded.neighbors.shape == (128, 16)
    nbrs, mask = np.asarray(padded.neighbors), np.asarray(padded.mask)
    v, d = ell.neighbors.shape
    # original block intact
    assert (nbrs[:v, :d] == np.asarray(ell.neighbors)).all()
    assert (mask[:v, :d] == np.asarray(ell.mask)).all()
    # padding: self-loops, mask False
    assert not mask[v:].any() and not mask[:, d:].any()
    assert (nbrs[v:] == np.arange(v, 128)[:, None]).all()
    assert (nbrs[:v, d:] == np.arange(v)[:, None]).all()
    with pytest.raises(ValueError):
        pad_ell_graph(ell, v - 1, d)
    assert pad_ell_graph(ell, v, d) is ell  # no-op at the same shape


def test_padded_ell_cached_on_handle(batch):
    g = batch.graphs[0]
    shape = bucket_shape(g)
    _ = g.padded_ell(*shape)
    count = g.conversions.get("pad_ell")
    GraphBatch([g])          # re-batching hits the handle cache
    assert g.conversions.get("pad_ell") == count


def test_batch_result_protocol(batch):
    br = mis2_batch(batch)
    assert br.num_graphs == len(batch)
    assert len(br.digests) == len(batch)
    assert br.converged
    assert br.wall_time_s > 0 and br.graphs_per_second > 0
    assert br.num_buckets == batch.num_buckets
    assert type(br[0].payload) is np.ndarray
    assert [r.digest for r in br] == br.digests


def test_graph_batch_rejects_empty_and_coerces():
    with pytest.raises(ValueError):
        GraphBatch([])
    b = GraphBatch([laplace3d(4).graph])      # bare container coerces
    assert len(b) == 1
    assert GraphBatch(b).buckets is b.buckets  # batch-of-batch shares state


def test_coarsen_batch_serial_matches_reference(graphs):
    # serial skips bucket stacking entirely (host-sequential reference)
    subset = graphs[:3]
    ab = coarsen_batch(subset, method="serial")
    assert ab.bucket_shapes == []
    for g, r in zip(subset, ab):
        single = coarsen(g, method="serial")
        assert r.digest == single.digest
        assert r.num_aggregates == single.num_aggregates


def test_coarsen_batch_unknown_method_raises(batch):
    with pytest.raises(ValueError, match="two_phase"):
        coarsen_batch(batch, method="nope")
