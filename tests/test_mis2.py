"""MIS-2 invariants (paper Alg. 1): independence, maximality, determinism,
engine/representation agreement, induced-subgraph (active-mask) semantics."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # image has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from conftest import verify_mis2
from repro.core.mis2 import ABLATION_CHAIN, Mis2Options, mis2
from repro.graphs import (
    graph_power2,
    laplace3d,
    path_graph,
    random_skewed_graph,
    random_uniform_graph,
)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(10, 400),
       st.floats(1.0, 8.0))
def test_mis2_invariants_random(seed, n, avg_deg):
    g = random_uniform_graph(n, avg_deg, seed=seed)
    r = mis2(g)
    assert r.converged
    verify_mis2(g, r.in_set)


@pytest.mark.parametrize("maker", [
    lambda: path_graph(17),
    lambda: laplace3d(8).graph,
    lambda: random_skewed_graph(3000, 6.0, seed=3),
])
def test_mis2_invariants_structured(maker):
    g = maker()
    r = mis2(g)
    assert r.converged
    verify_mis2(g, r.in_set)


def test_all_ablation_variants_valid_and_packed_equivalence():
    g = random_uniform_graph(1500, 5.0, seed=11)
    results = {}
    for name, opt in ABLATION_CHAIN.items():
        r = mis2(g, options=opt)
        assert r.converged, name
        verify_mis2(g, r.in_set)
        results[name] = r
    # same priorities + worklists -> representation/layout must not matter
    a = results["+worklists"].in_set
    assert (a == results["+packed_status"].in_set).all()
    assert (a == results["+simd_ell"].in_set).all()


def test_dense_engine_bit_identical():
    g = random_uniform_graph(2500, 7.0, seed=5)
    rc = mis2(g, engine="compacted")
    rd = mis2(g, engine="dense")
    assert (rc.in_set == rd.in_set).all()
    assert rc.iterations == rd.iterations


def test_determinism_across_runs():
    g = random_uniform_graph(4000, 6.0, seed=9)
    a = mis2(g)
    b = mis2(g)
    assert (a.in_set == b.in_set).all()


def test_pallas_path_bit_identical():
    g = random_uniform_graph(2000, 8.0, seed=4)
    base = mis2(g)
    pal = mis2(g, options=Mis2Options(use_pallas=True))
    assert (base.in_set == pal.in_set).all()
    assert base.iterations == pal.iterations


def test_active_mask_induced_subgraph():
    """MIS-2 with an active mask == MIS-2 of the induced subgraph."""
    g = random_uniform_graph(600, 5.0, seed=21)
    rng = np.random.default_rng(0)
    active = rng.random(600) < 0.6
    r = mis2(g, active=np.asarray(active))
    in_set = r.in_set
    assert not in_set[~active].any()
    # verify against the explicitly-built induced subgraph
    import scipy.sparse as sp
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    rows = np.repeat(np.arange(600), np.diff(indptr))
    keep = active[rows] & active[indices]
    a = sp.csr_matrix((np.ones(keep.sum(), np.int8),
                       (rows[keep], indices[keep])), shape=(600, 600))
    a = a + sp.identity(600, dtype=np.int8, format="csr")
    a2 = (a @ a).tocoo()
    bad = in_set[a2.row] & in_set[a2.col] & (a2.row != a2.col)
    assert not bad.any(), "induced independence violated"
    covered = np.zeros(600, bool)
    np.logical_or.at(covered, a2.row, in_set[a2.col])
    covered |= in_set
    assert covered[active].all(), "induced maximality violated"


def test_table3_laplace_regression():
    """Paper Table III scaling: MIS-2 ~9% of V and <=10 iterations for
    Laplace 7-point problems."""
    m = laplace3d(20)
    r = mis2(m.graph)
    frac = r.size / m.graph.num_vertices
    assert 0.07 < frac < 0.11
    assert r.iterations <= 12


def test_paper_fig1_example():
    """The walkthrough graph of paper Fig. 1 yields a valid MIS-2 quickly."""
    import repro.graphs as G
    edges = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (0, 5), (1, 4)]
    rows = np.array([e[0] for e in edges] + [e[1] for e in edges] +
                    list(range(6)))
    cols = np.array([e[1] for e in edges] + [e[0] for e in edges] +
                    list(range(6)))
    g = G.csr_from_coo(rows, cols, 6)
    r = mis2(g)
    verify_mis2(g, r.in_set)
    assert r.iterations <= 4
