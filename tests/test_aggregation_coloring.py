"""Aggregation (Alg. 2/3) and coloring invariants."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # image has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from conftest import verify_mis2
from repro.core import (
    aggregate_basic,
    aggregate_serial_greedy,
    aggregate_two_phase,
    check_coloring,
    color_graph,
    edge_cut,
    partition,
)
from repro.graphs import (
    coarse_graph_from_labels,
    laplace3d,
    random_uniform_graph,
)


@pytest.mark.parametrize("agg_fn", [aggregate_basic, aggregate_two_phase,
                                    aggregate_serial_greedy])
def test_aggregation_total_coverage(agg_fn):
    g = laplace3d(10).graph
    a = agg_fn(g)
    assert (a.labels >= 0).all()
    assert a.labels.max() + 1 == a.num_aggregates
    assert a.num_aggregates < g.num_vertices


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000), st.integers(50, 500))
def test_aggregation_random_coverage(seed, n):
    g = random_uniform_graph(n, 5.0, seed=seed)
    for fn in (aggregate_basic, aggregate_two_phase):
        a = fn(g)
        assert (a.labels >= 0).all()
        # aggregates are connected to their members (every member is within
        # distance 2 of some member — weak sanity via sizes)
        sizes = np.bincount(a.labels)
        assert sizes.min() >= 1


def test_aggregation_roots_form_mis2():
    g = laplace3d(12).graph
    a = aggregate_basic(g)
    # phase-1 roots of Algorithm 2 are exactly an MIS-2
    phase1_roots = a.roots
    verify_mis2(g, phase1_roots)


def test_aggregation_deterministic():
    g = random_uniform_graph(2000, 6.0, seed=17)
    a = aggregate_two_phase(g)
    b = aggregate_two_phase(g)
    assert (a.labels == b.labels).all()


def test_two_phase_beats_basic_on_aggregate_count():
    """Alg. 3's secondary aggregates give finer coarsening than Alg. 2
    (more, smaller aggregates — the paper's quality mechanism)."""
    g = laplace3d(14).graph
    basic = aggregate_basic(g)
    two = aggregate_two_phase(g)
    assert two.num_aggregates >= basic.num_aggregates
    assert np.bincount(two.labels).max() <= np.bincount(basic.labels).max()


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100), st.integers(50, 400))
def test_coloring_valid(seed, n):
    g = random_uniform_graph(n, 4.0, seed=seed)
    c = color_graph(g)
    assert check_coloring(g, c.colors)
    assert c.num_colors >= 1


def test_coarse_graph_coloring_pipeline():
    g = laplace3d(10).graph
    a = aggregate_two_phase(g)
    cg = coarse_graph_from_labels(g, a.labels, a.num_aggregates)
    c = color_graph(cg)
    assert check_coloring(cg, c.colors)


def test_partition_balance_and_determinism():
    g = laplace3d(12).graph
    p1 = partition(g, 8)
    p2 = partition(g, 8)
    assert (p1.parts == p2.parts).all()
    sizes = np.bincount(p1.parts, minlength=8)
    assert sizes.min() > 0
    assert sizes.max() <= np.ceil(g.num_vertices / 8 * 1.3)
    assert p1.edge_cut < g.num_entries // 2


def test_color_round_limit_reports_not_raises():
    """Hitting max_rounds returns converged=False with -1 on the uncolored
    stragglers (the facade used to hardcode converged=True while the core
    raised)."""
    import repro

    g = repro.Graph(laplace3d(6).graph)
    r = repro.color(g, max_rounds=1)
    assert not r.converged
    assert (r.colors < 0).any()
    full = repro.color(g)
    assert full.converged and (full.colors >= 0).all()


def test_color_batch_round_limit_propagates_converged():
    import repro

    graphs = [repro.Graph(laplace3d(5).graph),
              repro.Graph(laplace3d(6).graph)]
    br = repro.color_batch(graphs, max_rounds=1)
    assert not br.converged
    assert any(not r.converged for r in br)
    full = repro.color_batch(graphs)
    assert full.converged
