"""End-to-end behaviour tests for the paper's system: the full
MIS-2 -> aggregation -> coarse graph -> coloring -> preconditioner pipelines,
deterministic across runs, on the paper's generated problem classes."""
import numpy as np
import jax.numpy as jnp

from conftest import verify_mis2
from repro.core import aggregate_two_phase, color_graph, check_coloring, mis2
from repro.graphs import (
    coarse_graph_from_labels,
    csr_to_ell_matrix,
    elasticity3d,
    laplace3d,
)
from repro.graphs.ops import spmv_ell
from repro.solvers import build_hierarchy, cg, gmres, setup_cluster_gs


def test_full_amg_pipeline_laplace():
    """Generate -> coarsen (Alg 3) -> SA-AMG -> preconditioned CG to 1e-10."""
    a = laplace3d(12)
    ell = csr_to_ell_matrix(a)
    b = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(a.num_rows).astype(np.float32))
    h = build_hierarchy(a, aggregation="mis2_agg")
    res = cg(lambda x: spmv_ell(ell, x), b, precond=h.as_precond(),
             tol=1e-10, maxiter=100)
    assert res.converged
    assert res.iterations < 40


def test_full_cluster_gs_pipeline_elasticity():
    """The paper's second use case on the Elasticity3D structure."""
    a = elasticity3d(4)
    ell = csr_to_ell_matrix(a)
    b = jnp.asarray(np.random.default_rng(1)
                    .standard_normal(a.num_rows).astype(np.float32))
    pre = setup_cluster_gs(a)
    res = gmres(lambda x: spmv_ell(ell, x), b,
                precond=pre.as_precond(sweeps=1, symmetric=True),
                tol=1e-6, maxiter=400)
    assert res.converged


def test_pipeline_determinism():
    g = laplace3d(10).graph
    runs = []
    for _ in range(2):
        r = mis2(g)
        a = aggregate_two_phase(g)
        cg_ = coarse_graph_from_labels(g, a.labels, a.num_aggregates)
        c = color_graph(cg_)
        runs.append((r.in_set.copy(), a.labels.copy(), c.colors.copy()))
    assert (runs[0][0] == runs[1][0]).all()
    assert (runs[0][1] == runs[1][1]).all()
    assert (runs[0][2] == runs[1][2]).all()


def test_elasticity_mis2_quality():
    """Table III: Elasticity (27-pt, 3 dof) MIS-2 ~0.7-0.9% of V."""
    g = elasticity3d(10).graph
    r = mis2(g)
    verify_mis2(g, r.in_set)
    frac = r.size / g.num_vertices
    assert 0.004 < frac < 0.02
