"""Flash attention (custom VJP) vs naive reference — values and gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import flash_attention, naive_attention


@pytest.mark.parametrize("b,sq,sk,h,kv,dh,win,causal", [
    (2, 37, 37, 4, 2, 16, 0, True),
    (1, 64, 64, 6, 3, 32, 0, True),
    (2, 50, 50, 4, 1, 16, 17, True),     # windowed (griffin local attn)
    (2, 20, 33, 4, 4, 16, 0, False),     # cross attention (whisper)
    (1, 16, 16, 2, 2, 8, 0, True),
])
def test_flash_fwd_bwd_vs_naive(b, sq, sk, h, kv, dh, win, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, kv, dh), jnp.float32)

    def f(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, kv, causal, win,
                                               16, 16)))

    def g(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v, kv, causal=causal,
                                               window=win)))

    np.testing.assert_allclose(f(q, k, v), g(q, k, v), rtol=2e-4)
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, bb in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=3e-3, atol=3e-3)


def test_flash_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 48, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 48, 4, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 48, 4, 16), jnp.float32)
    outs = [np.asarray(flash_attention(q, k, v, 4, True, 0, bq, bkv))
            for bq, bkv in ((8, 8), (16, 32), (48, 48))]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-6)
