"""repro.serve hardening: the robustness contract under fire.

Every test here drives the server through some failure mode — overload,
quota exhaustion, expired deadlines, injected engine faults, corrupted
or torn persistent entries, shutdown races — and asserts the one
invariant that matters: **every submitted future resolves with either a
digest-correct Result or a typed ServeError** (no hangs, no silent wrong
answers), and concurrent same-key requests cost exactly one compute.

Fault injection is seeded and deterministic (per-site RNG streams), so
these assertions are exact counts, not probabilistic bounds.
"""
import os
import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

import repro
from repro import obs
from repro.graphs import laplace3d, random_uniform_graph
from repro.serve import (
    AdmissionController,
    Batcher,
    DeadlineExceeded,
    DigestMismatch,
    EngineFailure,
    Fault,
    FaultPlan,
    InjectedFault,
    PendingRequest,
    QuotaConfig,
    QuotaExceeded,
    RetryPolicy,
    ServeError,
    Server,
    ServerClosed,
    ServerConfig,
    ServerOverloaded,
    TokenBucket,
)


def _graph(seed=0, n=80, deg=4.0):
    return repro.Graph(random_uniform_graph(n, deg, seed=seed))


# ---------------------------------------------------------------------------
# in-flight dedup: one compute per unique key
# ---------------------------------------------------------------------------

def test_dedup_coalesces_same_key_submits_onto_one_future():
    g = _graph(1)
    clones = [repro.Graph(g.csr) for _ in range(5)]     # digest-equal
    srv = Server(ServerConfig())
    with obs.capture() as cap:
        futs = [srv.submit("mis2", c) for c in [g] + clones]
        srv.flush()
    assert all(f is futs[0] for f in futs)      # joiners share the primary
    digests = {f.result(timeout=30).digest for f in futs}
    assert digests == {repro.mis2(g).digest}
    assert srv.stats.dedup_hits == len(clones)
    assert srv.stats.single_dispatches + srv.stats.batched_graphs == 1
    assert cap.value("serve.dedup_hits") == len(clones)


def test_dedup_distinguishes_engine_and_options():
    g = repro.Graph(laplace3d(4))
    srv = Server(ServerConfig())
    f1 = srv.submit("mis2", g)
    f2 = srv.submit("mis2", g, engine="dense")          # explicit engine
    f3 = srv.submit("color", g)                         # different kind
    srv.flush()
    assert len({id(f) for f in (f1, f2, f3)}) == 3
    assert srv.stats.dedup_hits == 0
    assert f1.result().digest == f2.result().digest     # still bit-identical


def test_dedup_disabled_computes_separately():
    g = _graph(2)
    srv = Server(ServerConfig(dedup=False, cache_bytes=0,
                              single_fast_path=True, max_batch=1))
    f1 = srv.submit("mis2", g)
    f2 = srv.submit("mis2", repro.Graph(g.csr))
    srv.flush()
    assert f1 is not f2
    assert srv.stats.dedup_hits == 0
    assert srv.stats.single_dispatches == 2
    assert f1.result().digest == f2.result().digest


def test_dedup_key_released_after_completion():
    g = _graph(3)
    srv = Server(ServerConfig(cache_bytes=0))   # no cache: recompute path
    f1 = srv.submit("mis2", g)
    srv.flush()
    f2 = srv.submit("mis2", g)                  # no longer in flight
    srv.flush()
    assert f1 is not f2
    assert f2.result().digest == f1.result().digest
    assert srv.server_stats()["inflight_keys"] == 0


# ---------------------------------------------------------------------------
# admission control: quota, bounded queue, deadline feasibility
# ---------------------------------------------------------------------------

def test_token_bucket_refill_with_manual_clock():
    b = TokenBucket(rate=2.0, burst=4.0, now=0.0)
    assert all(b.try_take(0.0) for _ in range(4))   # burst drained
    assert not b.try_take(0.0)
    assert not b.try_take(0.4)                      # 0.8 tokens: not enough
    assert b.try_take(0.5)                          # 1.0 accumulated
    assert b.try_take(10.0)                         # refill caps at burst
    assert sum(b.try_take(10.0) for _ in range(9)) == 3


def test_admission_controller_policies_with_injected_clock():
    clock = [0.0]
    ctl = AdmissionController(max_pending=2,
                              quota=QuotaConfig(rate=1.0, burst=2.0),
                              clock=lambda: clock[0])
    ctl.admit(caller="a", pending=0)
    ctl.admit(caller="a", pending=0)
    with pytest.raises(QuotaExceeded):              # burst of 2 spent
        ctl.admit(caller="a", pending=0)
    assert ctl.denials == {"a": 1}
    ctl.admit(caller="b", pending=0)                # independent bucket
    clock[0] = 1.0                                  # 1 token refilled for a
    ctl.admit(caller="a", pending=0)
    with pytest.raises(ServerOverloaded):
        ctl.admit(caller="c", pending=2)
    ctl.admit(caller="c", pending=2, joining=True)  # joins skip the queue
    with pytest.raises(DeadlineExceeded):
        ctl.admit(caller="d", deadline_s=0.0)
    with pytest.raises(DeadlineExceeded):           # infeasible deadline
        ctl.admit(caller="e", deadline_s=0.01, est_wait_s=1.0)
    ctl.admit(caller="f", deadline_s=2.0, est_wait_s=1.0)


def test_server_sheds_overload_with_typed_error_and_counter():
    srv = Server(ServerConfig(max_pending=1, dedup=False))
    with obs.capture() as cap:
        f1 = srv.submit("mis2", _graph(4))
        f2 = srv.submit("mis2", _graph(5))
    with pytest.raises(ServerOverloaded) as ei:
        f2.result(timeout=5)
    assert ei.value.retryable
    assert cap.value("serve.shed", {"reason": "overloaded"}) == 1
    srv.flush()
    assert f1.result(timeout=30).converged          # admitted one unharmed


def test_server_quota_is_per_caller():
    srv = Server(ServerConfig(quota=QuotaConfig(rate=0.0, burst=1.0),
                              dedup=False, cache_bytes=0))
    f_a1 = srv.submit("mis2", _graph(6), caller="alice")
    f_a2 = srv.submit("mis2", _graph(7), caller="alice")
    f_b = srv.submit("mis2", _graph(8), caller="bob")
    srv.flush()
    with pytest.raises(QuotaExceeded):
        f_a2.result(timeout=5)
    assert f_a1.result(timeout=30).converged
    assert f_b.result(timeout=30).converged
    assert srv.server_stats()["quota_denials"] == {"alice": 1}


def test_expired_deadline_is_shed_at_submit():
    srv = Server(ServerConfig())
    fut = srv.submit("mis2", _graph(9), deadline_s=0.0)
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=5)
    assert srv.stats.shed == 1
    assert len(srv.batcher) == 0                    # never queued


def test_cache_hits_bypass_admission():
    g = _graph(10)
    srv = Server(ServerConfig(quota=QuotaConfig(rate=0.0, burst=1.0)))
    first = srv.request("mis2", g)
    # quota is spent, but the cached answer is served unconditionally
    again = srv.submit("mis2", g).result(timeout=5)
    assert again.digest == first.digest
    with pytest.raises(QuotaExceeded):
        srv.submit("mis2", _graph(11)).result(timeout=5)


# ---------------------------------------------------------------------------
# batcher deadline semantics, injected clock (no sleeps, no wall time)
# ---------------------------------------------------------------------------

def _req(kind="mis2", key=("k",), deadline=None):
    return PendingRequest(kind=kind, graph=None, params={"p": key},
                          engine=None, backend=None, cache_key=key,
                          future=Future(), deadline=deadline)


def test_batcher_zero_delay_dispatches_immediately():
    b = Batcher(max_batch=8, max_delay_s=0.0)
    b.add(_req(key=("a",)), now=100.0)
    groups = b.due(now=100.0)                       # same instant: already due
    assert len(groups) == 1 and len(b) == 0


def test_batcher_force_flush_pops_every_group():
    b = Batcher(max_batch=8, max_delay_s=10.0)
    b.add(_req(key=("a",)), now=0.0)
    b.add(_req(kind="color", key=("b",)), now=0.0)
    b.add(_req(kind="coarsen", key=("c",)), now=0.0)
    assert b.due(now=0.1) == []                     # nothing due yet
    groups = b.due(now=0.1, force=True)
    assert len(groups) == 3 and len(b) == 0


def test_batcher_next_deadline_orders_batching_and_request_deadlines():
    b = Batcher(max_batch=8, max_delay_s=5.0)
    assert b.next_deadline(now=0.0) is None
    b.add(_req(key=("a",)), now=0.0)                # batch deadline at t=5
    assert b.next_deadline(now=0.0) == pytest.approx(5.0)
    b.add(_req(kind="color", key=("b",), deadline=2.0), now=0.0)
    assert b.next_deadline(now=0.0) == pytest.approx(2.0)   # request sooner
    assert b.next_deadline(now=1.5) == pytest.approx(0.5)
    assert b.next_deadline(now=3.0) == 0.0          # clamped, already late


def test_batcher_pop_expired_evicts_only_expired_requests():
    b = Batcher(max_batch=8, max_delay_s=100.0)
    live = _req(key=("a",), deadline=50.0)
    dead = _req(key=("a",), deadline=1.0)
    never = _req(kind="color", key=("b",))          # no deadline
    for r in (live, dead, never):
        b.add(r, now=0.0)
    expired = b.pop_expired(now=2.0)
    assert expired == [dead]
    assert len(b) == 2
    groups = b.due(now=2.0, force=True)
    popped = [r for _, reqs in groups for r in reqs]
    assert live in popped and never in popped and dead not in popped


def test_server_evicts_expired_request_before_dispatch():
    srv = Server(ServerConfig(max_delay_s=100.0))
    fut = srv.submit("mis2", _graph(12), deadline_s=0.001)
    time.sleep(0.01)
    with obs.capture() as cap:
        srv.pump()                                  # not forced: only evicts
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=5)
    assert srv.stats.expired == 1
    assert srv.stats.dispatches == 0                # never computed
    assert cap.value("serve.shed", {"reason": "expired"}) == 1


# ---------------------------------------------------------------------------
# stop(): terminal, typed, race-free
# ---------------------------------------------------------------------------

def test_stop_fails_queued_futures_with_server_closed():
    srv = Server(ServerConfig(max_delay_s=100.0))
    futs = [srv.submit("mis2", _graph(s)) for s in (13, 14)]
    srv.stop()
    for fut in futs:
        with pytest.raises(ServerClosed):
            fut.result(timeout=5)
    with pytest.raises(ServerClosed):               # post-stop submit
        srv.submit("mis2", _graph(15)).result(timeout=5)
    with pytest.raises(ServerClosed):
        srv.request("mis2", _graph(16))
    with pytest.raises(ServerClosed):
        srv.open_stream(_graph(17))
    with pytest.raises(ServerClosed):
        srv.start()
    srv.stop()                                      # idempotent


def test_concurrent_submitters_racing_shutdown_never_hang():
    srv = Server(ServerConfig(max_delay_s=0.0, poll_interval_s=0.001))
    srv.start()
    graphs = [_graph(20 + s, n=40) for s in range(4)]
    futures, lock = [], threading.Lock()
    stop_submitting = threading.Event()

    def submitter(i):
        k = 0
        while not stop_submitting.is_set():
            fut = srv.submit("mis2", graphs[(i + k) % len(graphs)])
            with lock:
                futures.append(fut)
            k += 1
            time.sleep(0.001)

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(3)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    srv.stop()                                      # race against submitters
    stop_submitting.set()
    for t in threads:
        t.join(timeout=10)
        assert not t.is_alive()
    assert futures
    referents = {g.digest: repro.mis2(g).digest for g in graphs}
    served = closed = 0
    for fut in futures:
        try:
            res = fut.result(timeout=10)            # must resolve: no hangs
        except ServerClosed:
            closed += 1
        else:
            assert res.digest in referents.values()
            served += 1
    assert served + closed == len(futures)


# ---------------------------------------------------------------------------
# fault injection: deterministic, retried, degraded — never wrong
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic_per_seed():
    def firing_pattern(seed):
        plan = FaultPlan(seed=seed, sites={
            "engine": Fault("error", rate=0.4)})
        return [plan.should_fire("engine") is not None for _ in range(64)]

    a, b = firing_pattern(7), firing_pattern(7)
    assert a == b                                   # same seed: same trace
    assert firing_pattern(8) != a                   # different seed differs
    assert 0 < sum(a) < 64                          # genuinely probabilistic


def test_fault_count_caps_firings():
    plan = FaultPlan(seed=0, sites={"engine": Fault("error", count=2)})
    fired = sum(plan.should_fire("engine") is not None for _ in range(10))
    assert fired == 2 and plan.fired["engine"] == 2


def test_transient_fault_retried_to_correct_digest():
    g = _graph(30)
    plan = FaultPlan(seed=1, sites={
        "engine": Fault("error", count=2, transient=True)})
    srv = Server(ServerConfig(
        faults=plan, retry=RetryPolicy(max_attempts=3, base_backoff_s=0.0)))
    with obs.capture() as cap:
        res = srv.request("mis2", g)
    assert res.digest == repro.mis2(g).digest
    assert srv.stats.retries == 2
    assert cap.value("serve.retries", {"site": "engine"}) == 2
    assert cap.value("serve.faults.injected", {"site": "engine"}) == 2


def test_persistent_fault_degrades_to_fallback_engine():
    g = _graph(31)
    plan = FaultPlan(seed=1, sites={
        "engine": Fault("error", transient=False)})
    srv = Server(ServerConfig(faults=plan))
    with obs.capture() as cap:
        res = srv.request("mis2", g)
    assert res.digest == repro.mis2(g, engine="dense").digest
    assert res.engine == "dense"
    assert srv.stats.fallbacks == 1
    assert cap.value("serve.fallbacks",
                     {"from": "auto", "to": "dense"}) == 1


def test_exhausted_retry_budget_falls_back():
    g = _graph(32)
    plan = FaultPlan(seed=1, sites={
        "engine": Fault("error", transient=True)})      # fires every visit
    srv = Server(ServerConfig(
        faults=plan, retry=RetryPolicy(max_attempts=2, base_backoff_s=0.0)))
    res = srv.request("mis2", g)
    assert res.digest == repro.mis2(g).digest
    assert srv.stats.retries == 1                   # attempts 1->2, then
    assert srv.stats.fallbacks == 1                 # budget spent: fallback


def test_fallback_disabled_surfaces_injected_fault():
    plan = FaultPlan(seed=1, sites={
        "engine": Fault("error", transient=False)})
    srv = Server(ServerConfig(
        faults=plan, retry=RetryPolicy(fallback=False)))
    fut = srv.submit("mis2", _graph(33))
    srv.flush()
    with pytest.raises(InjectedFault):
        fut.result(timeout=5)


def test_slow_fault_delays_but_serves_correctly():
    g = _graph(34)
    plan = FaultPlan(seed=1, sites={
        "dispatch": Fault("slow", count=1, delay_s=0.05)})
    srv = Server(ServerConfig(faults=plan))
    t0 = time.perf_counter()
    res = srv.request("mis2", g)
    assert time.perf_counter() - t0 >= 0.05
    assert res.digest == repro.mis2(g).digest


def test_streaming_repair_fault_degrades_to_exact_recompute():
    g = repro.Graph(laplace3d(4))
    plan = FaultPlan(seed=2, sites={"repair": Fault("error", count=1)})
    srv = Server(ServerConfig(faults=plan))
    sess = srv.open_stream(g)
    with obs.capture() as cap:
        res = sess.apply_delta(edge_adds=[(0, 9)])
    assert sess.last_repair.degraded
    assert sess.last_repair.mode == "recompute"
    assert cap.value("serve.fallbacks",
                     {"from": "repair", "to": "recompute"}) == 1
    assert res.digest == repro.mis2(sess.graph, engine="dense",
                                    options=sess.options).digest
    sess.apply_delta(edge_adds=[(1, 11)])           # fault spent: repairs
    assert sess.last_repair.mode == "repair"
    assert not sess.last_repair.degraded


def test_real_engine_exception_wrapped_as_engine_failure():
    srv = Server(ServerConfig(retry=RetryPolicy(fallback=False)))
    boom = RuntimeError("engine exploded")

    def exploding(reqs):
        raise boom

    srv._compute = exploding
    fut = srv.submit("mis2", _graph(35))
    srv.flush()
    with pytest.raises(EngineFailure) as ei:
        fut.result(timeout=5)
    assert ei.value.__cause__ is boom


# ---------------------------------------------------------------------------
# the digest ledger: one key, one digest, forever
# ---------------------------------------------------------------------------

def test_digest_ledger_refuses_conflicting_digest():
    g = _graph(36)
    srv = Server(ServerConfig(cache_bytes=0))       # force recompute path
    first = srv.request("mis2", g)
    key = next(iter(srv._ledger))
    srv._ledger[key] = "poisoned_digest!"           # simulate corruption
    fut = srv.submit("mis2", g)
    srv.flush()
    with pytest.raises(DigestMismatch):
        fut.result(timeout=5)
    assert first.converged                          # first answer unaffected


def test_digest_ledger_accepts_repeat_of_same_digest():
    g = _graph(37)
    srv = Server(ServerConfig(cache_bytes=0))
    a = srv.request("mis2", g)
    b = srv.request("mis2", g)                      # recomputed, same bytes
    assert a.digest == b.digest
    assert srv.server_stats()["ledger_keys"] == 1


# ---------------------------------------------------------------------------
# chaos: seeded faults + overload + deadlines, typed-or-correct throughout
# ---------------------------------------------------------------------------

def test_chaos_run_every_response_typed_or_digest_correct():
    graphs = [_graph(40 + s, n=60) for s in range(6)]
    referents = {g.digest: repro.mis2(g).digest for g in graphs}
    plan = FaultPlan(seed=9, sites={
        "engine": Fault("error", rate=0.3, transient=True),
        "dispatch": Fault("slow", rate=0.2, delay_s=0.002),
    })
    srv = Server(ServerConfig(
        max_pending=4, quota=QuotaConfig(rate=200.0, burst=8.0),
        default_deadline_s=30.0, faults=plan,
        retry=RetryPolicy(max_attempts=2, base_backoff_s=0.0),
        cache_bytes=0, dedup=False))
    futs = []
    for round_ in range(6):
        for i, g in enumerate(graphs):
            futs.append((g, srv.submit("mis2", g,
                                       caller=f"c{(round_ + i) % 3}")))
        srv.flush()
    srv.flush()
    served = shed = 0
    for g, fut in futs:
        assert fut.done()                           # nothing hangs
        try:
            res = fut.result()
        except ServeError:
            shed += 1
        else:
            assert res.digest == referents[g.digest]    # never wrong
            served += 1
    assert served > 0                               # progress under chaos
    assert served + shed == len(futs)
    stats = srv.server_stats()
    assert stats["retries"] + stats["fallbacks"] > 0    # faults really fired


# ---------------------------------------------------------------------------
# persistent tier: atomic, digest-verified, restart-safe
# ---------------------------------------------------------------------------

def test_persist_roundtrip_survives_restart(tmp_path):
    d = str(tmp_path / "tier")
    g = _graph(50)
    gc = repro.Graph(laplace3d(4))
    srv = Server(ServerConfig(persist_dir=d))
    ref_mis2 = srv.request("mis2", g)
    ref_color = srv.request("color", gc)
    ref_coarsen = srv.request("coarsen", gc)
    assert srv.persist.stats.writes == 3
    srv.stop()

    srv2 = Server(ServerConfig(persist_dir=d))      # fresh process stand-in
    assert srv2.request("mis2", g).digest == ref_mis2.digest
    assert srv2.request("color", gc).digest == ref_color.digest
    got = srv2.request("coarsen", gc)
    assert got.digest == ref_coarsen.digest
    assert np.array_equal(got.roots, ref_coarsen.roots)
    assert got.num_aggregates == ref_coarsen.num_aggregates
    assert srv2.persist.stats.hits == 3
    assert srv2.stats.dispatches == 0               # rehydrated, not computed
    assert srv2.persist.stats.corrupt == 0


def test_persist_corrupt_entry_dropped_never_served(tmp_path):
    d = str(tmp_path / "tier")
    g = _graph(51)
    plan = FaultPlan(seed=3, sites={
        "persist_corrupt": Fault("corrupt", count=1)})
    srv = Server(ServerConfig(persist_dir=d, faults=plan))
    ref = srv.request("mis2", g)                    # written corrupted
    srv.stop()

    with obs.capture() as cap:
        srv2 = Server(ServerConfig(persist_dir=d))
        res = srv2.request("mis2", g)               # verify -> drop -> compute
    assert res.digest == ref.digest
    assert srv2.persist.stats.corrupt == 1
    assert srv2.persist.stats.hits == 0
    assert srv2.stats.dispatches == 1               # recomputed honestly
    assert cap.value("serve.persist.corrupt") == 1
    assert len(srv2.persist) == 1                   # recompute re-persisted


def test_persist_torn_write_leaves_no_entry_and_is_swept(tmp_path):
    d = str(tmp_path / "tier")
    g = _graph(52)
    plan = FaultPlan(seed=4, sites={
        "persist_write": Fault("error", count=1)})
    srv = Server(ServerConfig(persist_dir=d, faults=plan))
    ref = srv.request("mis2", g)                    # commit crashed
    assert srv.persist.stats.writes == 0
    assert any(n.endswith(".tmp") for n in os.listdir(d))
    srv.stop()

    srv2 = Server(ServerConfig(persist_dir=d))
    assert srv2.persist.stats.torn_cleaned == 1     # orphan swept at open
    assert not any(n.endswith(".tmp") for n in os.listdir(d))
    assert srv2.request("mis2", g).digest == ref.digest
    assert srv2.stats.dispatches == 1               # disk had nothing usable


def test_persist_byte_budget_evicts_oldest(tmp_path):
    from repro.serve.persist import PersistTier

    tier = PersistTier(str(tmp_path / "tier"))
    graphs = [_graph(60 + s, n=120) for s in range(4)]
    results = [repro.mis2(g) for g in graphs]
    keys = [("mis2", g.digest, "auto", ()) for g in graphs]
    entry_bytes = []
    for k, r in zip(keys, results):
        assert tier.store(k, r)
        entry_bytes.append(tier.stats.bytes_used - sum(entry_bytes))
    # rebuild with a budget that holds ~2 entries
    budget = entry_bytes[-1] + entry_bytes[-2] + entry_bytes[-3] // 2
    tier2 = PersistTier(str(tmp_path / "tier2"), max_bytes=budget)
    for k, r in zip(keys, results):
        assert tier2.store(k, r)
        time.sleep(0.01)                            # distinct mtimes
    assert tier2.stats.evictions >= 1
    assert tier2.stats.bytes_used <= budget
    assert tier2.load(keys[-1]).digest == results[-1].digest    # newest kept
    assert tier2.load(keys[0]) is None              # oldest evicted
    assert tier2.stats.corrupt == 0


def test_persist_skips_amg_and_server_still_serves(tmp_path):
    from repro.graphs import er_laplacian

    d = str(tmp_path / "tier")
    m = repro.Graph(er_laplacian(120, 5.0, seed=6))
    srv = Server(ServerConfig(persist_dir=d))
    res = srv.request("amg_setup", m)
    assert res.num_levels >= 1
    assert srv.persist.stats.writes == 0            # memory-only kind
    assert len(srv.persist) == 0
    # ...but the in-memory cache still serves it
    assert srv.submit("amg_setup", m).result(timeout=5).digest == res.digest


def test_persist_wrong_key_same_address_not_served(tmp_path):
    from repro.serve.persist import PersistTier

    tier = PersistTier(str(tmp_path / "tier"))
    g = _graph(53)
    res = repro.mis2(g)
    key = ("mis2", g.digest, "auto", ())
    assert tier.store(key, res)
    # manifest key must match the *requested* key, not just the address
    other = ("mis2", g.digest, "dense", ())
    assert tier.load(other) is None
    assert tier.load(key).digest == res.digest


# ---------------------------------------------------------------------------
# I/O containment and lock scope: a broken disk or a slow retry never
# hangs a future, kills the pump thread, or blocks submit()
# ---------------------------------------------------------------------------

def _enospc(*a, **k):
    raise OSError(28, "No space left on device")


def test_persist_store_io_error_degrades_to_memory_only(tmp_path,
                                                        monkeypatch):
    g = _graph(70)
    srv = Server(ServerConfig(persist_dir=str(tmp_path / "tier")))
    monkeypatch.setattr("repro.serve.persist.np.savez", _enospc)
    fut = srv.submit("mis2", g)
    srv.flush()
    res = fut.result(timeout=30)            # resolves: no hang, no raise
    assert res.digest == repro.mis2(g).digest
    assert srv.persist.stats.io_errors == 1
    assert srv.persist.stats.writes == 0
    # the memory tier still serves the entry
    assert srv.submit("mis2", g).result(timeout=5).digest == res.digest


def test_pump_thread_survives_persist_io_errors(tmp_path, monkeypatch):
    monkeypatch.setattr("repro.serve.persist.np.savez", _enospc)
    srv = Server(ServerConfig(persist_dir=str(tmp_path / "tier"),
                              max_delay_s=0.0, poll_interval_s=0.001))
    g1, g2 = _graph(71), _graph(72)
    with srv:
        r1 = srv.submit("mis2", g1).result(timeout=30)
        r2 = srv.submit("mis2", g2).result(timeout=30)  # pump still alive
    assert r1.digest == repro.mis2(g1).digest
    assert r2.digest == repro.mis2(g2).digest
    assert srv.persist.stats.io_errors == 2


def test_pump_crash_fails_queued_futures_and_loop_survives():
    srv = Server(ServerConfig(max_delay_s=0.0, poll_interval_s=0.001,
                              cache_bytes=0))
    crashed = {"n": 0}
    orig_due = srv.batcher.due

    def flaky_due(now, force=False):
        if crashed["n"] == 0 and len(srv.batcher):
            crashed["n"] += 1
            raise RuntimeError("boom outside dispatch fan-out")
        return orig_due(now, force=force)

    srv.batcher.due = flaky_due
    with srv:
        fut = srv.submit("mis2", _graph(73))
        with pytest.raises(EngineFailure):  # typed, not a silent hang
            fut.result(timeout=30)
        g = _graph(74)                      # the loop kept pumping
        res = srv.submit("mis2", g).result(timeout=30)
    assert crashed["n"] == 1
    assert res.digest == repro.mis2(g).digest


def test_persist_load_utime_race_is_a_miss(tmp_path, monkeypatch):
    from repro.serve.persist import PersistTier

    tier = PersistTier(str(tmp_path / "tier"))
    g = _graph(75)
    key = ("mis2", g.digest, "auto", ())
    assert tier.store(key, repro.mis2(g))

    def vanished(*a, **k):
        raise FileNotFoundError("entry evicted by a sharing process")

    monkeypatch.setattr("repro.serve.persist.os.utime", vanished)
    misses = tier.stats.misses
    assert tier.load(key) is None           # a miss, never an exception
    assert tier.stats.misses == misses + 1


def test_persist_tampered_toplevel_digest_is_corrupt(tmp_path):
    import json

    from repro.serve.persist import PersistTier, entry_name

    tier = PersistTier(str(tmp_path / "tier"))
    g = _graph(76)
    key = ("mis2", g.digest, "auto", ())
    assert tier.store(key, repro.mis2(g))
    # corrupt ONLY the top-level digest; arrays and their digests stay valid
    mpath = os.path.join(tier.directory, entry_name(key), "manifest.json")
    with open(mpath) as fh:
        manifest = json.load(fh)
    manifest["digest"] = "0" * 16
    with open(mpath, "w") as fh:
        json.dump(manifest, fh)
    assert tier.load(key) is None           # dropped, not rehydrated
    assert tier.stats.corrupt == 1


def test_admission_bucket_recycling_is_lru_not_fifo(monkeypatch):
    from repro.serve import admission

    monkeypatch.setattr(admission, "MAX_TRACKED_CALLERS", 2)
    clock = [0.0]
    ctl = AdmissionController(quota=QuotaConfig(rate=0.0, burst=1.0),
                              clock=lambda: clock[0])
    ctl.admit(caller="hot")                 # hot's burst is now spent
    ctl.admit(caller="b")
    with pytest.raises(QuotaExceeded):
        ctl.admit(caller="hot")             # denied; refreshes hot's bucket
    ctl.admit(caller="c")                   # at capacity: evicts b, NOT hot
    with pytest.raises(QuotaExceeded):
        ctl.admit(caller="hot")             # hot never reset to full burst


def test_submit_not_blocked_by_slow_dispatch():
    plan = FaultPlan(seed=5, sites={
        "dispatch": Fault("slow", count=1, delay_s=0.5)})
    srv = Server(ServerConfig(faults=plan, max_delay_s=0.0,
                              poll_interval_s=0.001))
    with srv:
        slow = srv.submit("mis2", _graph(77))
        time.sleep(0.1)                     # pump is inside the 0.5s fault
        g = _graph(78)
        t0 = time.perf_counter()
        fast = srv.submit("mis2", g)
        submit_latency = time.perf_counter() - t0
        assert slow.result(timeout=30).converged
        assert fast.result(timeout=30).digest == repro.mis2(g).digest
    # pre-fix the injected sleep ran under the server lock, so this
    # submit would have blocked for the remaining ~0.4s of the fault
    assert submit_latency < 0.25
