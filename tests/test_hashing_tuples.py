"""Hash limb-emulation bit-exactness + compressed-tuple properties (§V-A/C)."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # image has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import hashing
from repro.core.tuples import IN, OUT, effective_priority, id_bits, pack, unpack_id


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 60))
def test_hash_bit_exact_vs_uint64_oracle(seed, iteration):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, 2**31 - 1, size=64, dtype=np.uint32)
    for kind in ("xorshift", "xorshift_star", "fixed"):
        ours = np.asarray(hashing.PRIORITY_FNS[kind](iteration, jnp.asarray(ids)))
        ref = hashing.np_priorities(kind, iteration, ids)
        assert (ours == ref).all(), kind


def test_hash_iteration_decorrelation():
    """xorshift* outputs differ across iterations for the same vertex."""
    ids = jnp.arange(1000, dtype=jnp.uint32)
    a = np.asarray(hashing.priorities_xorshift_star(1, ids))
    b = np.asarray(hashing.priorities_xorshift_star(2, ids))
    assert (a != b).mean() > 0.99


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 2**20))
def test_pack_range_and_ordering(num_vertices):
    """Equation (1): no packed tuple collides with IN or OUT; ids recoverable."""
    b = id_bits(num_vertices)
    rng = np.random.default_rng(num_vertices)
    ids = rng.integers(0, num_vertices, size=128, dtype=np.uint32)
    prios = rng.integers(0, 2**32 - 1, size=128, dtype=np.uint32)
    packed = np.asarray(pack(jnp.asarray(prios), jnp.asarray(ids), b))
    assert (packed != IN).all()
    assert (packed != OUT).all()
    assert (np.asarray(unpack_id(jnp.asarray(packed), b)) == ids).all()
    # lexicographic: equal effective priorities are tie-broken by id
    eff = np.asarray(effective_priority(jnp.asarray(prios), b))
    same = eff[:, None] == eff[None, :]
    lt = packed[:, None] < packed[None, :]
    id_lt = ids[:, None] < ids[None, :]
    assert (lt[same] == id_lt[same]).all()
