"""General MIS-k + degree-bucketed ELL (paper baseline generality + the
skew adaptation noted in DESIGN.md)."""
import numpy as np
import pytest
import scipy.sparse as sp

from conftest import verify_mis2
from repro.core import mis_k
from repro.core.mis2 import mis2
from repro.graphs import (
    csr_to_bucketed_ell,
    csr_to_ell_graph,
    laplace3d,
    random_skewed_graph,
    random_uniform_graph,
)


def _power_k(g, k):
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    v = len(indptr) - 1
    a = sp.csr_matrix((np.ones(len(indices), np.int8), indices, indptr),
                      shape=(v, v)) + sp.identity(v, dtype=np.int8,
                                                  format="csr")
    out = sp.identity(v, dtype=np.int8, format="csr")
    for _ in range(k):
        out = (out @ a).tocsr()
        out.data[:] = 1
    return out.tocoo()


@pytest.mark.parametrize("k", [1, 2, 3])
def test_misk_invariants(k):
    g = random_uniform_graph(800, 5.0, seed=k)
    r = mis_k(g, k=k)
    assert r.converged
    ak = _power_k(g, k)
    in_set = r.in_set
    bad = in_set[ak.row] & in_set[ak.col] & (ak.row != ak.col)
    assert not bad.any(), f"distance-{k} independence violated"
    covered = np.zeros(800, bool)
    np.logical_or.at(covered, ak.row, in_set[ak.col])
    covered |= in_set
    assert covered.all(), f"distance-{k} maximality violated"


def test_misk_k2_is_valid_mis2():
    g = laplace3d(8).graph
    r = mis_k(g, k=2)
    verify_mis2(g, r.in_set)


def test_misk_sizes_decrease_with_k():
    g = random_uniform_graph(2000, 4.0, seed=7)
    sizes = [mis_k(g, k=k).size for k in (1, 2, 3)]
    assert sizes[0] > sizes[1] > sizes[2]


def test_bucketed_ell_reduces_padding_on_skewed():
    g = random_skewed_graph(5000, 6.0, seed=3)
    flat = csr_to_ell_graph(g)
    bucketed = csr_to_bucketed_ell(g)
    flat_ratio = flat.neighbors.size / max(1, int(np.asarray(flat.mask).sum()))
    assert bucketed.num_vertices == g.num_vertices
    assert bucketed.padding_ratio < 0.5 * flat_ratio
    # content round-trip: union of bucket rows covers all vertices once
    all_rows = np.concatenate([np.asarray(r) for r in bucketed.rows])
    assert len(np.unique(all_rows)) == g.num_vertices


def test_bucketed_ell_mis2_agrees():
    """MIS-2 per-bucket gathers == flat-ELL result (same closed-nbhd min)."""
    g = random_skewed_graph(1500, 5.0, seed=9)
    flat = mis2(g)
    bucketed = csr_to_bucketed_ell(g)
    # run mis2 on the reconstructed flat graph from buckets
    import repro.graphs as G
    rows, cols = [], []
    for r, bg in zip(bucketed.rows, bucketed.graphs):
        nb = np.asarray(bg.neighbors)
        mk = np.asarray(bg.mask)
        rr = np.repeat(np.asarray(r), mk.sum(axis=1))
        rows.append(rr)
        cols.append(nb[mk])
    g2 = G.csr_from_coo(np.concatenate(rows), np.concatenate(cols),
                        g.num_vertices)
    again = mis2(g2)
    assert (flat.in_set == again.in_set).all()
