"""Device-resident MIS-2 hot loop (ISSUE 4): digest parity with the
host-driven engines across the full option matrix, zero host round-trips
inside the fixed point (one dispatch per solve), fused Pallas pass
bit-exactness, the ELL row-traffic model, and the jit-churn accounting."""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import verify_mis2
from repro import obs
from repro.api import Backend, Graph, Mis2Options, coarsen, color, mis2
from repro.core.mis2 import compact_worklist
from repro.graphs import csr_from_coo, laplace3d, random_uniform_graph

PRIORITIES = ("fixed", "xorshift", "xorshift_star")


def graph_cases():
    return {
        "laplace3d": Graph(laplace3d(8).graph),            # V = 512
        "er_random": Graph(random_uniform_graph(600, 5.0, seed=21)),
        # PR 3's adversarial size: 1022 straddles the 1024 pow2 boundary
        "er_1022": Graph(random_uniform_graph(1022, 6.0, seed=9)),
    }


# ---------------------------------------------------------------------------
# digest-parity matrix: resident vs host-driven vs dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("priority", PRIORITIES)
def test_resident_parity_priorities(priority):
    g = graph_cases()["laplace3d"]
    opts = Mis2Options(priority=priority)
    ref = mis2(g, options=opts, engine="compacted")
    verify_mis2(g.csr, ref.in_set)
    for eng in ("compacted_resident", "pallas_resident", "dense"):
        r = mis2(g, options=opts, engine=eng)
        assert r.digest == ref.digest, (priority, eng)
        assert r.iterations == ref.iterations, (priority, eng)


@pytest.mark.parametrize("layout", ["ell", "csr_segment"])
@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("gname", ["er_random", "er_1022"])
def test_resident_parity_layout_packed(gname, layout, packed):
    g = graph_cases()[gname]
    opts = Mis2Options(layout=layout, packed=packed)
    a = mis2(g, options=opts, engine="compacted")
    b = mis2(g, options=opts, engine="compacted_resident")
    assert a.digest == b.digest, (gname, layout, packed)
    assert a.iterations == b.iterations, (gname, layout, packed)
    assert a.converged and b.converged


def test_resident_parity_active_mask():
    g = graph_cases()["er_random"]
    active = np.random.default_rng(0).random(600) < 0.6
    a = mis2(g, active=active, engine="compacted")
    for eng in ("compacted_resident", "pallas_resident"):
        r = mis2(g, active=active, engine=eng)
        assert r.digest == a.digest and r.iterations == a.iterations, eng
    assert not a.in_set[~active].any()


def test_resident_zero_active_vertices():
    g = graph_cases()["er_random"]
    active = np.zeros(600, dtype=bool)
    for eng in ("compacted", "compacted_resident", "pallas_resident"):
        r = mis2(g, active=active, engine=eng)
        assert r.iterations == 0 and r.converged and r.size == 0, eng


def test_resident_single_vertex():
    g = Graph(csr_from_coo(np.array([0]), np.array([0]), 1))
    ref = mis2(g, engine="compacted")
    for eng in ("compacted_resident", "pallas_resident", "dense"):
        r = mis2(g, engine=eng)
        assert r.digest == ref.digest and r.iterations == ref.iterations, eng
    assert ref.size == 1


def test_resident_rejects_no_worklist_ablation():
    g = graph_cases()["laplace3d"]
    with pytest.raises(ValueError, match="worklist"):
        mis2(g, options=Mis2Options(worklists=False),
             engine="compacted_resident")
    with pytest.raises(ValueError, match="packed"):
        mis2(g, options=Mis2Options(packed=False), engine="pallas_resident")


# ---------------------------------------------------------------------------
# execution shape: zero host round-trips, one dispatch per solve
# ---------------------------------------------------------------------------

def test_resident_zero_host_syncs_one_dispatch():
    g = graph_cases()["er_random"]
    mis2(g, engine="compacted_resident")        # warm the jit cache
    with obs.capture() as cap:
        r = mis2(g, engine="compacted_resident")
    assert r.iterations > 1                      # a real multi-round solve
    assert cap.value("mis2.host_syncs") == 0
    assert cap.value("mis2.resident_dispatches") == 1
    with obs.capture() as cap:
        mis2(g, engine="pallas_resident")
    assert cap.value("mis2.host_syncs") == 0
    assert cap.value("mis2.resident_dispatches") == 1


def test_host_driven_engine_pays_syncs_every_round():
    g = graph_cases()["er_random"]
    with obs.capture() as cap:
        r = mis2(g, engine="compacted")
    # 2 transfers (T and M) per fixed-point round to rebuild worklists
    assert cap.value("mis2.host_syncs") == 2 * r.iterations
    assert cap.value("mis2.resident_dispatches") == 0


def test_num_compiles_accounting():
    g = graph_cases()["er_random"]
    host = mis2(g, engine="compacted")
    res = mis2(g, engine="compacted_resident")
    # legacy driver: one specialization per distinct pow2 bucket pair
    assert host.num_compiles is not None and host.num_compiles >= 2
    assert res.num_compiles == 1
    # accounting is per solve, so it is stable across repeat solves
    assert mis2(g, engine="compacted").num_compiles == host.num_compiles


def test_compact_worklist_matches_flatnonzero():
    rng = np.random.default_rng(5)
    for frac in (0.0, 0.3, 1.0):
        mask = rng.random(777) < frac
        wl, n = compact_worklist(jnp.asarray(mask))
        wl, n = np.asarray(wl), int(n)
        idx = np.flatnonzero(mask)
        assert n == len(idx)
        assert (wl[:n] == idx).all()
        assert (wl[n:] == 777).all()             # sentinel-padded tail


# ---------------------------------------------------------------------------
# fused Pallas passes: bit-exact vs oracles, single-row-read traffic model
# ---------------------------------------------------------------------------

def _fused_inputs(v=700, deg=7.0, seed=3):
    from repro.graphs import csr_to_ell_graph

    ell = csr_to_ell_graph(random_uniform_graph(v, deg, seed=seed))
    rng = np.random.default_rng(seed)
    t = rng.integers(1, 2**32 - 2, size=v, dtype=np.uint32)
    t[rng.random(v) < 0.1] = 0                   # some IN
    t[rng.random(v) < 0.1] = np.uint32(0xFFFFFFFF)   # some OUT
    m = rng.integers(0, 2**32 - 1, size=v, dtype=np.uint32)
    active = rng.random(v) < 0.9
    wl = np.full(v, v, dtype=np.int32)
    live = rng.permutation(v)[: v // 2].astype(np.int32)
    wl[: len(live)] = live
    return ell, jnp.asarray(t), jnp.asarray(m), jnp.asarray(active), \
        jnp.asarray(wl), len(live)


@pytest.mark.parametrize("count_frac", [1.0, 0.4])
def test_fused_refresh_columns_bit_exact(count_frac):
    from repro.core.tuples import id_bits
    from repro.kernels.minprop_ell.kernel import fused_refresh_columns_pallas
    from repro.kernels.minprop_ell.ref import fused_refresh_columns_ref

    ell, t, m, active, wl, nlive = _fused_inputs()
    count = max(1, int(nlive * count_frac))
    b = id_bits(ell.num_vertices)
    it = jnp.uint32(4)
    out_k = fused_refresh_columns_pallas(
        t, jnp.asarray(ell.neighbors).reshape(-1), wl,
        jnp.int32(count), it, priority="xorshift_star", b=b)
    out_r = fused_refresh_columns_ref(t, ell.neighbors, wl, count, it,
                                      "xorshift_star", b)
    assert (np.asarray(out_k)[:count] == np.asarray(out_r)[:count]).all()


@pytest.mark.parametrize("count_frac", [1.0, 0.4])
def test_fused_decide_bit_exact(count_frac):
    from repro.core.tuples import id_bits
    from repro.kernels.minprop_ell.kernel import fused_decide_pallas
    from repro.kernels.minprop_ell.ref import fused_decide_ref

    ell, t, m, active, wl, nlive = _fused_inputs(seed=8)
    count = max(1, int(nlive * count_frac))
    b = id_bits(ell.num_vertices)
    it = jnp.uint32(2)
    out_k = fused_decide_pallas(
        t, m, active, jnp.asarray(ell.neighbors).reshape(-1), wl,
        jnp.int32(count), it, priority="xorshift_star", b=b)
    out_r = fused_decide_ref(t, m, active, ell.neighbors, wl, count, it,
                             "xorshift_star", b)
    assert (np.asarray(out_k)[:count] == np.asarray(out_r)[:count]).all()


def test_ell_row_traffic_model():
    """The fused passes read each live row's ELL entries exactly once and
    materialize no worklist copy; the host-driven pipeline moves the same
    row data through HBM three times per pass."""
    from repro.kernels.minprop_ell import ops

    assert ops.ELL_ROW_TRAFFIC["pallas_resident"] == {"reads": 1, "writes": 0}
    assert ops.ell_row_movements("pallas") == 3 * ops.ell_row_movements(
        "pallas_resident")


def test_fused_wrappers_take_indices_not_gathered_rows():
    """Structural guarantee behind the traffic model: the fused kernels
    consume worklist indices + the flat adjacency (in-kernel gather), not
    pre-gathered ``[W, D]`` row copies like the legacy pair."""
    import inspect

    from repro.kernels.minprop_ell import kernel

    legacy = inspect.signature(kernel.refresh_columns_pallas)
    fused = inspect.signature(kernel.fused_refresh_columns_pallas)
    assert "wl_neighbors" in legacy.parameters        # the [W, D] copy
    assert "wl_neighbors" not in fused.parameters
    assert {"nbrs_flat", "wl"} <= set(fused.parameters)


# ---------------------------------------------------------------------------
# facade default selection + resident reuse in coloring/coarsening
# ---------------------------------------------------------------------------

def test_default_engine_rule(monkeypatch):
    from repro.api import backend as backend_mod

    monkeypatch.setattr(backend_mod, "accelerator_present", lambda: False)
    assert backend_mod.default_mis2_engine() == "compacted"
    assert backend_mod.default_mis2_engine(Backend(pallas=True)) == "pallas"
    monkeypatch.setattr(backend_mod, "accelerator_present", lambda: True)
    assert backend_mod.default_mis2_engine() == "compacted_resident"
    assert backend_mod.default_mis2_engine(
        Backend(pallas=True)) == "pallas_resident"


def test_default_engine_rule_is_total_over_options(monkeypatch):
    """The worklists=False ablation must auto-select the host-driven
    driver (which supports it) instead of raising, even on accelerators."""
    from repro.api import backend as backend_mod

    g = graph_cases()["laplace3d"]
    opts = Mis2Options(worklists=False)
    monkeypatch.setattr(backend_mod, "accelerator_present", lambda: True)
    assert backend_mod.default_mis2_engine(options=opts) == "compacted"
    r = mis2(g, options=opts)           # engine=None must not raise
    assert r.engine == "compacted" and r.converged


def test_legacy_worklists_reconverted_fresh_per_iteration():
    """The pad cache must never hand back an aliased staging buffer:
    wl1/wl2 of the same bucket size must be independent device arrays
    (jnp.asarray of an aligned numpy buffer can be zero-copy on CPU)."""
    from repro.core.mis2 import _WorklistPadCache

    pads = _WorklistPadCache(4096)
    a = pads.pad(np.arange(3000, dtype=np.int32))        # bucket 4096
    b = pads.pad(np.arange(4000, dtype=np.int32))        # same bucket
    assert (np.asarray(a)[:3000] == np.arange(3000)).all()
    assert (np.asarray(a)[3000:] == 4096).all()          # not b's contents
    assert (np.asarray(b)[:4000] == np.arange(4000)).all()


def test_facade_default_resolves_resident_on_accelerator(monkeypatch):
    from repro.api import backend as backend_mod

    g = graph_cases()["laplace3d"]
    base = mis2(g)                       # CPU host: host-driven default
    assert base.engine == "compacted"
    monkeypatch.setattr(backend_mod, "accelerator_present", lambda: True)
    r = mis2(g)
    assert r.engine == "compacted_resident"
    assert r.digest == base.digest       # the rule never changes results


def test_explicit_engine_still_honored():
    g = graph_cases()["laplace3d"]
    assert mis2(g, engine="dense").engine == "dense"
    assert mis2(g, engine="compacted_resident").engine == "compacted_resident"


def test_coarsen_inner_resident_engine_matches():
    g = graph_cases()["er_random"]
    a = coarsen(g, mis2_engine="compacted")
    b = coarsen(g, mis2_engine="compacted_resident")
    assert a.digest == b.digest
    assert (a.phase == b.phase).all() and (a.roots == b.roots).all()


def test_color_resident_loop_matches_legacy_rounds():
    """The coloring round loop is now one jitted while_loop; results and
    the do-while round count must match the old host-driven loop."""
    g = graph_cases()["er_random"]
    r = color(g)
    assert r.converged and r.num_colors > 0
    # rerun: deterministic, and at least one round always runs
    r2 = color(g)
    assert r2.digest == r.digest and r2.rounds == r.rounds >= 1
