"""Degree-aware hybrid layout (ISSUE 10): sliced-ELL + COO spill layout
invariants, digest parity of the ``pallas_hybrid`` MIS-2 engine (and the
hybrid coloring / coarsening paths) with the monolithic ELL engines across
priorities and adversarial degree distributions, the ELL byte-budget guard
and auto-selection rule, the row-traffic model, and the serve-side
``LayoutInfeasible`` admission shed."""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import verify_mis2
from repro import obs
from repro.api import Graph, Mis2Options, coarsen, color, mis2
from repro.graphs import (
    HybridEllGraph,
    LayoutOverflowError,
    csr_from_coo,
    csr_to_hybrid_ell,
    laplace3d,
    powerlaw_graph,
    random_uniform_graph,
)
from repro.graphs import hybrid as hybrid_mod

PRIORITIES = ("fixed", "xorshift", "xorshift_star")


def graph_cases():
    return {
        "laplace3d": Graph(laplace3d(8).graph),            # bounded degree
        "er_random": Graph(random_uniform_graph(600, 5.0, seed=21)),
        "powerlaw": Graph(powerlaw_graph(900, 8.0, seed=4)),
    }


# ---------------------------------------------------------------------------
# layout invariants
# ---------------------------------------------------------------------------

def test_hybrid_partition_disjoint_and_covering():
    for name, g in graph_cases().items():
        hyb = g.hybrid()
        owned = np.concatenate(
            [np.asarray(sl.rows) for sl in hyb.slices]
            + [np.asarray(hyb.spill_rows)])
        assert len(owned) == g.num_vertices, name
        assert len(np.unique(owned)) == g.num_vertices, name


def test_hybrid_slab_content_matches_csr():
    g = graph_cases()["powerlaw"]
    indptr = np.asarray(g.csr.indptr)
    indices = np.asarray(g.csr.indices)
    hyb = g.hybrid()
    for sl in hyb.slices:
        rows = np.asarray(sl.rows)
        nbrs = np.asarray(sl.neighbors)
        mask = np.asarray(sl.mask)
        for j in (0, len(rows) // 2, len(rows) - 1):
            r = rows[j]
            want = indices[indptr[r]:indptr[r + 1]]
            assert np.array_equal(nbrs[j][mask[j]], want), (sl.width, r)
            # padding holds the row's own id (inert under closed reductions)
            assert (nbrs[j][~mask[j]] == r).all()
    # spill holds the heavy rows, CSR order
    seg = np.asarray(hyb.spill_seg)
    cols = np.asarray(hyb.spill_cols)
    for i, r in enumerate(np.asarray(hyb.spill_rows)):
        want = indices[indptr[r]:indptr[r + 1]]
        assert np.array_equal(cols[seg == i], want)
        assert len(want) > hyb.spill_cap


def test_hybrid_empty_buckets_skipped_and_widths_ascend():
    # bounded-degree mesh: exactly the buckets with rows, no spill
    g = graph_cases()["laplace3d"]
    hyb = g.hybrid()
    assert hyb.num_spill_rows == 0
    widths = hyb.slice_widths
    assert widths == tuple(sorted(widths))
    assert all(sl.num_rows > 0 for sl in hyb.slices)


def test_hybrid_forced_spill_lone_max_degree_row():
    g = graph_cases()["er_random"]
    deg = np.diff(np.asarray(g.csr.indptr))
    second = int(np.sort(deg)[-2])
    hyb = g.hybrid(spill_cap=max(second, hybrid_mod.MIN_SLICE_WIDTH))
    if deg.max() > max(second, hybrid_mod.MIN_SLICE_WIDTH):
        assert hyb.num_spill_rows == 1
        assert int(np.asarray(hyb.spill_rows)[0]) == int(deg.argmax())
    r = mis2(g, engine="pallas_hybrid")
    ref = mis2(g, engine="dense")
    assert r.digest == ref.digest


def test_hybrid_explicit_widths_must_cover():
    g = graph_cases()["er_random"]
    with pytest.raises(ValueError, match="do not cover"):
        csr_to_hybrid_ell(g.csr, widths=(4,), spill_cap=10_000)


def test_hybrid_single_vertex_graph():
    g = Graph(csr_from_coo(np.array([0]), np.array([0]), 1))
    hyb = g.hybrid()
    assert isinstance(hyb, HybridEllGraph)
    r = mis2(g, engine="pallas_hybrid")
    assert r.in_set.tolist() == [True]
    assert r.digest == mis2(g, engine="dense").digest


def test_hybrid_handle_caches_conversion():
    g = graph_cases()["er_random"]
    assert g.hybrid() is g.hybrid()
    assert g.hybrid(spill_cap=64) is not g.hybrid()


# ---------------------------------------------------------------------------
# digest-parity matrix: pallas_hybrid vs dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("priority", PRIORITIES)
@pytest.mark.parametrize("gname", ["laplace3d", "er_random", "powerlaw"])
def test_hybrid_parity(gname, priority):
    g = graph_cases()[gname]
    opts = Mis2Options(priority=priority)
    ref = mis2(g, options=opts, engine="dense")
    r = mis2(g, options=opts, engine="pallas_hybrid")
    assert r.digest == ref.digest, (gname, priority)
    assert r.iterations == ref.iterations, (gname, priority)
    assert r.converged
    verify_mis2(g.csr, r.in_set)


def test_hybrid_parity_active_mask():
    g = graph_cases()["powerlaw"]
    active = np.random.default_rng(2).random(g.num_vertices) < 0.6
    a = mis2(g, active=active, engine="dense")
    b = mis2(g, active=active, engine="pallas_hybrid")
    assert a.digest == b.digest
    assert not b.in_set[~active].any()


def test_hybrid_zero_active():
    g = graph_cases()["er_random"]
    active = np.zeros(g.num_vertices, dtype=bool)
    r = mis2(g, active=active, engine="pallas_hybrid")
    assert not r.in_set.any()
    assert r.converged


def test_hybrid_rejects_incompatible_options():
    g = graph_cases()["er_random"]
    with pytest.raises(ValueError, match="worklist"):
        mis2(g, options=Mis2Options(worklists=False), engine="pallas_hybrid")
    with pytest.raises(ValueError, match="packed"):
        mis2(g, options=Mis2Options(packed=False), engine="pallas_hybrid")


# ---------------------------------------------------------------------------
# coloring + coarsening over the hybrid layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname", ["laplace3d", "powerlaw"])
def test_hybrid_coloring_parity(gname):
    g = graph_cases()[gname]
    a = color(g, engine="luby")
    b = color(g, engine="luby_hybrid")
    assert np.array_equal(a.colors, b.colors), gname
    assert a.num_colors == b.num_colors
    assert a.rounds == b.rounds


@pytest.mark.parametrize("method", ["basic", "two_phase"])
def test_hybrid_coarsen_parity(method):
    g = graph_cases()["powerlaw"]
    a = coarsen(g, method=method, mis2_engine="dense")
    b = coarsen(g, method=method, mis2_engine="pallas_hybrid")
    assert np.array_equal(a.labels, b.labels), method
    assert a.num_aggregates == b.num_aggregates
    assert np.array_equal(a.roots, b.roots)
    assert np.array_equal(a.phase, b.phase)


# ---------------------------------------------------------------------------
# byte budget, typed overflow, auto-selection
# ---------------------------------------------------------------------------

def test_ell_bytes_estimate():
    g = graph_cases()["powerlaw"]
    assert g.ell_bytes_estimate() == (g.num_vertices * g.max_degree
                                      * hybrid_mod.ELL_BYTES_PER_SLOT)


def test_layout_overflow_error(monkeypatch):
    g = graph_cases()["powerlaw"]
    monkeypatch.setattr(hybrid_mod, "ELL_BYTE_LIMIT",
                        g.ell_bytes_estimate() - 1)
    fresh = Graph(g.csr)                   # uncached handle
    with pytest.raises(LayoutOverflowError, match="pallas_hybrid") as ei:
        fresh.ell
    assert ei.value.estimate == g.ell_bytes_estimate()
    with pytest.raises(LayoutOverflowError):
        fresh.padded_ell(g.num_vertices, g.max_degree)
    # the degree-aware path still works on the same handle
    r = mis2(fresh, engine="pallas_hybrid")
    assert r.converged


def test_auto_selection_prefers_hybrid(monkeypatch):
    g = graph_cases()["powerlaw"]
    monkeypatch.setattr(hybrid_mod, "HYBRID_AUTO_BYTES",
                        g.ell_bytes_estimate() - 1)
    r = mis2(g)                            # engine=None -> auto
    assert r.engine == "pallas_hybrid"
    assert r.digest == mis2(g, engine="dense").digest
    # worklists=False ablation must keep the host-driven default
    r2 = mis2(g, options=Mis2Options(worklists=False))
    assert r2.engine != "pallas_hybrid"


def test_auto_selection_keeps_default_below_threshold(monkeypatch):
    g = graph_cases()["er_random"]
    monkeypatch.setattr(hybrid_mod, "HYBRID_AUTO_BYTES",
                        g.ell_bytes_estimate() + 1)
    assert mis2(g).engine != "pallas_hybrid"


# ---------------------------------------------------------------------------
# power-law generator
# ---------------------------------------------------------------------------

def test_powerlaw_deterministic_and_canonical():
    a = powerlaw_graph(2000, 8.0, seed=13)
    b = powerlaw_graph(2000, 8.0, seed=13)
    assert np.array_equal(np.asarray(a.indptr), np.asarray(b.indptr))
    assert np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
    c = powerlaw_graph(2000, 8.0, seed=14)
    assert not np.array_equal(np.asarray(a.indices), np.asarray(c.indices))
    # symmetric with a full diagonal (the repo-wide self-loop invariant)
    import scipy.sparse as sp
    ip, ix = np.asarray(a.indptr), np.asarray(a.indices)
    m = sp.csr_matrix((np.ones(len(ix)), ix, ip), shape=(2000, 2000))
    assert (m != m.T).nnz == 0
    assert (m.diagonal() == 1).all()


def test_powerlaw_degree_skew():
    g = powerlaw_graph(5000, 8.0, exponent=2.5, seed=3)
    deg = np.diff(np.asarray(g.indptr))
    # hub far above the mean: the regime where padded ELL explodes
    assert deg.max() > 20 * deg.mean()
    # ...but most rows stay near the mean (sliced ELL stays compact)
    assert np.percentile(deg, 95) < 8 * deg.mean()


# ---------------------------------------------------------------------------
# traffic model + execution shape
# ---------------------------------------------------------------------------

def test_hybrid_traffic_registry_matches_model():
    from repro.kernels.minprop_ell.ops import (
        ELL_ROW_TRAFFIC,
        hybrid_row_traffic_bytes,
    )

    assert "pallas_hybrid" in ELL_ROW_TRAFFIC
    g = graph_cases()["powerlaw"]
    mis2(g, engine="pallas_hybrid")        # warm
    with obs.capture() as cap:
        r = mis2(g, engine="pallas_hybrid")
    c = r.collectives
    want = hybrid_row_traffic_bytes(c["slice_widths"],
                                    c["slice_rows_processed"],
                                    c["spill_entries"], c["spill_passes"])
    assert cap.value("mis2.hybrid_row_bytes") == want == c["row_bytes_total"]
    assert cap.value("mis2.resident_dispatches") == 1
    assert cap.value("mis2.host_syncs") == 0
    assert r.num_compiles == 1


# ---------------------------------------------------------------------------
# serve: layout-infeasible admission shed
# ---------------------------------------------------------------------------

def test_serve_sheds_layout_infeasible(monkeypatch):
    from repro.serve import LayoutInfeasible, Server

    g = graph_cases()["powerlaw"]
    monkeypatch.setattr(hybrid_mod, "ELL_BYTE_LIMIT",
                        g.ell_bytes_estimate() - 1)
    monkeypatch.setattr(hybrid_mod, "HYBRID_AUTO_BYTES",
                        g.ell_bytes_estimate() // 2)
    srv = Server()
    try:
        with obs.capture() as cap:
            fut = srv.submit("mis2", g, engine="dense")
            with pytest.raises(LayoutInfeasible) as ei:
                fut.result(timeout=30)
        assert ei.value.reason == "layout"
        assert not ei.value.retryable
        assert cap.value("serve.shed", {"reason": "layout"}) == 1
        # degree-aware engines pass admission and serve correctly
        r = srv.request("mis2", g)
        assert r.engine == "pallas_hybrid"
        r2 = srv.request("mis2", g, engine="pallas_hybrid")
        assert r.digest == r2.digest
    finally:
        srv.stop()
