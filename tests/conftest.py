import os
import sys

# tests run single-device (the dry-run sets its own XLA_FLAGS in a subprocess)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.graphs import graph_power2  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def verify_mis2(graph, in_set: np.ndarray) -> None:
    """Independence + maximality via G^2 (paper Lemma IV.2)."""
    g2 = graph_power2(graph)
    indptr = np.asarray(g2.indptr)
    indices = np.asarray(g2.indices)
    v = len(indptr) - 1
    rows = np.repeat(np.arange(v), np.diff(indptr))
    bad = in_set[rows] & in_set[indices] & (rows != indices)
    assert not bad.any(), "distance-2 independence violated"
    covered = np.zeros(v, dtype=bool)
    np.logical_or.at(covered, rows, in_set[indices])
    covered |= in_set
    assert covered.all(), "maximality violated"
