"""Solver substrate: CG/GMRES correctness, SA-AMG, cluster/point SGS."""
import numpy as np
import jax.numpy as jnp
import pytest
import scipy.sparse.linalg as spla

from repro.graphs import csr_to_ell_matrix, laplace3d, matrix_to_scipy
from repro.graphs.ops import spmv_ell
from repro.solvers import (
    build_hierarchy,
    cg,
    gmres,
    setup_cluster_gs,
    setup_point_gs,
    v_cycle,
)


@pytest.fixture(scope="module")
def system():
    a = laplace3d(10)
    ell = csr_to_ell_matrix(a)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(a.num_rows).astype(np.float32))
    x_ref = spla.spsolve(matrix_to_scipy(a).tocsc(),
                         np.asarray(b, dtype=np.float64))
    return a, ell, b, x_ref


def test_cg_matches_direct(system):
    a, ell, b, x_ref = system
    res = cg(lambda x: spmv_ell(ell, x), b, tol=1e-10, maxiter=2000)
    assert res.converged
    assert np.linalg.norm(res.x - x_ref) / np.linalg.norm(x_ref) < 1e-4


def test_gmres_matches_direct(system):
    a, ell, b, x_ref = system
    res = gmres(lambda x: spmv_ell(ell, x), b, tol=1e-8, maxiter=600)
    assert res.converged
    assert np.linalg.norm(res.x - x_ref) / np.linalg.norm(x_ref) < 1e-4


@pytest.mark.parametrize("agg", ["mis2_basic", "mis2_agg", "serial"])
def test_amg_preconditioned_cg(system, agg):
    a, ell, b, x_ref = system
    h = build_hierarchy(a, aggregation=agg, coarse_size=100)
    res = cg(lambda x: spmv_ell(ell, x), b, precond=h.as_precond(),
             tol=1e-10, maxiter=200)
    assert res.converged
    # AMG must beat plain CG on iterations
    plain = cg(lambda x: spmv_ell(ell, x), b, tol=1e-10, maxiter=2000)
    assert res.iterations < plain.iterations


def test_amg_vcycle_reduces_error(system):
    a, ell, b, _ = system
    h = build_hierarchy(a, aggregation="mis2_agg", coarse_size=100)
    x = v_cycle(h, b)
    r0 = float(jnp.linalg.norm(b))
    r1 = float(jnp.linalg.norm(b - spmv_ell(ell, x)))
    assert r1 < 0.5 * r0


@pytest.mark.parametrize("setup", [setup_point_gs, setup_cluster_gs])
def test_multicolor_sgs_preconditioner(system, setup):
    a, ell, b, x_ref = system
    pre = setup(a)
    # fp32 preconditioner apply floors the achievable relative residual
    res = gmres(lambda x: spmv_ell(ell, x), b,
                precond=pre.as_precond(sweeps=1, symmetric=True),
                tol=1e-6, maxiter=600)
    assert res.converged
    plain = gmres(lambda x: spmv_ell(ell, x), b, tol=1e-6, maxiter=600)
    assert res.iterations <= plain.iterations


def test_cluster_no_worse_than_point(system):
    """Paper Table VI: cluster SGS needs <= point SGS iterations (~5%)."""
    a, ell, b, _ = system
    it = {}
    for name, setup in (("point", setup_point_gs),
                        ("cluster", setup_cluster_gs)):
        pre = setup(a)
        r = gmres(lambda x: spmv_ell(ell, x), b,
                  precond=pre.as_precond(sweeps=1, symmetric=True),
                  tol=1e-6, maxiter=600)
        it[name] = r.iterations
    assert it["cluster"] <= it["point"] * 1.1


def test_gs_sweep_is_exact_gauss_seidel():
    """One cluster-GS sweep with a single color+cluster == sequential GS."""
    a = laplace3d(4)
    ell = csr_to_ell_matrix(a)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(a.num_rows).astype(np.float32)
    # reference sequential GS from x0=0
    asp = matrix_to_scipy(a).toarray()
    x_ref = np.zeros(a.num_rows)
    for i in range(a.num_rows):
        x_ref[i] = (b[i] - asp[i] @ x_ref) / asp[i, i] + x_ref[i] * 0
        # classic GS update: x_i = (b_i - sum_{j != i} a_ij x_j)/a_ii
        x_ref[i] = (b[i] - asp[i] @ x_ref + asp[i, i] * x_ref[i]) / asp[i, i]
    from repro.solvers.multicolor_gs import MulticolorGSPreconditioner
    from repro.graphs.ops import extract_diagonal
    rows = jnp.asarray(np.arange(a.num_rows, dtype=np.int32)[None, :])
    pre = MulticolorGSPreconditioner(
        ell, extract_diagonal(a), (rows,), 1, 1, 0.0, "cluster")
    x = pre.apply(jnp.asarray(b), sweeps=1, symmetric=False)
    np.testing.assert_allclose(np.asarray(x), x_ref, rtol=1e-4, atol=1e-5)
