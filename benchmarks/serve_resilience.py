"""repro.serve resilience: shed-mode latency, fault-rate sweeps, restart.

Three measurements of the hardened serving layer:

* **overload** — a burst far past batched capacity, served (a) by an
  unbounded queue (the pre-hardening execution model: everything is
  admitted, p99 grows with the backlog) and (b) under admission control
  (bounded queue + deadline-aware shedding: excess requests fail fast
  with typed errors and the p99 of *served* requests stays bounded by
  the queue depth, not the offered load).  The run asserts shed-mode
  p99 <= unbounded p99 and that every served result is digest-correct.
* **faults** — a seeded fault-rate sweep on the engine site (transient
  errors, retried with zero backoff): throughput vs injected fault rate,
  with every response digest-asserted against the direct referent — the
  cost of resilience, with proof it never trades away correctness.
* **persist** — cold compute vs restart-rehydration from the
  digest-verified disk tier (same workload, fresh server on the same
  directory): the restart serves entirely from disk (0 dispatches,
  0 corrupt entries served).

Headline metrics append to ``BENCH_serve_resilience.json`` at the repo
root via ``emit_trajectory``.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

import repro
from repro.graphs import laplace3d, random_uniform_graph
from repro.serve import (Fault, FaultPlan, QuotaConfig, RetryPolicy,
                         ServeError, Server, ServerConfig, warm_buckets_for)

from .common import emit, emit_trajectory


def _pool(quick: bool):
    """Digest-distinct small graphs — the serving regime (request-rate
    bound, not solve-bound)."""
    if quick:
        meshes, uniforms = (4, 5), ((150, 5.0), (250, 6.0))
    else:
        meshes, uniforms = (5, 6, 8), ((400, 6.0), (800, 8.0), (250, 5.0))
    graphs = [repro.Graph(laplace3d(n)) for n in meshes]
    graphs += [repro.Graph(random_uniform_graph(v, d, seed=i))
               for i, (v, d) in enumerate(uniforms)]
    return graphs


def _burst(server, graphs, n_requests):
    """Submit a burst, wait everything out; returns (latencies of served
    requests in seconds, shed count, digest-ok bool)."""
    referents = {g.digest: repro.mis2(g).digest for g in graphs}
    records = []
    for i in range(n_requests):
        g = graphs[i % len(graphs)]
        t0 = time.perf_counter()
        fut = server.submit("mis2", g)
        records.append((g, t0, fut))
    served, shed, ok = [], 0, True
    for g, t0, fut in records:
        try:
            res = fut.result(timeout=300)
        except ServeError:
            shed += 1
            continue
        served.append(time.perf_counter() - t0)
        ok = ok and (res.digest == referents[g.digest])
    return served, shed, ok


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def run(quick: bool = False) -> None:
    graphs = _pool(quick)
    warm = warm_buckets_for(graphs)
    n_burst = 48 if quick else 160
    rows = []

    # --- overload: unbounded backlog vs admission-controlled shedding ----
    base = dict(max_batch=8, max_delay_s=0.002, warm_buckets=warm,
                cache_bytes=0, dedup=False, poll_interval_s=0.0005)
    with Server(ServerConfig(**base)) as srv:
        lat_u, shed_u, ok_u = _burst(srv, graphs, n_burst)
    assert ok_u and shed_u == 0
    p99_unbounded = _percentile(lat_u, 99)

    with Server(ServerConfig(**base, max_pending=8,
                             quota=QuotaConfig(rate=1e6, burst=1e6))) as srv:
        lat_s, shed_s, ok_s = _burst(srv, graphs, n_burst)
        shed_stats = srv.server_stats()
    assert ok_s, "shed-mode served a digest-incorrect result"
    assert shed_s > 0, "overload burst was never shed; raise n_burst"
    p99_shed = _percentile(lat_s, 99)
    assert p99_shed <= p99_unbounded, (
        f"shed-mode p99 {p99_shed:.4f}s exceeds unbounded {p99_unbounded:.4f}s")
    rows.append({"section": "overload", "variant": "unbounded",
                 "seconds": p99_unbounded, "served": len(lat_u), "shed": 0,
                 "p50_s": round(_percentile(lat_u, 50), 6)})
    rows.append({"section": "overload", "variant": "admission",
                 "seconds": p99_shed, "served": len(lat_s), "shed": shed_s,
                 "p50_s": round(_percentile(lat_s, 50), 6)})

    # --- faults: throughput vs seeded transient fault rate ---------------
    fault_rows = []
    referents = {g.digest: repro.mis2(g).digest for g in graphs}
    n_fault = 24 if quick else 60
    for rate in (0.0, 0.25, 0.5):
        plan = None
        if rate > 0.0:
            plan = FaultPlan(seed=11, sites={
                "engine": Fault("error", rate=rate, transient=True)})
        srv = Server(ServerConfig(
            max_batch=8, max_delay_s=0.0, warm_buckets=warm, cache_bytes=0,
            dedup=False, faults=plan,
            retry=RetryPolicy(max_attempts=3, base_backoff_s=0.0)))
        t0 = time.perf_counter()
        futs = [(graphs[i % len(graphs)],
                 srv.submit("mis2", graphs[i % len(graphs)]))
                for i in range(n_fault)]
        srv.flush()
        dt = time.perf_counter() - t0
        for g, fut in futs:
            assert fut.result().digest == referents[g.digest], (
                f"fault rate {rate}: digest-incorrect response")
        st = srv.server_stats()
        srv.stop()
        fault_rows.append({"section": "faults", "rate": rate,
                           "seconds": dt, "rps": round(n_fault / dt, 1),
                           "retries": st["retries"],
                           "fallbacks": st["fallbacks"]})
    rows += fault_rows

    # --- persist: cold compute vs restart rehydration --------------------
    tier_dir = tempfile.mkdtemp(prefix="repro_serve_tier_")
    try:
        srv = Server(ServerConfig(max_delay_s=0.0, persist_dir=tier_dir))
        t0 = time.perf_counter()
        for g in graphs:
            assert srv.request("mis2", g).digest == referents[g.digest]
        cold_s = time.perf_counter() - t0
        srv.stop()

        srv2 = Server(ServerConfig(max_delay_s=0.0, persist_dir=tier_dir))
        t0 = time.perf_counter()
        for g in graphs:
            assert srv2.request("mis2", g).digest == referents[g.digest]
        rehydrated_s = time.perf_counter() - t0
        persist_stats = srv2.persist.stats.as_dict()
        assert srv2.stats.dispatches == 0, "restart recomputed instead of " \
            "rehydrating"
        assert persist_stats["corrupt"] == 0
        srv2.stop()
    finally:
        shutil.rmtree(tier_dir, ignore_errors=True)
    rows.append({"section": "persist", "variant": "cold", "seconds": cold_s,
                 "graphs": len(graphs)})
    rows.append({"section": "persist", "variant": "rehydrated",
                 "seconds": rehydrated_s, "graphs": len(graphs),
                 "speedup": round(cold_s / max(rehydrated_s, 1e-9), 1)})

    fieldnames = []
    for r in rows:
        fieldnames += [k for k in r if k not in fieldnames]
    rows = [{k: r.get(k, "") for k in fieldnames} for r in rows]
    emit("serve_resilience", rows)
    emit_trajectory("serve_resilience", {
        "quick": quick,
        "burst_requests": n_burst,
        "p99_unbounded_s": round(p99_unbounded, 6),
        "p99_shed_s": round(p99_shed, 6),
        "shed_count": shed_s,
        "served_under_admission": len(lat_s),
        "shed_counters": {"shed": shed_stats["shed"],
                          "expired": shed_stats["expired"]},
        "fault_sweep": [{"rate": r["rate"], "rps": r["rps"],
                         "retries": r["retries"],
                         "fallbacks": r["fallbacks"]} for r in fault_rows],
        "persist_cold_s": round(cold_s, 6),
        "persist_rehydrated_s": round(rehydrated_s, 6),
        "persist_stats": persist_stats,
    })


if __name__ == "__main__":
    from .common import standalone

    standalone(run)
