"""Paper Table III: MIS-2 size and iteration count on structured problems.

This is an *exact* reproduction (same Galeri-style generators): the paper's
own numbers are listed beside ours.
"""
from __future__ import annotations

from repro.api import Graph, mis2
from repro.graphs import elasticity3d, laplace3d

from benchmarks.common import emit, timeit

PAPER = {
    ("laplace", (50, 50, 50)): (11469, 9),
    ("laplace", (100, 50, 50)): (22909, 9),
    ("laplace", (100, 100, 50)): (45333, 9),
    ("laplace", (100, 100, 100)): (90041, 10),
    ("elasticity", (30, 30, 30)): (634, 8),
    ("elasticity", (60, 30, 30)): (1291, 10),
    ("elasticity", (60, 60, 30)): (2454, 10),
    ("elasticity", (60, 60, 60)): (4833, 10),
}


def run(quick: bool = False):
    cases = [("laplace", (50, 50, 50)), ("laplace", (100, 100, 100)),
             ("elasticity", (30, 30, 30))]
    if not quick:
        cases += [("laplace", (100, 50, 50)), ("laplace", (100, 100, 50)),
                  ("elasticity", (60, 30, 30)), ("elasticity", (60, 60, 30))]
    rows = []
    for kind, dims in cases:
        g = Graph((laplace3d(*dims) if kind == "laplace"
                   else elasticity3d(*dims)).graph)
        r = mis2(g)
        t = timeit(lambda: mis2(g), repeats=1)
        psize, piters = PAPER[(kind, dims)]
        rows.append({
            "problem": f"{kind} {'x'.join(map(str, dims))}",
            "V": g.num_vertices,
            "mis2_size": r.size, "iters": r.iterations,
            "paper_size": psize, "paper_iters": piters,
            "size_ratio_vs_paper": round(r.size / psize, 4),
            "seconds": t, "us_per_call": t * 1e6,
        })
    emit("table3_scaling", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import standalone

    standalone(run)
