"""Paper Fig. 2: cumulative speedup of the four algorithmic optimizations
over the Bell-baseline implementation (CPU wall clock; the paper's V100
absolute numbers do not transfer, the cumulative ordering is the claim).

Chain: baseline(Bell) -> +rand_priority -> +worklists -> +packed_status ->
+simd_ell (== production defaults).

Runs entirely against the ``repro.api`` facade; the shared ``Graph``
handles from ``bench_suite`` cache the ELL/CSR/edge-list conversions so
the five ablation variants measure the solve, not format churn.
"""
from __future__ import annotations

from repro.api import ABLATION_CHAIN, mis2

from benchmarks.common import bench_suite, emit, timeit


def run(quick: bool = False):
    rows = []
    suite = bench_suite("quick" if quick else "bench")
    for name, g in suite.items():
        base_t = None
        for impl, opts in ABLATION_CHAIN.items():
            t = timeit(lambda: mis2(g, options=opts), repeats=2 if quick else 3)
            if base_t is None:
                base_t = t
            rows.append({
                "graph": name, "impl": impl, "seconds": t,
                "speedup_vs_baseline": round(base_t / t, 3),
                "us_per_call": t * 1e6,
            })
    emit("fig2_optimizations", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import standalone

    standalone(run)
