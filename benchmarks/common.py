"""Shared benchmark utilities: timing, CSV/JSON emission, graph suite."""
from __future__ import annotations

import csv
import json
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
ARTIFACTS = REPO_ROOT / "artifacts" / "bench"


def timeit(fn, repeats: int = 3):
    """Best-of-N wall time in seconds (first call may include compile)."""
    fn()  # warmup/compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, rows: list[dict]):
    """Write artifacts/bench/<name>.csv and print `name,us_per_call,derived`
    CSV lines to stdout (harness contract)."""
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / f"{name}.csv"
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    for r in rows:
        us = r.get("us_per_call", r.get("seconds", 0) * 1e6)
        derived = {k: v for k, v in r.items()
                   if k not in ("us_per_call", "seconds")}
        print(f"{name},{us:.1f},{derived}")
    return path


def emit_trajectory(name: str, record: dict) -> Path:
    """Append one timestamped record to ``artifacts/bench/BENCH_<name>.json``
    and mirror the full history to ``BENCH_<name>.json`` at the repo root
    (the root copy is the committed, regression-checked trajectory).

    The trajectory is a JSON list, one entry per benchmark run, so headline
    metrics (e.g. batched graphs/sec) accumulate across commits and can be
    plotted or regression-checked without re-parsing per-run CSVs.

    Every record is stamped with a ``"metrics"`` snapshot of the process
    ``repro.obs`` registry (flat ``name{labels} -> value``) unless the
    caller already supplied one — execution shape (dispatches, syncs,
    compiles, cache traffic, collective bytes) travels with the timing it
    explains, and ``benchmarks.run --quick`` gates on its presence."""
    if "metrics" not in record:
        from repro import obs

        record = {**record, "metrics": obs.snapshot().flat()}
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / f"BENCH_{name}.json"
    root = REPO_ROOT / f"BENCH_{name}.json"
    # artifacts/ is gitignored while the root mirror is committed, so the
    # two copies can disagree (fresh clone: no artifacts copy; local runs
    # vs. pulled teammate entries after a fetch).  Merge both histories:
    # distinct records survive from either side, exact duplicates collapse.
    merged: dict[str, dict] = {}
    for p in (path, root):
        if p.exists():
            for entry in json.loads(p.read_text()):
                merged[json.dumps(entry, sort_keys=True)] = entry
    history = sorted(merged.values(), key=lambda e: e.get("timestamp", ""))
    history.append({"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"), **record})
    payload = json.dumps(history, indent=2) + "\n"
    path.write_text(payload)
    root.write_text(payload)
    return path


def standalone(run_fn):
    """``python -m benchmarks.<name> [--quick]`` entry, identical to the
    corresponding ``benchmarks.run --only`` invocation.  (No PYTHONPATH
    needed: ``benchmarks/__init__.py`` bootstraps ``src`` before any
    benchmark module's top-level imports run.)"""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced problem sizes (CI)")
    run_fn(quick=ap.parse_args().quick)


def bench_suite(scale="bench"):
    """Graph suite standing in for the paper's 17 matrices (generated:
    SuiteSparse is unavailable offline — stated in EXPERIMENTS.md).

    Returns ``repro.api.Graph`` handles so repeated benchmarking of one
    graph reuses the cached ELL/CSR/edge-list formats instead of paying
    the conversion on every variant."""
    from repro.api import Graph
    from repro.graphs import (elasticity3d, laplace3d, random_skewed_graph,
                              random_uniform_graph)
    if scale == "quick":
        graphs = {
            "Laplace3D_16": laplace3d(16).graph,
            "Elasticity3D_6": elasticity3d(6).graph,
            "uniform_20k": random_uniform_graph(20_000, 8.0, seed=1),
            "skewed_20k": random_skewed_graph(20_000, 8.0, seed=2),
        }
    else:
        graphs = {
            "Laplace3D_32": laplace3d(32).graph,
            "Elasticity3D_12": elasticity3d(12).graph,
            "uniform_100k": random_uniform_graph(100_000, 8.0, seed=1),
            "skewed_100k": random_skewed_graph(100_000, 8.0, seed=2),
            "uniform_dense_50k": random_uniform_graph(50_000, 24.0, seed=3),
        }
    return {name: Graph(g) for name, g in graphs.items()}
