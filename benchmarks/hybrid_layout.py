"""Paper-scale hybrid-layout benchmark (ISSUE 10 tentpole metric).

Exercises the degree-aware hybrid layout (sliced-ELL + COO spill,
``graphs.hybrid``) in exactly the regime it exists for: a Chung-Lu
power-law graph whose hub row makes the monolithic padded-ELL layout
infeasible (``Graph.ell`` raises :class:`LayoutOverflowError` past
``ELL_BYTE_LIMIT``) while the total edge count stays modest.

Measured per scale:

* layout build — CSR -> hybrid conversion wall time plus the layout's own
  accounting (slice widths/rows, spill rows/entries, padded bytes vs. the
  monolithic estimate, padding ratio);
* MIS-2 (``engine="pallas_hybrid"``) — solve wall time, iterations, the
  §V-D row-traffic model bytes, and the compile accounting (the resident
  fixed point is ONE dispatch; jit churn is O(#slices), not O(graph));
* two-phase coarsening (``mis2_engine="pallas_hybrid"``) — end-to-end
  Algorithm 3 over the hybrid join loops: wall time, aggregate count,
  coarsening ratio.

Full mode runs V = 1M (the ISSUE 10 acceptance scale) and *asserts* the
monolithic padded-ELL is infeasible; ``--quick`` (the CI examples-smoke
lane) keeps the same shape at V = 20k, where the monolith still fits —
the record carries ``ell_infeasible`` so the trajectory distinguishes the
two regimes.  The headline record is appended to
``BENCH_hybrid_layout.json`` (root mirror committed).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, emit_trajectory, standalone, timeit


def run(quick: bool = False) -> None:
    from repro.api import Graph, coarsen, mis2
    from repro.graphs.generators import powerlaw_graph
    from repro.graphs.hybrid import ELL_BYTE_LIMIT, LayoutOverflowError

    if quick:
        v, repeats = 20_000, 3
    else:
        v, repeats = 1_000_000, 1

    t0 = time.perf_counter()
    g = Graph(powerlaw_graph(v, 8.0, exponent=2.5, seed=42))
    gen_s = time.perf_counter() - t0

    est = g.ell_bytes_estimate()
    infeasible = est > ELL_BYTE_LIMIT
    if not quick:
        # the acceptance regime: the monolithic layout must be refused
        assert infeasible, (
            f"V={v} power-law monolith estimate {est:,} B unexpectedly fits "
            f"the {ELL_BYTE_LIMIT:,} B budget — not the paper-scale regime")
    if infeasible:
        try:
            g.ell
        except LayoutOverflowError:
            pass
        else:
            raise AssertionError("Graph.ell materialized past ELL_BYTE_LIMIT")

    t0 = time.perf_counter()
    hyb = g.hybrid()
    build_s = time.perf_counter() - t0

    r = mis2(g, engine="pallas_hybrid")            # warmup/compile
    mis2_s = timeit(lambda: mis2(g, engine="pallas_hybrid"), repeats=repeats)
    c = r.collectives

    agg = coarsen(g, method="two_phase", mis2_engine="pallas_hybrid")
    coarsen_s = timeit(
        lambda: coarsen(g, method="two_phase", mis2_engine="pallas_hybrid"),
        repeats=repeats)

    layout = {
        "num_slices": hyb.num_slices,
        "slice_widths": list(hyb.slice_widths),
        "spill_rows": hyb.num_spill_rows,
        "spill_entries": hyb.num_spill_entries,
        "hybrid_bytes": hyb.padded_bytes,
        "monolith_ell_bytes_estimate": est,
        "padding_ratio": round(hyb.padding_ratio, 4),
    }
    rows = [
        {"stage": "generate", "seconds": gen_s, "V": v,
         "detail": f"entries={g.num_entries} max_degree={g.max_degree}"},
        {"stage": "hybrid_build", "seconds": build_s, "V": v,
         "detail": (f"slices={hyb.num_slices} spill_rows="
                    f"{hyb.num_spill_rows} padding_ratio="
                    f"{hyb.padding_ratio:.3f}")},
        {"stage": "mis2_hybrid", "seconds": mis2_s, "V": v,
         "detail": (f"iterations={r.iterations} compiles={r.num_compiles} "
                    f"row_bytes={c['row_bytes_total']}")},
        {"stage": "coarsen_two_phase_hybrid", "seconds": coarsen_s, "V": v,
         "detail": (f"aggregates={agg.num_aggregates} ratio="
                    f"{agg.coarsening_ratio:.2f}")},
    ]
    emit("hybrid_layout", rows)

    assert r.converged and agg.converged
    # compile accounting: the resident fixed point is one jitted dispatch,
    # so jit churn is bounded by the slice count, not the graph
    assert r.num_compiles <= hyb.num_slices + 1, (
        f"{r.num_compiles} compiles for {hyb.num_slices} slices")

    emit_trajectory("hybrid_layout", {
        "quick": quick,
        "V": v,
        "entries": int(g.num_entries),
        "max_degree": int(g.max_degree),
        "ell_infeasible": bool(infeasible),
        "layout": layout,
        "generate_s": round(gen_s, 4),
        "hybrid_build_s": round(build_s, 4),
        "mis2_s": round(mis2_s, 4),
        "mis2_iterations": int(r.iterations),
        "mis2_num_compiles": int(r.num_compiles),
        "mis2_row_bytes": int(c["row_bytes_total"]),
        "coarsen_s": round(coarsen_s, 4),
        "num_aggregates": int(agg.num_aggregates),
        "coarsening_ratio": round(agg.coarsening_ratio, 3),
    })


if __name__ == "__main__":
    standalone(run)
