"""Paper Table V: SA-AMG aggregation comparison on Laplace3D.

Serial (host-sequential greedy, the 'Serial Agg' stand-in) vs MIS2 Basic
(Alg. 2) vs MIS2 Agg (Alg. 3), each used to build the V-cycle hierarchy for
CG to 1e-12.  Claims validated: MIS2 Agg needs the fewest iterations of the
MIS-2 schemes (paper: 22 vs 49 for Basic) and all MIS-2 schemes are
deterministic.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.api import Graph, amg
from repro.graphs import laplace3d
from repro.graphs.ops import spmv_ell
from repro.solvers import cg

from benchmarks.common import emit


def run(quick: bool = False):
    n = 16 if quick else 32
    a = Graph(laplace3d(n))
    ell = a.ell_matrix
    b = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(a.num_vertices).astype(np.float32))
    mv = lambda x: spmv_ell(ell, x)  # noqa: E731
    rows = []
    for agg in ("serial", "basic", "two_phase"):
        h = amg(a, aggregation=agg, coarse_size=200)
        t0 = time.perf_counter()
        res = cg(mv, b, precond=h.as_precond(), tol=1e-10, maxiter=300)
        solve_s = time.perf_counter() - t0
        # determinism: rebuild + resolve must match iteration count
        h2 = amg(a, aggregation=agg, coarse_size=200)
        res2 = cg(mv, b, precond=h2.as_precond(), tol=1e-10, maxiter=300)
        rows.append({
            "aggregation": agg, "V": a.num_vertices,
            "cg_iters": res.iterations,
            "agg_seconds": round(h.aggregation_seconds, 3),
            "setup_seconds": round(h.setup_seconds, 3),
            "solve_seconds": round(solve_s, 3),
            "levels": len(h.level_sizes),
            "deterministic": int(res.iterations == res2.iterations),
            "converged": int(res.converged),
            "us_per_call": solve_s * 1e6,
        })
    emit("table5_amg", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import standalone

    standalone(run)
