"""Paper Table IV: MIS-2 set sizes across implementations agree closely.

Ours (Alg. 1, all optimizations) vs the Bell-style baseline (fixed
priorities, unpacked, no worklists) vs the dense jitted engine.
"""
from __future__ import annotations

from repro.api import ABLATION_CHAIN, mis2

from benchmarks.common import bench_suite, emit


def run(quick: bool = False):
    rows = []
    for name, g in bench_suite("quick" if quick else "bench").items():
        kk = mis2(g)                                           # production
        bell = mis2(g, options=ABLATION_CHAIN["baseline_bell"])
        dense = mis2(g, engine="dense")
        rows.append({
            "graph": name, "V": g.num_vertices,
            "kk_size": kk.size, "bell_size": bell.size,
            "dense_size": dense.size,
            "rel_spread": round(
                (max(kk.size, bell.size) - min(kk.size, bell.size))
                / max(1, kk.size), 4),
            "us_per_call": 0.0,
        })
    emit("table4_quality", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import standalone

    standalone(run)
