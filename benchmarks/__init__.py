"""Benchmark package: one module per paper table/figure.

Importing the package bootstraps ``src`` onto ``sys.path``, so
``python -m benchmarks.run`` and ``python -m benchmarks.<name>`` both work
without PYTHONPATH — the package import (and therefore this bootstrap)
runs before any benchmark module's top-level ``repro`` imports.
"""
import sys
from pathlib import Path

_SRC = str(Path(__file__).resolve().parents[1] / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
