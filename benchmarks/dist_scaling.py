"""Distributed MIS-2 scaling (the ROADMAP sharding axis made measurable).

Two measurements per run:

1. **Execution parity + wall time** — a subprocess forced to 8 host
   devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) runs
   both distributed engines against ``dense`` for V ∈ {1000, 1022, 997}
   (1022 pads to 1024 on 8 devices — the power-of-two id_bits crossing)
   and asserts determinism-digest equality: the paper's portability claim
   exercised on a real vertex-partitioned mesh every benchmark run.
2. **Collective-traffic model** — the analytic per-iteration §V-C model
   (two_gather = 2·V·4 B, single_gather = V·4 B) across device counts
   16 → 512, persisted as ``artifacts/dryrun_graph/mis2_*.json`` records —
   the inputs ``figs4_5_scaling`` axis B consumes (per-device wire bytes
   stay ~flat: the all-gather volume is V·4 B × (P-1)/P per device).

    PYTHONPATH=src python -m benchmarks.run --only dist [--quick]

Emits ``dist_scaling.csv`` plus a ``BENCH_dist_scaling.json`` trajectory
entry (mirrored to the repo root).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit, emit_trajectory

REPO = Path(__file__).resolve().parents[1]

# the subprocess is unavoidable: host-device forcing must precede jax init
_CHILD = """
import json, sys
import jax
import repro
from repro.graphs import laplace3d, random_uniform_graph

sizes = json.loads(sys.argv[1])
out = {"num_devices": len(jax.devices()), "rows": []}
for v in sizes:
    g = repro.Graph(laplace3d(10).graph) if v == 1000 else \\
        repro.Graph(random_uniform_graph(v, 6.0, seed=v))
    dense = repro.mis2(g, engine="dense")
    for eng in ("distributed", "distributed_single_gather"):
        r = repro.mis2(g, engine=eng)
        out["rows"].append({
            "V": v, "engine": eng, "iterations": r.iterations,
            "seconds": r.wall_time_s,
            "digest_match": r.digest == dense.digest,
            "wire_bytes_per_device": r.collectives["wire_bytes_per_device"],
            "wire_bytes_per_device_per_iteration":
                r.collectives["wire_bytes_per_device_per_iteration"],
        })
print("RESULT:" + json.dumps(out))
"""

MODEL_V, MODEL_D = 1_000_000, 7        # Laplace3D-100^3 scale, 7-point stencil


def _run_forced_devices(sizes, num_devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run([sys.executable, "-c", _CHILD, json.dumps(sizes)],
                         capture_output=True, text=True, env=env, cwd=REPO,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"dist_scaling subprocess failed:\n"
                           f"{out.stderr[-2000:]}")
    return json.loads(out.stdout.rsplit("RESULT:", 1)[1])


def run(quick: bool = False):
    from repro.core.dist import (
        collective_bytes_per_iteration,
        write_mis2_dryrun_record,
    )

    rows = []

    # 1. execution on a forced 8-device host mesh: digest parity + time
    sizes = [254] if quick else [1000, 1022, 997]
    payload = _run_forced_devices(sizes)
    for r in payload["rows"]:
        if not r["digest_match"]:
            raise AssertionError(
                f"distributed drift vs dense: V={r['V']} {r['engine']}")
        rows.append({
            "axis": "exec_8dev", "case": f"V{r['V']}",
            "V": r["V"], "engine": r["engine"], "devices": 8,
            "iterations": r["iterations"], "seconds": r["seconds"],
            # per-iteration, the same unit the model rows report
            "wire_mb_per_device": round(
                r["wire_bytes_per_device_per_iteration"] / 1e6, 4),
            "wire_mb_per_device_total": round(
                r["wire_bytes_per_device"] / 1e6, 4),
            "us_per_call": r["seconds"] * 1e6,
        })

    # 2. collective-traffic model across device counts -> dry-run records
    # (clear this run's namespace first: a --quick pass writes fewer device
    # counts, and stale p<N> records would otherwise leak into axis B)
    from repro.core.dist import DRYRUN_GRAPH_DIR

    for stale in DRYRUN_GRAPH_DIR.glob("mis2_*__p*.json"):
        stale.unlink()
    counts = (16, 64) if quick else (16, 64, 256, 512)
    for p in counts:
        for single in (False, True):
            write_mis2_dryrun_record(MODEL_V, MODEL_D, p,
                                     single_gather=single)
            per = collective_bytes_per_iteration(MODEL_V, p, single)
            rows.append({
                "axis": "model", "case": f"V{MODEL_V}_P{p}",
                "V": MODEL_V,
                "engine": "distributed_single_gather" if single
                else "distributed",
                "devices": p, "iterations": "", "seconds": 0.0,
                "wire_mb_per_device": round(
                    per["wire_bytes_per_device_per_iteration"] / 1e6, 3),
                "wire_mb_per_device_total": "",
                "us_per_call": 0.0,
            })

    emit("dist_scaling", rows)
    exec_rows = [r for r in rows if r["axis"] == "exec_8dev"]
    two = [r for r in exec_rows if r["engine"] == "distributed"]
    single = [r for r in exec_rows
              if r["engine"] == "distributed_single_gather"]
    emit_trajectory("dist_scaling", {
        "quick": quick,
        "num_devices": payload["num_devices"],
        "sizes": sizes,
        "digest_parity": True,       # asserted above for every row
        "two_gather_seconds": {r["case"]: r["seconds"] for r in two},
        "single_gather_seconds": {r["case"]: r["seconds"] for r in single},
        "model_wire_mb_per_device_ratio_single_over_two": 0.5,
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import standalone

    standalone(run)
