"""Regenerate the §Roofline table inside EXPERIMENTS.md from the dry-run
artifacts.  Run after a sweep:

    PYTHONPATH=src python -m benchmarks.inject_roofline
"""
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.roofline import load_records, markdown_table  # noqa: E402

MARK = "<!-- ROOFLINE_TABLE -->"


def main():
    root = Path(__file__).resolve().parents[1]
    exp = root / "EXPERIMENTS.md"
    text = exp.read_text()
    recs = [r for r in load_records() if "__" not in str(r.get("rules", ""))]
    table = markdown_table(recs)
    if MARK in text:
        # replace the marker (and any previously injected table after it)
        pattern = re.escape(MARK) + r"(?:\n(?:\|[^\n]*\n?)*)?"
        text = re.sub(pattern, MARK + "\n" + table + "\n", text, count=1)
    exp.write_text(text)
    n_ok = sum(1 for r in recs if r.get("ok") and "roofline" in r)
    print(f"injected {n_ok} compiled cells into EXPERIMENTS.md §Roofline")


if __name__ == "__main__":
    main()
