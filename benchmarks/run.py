"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only tableX,...]

Prints ``name,us_per_call,derived`` CSV lines and writes
artifacts/bench/<name>.csv per table.

Under ``--quick`` (the CI lane) the driver additionally gates on the
observability contract: every root ``BENCH_*.json`` trajectory touched by
the run must carry a ``"metrics"`` registry snapshot in its newest record
(``common.emit_trajectory`` stamps it; a benchmark bypassing that helper
fails the run).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]

# src path bootstrap lives in benchmarks/__init__.py (runs on package import)
from benchmarks import (  # noqa: E402
    batch_throughput,
    dist_scaling,
    fig2_optimizations,
    figs4_5_scaling,
    hotloop_overhead,
    hybrid_layout,
    roofline,
    serve_resilience,
    serve_throughput,
    setup_overhead,
    table1_priorities,
    table3_scaling,
    table4_quality,
    table5_amg,
    table6_cluster_gs,
)

ALL = {
    "table1": table1_priorities.run,
    "fig2": fig2_optimizations.run,
    "table3": table3_scaling.run,
    "table4": table4_quality.run,
    "table5": table5_amg.run,
    "table6": table6_cluster_gs.run,
    # dist before figs4_5: it generates the dry-run records axis B reads
    "dist": dist_scaling.run,
    "figs4_5": figs4_5_scaling.run,
    "roofline": roofline.run,
    "batch": batch_throughput.run,
    "hotloop": hotloop_overhead.run,
    "hybrid": hybrid_layout.run,
    "setup": setup_overhead.run,
    "serve": serve_throughput.run,
    "serve_resilience": serve_resilience.run,
}


def _check_trajectory_metrics(started_at: float) -> list[str]:
    """Root ``BENCH_*.json`` files modified during this run whose newest
    record is missing the ``"metrics"`` snapshot (the obs contract)."""
    bad = []
    for path in sorted(REPO_ROOT.glob("BENCH_*.json")):
        if path.stat().st_mtime < started_at:
            continue        # not touched by this run
        try:
            history = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            bad.append(f"{path.name}: unreadable")
            continue
        if not history or "metrics" not in history[-1]:
            bad.append(f"{path.name}: newest record lacks 'metrics'")
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced problem sizes (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(ALL))
    args = ap.parse_args()
    names = list(ALL) if not args.only else args.only.split(",")
    started_at = time.time()
    for name in names:
        t0 = time.perf_counter()
        print(f"# --- {name} ---", flush=True)
        ALL[name](quick=args.quick)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)
    if args.quick:
        bad = _check_trajectory_metrics(started_at)
        if bad:
            for line in bad:
                print(f"# OBS GATE FAIL: {line}", flush=True)
            sys.exit(1)
        print("# obs gate: every touched BENCH_*.json carries a metrics "
              "snapshot", flush=True)


if __name__ == "__main__":
    main()
