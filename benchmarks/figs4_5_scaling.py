"""Paper Figs. 4/5 analogue: scaling behaviour of MIS-2.

The paper measures OpenMP strong scaling on 48/56-core CPUs — this host has
one core, so we report the two scaling axes that ARE measurable here:

A. *algorithmic weak scaling* — single-device wall time per vertex vs
   problem size (should stay ~flat: O((V+E) log V) total work);
B. *distributed scaling* — per-device collective wire bytes of the
   shard_map MIS-2 vs device count (16 -> 64 -> 256 -> 512), from the
   graph dry-run artifacts: per-device bytes stay ~constant (all-gather
   volume is V x 4B x (P-1)/P -> the algorithm weak-scales across pods),
   and the single-gather variant sits at ~55% of two_gather everywhere.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.api import Graph, mis2
from repro.graphs import laplace3d

from .common import emit, timeit

GRAPH_ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun_graph"


def run(quick: bool = False):
    rows = []
    # A: algorithmic weak scaling (wall time per vertex)
    sizes = (16, 24, 32) if quick else (16, 24, 32, 48, 64)
    for n in sizes:
        g = Graph(laplace3d(n).graph)
        t = timeit(lambda: mis2(g), repeats=1)
        rows.append({
            "axis": "A_weak_scaling", "case": f"laplace_{n}^3",
            "V": g.num_vertices, "seconds": t,
            "ns_per_vertex": round(t * 1e9 / g.num_vertices, 1),
            "wire_mb_per_device": "", "variant": "", "devices": "",
            "us_per_call": t * 1e6,
        })
    # B: distributed wire bytes vs device count (dry-run artifacts)
    for p in sorted(GRAPH_ART.glob("mis2_*.json")):
        rec = json.loads(p.read_text())
        rows.append({
            "axis": "B_distributed", "case": p.stem,
            "V": rec["V"], "seconds": 0.0, "ns_per_vertex": "",
            "wire_mb_per_device": round(rec["wire_bytes_per_device"] / 1e6, 2),
            "variant": rec["variant"], "devices": rec["num_devices"],
            "us_per_call": 0.0,
        })
    emit("figs4_5_scaling", rows)
    return rows
