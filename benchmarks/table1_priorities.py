"""Paper Table I: MIS-2 iteration counts for Fixed / Xor / Xor* priorities.

Claim validated: xorshift* needs the fewest iterations; plain xorshift is
*worse* than fixed priorities (correlated across iterations).
"""
from __future__ import annotations

from repro.api import Mis2Options, mis2

from benchmarks.common import bench_suite, emit


def run(quick: bool = False):
    rows = []
    suite = bench_suite("quick" if quick else "bench")
    for name, g in suite.items():
        iters = {}
        for prio in ("fixed", "xorshift", "xorshift_star"):
            r = mis2(g, options=Mis2Options(priority=prio))
            assert r.converged
            iters[prio] = r.iterations
        rows.append({
            "graph": name, "V": g.num_vertices,
            "fixed": iters["fixed"], "xor": iters["xorshift"],
            "xor_star": iters["xorshift_star"],
            "paper_claim_holds": int(iters["xorshift_star"] <= iters["fixed"]
                                     <= iters["xorshift"] + 2),
            "us_per_call": 0.0,
        })
    emit("table1_priorities", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import standalone

    standalone(run)
