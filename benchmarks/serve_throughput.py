"""repro.serve throughput: continuous batching vs serialize-every-request.

Three measurements over a mixed-size graph pool:

* **throughput** — requests/sec for (a) a serialize-every-request baseline
  (one facade ``mis2`` call per request, the pre-serve execution model)
  and (b) the server's continuous batcher dispatching the same workload
  through the warm AOT executables.  Digest equality is asserted per
  request, and the run must finish with at most ``len(warm_buckets)``
  compiles (all front-loaded at startup: ``runtime_cold == 0``).
* **latency** — p50/p99 request latency under a live Poisson arrival
  process against the threaded pump (arrivals faster than the latency
  budget coalesce; stragglers pay at most ``max_delay_s``).
* **cache sweep** — requests/sec and observed hit rate as the workload's
  resubmission fraction rises (digest-keyed hits skip compute entirely;
  ``--quick`` forces ``parity_fraction=1.0`` so every CI hit is
  recomputed and digest-asserted).

Headline metrics append to ``BENCH_serve_throughput.json`` at the repo
root via ``emit_trajectory``.
"""
from __future__ import annotations

import time

import numpy as np

import repro
from repro.batch.container import bucket_shape
from repro.graphs import laplace3d, random_uniform_graph
from repro.serve import Server, ServerConfig, warm_buckets_for

from .common import emit, emit_trajectory


def _pool(quick: bool):
    """Mixed-size pool: a few bucket shapes, structure + matrix sources.

    Sizes sit in the serving regime — many small/medium graphs where
    per-request dispatch and compile overhead dominate a serialized
    baseline.  (Single huge graphs are the multilevel/distributed tiers'
    territory; a request server earns its keep on request *rate*.)
    """
    if quick:
        meshes, uniforms = (4, 5, 6), ((200, 5.0), (350, 6.0), (120, 4.0))
    else:
        meshes, uniforms = (6, 8, 10), \
            ((500, 6.0), (1_200, 8.0), (2_000, 8.0), (800, 16.0), (300, 5.0))
    graphs = [repro.Graph(laplace3d(n)) for n in meshes]
    graphs += [repro.Graph(random_uniform_graph(v, d, seed=i))
               for i, (v, d) in enumerate(uniforms)]
    return graphs


def _workload(pool, n_requests, rng, resubmit_fraction=0.0, distinct=False):
    """A request stream over the pool.  ``resubmit_fraction`` of requests
    re-ask a graph already seen in the stream — as a *fresh* handle over
    the same structure, so only the canonical digest can match it to the
    cached result (object identity never helps).  With ``distinct`` each
    base request is a brand-new graph (digest-unique), so the resubmit
    fraction alone controls the achievable cache hit rate."""
    sizes = sorted({g.num_vertices for g in pool})
    seen: list = []
    stream = []
    for k in range(n_requests):
        if seen and rng.random() < resubmit_fraction:
            g = seen[int(rng.integers(len(seen)))]
            stream.append(repro.Graph(g.csr))        # digest-equal clone
        else:
            if distinct:
                v = sizes[k % len(sizes)]
                g = repro.Graph(random_uniform_graph(v, 5.0, seed=10_000 + k))
            else:
                g = pool[int(rng.integers(len(pool)))]
            seen.append(g)
            stream.append(g)
    return stream


def run(quick: bool = False) -> None:
    rng = np.random.default_rng(0)
    pool = _pool(quick)
    n_requests = 48 if quick else 160
    buckets = warm_buckets_for(pool)
    rows = []

    # -- throughput: serialized baseline vs continuous batching ------------
    # The serialized baseline runs first, on cold jit caches: in the
    # serialize-every-request execution model each distinct graph shape
    # pays its compile on the request path.  The server front-loads that
    # churn into startup AOT compiles (asserted <= len(buckets) below),
    # which is the point of the warm-executable registry.
    stream = _workload(pool, n_requests, rng)
    t0 = time.perf_counter()
    serial_digests = [repro.mis2(g, engine="dense").digest for g in stream]
    serial_s = time.perf_counter() - t0
    rps_serial = len(stream) / serial_s

    direct = {}                      # digest referents, outside the clock
    for g in pool:
        direct[g.digest] = repro.mis2(g, engine="dense").digest

    srv = Server(ServerConfig(max_batch=8, warm_buckets=buckets,
                              cache_bytes=0))      # batching only, no cache
    t0 = time.perf_counter()
    futs = [srv.submit("mis2", g) for g in stream]
    srv.flush()
    results = [f.result() for f in futs]
    batched_s = time.perf_counter() - t0
    rps_batched = len(stream) / batched_s

    for g, r, sd in zip(stream, results, serial_digests):
        assert r.digest == direct[g.digest] == sd, "served digest mismatch"
    comp = srv.server_stats()["compiles"]
    assert comp["runtime_cold"] == 0, \
        f"warm registry missed live shapes: {comp}"
    total_compiles = comp["startup_aot"] + comp["runtime_cold"]
    assert total_compiles <= len(buckets), (total_compiles, len(buckets))
    assert rps_batched > rps_serial, \
        f"batched serving must beat serialize-every-request " \
        f"({rps_batched:.1f} vs {rps_serial:.1f} req/s)"
    rows.append({"seconds": batched_s / len(stream),
                 "mode": "batched", "requests": len(stream),
                 "rps": round(rps_batched, 1),
                 "speedup_vs_serial": round(rps_batched / rps_serial, 2),
                 "compiles": total_compiles, "buckets": len(buckets)})
    rows.append({"seconds": serial_s / len(stream),
                 "mode": "serialized", "requests": len(stream),
                 "rps": round(rps_serial, 1), "speedup_vs_serial": 1.0,
                 "compiles": -1, "buckets": len(buckets)})

    # -- latency under Poisson arrivals (threaded pump) --------------------
    lat_n = 32 if quick else 96
    lat_stream = _workload(pool, lat_n, rng)
    # offered load above what serialize-every-request could sustain but
    # below batched capacity (an overloaded queue measures backlog
    # growth, not serving latency); capped so sleep() stays meaningful
    # relative to the 5 ms latency budget
    rate = min(0.5 * rps_batched, 500.0)
    assert rate > rps_serial, (rate, rps_serial)
    latencies = np.zeros(lat_n)
    done_at = [None] * lat_n
    # single_fast_path off: a latency-sensitive server routes stragglers
    # through the warm executables too (a size-1 "batch" pads to bucket
    # capacity but never compiles), instead of the facade fast path whose
    # engines would jit-compile per shape on first touch.
    with Server(ServerConfig(max_batch=8, warm_buckets=buckets,
                             max_delay_s=0.005,
                             single_fast_path=False)) as live:
        submit_at = np.zeros(lat_n)
        futs = []
        for i, g in enumerate(lat_stream):
            submit_at[i] = time.monotonic()
            fut = live.submit("mis2", g)
            fut.add_done_callback(
                lambda _, i=i: done_at.__setitem__(i, time.monotonic()))
            futs.append(fut)
            time.sleep(float(rng.exponential(1.0 / rate)))
        for f in futs:
            f.result(timeout=120)
    for i in range(lat_n):
        latencies[i] = done_at[i] - submit_at[i]
    p50, p99 = (float(np.percentile(latencies, q) * 1e3) for q in (50, 99))
    rows.append({"seconds": float(latencies.mean()), "mode": "poisson",
                 "requests": lat_n, "rps": round(rate, 1),
                 "p50_ms": round(p50, 2), "p99_ms": round(p99, 2)})

    # -- cache-hit-rate sweep ----------------------------------------------
    # Distinct base graphs so the resubmit fraction alone sets the
    # achievable hit rate; jit caches pre-warmed (one request per bucket
    # shape, outside every timed window) so all fractions compare steady
    # state.  Requests run sequentially: the cache is populated as the
    # stream progresses, so resubmitted digests can actually hit (a
    # submit-everything-then-flush pattern looks up before any result
    # has been inserted and measures batching, not caching).
    fracs = (0.0, 0.5, 0.9)
    streams = {f: _workload(pool, n_requests, rng,
                            resubmit_fraction=f, distinct=True)
               for f in fracs}
    reps: dict = {}
    for s in streams.values():
        for g in s:
            reps.setdefault(bucket_shape(g), g)
    warm = Server(ServerConfig(max_batch=8, single_fast_path=False,
                               cache_bytes=0))
    for g in reps.values():
        warm.request("mis2", g)
        repro.mis2(g, engine="dense")    # the parity-referent engine

    sweep = {}
    for frac in fracs:
        srv = Server(ServerConfig(
            max_batch=8, single_fast_path=False,
            parity_fraction=1.0 if quick else 0.1))
        t0 = time.perf_counter()
        for g in streams[frac]:
            srv.request("mis2", g)
        dt = time.perf_counter() - t0
        stats = srv.server_stats()["cache"]
        assert stats["parity_failures"] == 0
        assert (stats["hits"] > 0) == (frac > 0), \
            f"resubmit_fraction={frac}: unexpected hits={stats['hits']}"
        cache_stream = streams[frac]
        sweep[frac] = {"rps": round(len(cache_stream) / dt, 1),
                       "hit_rate": round(stats["hit_rate"], 3),
                       "parity_checks": stats["parity_checks"]}
        rows.append({"seconds": dt / len(cache_stream), "mode": "cache",
                     "requests": len(cache_stream),
                     "resubmit_fraction": frac, **sweep[frac]})

    # rows are heterogeneous across modes; square them up for DictWriter
    keys = list(dict.fromkeys(k for r in rows for k in r))
    emit("serve_throughput", [{k: r.get(k, "") for k in keys} for r in rows])
    emit_trajectory("serve_throughput", {
        "quick": quick, "requests": n_requests,
        "pool_graphs": len(pool), "warm_buckets": len(buckets),
        "rps_serialized": round(rps_serial, 1),
        "rps_batched": round(rps_batched, 1),
        "batched_speedup": round(rps_batched / rps_serial, 2),
        "compiles": total_compiles,
        "poisson_p50_ms": round(p50, 2), "poisson_p99_ms": round(p99, 2),
        "cache_sweep": {str(k): v for k, v in sweep.items()},
    })


if __name__ == "__main__":
    from .common import standalone

    standalone(run)
