"""Paper Table VI: point vs cluster multicolor symmetric Gauss-Seidel as
GMRES preconditioners — setup time, apply (solve) time, iterations.

Claims validated: cluster SGS has faster setup (colors the much smaller
coarse graph) and fewer/equal iterations.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.api import Graph
from repro.graphs import elasticity3d, laplace3d
from repro.graphs.ops import spmv_ell
from repro.solvers import gmres, setup_cluster_gs, setup_point_gs

from benchmarks.common import emit


def run(quick: bool = False):
    problems = {
        "Laplace3D_16": laplace3d(16),
        "Elasticity3D_5": elasticity3d(5),
    }
    if not quick:
        problems["Laplace3D_24"] = laplace3d(24)
        problems["Elasticity3D_8"] = elasticity3d(8)
    rows = []
    for pname, mat in problems.items():
        a = Graph(mat)
        ell = a.ell_matrix
        b = jnp.asarray(np.random.default_rng(0)
                        .standard_normal(a.num_vertices).astype(np.float32))
        mv = lambda x: spmv_ell(ell, x)  # noqa: E731
        for kind, setup in (("point", setup_point_gs),
                            ("cluster", setup_cluster_gs)):
            pre = setup(a)
            t0 = time.perf_counter()
            res = gmres(mv, b, precond=pre.as_precond(1, True),
                        tol=1e-6, maxiter=800)
            apply_s = time.perf_counter() - t0
            rows.append({
                "problem": pname, "kind": kind, "V": a.num_vertices,
                "setup_seconds": round(pre.setup_seconds, 3),
                "apply_seconds": round(apply_s, 3),
                "gmres_iters": res.iterations,
                "colors": pre.num_colors, "clusters": pre.num_clusters,
                "aggregate_s": round(pre.timings.get("aggregate", 0.0), 4),
                "color_s": round(pre.timings.get("color", 0.0), 4),
                "pack_s": round(pre.timings.get("pack", 0.0), 4),
                "converged": int(res.converged),
                "us_per_call": apply_s * 1e6,
            })
    emit("table6_cluster_gs", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import standalone

    standalone(run)
