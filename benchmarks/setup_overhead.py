"""Host vs device-resident multilevel setup (ISSUE 5 tentpole metric).

Benchmarks the two execution shapes of the AMG setup phase
(``repro.amg_setup``):

* ``host``: scipy smoothed prolongator + canonical numpy Galerkin +
  numpy transfer packing — every level round-trips matrix-sized data
  through host memory (``SETUP_STATS.host_syncs``, 3/level);
* ``resident``: the whole per-level setup jitted on device (fixed-shape
  prolongator assembly, padded sorted-COO SpGEMM, coarse ELL repack) —
  7 dispatches per level, zero matrix-sized host syncs.

Reported per engine: levels/sec (= built hierarchy levels / setup wall
time), matrix-sized host syncs per level, and resident dispatches per
level.  The headline record appended to ``BENCH_setup_overhead.json`` is
the resident-over-host levels/sec ratio per graph; per-level ``A_l``
digests are asserted equal on every measured pair, so the benchmark
doubles as a parity smoke check.
"""
from __future__ import annotations

from benchmarks.common import emit, emit_trajectory, standalone, timeit


def run(quick: bool = False) -> None:
    from repro.api import Graph, amg_setup
    from repro.graphs import er_laplacian, laplace3d
    from repro.multilevel import SETUP_STATS

    if quick:
        graphs = {
            "laplace3d_512": Graph(laplace3d(8)),
            "er_1024": Graph(er_laplacian(1024, 6.0, seed=3)),
        }
        repeats = 2
    else:
        graphs = {
            "laplace3d_4096": Graph(laplace3d(16)),     # V = 4096
            "er_4096": Graph(er_laplacian(4096, 7.0, seed=3)),
        }
        repeats = 5

    rows = []
    headline: dict = {}
    for gname, g in graphs.items():
        stats = {}
        for eng in ("host", "resident"):
            # this call doubles as warmup/compile; timeit() below does its
            # own warmup call before timing
            SETUP_STATS.reset()
            setup = amg_setup(g, engine=eng)
            syncs = SETUP_STATS.host_syncs
            dispatches = SETUP_STATS.resident_dispatches
            built = max(1, setup.num_levels - 1)     # levels with transfers
            dt = timeit(lambda e=eng: amg_setup(g, engine=e),
                        repeats=repeats)
            stats[eng] = dict(setup=setup, seconds=dt,
                              levels_per_sec=built / dt, syncs=syncs)
            rows.append({
                "graph": gname, "engine": eng,
                "us_per_call": dt * 1e6,
                "levels": setup.num_levels,
                "levels_per_sec": round(built / dt, 2),
                "host_syncs_per_level": round(syncs / built, 2),
                "dispatches_per_level": round(dispatches / built, 2),
                "digest0": setup.level_digests[0],
            })
        h, r = stats["host"], stats["resident"]
        assert h["setup"].level_digests == r["setup"].level_digests, \
            f"parity break: {gname} host vs resident"
        assert r["syncs"] == 0, \
            f"resident issued {r['syncs']} matrix-sized host syncs"
        speedup = r["levels_per_sec"] / h["levels_per_sec"]
        headline[f"{gname}_resident_speedup"] = round(speedup, 3)
        headline[f"{gname}_host_syncs"] = h["syncs"]

    emit("setup_overhead", rows)
    emit_trajectory("setup_overhead", {
        "quick": quick,
        **headline,
    })

    if not quick:
        # On CPU-only runners the host engine's round-trips are
        # address-space memcpys and numpy's single-thread kernels beat
        # XLA's scatter/sort primitives, so the measured ratio is < 1 —
        # which is exactly why `engine=None` auto-selects `host` on CPU
        # hosts.  The resident engine's levels/sec advantage (and the
        # >=2x target) applies to accelerator-attached runners, where the
        # host engine would serialize each level on PCIe transfers and
        # host-speed scipy while the device idles.
        for gname in graphs:
            s = headline[f"{gname}_resident_speedup"]
            print(f"# {gname}: resident/host setup levels/sec ratio "
                  f"{s:.2f}x (CPU runner; see note above)")


if __name__ == "__main__":
    standalone(run)
