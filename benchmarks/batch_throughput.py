"""Batch throughput: graphs/sec for `repro.batch` vs. a single-graph loop.

The `repro.batch` pitch quantified: a fleet of small/medium graphs (the
ROADMAP's many-users traffic shape) dispatched

* ``looped``  — one ``repro.mis2(g, engine="dense")`` call per graph, and
* ``batched`` — one ``repro.mis2_batch(batch)`` over the size-bucketed
  ``[B, rows, deg]`` stacks (one compiled step per bucket shape),

with the same comparison for two-phase coarsening.  Digest equality of the
two paths is asserted on every run — a throughput benchmark that silently
changed the answer would be measuring a different algorithm.

    PYTHONPATH=src python -m benchmarks.run --only batch [--quick]

Emits ``batch_throughput.csv`` plus a ``BENCH_batch_throughput.json``
trajectory entry (headline: batched graphs/sec and speedup).
"""
from __future__ import annotations

from benchmarks.common import emit, emit_trajectory, timeit


def _fleet(quick: bool):
    """A mixed fleet: laplace3d meshes + ER graphs, several size buckets."""
    from repro.api import Graph
    from repro.graphs import laplace3d, random_uniform_graph

    if quick:
        meshes = [4, 5, 6]
        ns, copies = [200, 400, 700], 2
    else:
        meshes = [6, 8, 10, 12]
        ns, copies = [1_000, 2_000, 5_000, 10_000], 4
    graphs = [Graph(laplace3d(m).graph) for m in meshes]
    seed = 0
    for n in ns:
        for _ in range(copies):
            graphs.append(Graph(random_uniform_graph(n, 6.0, seed=seed)))
            seed += 1
    return graphs


def run(quick: bool = False):
    from repro.api import GraphBatch, coarsen_batch, mis2, mis2_batch

    graphs = _fleet(quick)
    batch = GraphBatch(graphs)

    rows = []
    # -- MIS-2 ---------------------------------------------------------------
    t_loop = timeit(lambda: [mis2(g, engine="dense") for g in graphs])
    t_batch = timeit(lambda: mis2_batch(batch))
    br = mis2_batch(batch)
    for g, r in zip(graphs, br):
        assert r.digest == mis2(g, engine="dense").digest, "batch drift!"
    rows.append({
        "pipeline": "mis2", "num_graphs": len(graphs),
        "num_buckets": batch.num_buckets,
        "seconds": t_batch,
        "looped_gps": len(graphs) / t_loop,
        "batched_gps": len(graphs) / t_batch,
        "speedup": t_loop / t_batch,
    })

    # -- two-phase coarsening ------------------------------------------------
    from repro.api import coarsen

    t_loop_c = timeit(
        lambda: [coarsen(g, mis2_engine="dense") for g in graphs], repeats=1)
    t_batch_c = timeit(lambda: coarsen_batch(batch), repeats=1)
    rows.append({
        "pipeline": "coarsen_two_phase", "num_graphs": len(graphs),
        "num_buckets": batch.num_buckets,
        "seconds": t_batch_c,
        "looped_gps": len(graphs) / t_loop_c,
        "batched_gps": len(graphs) / t_batch_c,
        "speedup": t_loop_c / t_batch_c,
    })

    emit("batch_throughput", rows)
    emit_trajectory("batch_throughput", {
        "quick": quick,
        "num_graphs": len(graphs),
        "bucket_shapes": batch.bucket_shapes,
        "mis2_batched_gps": rows[0]["batched_gps"],
        "mis2_looped_gps": rows[0]["looped_gps"],
        "mis2_speedup": rows[0]["speedup"],
        "coarsen_batched_gps": rows[1]["batched_gps"],
        "coarsen_speedup": rows[1]["speedup"],
    })


if __name__ == "__main__":
    from benchmarks.common import standalone

    standalone(run)
