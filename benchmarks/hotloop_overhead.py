"""Host-driven vs device-resident MIS-2 hot loop (ISSUE 4 tentpole metric).

Benchmarks the two execution shapes of the compacted §V-B fixed point:

* host-driven (``compacted`` / ``pallas``): every round syncs T/M to the
  host, rebuilds the worklists in numpy, and re-dispatches the step
  kernels (re-specializing per pow2 bucket pair);
* device-resident (``compacted_resident`` / ``pallas_resident``): one
  jitted ``lax.while_loop`` per solve, worklists compacted on device,
  zero host round-trips.

Reported per engine: rounds/sec (= fixed-point iterations / solve wall
time), host syncs per solve (``HOTLOOP_STATS``), and the jit-churn
accounting (``Mis2Result.num_compiles``).  The headline record appended to
``BENCH_hotloop_overhead.json`` is the resident-over-host rounds/sec ratio
per engine pair; digests are asserted equal on every measured pair, so the
benchmark doubles as a parity smoke check.
"""
from __future__ import annotations

from benchmarks.common import emit, emit_trajectory, standalone, timeit

PAIRS = [("compacted", "compacted_resident"),
         ("pallas", "pallas_resident")]


def run(quick: bool = False) -> None:
    from repro.api import Graph, mis2
    from repro.core.mis2 import HOTLOOP_STATS
    from repro.graphs import laplace3d, random_uniform_graph

    if quick:
        graphs = {
            "laplace3d_512": Graph(laplace3d(8).graph),
            "er_1024": Graph(random_uniform_graph(1024, 6.0, seed=3)),
        }
        repeats = 3
    else:
        graphs = {
            "laplace3d_4096": Graph(laplace3d(16).graph),   # V = 4096
            "er_4096": Graph(random_uniform_graph(4096, 8.0, seed=3)),
        }
        repeats = 7

    rows = []
    headline: dict = {}
    for gname, g in graphs.items():
        for host_eng, res_eng in PAIRS:
            stats = {}
            for eng in (host_eng, res_eng):
                mis2(g, engine=eng)                     # warmup/compile
                HOTLOOP_STATS.reset()
                r = mis2(g, engine=eng)
                syncs = HOTLOOP_STATS.host_syncs
                dt = timeit(lambda e=eng: mis2(g, engine=e), repeats=repeats)
                stats[eng] = dict(result=r, seconds=dt, syncs=syncs,
                                  rounds_per_sec=r.iterations / dt)
                rows.append({
                    "graph": gname, "engine": eng,
                    "us_per_call": dt * 1e6,
                    "iterations": r.iterations,
                    "rounds_per_sec": round(r.iterations / dt, 1),
                    "host_syncs_per_solve": syncs,
                    "num_compiles": r.num_compiles,
                    "digest": r.digest,
                })
            h, d = stats[host_eng], stats[res_eng]
            assert h["result"].digest == d["result"].digest, \
                f"parity break: {gname} {host_eng} vs {res_eng}"
            assert d["syncs"] == 0, \
                f"{res_eng} issued {d['syncs']} in-loop host syncs"
            speedup = d["rounds_per_sec"] / h["rounds_per_sec"]
            headline[f"{gname}_{host_eng}_resident_speedup"] = round(speedup, 3)
            headline[f"{gname}_{host_eng}_host_syncs"] = h["syncs"]

    emit("hotloop_overhead", rows)
    emit_trajectory("hotloop_overhead", {
        "quick": quick,
        **headline,
    })

    if not quick:
        # acceptance floor: the CPU-interpret (Pallas) pair at V=4096 must
        # hold >= 2x rounds/sec for the resident driver
        for gname in graphs:
            s = headline[f"{gname}_pallas_resident_speedup"]
            print(f"# {gname}: pallas_resident speedup {s:.2f}x")


if __name__ == "__main__":
    standalone(run)
