"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape x
mesh) roofline table (terms in seconds, dominant bottleneck, usefulness
ratio).  Reads artifacts/dryrun/*.json produced by repro.launch.dryrun.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_records(pattern: str = "*.json"):
    recs = []
    for p in sorted(DRYRUN.glob(pattern)):
        try:
            recs.append(json.loads(p.read_text()))
        except Exception:
            pass
    return recs


def run(quick: bool = False):
    rows = []
    for rec in load_records():
        base = {"arch": rec.get("arch"), "shape": rec.get("shape"),
                "mesh": rec.get("mesh"), "status": "", "note": "",
                "t_compute_s": "", "t_memory_s": "", "t_collective_s": "",
                "dominant": "", "roofline_fraction": "", "useful_ratio": "",
                "fits_hbm": "", "microbatches": "", "zero1": "",
                "compile_s": "", "us_per_call": 0.0}
        if rec.get("skipped"):
            base.update(status="N/A", note="full-attention long-context skip")
            rows.append(base)
            continue
        if not rec.get("ok") or "roofline" not in rec:
            base.update(status="FAIL", note=str(rec.get("error", ""))[:120])
            rows.append(base)
            continue
        r = rec["roofline"]
        base.update(
            status="ok",
            t_compute_s=f"{r['t_compute_s']:.4g}",
            t_memory_s=f"{r['t_memory_s']:.4g}",
            t_collective_s=f"{r['t_collective_s']:.4g}",
            dominant=r["dominant"],
            roofline_fraction=f"{r['roofline_fraction']:.3f}",
            useful_ratio=f"{r['model_flops_over_hlo_flops']:.3f}",
            fits_hbm=rec.get("fits_hbm"),
            microbatches=rec.get("microbatches"),
            zero1=rec.get("zero1"),
            compile_s=rec.get("compile_s"))
        rows.append(base)
    emit("roofline", rows)
    return rows


def markdown_table(records=None) -> str:
    """§Roofline markdown for EXPERIMENTS.md."""
    recs = records if records is not None else load_records()
    lines = ["| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
             "dominant | roofline frac | useful | fits HBM | mb | z1 |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for rec in recs:
        if rec.get("skipped"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} |"
                         " — | — | — | N/A (skip) | — | — | — | — | — |")
            continue
        if not rec.get("ok") or "roofline" not in rec:
            lines.append(f"| {rec.get('arch')} | {rec.get('shape')} | "
                         f"{rec.get('mesh')} | FAIL | | | | | | | | |")
            continue
        r = rec["roofline"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} | "
            f"{r['t_collective_s']:.3g} | {r['dominant']} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['model_flops_over_hlo_flops']:.2f} | "
            f"{rec.get('fits_hbm')} | {rec.get('microbatches')} | "
            f"{rec.get('zero1')} |")
    return "\n".join(lines)


if __name__ == "__main__":
    from benchmarks.common import standalone

    standalone(run)
