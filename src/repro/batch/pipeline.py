"""Batched drivers: vmapped dense fixed points over `GraphBatch` buckets.

Each driver loops over a batch's shape buckets and makes **one** vmapped,
jitted dispatch per bucket (per fixed point), so a mixed workload of many
small/medium graphs costs a handful of XLA compilations and ``B`` graphs
per launch instead of one launch (and one compile per vertex count) each.

The load-bearing invariant: every per-graph result is **bit-identical** to
the single-graph ``dense`` engine's result for the same options.  The
ingredients:

* each graph keeps its own packing bit width ``b = id_bits(V_real)`` (a
  traced per-element scalar, not a property of the padded shape);
* padded rows are inactive — MIS-2 pins them to OUT, coloring pre-colors
  them — and self-loop adjacency keeps them out of every real row's
  closed neighborhood;
* per-element iteration counters only advance while that graph is live,
  so the §V-A priority stream matches the single-graph run even when
  bucket mates need more rounds;
* host-side label bookkeeping (cumsum ids, bincount sizes, singleton
  cleanup) runs per graph on the unpadded slice, exactly as the
  single-graph aggregation does.

Everything returns *core* result dataclasses in batch input order; the
facade (``repro.api.facade``) wraps them into the Result protocol and a
``BatchResult``.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.aggregation import (
    INT32_MAX,
    AggregationResult,
    _aggregate_serial_greedy_impl,
    _count_unagg_neighbors,
    _finalize_singletons,
    _join_adjacent_root,
    _phase3_join,
)
from ..core.coloring import MAX_COLORS, ColoringResult, _color_round_masked
from ..core.mis2 import (
    Mis2Options,
    Mis2Result,
    mis2_dense_fixed_point,
)
from ..core.tuples import IN, is_undecided
from .container import GraphBatch, as_graph_batch

# ---------------------------------------------------------------------------
# bucket-level jitted kernels (one compilation per [B, rows, width] shape)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("priority", "max_iters"))
def _mis2_bucket_run(neighbors, active, bits, priority: str, max_iters: int):
    def fp(n, a, b):
        return mis2_dense_fixed_point(n, a, b, priority, max_iters)

    return jax.vmap(fp)(neighbors, active, bits)


@jax.jit
def _color_bucket_round(neighbors, mask, colors, rnd, bits):
    return jax.vmap(_color_round_masked, in_axes=(0, 0, 0, None, 0))(
        neighbors, mask, colors, rnd, bits)


_join_adjacent_root_b = jax.jit(jax.vmap(_join_adjacent_root))
_count_unagg_neighbors_b = jax.jit(jax.vmap(_count_unagg_neighbors))
_phase3_join_b = jax.jit(jax.vmap(_phase3_join))


# ---------------------------------------------------------------------------
# MIS-2
# ---------------------------------------------------------------------------

def _bucket_actives(bucket, actives) -> jnp.ndarray:
    """Stack per-graph active masks into [B, rows] (False on padding)."""
    if actives is None:
        return bucket.row_valid
    stacked = np.zeros((bucket.size, bucket.rows), dtype=bool)
    for j, gi in enumerate(bucket.indices):
        act = actives[gi]
        if act is None:
            stacked[j, : bucket.num_vertices[j]] = True
        else:
            act = np.asarray(act)
            stacked[j, : len(act)] = act
    return jnp.asarray(stacked)


def _mis2_batch_impl(batch: GraphBatch,
                     options: Optional[Mis2Options] = None,
                     actives: Optional[Sequence] = None) -> list[Mis2Result]:
    """Batched dense MIS-2; returns core Mis2Results in batch input order."""
    options = Mis2Options() if options is None else options
    out: list = [None] * len(batch)
    for bucket in batch.buckets:
        act = _bucket_actives(bucket, actives)
        t, iters = _mis2_bucket_run(bucket.neighbors, act, bucket.id_bits,
                                    options.priority, options.max_iters)
        t_np, iters_np = np.asarray(t), np.asarray(iters)
        act_np = np.asarray(act)
        for j, gi in enumerate(bucket.indices):
            v = int(bucket.num_vertices[j])
            tj = t_np[j, :v]
            undecided = is_undecided(tj) & act_np[j, :v]
            out[gi] = Mis2Result(tj == np.uint32(IN), int(iters_np[j]),
                                 not undecided.any())
    return out


# ---------------------------------------------------------------------------
# coloring
# ---------------------------------------------------------------------------

def _color_batch_impl(batch: GraphBatch,
                      max_rounds: int = 256) -> list[ColoringResult]:
    """Batched Luby coloring; per-graph results match `_color_graph_impl`."""
    out: list = [None] * len(batch)
    for bucket in batch.buckets:
        valid = np.asarray(bucket.row_valid)
        # padded rows enter pre-colored (0) so they are never contenders and
        # never block termination; they are nobody's neighbor, so the color
        # itself is inert.
        colors = jnp.asarray(np.where(valid, -1, 0).astype(np.int32))
        done_round = np.full(bucket.size, -1, dtype=np.int64)
        rnd = 0
        while True:
            colors = _color_bucket_round(bucket.neighbors, bucket.mask,
                                         colors, np.uint32(rnd),
                                         bucket.id_bits)
            rnd += 1
            c = np.asarray(colors)
            finished = ((c >= 0) | ~valid).all(axis=1)
            done_round[(done_round < 0) & finished] = rnd
            if finished.all() or rnd >= max_rounds:
                break
        for j, gi in enumerate(bucket.indices):
            v = int(bucket.num_vertices[j])
            cj = c[j, :v]
            converged = not (cj < 0).any()
            num = int(cj.max()) + 1 if v and (cj >= 0).any() else 0
            if num > MAX_COLORS:
                raise RuntimeError(
                    f"{num} colors exceed MAX_COLORS={MAX_COLORS}")
            # round-limit hits are reported (converged=False, -1 colors on
            # the stragglers), matching the single-graph engine
            out[gi] = ColoringResult(
                cj, num, int(done_round[j]) if converged else rnd, converged)
    return out


# ---------------------------------------------------------------------------
# MIS-2 aggregation (coarsening)
# ---------------------------------------------------------------------------

def _stacked_root_labels(roots: np.ndarray, num_vertices, offsets,
                         rows: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-graph cumsum aggregate ids for root masks stacked [B, rows].

    Returns (root_label [B, rows] int32 with INT32_MAX non-roots, counts).
    """
    bsz = roots.shape[0]
    root_label = np.full((bsz, rows), INT32_MAX, dtype=np.int32)
    counts = np.zeros(bsz, dtype=np.int64)
    for j in range(bsz):
        v = int(num_vertices[j])
        rj = roots[j, :v]
        ids = int(offsets[j]) + np.cumsum(rj) - 1
        root_label[j, :v] = np.where(rj, ids, INT32_MAX).astype(np.int32)
        counts[j] = int(rj.sum())
    return root_label, counts


def _coarsen_batch_impl(batch: GraphBatch, method: str = "two_phase",
                        options: Optional[Mis2Options] = None,
                        min_secondary_neighbors: int = 2
                        ) -> list[AggregationResult]:
    """Batched MIS-2 coarsening (paper Alg. 2 / Alg. 3) over dense MIS-2.

    ``serial`` falls back to the host-sequential reference per graph (it
    has no data-parallel fixed point to batch).
    """
    options = Mis2Options() if options is None else options
    if method == "serial":
        return [_aggregate_serial_greedy_impl(g) for g in batch.graphs]
    if method not in ("basic", "two_phase"):
        raise ValueError(
            f"unknown batch aggregation method {method!r} "
            "(basic | two_phase | serial)")
    out: list = [None] * len(batch)
    for bucket in batch.buckets:
        results = _coarsen_bucket(bucket, method, options,
                                  min_secondary_neighbors)
        for j, gi in enumerate(bucket.indices):
            out[gi] = results[j]
    return out


def _coarsen_bucket(bucket, method: str, options: Mis2Options,
                    min_secondary_neighbors: int) -> list[AggregationResult]:
    bsz, rows = bucket.size, bucket.rows
    nv = bucket.num_vertices
    valid = np.asarray(bucket.row_valid)

    # Phase 1: MIS-2 roots + direct neighbors (batched fixed point)
    t1, it1 = _mis2_bucket_run(bucket.neighbors, bucket.row_valid,
                               bucket.id_bits, options.priority,
                               options.max_iters)
    t1_np, it1_np = np.asarray(t1), np.asarray(it1)
    in_set1 = (t1_np == np.uint32(IN)) & valid
    conv = np.empty(bsz, dtype=bool)
    for j in range(bsz):
        conv[j] = not is_undecided(t1_np[j, :nv[j]]).any()
    total_iters = it1_np.astype(np.int64).copy()

    root_label, nagg = _stacked_root_labels(in_set1, nv, np.zeros(bsz), rows)
    labels = np.asarray(_join_adjacent_root_b(bucket.neighbors,
                                              jnp.asarray(root_label)))
    phase = np.where(labels >= 0, 1, 0).astype(np.uint8)
    roots = in_set1.copy()

    if method == "two_phase":
        # Phase 2: MIS-2 on the induced unaggregated subgraph.  Graphs with
        # nothing left run an empty-active fixed point (0 iterations, empty
        # set) — equivalent to the single-graph path skipping phase 2.
        unagg = (labels < 0) & valid
        t2, it2 = _mis2_bucket_run(bucket.neighbors, jnp.asarray(unagg),
                                   bucket.id_bits, options.priority,
                                   options.max_iters)
        t2_np, it2_np = np.asarray(t2), np.asarray(it2)
        total_iters += it2_np
        in_set2 = (t2_np == np.uint32(IN)) & valid
        for j in range(bsz):
            und = is_undecided(t2_np[j, :nv[j]]) & unagg[j, :nv[j]]
            conv[j] &= not und.any()
        n_unagg = np.asarray(_count_unagg_neighbors_b(
            bucket.neighbors, bucket.mask,
            jnp.asarray(labels.astype(np.int32))))
        roots2 = in_set2 & (n_unagg >= min_secondary_neighbors)
        roots |= roots2
        rl2, counts2 = _stacked_root_labels(roots2, nv, nagg, rows)
        adj2 = np.asarray(_join_adjacent_root_b(bucket.neighbors,
                                                jnp.asarray(rl2)))
        newly = (labels < 0) & (adj2 >= 0)
        labels = np.where(newly, adj2, labels)
        phase[newly] = 2
        nagg += counts2

        # Phase 3: max-coupling join against frozen tentative labels
        rounds = 0
        while ((labels < 0) & valid).any() and rounds < 4:
            aggsize = np.zeros((bsz, rows), dtype=np.int32)
            for j in range(bsz):
                lj = labels[j, :nv[j]]
                asz = np.bincount(lj[lj >= 0], minlength=max(int(nagg[j]), 1))
                aggsize[j, :len(asz)] = asz.astype(np.int32)
            new_labels = np.asarray(_phase3_join_b(
                bucket.neighbors, bucket.mask,
                jnp.asarray(labels.astype(np.int32)), jnp.asarray(aggsize)))
            newly = (labels < 0) & (new_labels >= 0)
            phase[newly] = 3
            labels = new_labels
            rounds += 1
    else:  # basic: leftovers join the min adjacent aggregate
        rounds = 0
        while ((labels < 0) & valid).any() and rounds < 4:
            lab_j = jnp.asarray(
                np.where(labels >= 0, labels, INT32_MAX).astype(np.int32))
            adj = np.asarray(_join_adjacent_root_b(bucket.neighbors, lab_j))
            newly = (labels < 0) & (adj >= 0)
            labels = np.where(newly, adj, labels)
            phase[newly] = 3
            rounds += 1

    results = []
    for j in range(bsz):
        v = int(nv[j])
        lab_j, nagg_j = _finalize_singletons(labels[j, :v].copy(),
                                             int(nagg[j]), phase[j, :v])
        results.append(AggregationResult(
            lab_j.astype(np.int32), nagg_j, roots[j, :v], phase[j, :v],
            int(total_iters[j]), bool(conv[j])))
    return results


def _amg_setup_batch_impl(batch: GraphBatch, aggregation: str = "two_phase",
                          options: Optional[Mis2Options] = None,
                          min_secondary_neighbors: int = 2,
                          engine: str = "host", **hier_kwargs) -> list:
    """Batched AMG setup: the finest-level aggregation of every member —
    the dominant setup cost — runs through the vmapped bucketed coarsening
    (one dispatch per bucket shape), and each hierarchy is finished
    per-graph with the precomputed labels injected via ``first_agg``.

    Per-graph hierarchies are digest-identical to ``amg_setup(g, ...)``:
    the batched labels are bit-identical to the single-graph engines
    (the ``repro.batch`` invariant), and everything downstream of the
    labels is the same multilevel engine code path.
    """
    from ..multilevel.hierarchy import _build_hierarchy_impl

    coarse_size = hier_kwargs.get("coarse_size", 200)
    aggs: list = [None] * len(batch)
    if aggregation in ("basic", "two_phase"):
        sub = [i for i, g in enumerate(batch.graphs)
               if g.num_vertices > coarse_size]
        if sub:
            res = _coarsen_batch_impl(GraphBatch([batch.graphs[i]
                                                  for i in sub]),
                                      aggregation, options,
                                      min_secondary_neighbors)
            for i, r in zip(sub, res):
                aggs[i] = r
    return [_build_hierarchy_impl(g, aggregation=aggregation, engine=engine,
                                  options=options, first_agg=agg,
                                  **hier_kwargs)
            for g, agg in zip(batch.graphs, aggs)]


__all__ = [
    "as_graph_batch",
    "_mis2_batch_impl", "_color_batch_impl", "_coarsen_batch_impl",
    "_amg_setup_batch_impl",
]
