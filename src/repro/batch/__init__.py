"""``repro.batch`` — size-bucketed, vmapped multi-graph pipelines.

High-throughput companion to the one-graph-per-call facade: a
:class:`GraphBatch` stacks many graphs into a few padded ``[B, rows, deg]``
shape buckets (power-of-two rows x degree, the ``mis2_compacted`` bucket
policy) and the pipeline drivers vmap the dense MIS-2 / coloring /
aggregation fixed points over each bucket — one XLA compilation per bucket
shape, ``B`` graphs per dispatch, with per-graph results bit-identical to
the single-graph ``dense`` engine.

The public entry points live on the facade: ``repro.mis2_batch``,
``repro.color_batch``, ``repro.coarsen_batch`` (see ``repro.api``); this
package holds the container and the batched drivers.
"""
from .container import GraphBatch, GraphBucket, as_graph_batch, bucket_shape
from .pipeline import (
    _coarsen_batch_impl,
    _color_batch_impl,
    _mis2_batch_impl,
)

__all__ = [
    "GraphBatch", "GraphBucket", "as_graph_batch", "bucket_shape",
    "_mis2_batch_impl", "_color_batch_impl", "_coarsen_batch_impl",
]
