"""`GraphBatch`: many graphs, few shapes, one dispatch per shape.

Every facade entry point in ``repro.api`` processes exactly one graph per
call; under many-graph traffic that serializes dispatch and recompiles per
vertex count.  ``GraphBatch`` buckets a list of :class:`~repro.graphs.handle.
Graph` handles by padded ELL shape — power-of-two rows x power-of-two max
degree, reusing the worklist bucket policy from ``mis2_compacted``
(``core.mis2._bucket``) — and stacks each bucket's padded adjacency into
one ``[B, rows, width]`` array.  The batched pipelines then vmap the dense
fixed points over each bucket: one XLA compilation per bucket shape, ``B``
graphs per dispatch.

Per-graph identity is preserved inside the stack: each member carries its
real vertex count, a ``row_valid`` mask, and its own packing id-bit count
``b = id_bits(V_real)``, so the batched math is bit-identical to the
single-graph ``dense`` engine (the load-bearing invariant; see
``tests/test_batch.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from ..core.mis2 import _bucket
from ..core.tuples import id_bits
from ..graphs.handle import Graph, as_graph


def bucket_shape(graph: Graph) -> tuple[int, int]:
    """The (rows, width) padding bucket a graph lands in: both dimensions
    rounded up to the next power of two so mixed workloads fall into a
    handful of compiled shapes."""
    gh = as_graph(graph)
    return _bucket(gh.num_vertices), _bucket(max(1, gh.ell.width))


@dataclass(frozen=True)
class GraphBucket:
    """One stacked shape class of a :class:`GraphBatch`."""

    rows: int                 # padded vertex count (power of two)
    width: int                # padded ELL degree (power of two)
    indices: tuple            # positions of the members in the batch order
    neighbors: jnp.ndarray    # int32 [B, rows, width]
    mask: jnp.ndarray         # bool  [B, rows, width]
    row_valid: jnp.ndarray    # bool  [B, rows]  (True on real vertices)
    num_vertices: np.ndarray  # int64 [B] real vertex counts
    id_bits: jnp.ndarray      # uint32 [B] per-graph packing bit width

    @property
    def size(self) -> int:
        return len(self.indices)

    @property
    def shape(self) -> tuple[int, int]:
        return (self.rows, self.width)


class GraphBatch:
    """An ordered collection of graphs, stacked into shape buckets.

    Construct from any sequence of :class:`Graph` handles or bare
    structural containers::

        batch = GraphBatch([g1, g2, g3, ...])
        for bucket in batch.buckets:   # one vmapped dispatch each
            ...

    Results are always reported in the original input order (each bucket
    remembers its members' positions).  Stacking reuses each handle's
    cached padded ELL, so re-batching the same graphs is cheap.
    """

    def __init__(self, graphs: Sequence):
        if isinstance(graphs, GraphBatch):
            self.graphs = graphs.graphs
            self.buckets = graphs.buckets
            return
        self.graphs: list[Graph] = [as_graph(g) for g in graphs]
        if not self.graphs:
            raise ValueError("GraphBatch needs at least one graph")
        by_shape: dict[tuple[int, int], list[int]] = {}
        for i, gh in enumerate(self.graphs):
            by_shape.setdefault(bucket_shape(gh), []).append(i)
        self.buckets: list[GraphBucket] = []
        for (rows, width), idxs in sorted(by_shape.items()):
            nbrs, masks, valid, nv, bits = [], [], [], [], []
            for i in idxs:
                gh = self.graphs[i]
                ell = gh.padded_ell(rows, width)
                nbrs.append(ell.neighbors)
                masks.append(ell.mask)
                v = gh.num_vertices
                valid.append(np.arange(rows) < v)
                nv.append(v)
                bits.append(id_bits(v))
            self.buckets.append(GraphBucket(
                rows=rows, width=width, indices=tuple(idxs),
                neighbors=jnp.stack(nbrs), mask=jnp.stack(masks),
                row_valid=jnp.asarray(np.stack(valid)),
                num_vertices=np.asarray(nv, dtype=np.int64),
                id_bits=jnp.asarray(np.asarray(bits, dtype=np.uint32))))

    def __len__(self) -> int:
        return len(self.graphs)

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    @property
    def bucket_shapes(self) -> list[tuple[int, int, int]]:
        """[(rows, width, member count)] per bucket — the compilation
        footprint of a batched dispatch."""
        return [(b.rows, b.width, b.size) for b in self.buckets]

    def stats(self) -> dict:
        padded = sum(b.size * b.rows * b.width for b in self.buckets)
        real = sum(g.num_entries for g in self.graphs)
        return {
            "num_graphs": len(self.graphs),
            "num_buckets": self.num_buckets,
            "bucket_shapes": self.bucket_shapes,
            "padding_ratio": padded / max(1, real),
        }

    def __repr__(self) -> str:
        shapes = ", ".join(f"{r}x{w}:{n}" for r, w, n in self.bucket_shapes)
        return f"GraphBatch({len(self.graphs)} graphs, buckets=[{shapes}])"


def as_graph_batch(obj) -> GraphBatch:
    """Coerce a GraphBatch, or any sequence of graphs, to a GraphBatch."""
    return obj if isinstance(obj, GraphBatch) else GraphBatch(obj)
