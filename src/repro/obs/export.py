"""Snapshot exporters: Prometheus text exposition and canonical JSON.

Both operate on :class:`~repro.obs.registry.Snapshot` values, so a scrape
is just ``to_prometheus(obs.snapshot())`` — no live-registry traversal,
no locking on the scrape path, and the same snapshot can be diffed by a
gate and exported to a dashboard without re-reading.
"""
from __future__ import annotations

from .registry import Snapshot

_PROM_HELP = {
    "counter": "counter",
    "gauge": "gauge",
    # no bucket config: histograms export the summary-style _count/_sum
    # (+ _min/_max gauges), which is what the gates and dashboards consume
    "histogram": "summary",
}


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _prom_labels(labels: tuple) -> str:
    if not labels:
        return ""
    quoted = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + quoted + "}"


def to_prometheus(snapshot: Snapshot, prefix: str = "repro_") -> str:
    """Render a snapshot in the Prometheus text exposition format."""
    lines: list[str] = []
    seen_type: set[str] = set()
    for s in snapshot:
        base = prefix + _prom_name(s.name)
        if base not in seen_type:
            seen_type.add(base)
            lines.append(f"# TYPE {base} {_PROM_HELP[s.kind]}")
        lab = _prom_labels(s.labels)
        if s.kind == "histogram":
            lines.append(f"{base}_count{lab} {s.count}")
            lines.append(f"{base}_sum{lab} {_fmt(s.value)}")
            if s.count:
                lines.append(f"{base}_min{lab} {_fmt(s.min)}")
                lines.append(f"{base}_max{lab} {_fmt(s.max)}")
        else:
            lines.append(f"{base}{lab} {_fmt(s.value)}")
    return "\n".join(lines) + "\n"


def _fmt(x: float) -> str:
    return str(int(x)) if float(x).is_integer() else repr(float(x))


def to_json(snapshot: Snapshot, indent: int = 2) -> str:
    """Canonical JSON form (round-trips through ``Snapshot.from_json``)."""
    return snapshot.to_json(indent=indent)


def from_json(text: str) -> Snapshot:
    return Snapshot.from_json(text)
