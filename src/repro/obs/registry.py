"""Typed process-wide metrics registry: counters, gauges, histograms.

Every ad-hoc stats object in the repo (``HOTLOOP_STATS``, ``SETUP_STATS``,
serve's ``CacheStats``/``ServeStats``, ``WarmRegistry`` compile churn,
``Graph`` conversion counters, the distributed engines' collective-byte
accounting) now writes through here, so one :func:`MetricsRegistry.snapshot`
captures the execution shape of the whole process — dispatches, in-loop
host syncs, compiles, cache traffic, wire bytes — with one schema and one
time semantics (delta-since-snapshot).

Design constraints, in order:

* **Writes are cheap.**  A counter increment is a dict lookup plus a float
  add under an ``RLock``; handles are cached per ``(name, labels)`` so hot
  loops hold a bound handle and never re-resolve.
* **Cardinality is bounded.**  Label *names* come from code; label
  *values* must be short identifier-like tokens and each metric admits at
  most :data:`MAX_SERIES_PER_METRIC` distinct label sets.  Feeding an
  unbounded value (a raw graph digest, a request id) raises
  :class:`CardinalityError` instead of silently growing the registry —
  put unbounded identity in span attrs, never in metric labels.
* **Snapshots are values.**  :class:`Snapshot` is an immutable copy with
  ``delta``/``value``/``total`` arithmetic and a canonical JSON form, so
  tests and the ``tools/check_shape.py`` gates diff snapshots instead of
  resetting global state under each other.
"""
from __future__ import annotations

import json
import re
import threading
from dataclasses import dataclass, field
from typing import Iterator, Optional

KINDS = ("counter", "gauge", "histogram")

MAX_SERIES_PER_METRIC = 64
_LABEL_VALUE_RE = re.compile(r"^[A-Za-z0-9_][A-Za-z0-9_.:+/-]{0,47}$")
_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_.]*$")


class CardinalityError(ValueError):
    """A metric label set would grow the registry without bound."""


def _labelkey(labels: Optional[dict]) -> tuple:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclass
class _Series:
    kind: str
    value: float = 0.0
    # histogram moments (running; no buckets — min/max/count/sum answer
    # every question the gates and benchmarks ask without bucket config)
    count: int = 0
    min: float = float("inf")
    max: float = float("-inf")

    def observe(self, x: float) -> None:
        self.count += 1
        self.value += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)

    def stats(self) -> dict:
        if self.count == 0:
            return {"count": 0, "sum": 0.0}
        return {"count": self.count, "sum": self.value,
                "min": self.min, "max": self.max,
                "mean": self.value / self.count}

    def zero(self) -> None:
        self.value = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")


class _Handle:
    """A bound (name, labels) series; cached, safe to hold across resets."""

    __slots__ = ("_series", "_lock", "name", "labels")

    def __init__(self, series: _Series, lock: threading.RLock,
                 name: str, labels: tuple):
        self._series = series
        self._lock = lock
        self.name = name
        self.labels = labels

    @property
    def value(self) -> float:
        with self._lock:
            return self._series.value


class Counter(_Handle):
    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._series.value += n

    def set_(self, v: float) -> None:
        """Absolute set — exists only for the legacy ``stats.field += n``
        shims (property setters); new code should :meth:`inc`."""
        with self._lock:
            self._series.value = float(v)


class Gauge(_Handle):
    def set(self, v: float) -> None:
        with self._lock:
            self._series.value = float(v)

    def add(self, n: float) -> None:
        with self._lock:
            self._series.value += n


class Histogram(_Handle):
    def observe(self, x: float) -> None:
        with self._lock:
            self._series.observe(float(x))

    @property
    def stats(self) -> dict:
        with self._lock:
            return self._series.stats()


@dataclass(frozen=True)
class Sample:
    """One immutable series reading inside a :class:`Snapshot`."""

    name: str
    labels: tuple           # sorted ((k, v), ...) pairs
    kind: str
    value: float            # counter/gauge value; histogram sum
    count: int = 0          # histogram observation count
    min: float = 0.0
    max: float = 0.0

    def as_dict(self) -> dict:
        d = {"labels": dict(self.labels), "kind": self.kind,
             "value": self.value}
        if self.kind == "histogram":
            d["count"] = self.count
            if self.count:
                d.update(min=self.min, max=self.max,
                         mean=self.value / self.count)
        return d


class Snapshot:
    """An immutable point-in-time copy of the registry.

    ``snapshot.value(name, labels)`` reads one series (0 if absent),
    ``snapshot.total(name)`` sums a metric across label sets, and
    ``later.delta(earlier)`` subtracts counters/histograms (gauges keep
    their later reading) — the primitive every execution-shape gate is
    built on.  ``to_json``/``from_json`` round-trip exactly.
    """

    def __init__(self, samples: dict):
        self._samples: dict[tuple, Sample] = samples

    def __iter__(self) -> Iterator[Sample]:
        return iter(sorted(self._samples.values(),
                           key=lambda s: (s.name, s.labels)))

    def __len__(self) -> int:
        return len(self._samples)

    def value(self, name: str, labels: Optional[dict] = None,
              default: float = 0.0) -> float:
        s = self._samples.get((name, _labelkey(labels)))
        return s.value if s is not None else default

    def count(self, name: str, labels: Optional[dict] = None) -> int:
        s = self._samples.get((name, _labelkey(labels)))
        return s.count if s is not None else 0

    def total(self, name: str) -> float:
        return sum(s.value for s in self._samples.values()
                   if s.name == name)

    def delta(self, earlier: "Snapshot") -> "Snapshot":
        out: dict[tuple, Sample] = {}
        for key, s in self._samples.items():
            prev = earlier._samples.get(key)
            if s.kind == "gauge":
                d = s
            elif prev is None:
                d = s
            else:
                d = Sample(s.name, s.labels, s.kind,
                           s.value - prev.value, s.count - prev.count,
                           s.min, s.max)
            if d.value != 0.0 or d.count != 0:
                out[key] = d
        return Snapshot(out)

    def as_dict(self) -> dict:
        """Canonical nested form ``{metric: [sample, ...]}`` (sorted)."""
        out: dict[str, list] = {}
        for s in self:
            out.setdefault(s.name, []).append(s.as_dict())
        return out

    def flat(self) -> dict:
        """Compact one-level form ``{"name{k=v,...}": value}`` — counters
        and gauges map to their value, histograms to ``[count, sum]``.
        This is the form embedded in span records and ``BENCH_*.json``."""
        out = {}
        for s in self:
            key = s.name if not s.labels else (
                s.name + "{" + ",".join(f"{k}={v}" for k, v in s.labels)
                + "}")
            out[key] = [s.count, s.value] if s.kind == "histogram" \
                else s.value
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.as_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Snapshot":
        samples: dict[tuple, Sample] = {}
        for name, entries in json.loads(text).items():
            for e in entries:
                labels = _labelkey(e.get("labels"))
                samples[(name, labels)] = Sample(
                    name, labels, e["kind"], e["value"],
                    e.get("count", 0), e.get("min", 0.0), e.get("max", 0.0))
        return cls(samples)


class Capture:
    """Context-scoped metric capture: deltas since ``__enter__``.

    The registry-native replacement for the ``STATS.reset()`` footgun —
    two tests (or two threads) capturing concurrently cannot clobber each
    other because neither mutates shared state::

        with obs.capture() as cap:
            repro.mis2(g, engine="compacted_resident")
        assert cap.value("mis2.resident_dispatches") == 1
    """

    def __init__(self, registry: "MetricsRegistry"):
        self._registry = registry
        self._base: Optional[Snapshot] = None
        self._final: Optional[Snapshot] = None

    def __enter__(self) -> "Capture":
        self._base = self._registry.snapshot()
        return self

    def __exit__(self, *exc) -> None:
        self._final = self._registry.snapshot().delta(self._base)

    def delta(self) -> Snapshot:
        if self._final is not None:
            return self._final
        if self._base is None:
            raise RuntimeError("capture() used outside its with-block")
        return self._registry.snapshot().delta(self._base)

    def value(self, name: str, labels: Optional[dict] = None) -> float:
        return self.delta().value(name, labels)

    def count(self, name: str, labels: Optional[dict] = None) -> int:
        return self.delta().count(name, labels)

    def total(self, name: str) -> float:
        return self.delta().total(name)


@dataclass
class MetricsRegistry:
    """Thread-safe registry of named, labeled metric series."""

    max_series_per_metric: int = MAX_SERIES_PER_METRIC
    _lock: threading.RLock = field(default_factory=threading.RLock)
    _series: dict = field(default_factory=dict)     # (name, labelkey) -> _Series
    _kinds: dict = field(default_factory=dict)      # name -> kind
    _handles: dict = field(default_factory=dict)    # (name, labelkey) -> _Handle

    def _resolve(self, name: str, labels: Optional[dict], kind: str,
                 cls) -> _Handle:
        key = (name, _labelkey(labels))
        handle = self._handles.get(key)
        if handle is not None:
            if self._kinds[name] != kind:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{self._kinds[name]}, not {kind}")
            return handle
        with self._lock:
            handle = self._handles.get(key)
            if handle is not None:
                return handle
            if not _NAME_RE.match(name):
                raise ValueError(f"bad metric name {name!r}")
            prev_kind = self._kinds.setdefault(name, kind)
            if prev_kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {prev_kind}, "
                    f"not {kind}")
            for _, v in key[1]:
                if not _LABEL_VALUE_RE.match(v):
                    raise CardinalityError(
                        f"label value {v!r} on {name!r} is not a bounded "
                        "identifier (put unbounded identity — digests, "
                        "request ids — in span attrs, not metric labels)")
            n_series = sum(1 for (n, _) in self._series if n == name)
            if n_series >= self.max_series_per_metric:
                raise CardinalityError(
                    f"metric {name!r} exceeds {self.max_series_per_metric} "
                    "label sets — a label value is unbounded")
            series = self._series[key] = _Series(kind)
            handle = self._handles[key] = cls(series, self._lock, name,
                                              key[1])
            return handle

    def counter(self, name: str, labels: Optional[dict] = None) -> Counter:
        return self._resolve(name, labels, "counter", Counter)

    def gauge(self, name: str, labels: Optional[dict] = None) -> Gauge:
        return self._resolve(name, labels, "gauge", Gauge)

    def histogram(self, name: str,
                  labels: Optional[dict] = None) -> Histogram:
        return self._resolve(name, labels, "histogram", Histogram)

    # -- reading ------------------------------------------------------------

    def snapshot(self) -> Snapshot:
        with self._lock:
            return Snapshot({
                key: Sample(key[0], key[1], s.kind, s.value, s.count,
                            s.min, s.max)
                for key, s in self._series.items()})

    def capture(self) -> Capture:
        return Capture(self)

    # -- scoping ------------------------------------------------------------

    def reset(self, prefix: Optional[str] = None) -> None:
        """Zero every series (or those whose name starts with ``prefix``).

        Series objects stay alive so cached handles (and the legacy stats
        shims built on them) remain valid.  Prefer :meth:`capture` in
        tests — reset is process-global and order-dependent by nature.
        """
        with self._lock:
            for (name, _), s in self._series.items():
                if prefix is None or name.startswith(prefix):
                    s.zero()


# The process-wide registry.  Import as ``from repro import obs`` and use
# ``obs.metrics`` — everything in-repo writes here.
metrics = MetricsRegistry()
