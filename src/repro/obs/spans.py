"""Structured span tracing: nested wall-time scopes with metric deltas.

A span is a named ``with`` scope that records wall time
(``time.perf_counter``), arbitrary attrs, child spans, and — the part a
plain profiler cannot give you — the *metric deltas* that occurred inside
it: dispatches, in-loop host syncs, compiles, cache hits, collective
bytes.  Spans nest per-thread; the facade opens a root span per call and
attaches its serialized tree to the returned ``Result`` as a
:class:`Provenance` record, so any answer can explain its own cost::

    r = repro.mis2(g)
    r.provenance.span["duration_s"]            # wall time
    r.provenance.span["metrics"]               # execution-shape deltas
    json.dumps(r.provenance.as_dict())         # fully serializable

Device timing: pass ``fence=<arrays>`` and the span blocks on
``jax.block_until_ready`` before closing, so ``duration_s`` covers device
execution rather than async dispatch.  Every closed span also lands one
observation in the ``span.seconds{span=<name>}`` histogram (names are
code-defined, so cardinality stays bounded).
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional

from .registry import metrics as _metrics

_TLS = threading.local()
_RECENT_ROOTS: deque = deque(maxlen=64)

_SCALARS = (str, int, float, bool, type(None))


def _stack() -> list:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


@dataclass
class Span:
    """One recorded scope: name, attrs, wall time, children, metric deltas."""

    name: str
    attrs: dict = field(default_factory=dict)
    start_s: float = 0.0
    duration_s: float = 0.0
    metrics: dict = field(default_factory=dict)   # flat nonzero deltas
    children: list = field(default_factory=list)

    def annotate(self, **attrs) -> "Span":
        """Attach attrs discovered mid-scope (iteration counts, digests)."""
        self.attrs.update({k: v if isinstance(v, _SCALARS) else str(v)
                           for k, v in attrs.items()})
        return self

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "attrs": dict(self.attrs),
            "duration_s": self.duration_s,
            "metrics": dict(self.metrics),
            "children": [c.to_dict() for c in self.children],
        }


@contextmanager
def span(name: str, *, fence=None, **attrs):
    """Open a nested tracing scope; yields the live :class:`Span`.

    ``fence`` (optional pytree of jax arrays) is blocked on before the
    span closes so the duration covers device execution.  Keyword attrs
    are serialized into the record (non-scalars via ``str``).
    """
    base = _metrics.snapshot()
    sp = Span(name,
              {k: v if isinstance(v, _SCALARS) else str(v)
               for k, v in attrs.items()},
              time.perf_counter())
    stack = _stack()
    parent = stack[-1] if stack else None
    stack.append(sp)
    try:
        yield sp
    finally:
        if fence is not None:
            import jax

            jax.block_until_ready(fence)
        sp.duration_s = time.perf_counter() - sp.start_s
        sp.metrics = _metrics.snapshot().delta(base).flat()
        stack.pop()
        if parent is not None:
            parent.children.append(sp)
        else:
            _RECENT_ROOTS.append(sp)
        _metrics.histogram("span.seconds",
                           labels={"span": name}).observe(sp.duration_s)


def current_span() -> Optional[Span]:
    """The innermost open span on this thread, or None."""
    stack = _stack()
    return stack[-1] if stack else None


def recent_spans(n: int = 10) -> list:
    """The last ``n`` closed *root* spans (process-wide, bounded buffer)."""
    return list(_RECENT_ROOTS)[-n:]


@dataclass
class Provenance:
    """Serializable cost record attached to every facade ``Result``.

    ``span`` is the root :class:`Span` tree as a plain dict (wall time +
    metric deltas per scope); ``digest`` ties the record to the payload it
    explains, so a provenance pulled out of a cache or a log can always be
    matched back to its answer.
    """

    kind: str                    # facade entry: mis2 | color | amg_setup...
    engine: str
    backend: str                 # executing platform (cpu | tpu | gpu)
    digest: str
    span: dict = field(default_factory=dict)

    @property
    def wall_time_s(self) -> float:
        return self.span.get("duration_s", 0.0)

    @property
    def metrics(self) -> dict:
        """Flat metric deltas attributed to this call (root-span scope)."""
        return self.span.get("metrics", {})

    def as_dict(self) -> dict:
        return {"kind": self.kind, "engine": self.engine,
                "backend": self.backend, "digest": self.digest,
                "span": self.span}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.as_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Provenance":
        return cls(**json.loads(text))
