"""``repro.obs`` — unified observability: metrics, spans, exporters.

One registry for the whole process::

    from repro import obs

    obs.metrics.counter("mis2.dispatches", labels={"engine": "dense"}).inc()
    snap = obs.snapshot()                 # execution shape, one object
    snap.value("mis2.resident_dispatches")
    print(obs.to_prometheus(snap))        # scrape endpoint body

Context-scoped capture (the test-safe replacement for resetting global
stats)::

    with obs.capture() as cap:
        repro.mis2(g, engine="compacted_resident")
    assert cap.value("mis2.resident_dispatches") == 1
    assert cap.value("mis2.host_syncs") == 0

Span tracing (nested wall time + metric deltas; the facade attaches the
root span to every ``Result`` as ``result.provenance``)::

    with obs.span("serve.dispatch", bucket="1024x32"):
        ...

Every legacy stats object (``HOTLOOP_STATS``, ``SETUP_STATS``,
``CacheStats``, ``ServeStats``, ``WarmRegistry`` counters, ``Graph``
conversion counts) is a live view over this registry — reading either
surface sees the same numbers.
"""
from .export import from_json, to_json, to_prometheus
from .registry import (
    Capture,
    CardinalityError,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Sample,
    Snapshot,
    metrics,
)
from .spans import Provenance, Span, current_span, recent_spans, span


def snapshot() -> Snapshot:
    """Snapshot the process-wide registry."""
    return metrics.snapshot()


def capture() -> Capture:
    """Context-scoped delta capture over the process-wide registry."""
    return metrics.capture()


def reset(prefix=None) -> None:
    """Zero the process-wide registry (or one name prefix).  Prefer
    :func:`capture` in tests — reset is global and order-dependent."""
    metrics.reset(prefix)


__all__ = [
    "metrics", "snapshot", "capture", "reset",
    "MetricsRegistry", "Snapshot", "Sample", "Capture",
    "Counter", "Gauge", "Histogram", "CardinalityError",
    "span", "Span", "current_span", "recent_spans", "Provenance",
    "to_prometheus", "to_json", "from_json",
]
