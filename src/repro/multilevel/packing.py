"""Cluster/color row packing for multicolor Gauss-Seidel (paper Alg. 4).

The apply-phase layout is a per-color padded int32 matrix
``rows[c][n_clusters_c, max_len_c]`` (sentinel = V, scatter-dropped).

Host backend: the numpy packing moved from
``solvers.multicolor_gs._pack_clusters``.  Device backend: one stable
device sort by ``(color(cluster), cluster, vertex)`` plus a scatter into
a single ``[num_clusters, max_len]`` block; the per-color views are
slices of that device-resident block, elementwise identical to the host
arrays (asserted in ``tests/test_multilevel.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# host backend (numpy; the reference)
# ---------------------------------------------------------------------------

def pack_clusters_host(labels: np.ndarray, cluster_colors: np.ndarray,
                       num_colors: int, v: int):
    """Group rows by (color(cluster), cluster) into padded per-color arrays."""
    order = np.lexsort((np.arange(v), labels))
    sorted_labels = labels[order]
    # row lists per cluster (ascending vertex ids — deterministic)
    starts = np.flatnonzero(np.r_[True, sorted_labels[1:] != sorted_labels[:-1]])
    ends = np.r_[starts[1:], v]
    cluster_ids = sorted_labels[starts]
    color_rows = []
    for c in range(num_colors):
        sel = np.flatnonzero(cluster_colors[cluster_ids] == c)
        if len(sel) == 0:
            continue
        lens = ends[sel] - starts[sel]
        max_len = int(lens.max())
        mat = np.full((len(sel), max_len), v, dtype=np.int32)
        for i, s in enumerate(sel):
            mat[i, : lens[i]] = order[starts[s]:ends[s]]
        color_rows.append(jnp.asarray(mat))
    return tuple(color_rows)


# ---------------------------------------------------------------------------
# device backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_colors",))
def _pack_analyze_device(labels, cluster_colors, *, num_colors: int):
    """Device sort + per-(color, cluster) geometry.

    Returns ``(row_order[V], per_color_clusters[C], per_color_maxlen[C],
    max_len)`` where ``row_order`` lists vertices sorted by (cluster
    color, cluster id, vertex id).
    """
    v = labels.shape[0]
    c = max(1, num_colors)
    color_of_v = cluster_colors[labels].astype(jnp.int64)
    key = (color_of_v * v + labels.astype(jnp.int64))
    row_order = jnp.argsort(key, stable=True).astype(jnp.int32)
    lab_s = labels[row_order]
    sizes = jnp.zeros(v, jnp.int32).at[labels].add(1)
    # one representative row per cluster -> per-color cluster counts/maxlens
    head = jnp.concatenate([jnp.ones(1, bool), lab_s[1:] != lab_s[:-1]])
    ccol = jnp.clip(cluster_colors[lab_s], 0, c)
    csize = sizes[lab_s]
    nclusters = jnp.zeros(c + 1, jnp.int32).at[
        jnp.where(head, ccol, c)].add(1)[:-1]
    maxlen = jnp.zeros(c + 1, jnp.int32).at[
        jnp.where(head, ccol, c)].max(csize)[:-1]
    return row_order, nclusters, maxlen, jnp.max(sizes)


@functools.partial(jax.jit, static_argnames=("num_clusters", "max_len"))
def _pack_rows_device(row_order, labels, *, num_clusters: int, max_len: int):
    """Scatter the sorted vertices into one padded ``[num_clusters,
    max_len]`` block, cluster rows ordered by (color, cluster id) — the
    concatenation of the per-color host matrices (sentinel = V)."""
    v = labels.shape[0]
    lab_s = labels[row_order]
    head = jnp.concatenate([jnp.ones(1, bool), lab_s[1:] != lab_s[:-1]])
    crow = jnp.cumsum(head.astype(jnp.int32)) - 1       # cluster rank
    pos = jnp.arange(v, dtype=jnp.int32)
    starts = jnp.where(head, pos, 0)
    starts = jax.lax.cummax(starts)                     # start of own cluster
    slot = pos - starts
    block = jnp.full((num_clusters, max(1, max_len)), v, jnp.int32)
    return block.at[crow, jnp.clip(slot, 0, max(1, max_len) - 1)].set(
        row_order, mode="drop")


def pack_clusters_device(labels, cluster_colors, num_colors: int, v: int):
    """Device packing; returns the same per-color tuple as the host
    backend, as slices of one device-resident block (no host copy of the
    packed rows — only the per-color geometry scalars come back)."""
    from jax.experimental import enable_x64

    labels_j = jnp.asarray(np.asarray(labels, dtype=np.int32))
    colors_j = jnp.asarray(np.asarray(cluster_colors, dtype=np.int32))
    with enable_x64():          # int64 (color, cluster) sort keys
        row_order, nclusters, maxlen, _ = _pack_analyze_device(
            labels_j, colors_j, num_colors=num_colors)
        ncl = np.asarray(nclusters[:num_colors])        # [C] ints (geometry)
        mll = np.asarray(maxlen[:num_colors])
        total = int(ncl.sum())
        lmax = int(mll.max()) if num_colors else 1
        block = _pack_rows_device(row_order, labels_j,
                                  num_clusters=max(1, total),
                                  max_len=max(1, lmax))
    color_rows = []
    start = 0
    for c in range(num_colors):
        n = int(ncl[c])
        if n == 0:
            continue
        color_rows.append(block[start:start + n, : int(mll[c])])
        start += n
    return tuple(color_rows)
