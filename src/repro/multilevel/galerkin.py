"""Galerkin triple product ``A_c = P^T A P`` as a padded sorted-COO SpGEMM.

Both multilevel engines compute the product through ONE canonical
two-stage algorithm so their hierarchies stay bit-identical (the
PR-3/PR-4 digest discipline extended to floats):

* stage 1 — ``Q = A P``: expand every (A slot, P-row slot) candidate
  ``val = A[v,w] * P[w,b]`` in fixed ``(v, j, l)`` order over the padded
  ELL slot grid (padding contributes exact ``0.0``, an IEEE no-op inside
  the later sums), stable-sort by the packed key ``v*K + b``, sum each
  run sequentially in sorted order, drop exact-zero sums, repack to a
  padded ``[V, Dq]`` row form;
* stage 2 — ``A_c = P^T Q``: expand ``val = P[v,a] * Q[v,b]`` in fixed
  ``(v, i, m)`` order, stable-sort by ``a*K + b``, run-sum, zero-drop.

Two stages keep the expansion at ``O(E·Dp + V·Dp·Dq)`` candidates
instead of the quartic ``O(E·Dp²)`` of a one-shot triple expansion —
the difference between milliseconds and minutes on the denser coarse
levels.

The host backend mirrors the device backend primitive-for-primitive
(``np.argsort(kind='stable')``/``np.add.at`` against jnp stable argsort/
``segment_sum`` — both accumulate in order on CPU, asserted by the
digest-parity gate).  All arithmetic is float64 (the device backend runs
under ``jax.experimental.enable_x64``); the float32 results agree with
the legacy scipy path ``graphs.ops.galerkin_coarse_matrix`` to rounding
(property-tested in ``tests/test_multilevel.py``) and agree across the
two backends bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.csr import CSRMatrix, ELLMatrix


# ---------------------------------------------------------------------------
# device backend (jitted, x64)
# ---------------------------------------------------------------------------

def _kept_row_slots(rows, keep, num_rows: int):
    """Scatter coordinates for a sorted kept-entry stream: ``r`` is the
    entry's row (sentinel ``num_rows`` when dropped), ``s`` its
    within-row rank among kept entries.  Shared by every repack kernel so
    the slot arithmetic cannot drift between them."""
    counts = jnp.zeros(num_rows + 1, jnp.int32).at[
        jnp.where(keep, rows, num_rows)].add(1)[:-1]
    starts = jnp.cumsum(counts) - counts
    rank = jnp.cumsum(keep.astype(jnp.int32)) - 1
    slot = rank - starts[jnp.clip(rows, 0, num_rows - 1)]
    return jnp.where(keep, rows, num_rows), slot


def _run_sums_device(keys, vals):
    """Stable-sort ``(keys, vals)``, sum each key run sequentially, drop
    exact-zero totals.  Returns ``(keys_sorted, sums, keep)`` with
    ``sums`` replicated over each run and ``keep`` marking nonzero run
    heads."""
    order = jnp.argsort(keys, stable=True)
    keys = keys[order]
    vals = vals[order]
    head = jnp.concatenate([jnp.ones(1, bool), keys[1:] != keys[:-1]])
    rid = jnp.cumsum(head.astype(jnp.int32)) - 1
    totals = jax.ops.segment_sum(vals, rid, num_segments=vals.shape[0])
    sums = totals[rid]
    keep = head & (sums != 0.0)
    return keys, sums, keep


@functools.partial(jax.jit, static_argnames=("key_base",))
def _spgemm_stage1_device(a_cols, a_vals64, p_cols, p_vals64, *,
                          key_base: int):
    """``Q = A P`` candidates: keys ``v*K + b`` over the ``[V, D, Dp]``
    slot grid in ``(v, j, l)`` order, run-summed.  Also returns the
    padded row width of Q (``dq``, the scalar the repack dispatch
    needs)."""
    v, d = a_cols.shape
    dp = p_cols.shape[1]
    w = a_cols                                               # [V, D]
    vals = (a_vals64[:, :, None] * p_vals64[w]).reshape(-1)
    vids = jnp.arange(v, dtype=jnp.int64)
    keys = (vids[:, None, None] * key_base
            + p_cols.astype(jnp.int64)[w]
            + jnp.zeros((v, d, dp), dtype=jnp.int64)).reshape(-1)
    keys, sums, keep = _run_sums_device(keys, vals)
    rows = (keys // key_base).astype(jnp.int32)
    counts = jnp.zeros(v + 1, jnp.int32).at[
        jnp.where(keep, rows, v)].add(1)[:-1]
    return keys, sums, keep, jnp.max(counts)


@functools.partial(jax.jit, static_argnames=("key_base", "num_rows",
                                             "width"))
def _coo_rows_repack_device(keys, sums, keep, *, key_base: int,
                            num_rows: int, width: int):
    """Repack kept sorted runs into a padded f64 row form ``(cols[R, W],
    vals64[R, W])`` (padding col 0, val 0.0) — the Q input of stage 2."""
    rows = (keys // key_base).astype(jnp.int32)
    cols = (keys % key_base).astype(jnp.int32)
    r, slot = _kept_row_slots(rows, keep, num_rows)
    s = jnp.clip(slot, 0, max(1, width) - 1)
    out_cols = jnp.zeros((num_rows, max(1, width)), jnp.int32
                         ).at[r, s].set(cols, mode="drop")
    out_vals = jnp.zeros((num_rows, max(1, width)), jnp.float64
                         ).at[r, s].set(sums, mode="drop")
    return out_cols, out_vals


@functools.partial(jax.jit, static_argnames=("key_base",))
def _spgemm_stage2_device(p_cols, p_vals64, q_cols, q_vals64, *,
                          key_base: int):
    """``A_c = P^T Q`` candidates: keys ``a*K + b`` over the
    ``[V, Dp, Dq]`` pair grid in ``(v, i, m)`` order, run-summed; returns
    the per-coarse-row nnz histogram inputs (counts max + total) too."""
    v, dp = p_cols.shape
    dq = q_cols.shape[1]
    vals = (p_vals64[:, :, None] * q_vals64[:, None, :]).reshape(-1)
    keys = (p_cols.astype(jnp.int64)[:, :, None] * key_base
            + q_cols.astype(jnp.int64)[:, None, :]
            + jnp.zeros((v, dp, dq), dtype=jnp.int64)).reshape(-1)
    keys, sums, keep = _run_sums_device(keys, vals)
    rows = (keys // key_base).astype(jnp.int32)
    counts = jnp.zeros(key_base + 1, jnp.int32).at[
        jnp.where(keep, rows, key_base)].add(1)[:-1]
    return keys, sums, keep, jnp.sum(keep, dtype=jnp.int32), jnp.max(counts)


@functools.partial(jax.jit, static_argnames=("key_base", "num_rows", "width"))
def _coo_to_ell_device(keys, sums, keep, *, key_base: int, num_rows: int,
                       width: int):
    """Repack kept sorted-COO runs into a square float32 ELL matrix.

    Follows the ``csr_to_ell_matrix`` convention exactly (padding
    ``col = row``, ``val = 0``, ``mask = False``) so the result's digest
    matches the host engine's ``csr_to_ell_matrix`` output bit for bit.
    """
    rows = (keys // key_base).astype(jnp.int32)
    cols = (keys % key_base).astype(jnp.int32)
    r, slot = _kept_row_slots(rows, keep, num_rows)
    rid = jnp.arange(num_rows, dtype=jnp.int32)
    out_cols = jnp.repeat(rid[:, None], max(1, width), axis=1)
    out_vals = jnp.zeros((num_rows, max(1, width)), jnp.float32)
    out_mask = jnp.zeros((num_rows, max(1, width)), bool)
    s = jnp.clip(slot, 0, max(1, width) - 1)
    out_cols = out_cols.at[r, s].set(cols, mode="drop")
    out_vals = out_vals.at[r, s].set(sums.astype(jnp.float32), mode="drop")
    out_mask = out_mask.at[r, s].set(True, mode="drop")
    diag = jnp.sum(jnp.where((out_cols == rid[:, None]) & out_mask,
                             out_vals, 0.0), axis=1)
    return out_cols, out_vals, out_mask, diag


# ---------------------------------------------------------------------------
# dense-accumulator device backend (sort-free).
#
# For moderate coarse sizes the product accumulates into a flat dense
# buffer (`scatter-add in candidate order` — the SAME accumulation
# sequence per output entry as the sorted-run path, so the f64 values are
# bit-identical either way) and the sparse rows are extracted with an
# integer cumsum + searchsorted compaction instead of a comparator sort.
# On CPU this is several times faster than sort-based runs; the sorted
# path remains the fallback when ``rows*cols`` would not fit a dense
# accumulator (see DENSE_ACCUM_LIMIT).
# ---------------------------------------------------------------------------

DENSE_ACCUM_LIMIT = 1 << 26          # max dense accumulator elements (f64)


@functools.partial(jax.jit, static_argnames=("num_cols",))
def _spgemm_stage1_dense_device(a_cols, a_vals64, p_cols, p_vals64, *,
                                num_cols: int):
    """``Q = A P`` into a dense ``[V, num_cols]`` accumulator; returns the
    flat dense buffer, its nonzero mask cumsum, and the Q width/nnz
    scalars the extraction dispatch needs."""
    v, d = a_cols.shape
    w = a_cols
    vals = (a_vals64[:, :, None] * p_vals64[w]).reshape(-1)
    vids = jnp.arange(v, dtype=jnp.int32)
    idx = (vids[:, None, None] * num_cols + p_cols[w]
           + jnp.zeros(a_vals64.shape + (p_cols.shape[1],),
                       dtype=jnp.int32)).reshape(-1)
    dense = jnp.zeros(v * num_cols, jnp.float64).at[idx].add(vals)
    mask = dense != 0.0
    csum = jnp.cumsum(mask.astype(jnp.int32))
    row_nnz = csum.reshape(v, num_cols)[:, -1]
    row_nnz = jnp.diff(row_nnz, prepend=jnp.int32(0))
    return dense, csum, jnp.max(row_nnz), csum[-1]


@functools.partial(jax.jit, static_argnames=("num_cols", "width",
                                             "nnz_bucket"))
def _dense_rows_extract_device(dense, csum, nnz, *, num_cols: int,
                               width: int, nnz_bucket: int):
    """Extract the nonzero entries of a flat dense ``[R, num_cols]``
    buffer into padded f64 rows ``(cols[R, W], vals64[R, W])`` (padding
    col 0 / val 0) without any comparator sort: the k-th nonzero's flat
    position is ``searchsorted(csum, k+1)``.

    ``nnz`` is traced; ``nnz_bucket`` is its pow2 padding (the repo's
    worklist bucket discipline) so the compilation is reused across
    builds with nearby nnz.
    """
    r = dense.shape[0] // num_cols
    k = max(1, nnz_bucket)
    pos = jnp.searchsorted(csum, jnp.arange(1, k + 1, dtype=jnp.int32))
    pos = jnp.clip(pos, 0, dense.shape[0] - 1)
    rows = (pos // num_cols).astype(jnp.int32)
    cols = (pos % num_cols).astype(jnp.int32)
    vals = dense[pos]
    slot = jnp.arange(k, dtype=jnp.int32) \
        - (csum[rows * num_cols] - (dense[rows * num_cols] != 0.0)
           ).astype(jnp.int32)
    out_cols = jnp.zeros((r, max(1, width)), jnp.int32)
    out_vals = jnp.zeros((r, max(1, width)), jnp.float64)
    s = jnp.clip(slot, 0, max(1, width) - 1)
    rr = jnp.where(jnp.arange(k) < nnz, rows, r)
    out_cols = out_cols.at[rr, s].set(cols, mode="drop")
    out_vals = out_vals.at[rr, s].set(vals, mode="drop")
    return out_cols, out_vals


@functools.partial(jax.jit, static_argnames=("num_cols",))
def _spgemm_stage2_dense_device(p_cols, p_vals64, q_cols, q_vals64, *,
                                num_cols: int):
    """``A_c = P^T Q`` into a dense ``[num_cols, num_cols]`` accumulator
    (coarse rows/cols); returns the flat buffer + extraction scalars."""
    v, dp = p_cols.shape
    dq = q_cols.shape[1]
    vals = (p_vals64[:, :, None] * q_vals64[:, None, :]).reshape(-1)
    idx = (p_cols[:, :, None].astype(jnp.int32) * num_cols
           + q_cols[:, None, :]
           + jnp.zeros((v, dp, dq), dtype=jnp.int32)).reshape(-1)
    dense = jnp.zeros(num_cols * num_cols, jnp.float64).at[idx].add(vals)
    mask = dense != 0.0
    csum = jnp.cumsum(mask.astype(jnp.int32))
    row_nnz = csum.reshape(num_cols, num_cols)[:, -1]
    row_nnz = jnp.diff(row_nnz, prepend=jnp.int32(0))
    return dense, csum, jnp.max(row_nnz), csum[-1]


@functools.partial(jax.jit, static_argnames=("num_cols", "num_rows",
                                             "width", "nnz_bucket"))
def _dense_to_ell_device(dense, csum, nnz, *, num_cols: int, num_rows: int,
                         width: int, nnz_bucket: int):
    """Extract a flat dense ``[num_rows(+pad), num_cols]`` coarse buffer
    into the float32 ELL convention (padding col=row, val 0, mask off) +
    the diagonal."""
    k = max(1, nnz_bucket)
    pos = jnp.searchsorted(csum, jnp.arange(1, k + 1, dtype=jnp.int32))
    pos = jnp.clip(pos, 0, dense.shape[0] - 1)
    rows = (pos // num_cols).astype(jnp.int32)
    cols = (pos % num_cols).astype(jnp.int32)
    vals = dense[pos].astype(jnp.float32)
    slot = jnp.arange(k, dtype=jnp.int32) \
        - (csum[rows * num_cols] - (dense[rows * num_cols] != 0.0)
           ).astype(jnp.int32)
    rid = jnp.arange(num_rows, dtype=jnp.int32)
    out_cols = jnp.repeat(rid[:, None], max(1, width), axis=1)
    out_vals = jnp.zeros((num_rows, max(1, width)), jnp.float32)
    out_mask = jnp.zeros((num_rows, max(1, width)), bool)
    s = jnp.clip(slot, 0, max(1, width) - 1)
    rr = jnp.where(jnp.arange(k) < nnz, rows, num_rows)
    out_cols = out_cols.at[rr, s].set(cols, mode="drop")
    out_vals = out_vals.at[rr, s].set(vals, mode="drop")
    out_mask = out_mask.at[rr, s].set(True, mode="drop")
    diag = jnp.sum(jnp.where((out_cols == rid[:, None]) & out_mask,
                             out_vals, 0.0), axis=1)
    return out_cols, out_vals, out_mask, diag


# ---------------------------------------------------------------------------
# host backend (numpy; same canonical order — np.add.at accumulates the
# sorted runs sequentially exactly like the device segment_sum)
# ---------------------------------------------------------------------------

def _run_sums_host(keys, vals):
    order = np.argsort(keys, kind="stable")
    keys = keys[order]
    vals = vals[order]
    head = np.ones(len(keys), dtype=bool)
    if len(keys):
        head[1:] = keys[1:] != keys[:-1]
    rid = np.cumsum(head) - 1
    totals = np.zeros(int(rid[-1]) + 1 if len(rid) else 0, dtype=np.float64)
    np.add.at(totals, rid, vals)
    hkeys = keys[head]
    keep = totals != 0.0
    return hkeys[keep], totals[keep]


def galerkin_coo_host(a_ell: ELLMatrix, p_cols: np.ndarray,
                      p_vals64: np.ndarray, num_aggregates: int):
    """Host-backend canonical two-stage Galerkin product.

    ``p_cols``/``p_vals64`` is the padded P row form (any width; padded
    slots ``col 0, val 0.0``).  Returns ``(rows, cols, vals_f64)`` of the
    kept (nonzero) coarse entries, sorted by (row, col).
    """
    v = a_ell.num_rows
    key_base = max(1, v, int(num_aggregates))
    a_cols = np.asarray(a_ell.cols)
    a_vals = np.where(np.asarray(a_ell.mask),
                      np.asarray(a_ell.vals, dtype=np.float64), 0.0)
    # stage 1: Q = A P
    w = a_cols
    vals1 = (a_vals[:, :, None] * p_vals64[w]).reshape(-1)
    vids = np.arange(v, dtype=np.int64)
    keys1 = np.broadcast_to(
        vids[:, None, None] * key_base + p_cols.astype(np.int64)[w],
        (v, a_cols.shape[1], p_cols.shape[1])).reshape(-1)
    qkeys, qvals = _run_sums_host(keys1, vals1)
    q_cols, q_vals = _pad_p_rows(qkeys // key_base, qkeys % key_base,
                                 qvals, v)
    # stage 2: A_c = P^T Q
    vals2 = (p_vals64[:, :, None] * q_vals[:, None, :]).reshape(-1)
    keys2 = np.broadcast_to(
        p_cols.astype(np.int64)[:, :, None] * key_base
        + q_cols.astype(np.int64)[:, None, :],
        (v, p_cols.shape[1], q_cols.shape[1])).reshape(-1)
    ckeys, cvals = _run_sums_host(keys2, vals2)
    return (ckeys // key_base).astype(np.int64), \
        (ckeys % key_base).astype(np.int64), cvals


# ---------------------------------------------------------------------------
# public entry (device path) — the property-test surface
# ---------------------------------------------------------------------------

def _pad_p_rows(p_rows: np.ndarray, p_cols: np.ndarray, p_vals: np.ndarray,
                nrows: int, width: int | None = None):
    """COO prolongator -> padded row form (f64 vals; padding col 0/val 0),
    rows sorted by (row, col) like ``scipy.sparse.csr_matrix``."""
    order = np.lexsort((p_cols, p_rows))
    rows, cols = p_rows[order], p_cols[order]
    vals = np.asarray(p_vals, dtype=np.float64)[order]
    counts = np.bincount(rows, minlength=nrows)
    d = int(width) if width is not None else max(1, int(counts.max()) if
                                                 len(counts) else 1)
    cmat = np.zeros((nrows, d), dtype=np.int32)
    vmat = np.zeros((nrows, d), dtype=np.float64)
    slot = np.arange(len(rows)) - np.repeat(np.cumsum(counts) - counts, counts)
    cmat[rows, slot] = cols
    vmat[rows, slot] = vals
    return cmat, vmat


def galerkin(a: CSRMatrix, p_rows: np.ndarray, p_cols: np.ndarray,
             p_vals: np.ndarray, num_aggregates: int) -> CSRMatrix:
    """Device-computed ``A_c = P^T A P`` with P in COO (rectangular ok).

    Drop-in counterpart of :func:`repro.graphs.ops.galerkin_coarse_matrix`
    (scipy): same signature, same result to float32 rounding — the
    property tests in ``tests/test_multilevel.py`` compare the two on
    random CSR matrices with empty rows, singleton aggregates and
    rectangular P.
    """
    from ..graphs.handle import as_graph
    from .hierarchy import x64_context

    nagg = max(1, int(num_aggregates))
    v = a.num_rows
    key_base = max(1, v, nagg)
    indptr = np.zeros(nagg + 1, dtype=np.int64)
    if a.num_entries == 0 or v == 0:       # empty matrix -> empty product
        return CSRMatrix(jnp.asarray(indptr.astype(np.int32)),
                         jnp.asarray(np.zeros(0, np.int32)),
                         jnp.asarray(np.zeros(0, np.float32)))
    a_ell = as_graph(a).ell_matrix
    pc, pv = _pad_p_rows(np.asarray(p_rows), np.asarray(p_cols),
                         np.asarray(p_vals), v)
    with x64_context():
        a_vals64 = jnp.where(a_ell.mask, a_ell.vals.astype(jnp.float64), 0.0)
        k1, s1, kp1, dq = _spgemm_stage1_device(
            a_ell.cols, a_vals64, jnp.asarray(pc), jnp.asarray(pv),
            key_base=key_base)
        q_cols, q_vals = _coo_rows_repack_device(
            k1, s1, kp1, key_base=key_base, num_rows=v, width=int(dq))
        keys, sums, keep, _, _ = _spgemm_stage2_device(
            jnp.asarray(pc), jnp.asarray(pv), q_cols, q_vals,
            key_base=key_base)
        keys, sums, keep = (np.asarray(keys), np.asarray(sums),
                            np.asarray(keep))
    rows = (keys[keep] // key_base).astype(np.int64)
    cols = (keys[keep] % key_base).astype(np.int64)
    vals = sums[keep]
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    return CSRMatrix(jnp.asarray(indptr),
                     jnp.asarray(cols.astype(np.int32)),
                     jnp.asarray(vals.astype(np.float32)))


# ---------------------------------------------------------------------------
# coarse graph structure (labels -> coarse adjacency), device backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("key_base",))
def _coarse_graph_keys_device(neighbors, mask, labels, *, key_base: int):
    """Unique sorted coarse-edge keys ``la * K + lb`` (+ the diagonal),
    the device counterpart of ``graphs.ops.coarse_graph_from_labels``."""
    la = labels.astype(jnp.int64)
    lb = labels[neighbors].astype(jnp.int64)
    keys = jnp.where(mask, la[:, None] * key_base + lb, jnp.int64(-1))
    diag = la * key_base + la
    keys = jnp.concatenate([keys.reshape(-1), diag])
    keys = jnp.sort(keys)
    head = jnp.concatenate([jnp.ones(1, bool), keys[1:] != keys[:-1]])
    keep = head & (keys >= 0)
    rows = jnp.where(keep, keys // key_base, key_base).astype(jnp.int32)
    counts = jnp.zeros(key_base + 1, jnp.int32).at[rows].add(1)[:-1]
    return keys, keep, counts, jnp.max(counts)


@functools.partial(jax.jit, static_argnames=("key_base", "num_rows", "width"))
def _coarse_graph_ell_device(keys, keep, *, key_base: int, num_rows: int,
                             width: int):
    """Repack kept coarse-edge keys into an ELL graph (padding = self)."""
    rows = jnp.where(keep, keys // key_base, num_rows).astype(jnp.int32)
    cols = (keys % key_base).astype(jnp.int32)
    r, slot = _kept_row_slots(rows, keep, num_rows)
    rid = jnp.arange(num_rows, dtype=jnp.int32)
    out_nbrs = jnp.repeat(rid[:, None], max(1, width), axis=1)
    out_mask = jnp.zeros((num_rows, max(1, width)), bool)
    s = jnp.clip(slot, 0, max(1, width) - 1)
    out_nbrs = out_nbrs.at[r, s].set(cols, mode="drop")
    out_mask = out_mask.at[r, s].set(True, mode="drop")
    return out_nbrs, out_mask
