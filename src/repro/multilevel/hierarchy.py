"""Multilevel setup orchestration: AMG hierarchies and cluster-GS packing.

Two engines, dispatched through the ``repro.api`` registry
(``multilevel: host | resident``), producing **digest-identical**
hierarchies (per-level ``A_l`` ELL digests, aggregation labels, coarse
colors — the PR-3/PR-4 bit-identity discipline):

* ``host``      the legacy orchestration: scipy smoothed prolongator,
  canonical sorted-COO Galerkin on numpy, numpy cluster packing — every
  level round-trips matrix-sized data through host memory (counted in
  ``SETUP_STATS.host_syncs``).
* ``resident``  the whole per-level setup runs jitted on device under
  ``jax.experimental.enable_x64``: prolongator assembly from aggregation
  labels via fixed-shape sort/segment-sum, the Galerkin triple product as
  a padded sorted-COO SpGEMM, coarse-level ELL repacking, and cluster/
  color row packing — reusing the PR-4 resident aggregation and coloring
  fixed points.  A full ``build_hierarchy`` is a bounded number of
  dispatches (7 per level + the aggregation's own) with zero matrix-sized
  host syncs; only per-level shape scalars (ELL widths) come back to pick
  the next dispatch's static shapes.

The solve phase (``solvers.amg.v_cycle``) is engine-agnostic: it consumes
the same :class:`AMGHierarchy` either engine builds.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

# pow2 padding for traced-count static buckets — the same policy as the
# MIS-2 worklist buckets, imported so the two can never drift
from ..core.mis2 import _bucket as _bucket_pow2
from ..graphs.csr import CSRMatrix, ELLMatrix, csr_to_ell_matrix
from ..obs import metrics as _OBS
from ..obs import span as _obs_span
from ..graphs.handle import Graph, as_graph
from ..graphs.ops import extract_diagonal, matrix_to_scipy
from .galerkin import (
    DENSE_ACCUM_LIMIT,
    _coarse_graph_ell_device,
    _coarse_graph_keys_device,
    _coo_rows_repack_device,
    _coo_to_ell_device,
    _dense_rows_extract_device,
    _dense_to_ell_device,
    _pad_p_rows,
    _spgemm_stage1_dense_device,
    _spgemm_stage1_device,
    _spgemm_stage2_dense_device,
    _spgemm_stage2_device,
    galerkin_coo_host,
)
from .packing import pack_clusters_device, pack_clusters_host
from .prolongator import (
    _prolongator_device,
    _prolongator_pack_device,
    rect_ell,
    smoothed_prolongator_host,
)


def x64_context():
    """Float64 tracing scope for the resident setup path (the host scipy
    reference computes in f64; the device path must match it before the
    final float32 rounding)."""
    from jax.experimental import enable_x64

    return enable_x64()


# ---------------------------------------------------------------------------
# setup-phase accounting (HOTLOOP_STATS counterpart for the setup path)
# ---------------------------------------------------------------------------

class SetupStats:
    """Compatibility view over the multilevel-setup registry counters.

    ``host_syncs`` counts matrix-sized device<->host round-trips in the
    *per-level* setup path of a hierarchy/cluster-GS build (the host
    engine pays 3 per level: prolongator, Galerkin product,
    transfer-operator packing); the one-time coarsest-level densify —
    bounded by ``dense_coarse_cap`` and needed only when the dense
    factorization runs on the host — is boundary work and counted by
    neither engine.  ``resident_dispatches`` counts whole-stage jitted
    dispatches of the resident engine (7 per AMG level).

    The numbers live in the process-wide :mod:`repro.obs` registry
    (``multilevel.host_syncs`` / ``multilevel.resident_dispatches``); this
    shim keeps the legacy attribute surface (including ``+=``) working.
    Tests should prefer ``obs.capture()`` over :meth:`reset`.
    """

    _SYNCS = "multilevel.host_syncs"
    _DISPATCHES = "multilevel.resident_dispatches"

    @property
    def host_syncs(self) -> int:
        return int(_OBS.counter(self._SYNCS).value)

    @host_syncs.setter
    def host_syncs(self, v: int) -> None:
        _OBS.counter(self._SYNCS).set_(v)

    @property
    def resident_dispatches(self) -> int:
        return int(_OBS.counter(self._DISPATCHES).value)

    @resident_dispatches.setter
    def resident_dispatches(self, v: int) -> None:
        _OBS.counter(self._DISPATCHES).set_(v)

    def reset(self) -> None:
        _OBS.reset(self._SYNCS)
        _OBS.reset(self._DISPATCHES)


SETUP_STATS = SetupStats()


# ---------------------------------------------------------------------------
# hierarchy containers (the solve phase consumes these; moved here from
# solvers/amg.py, which re-exports them)
# ---------------------------------------------------------------------------

@dataclass
class AMGLevel:
    a_ell: ELLMatrix
    diag: jnp.ndarray
    p_ell: ELLMatrix | None        # prolongator (fine x coarse), None at coarsest
    r_ell: ELLMatrix | None        # restriction = P^T
    n: int
    nnz: int


@dataclass
class AMGHierarchy:
    levels: List[AMGLevel]
    coarse_solve: Callable
    setup_seconds: float
    aggregation_seconds: float
    aggregation: str
    omega: float
    jacobi_weight: float
    smoother_sweeps: int
    level_sizes: list = field(default_factory=list)
    engine: str = "host"
    coarse_dtype: str = "float32"
    coarse_kind: str = "lu"        # 'lu' | 'jacobi' (above dense_coarse_cap)
    timings: dict = field(default_factory=dict)
    dispatches: int = 0            # resident jitted dispatches this build
    _digests: list | None = None

    def as_precond(self) -> Callable:
        from ..solvers.amg import v_cycle   # lazy: solvers imports us

        return functools.partial(v_cycle, self)

    def level_digests(self) -> list[str]:
        """Per-level ``A_l`` ELL digest (cols + vals + mask), lazily
        computed — the build itself never pulls level matrices to host."""
        if self._digests is None:
            self._digests = [ell_matrix_digest(lvl.a_ell)
                             for lvl in self.levels]
        return self._digests


def ell_matrix_digest(ell: ELLMatrix) -> str:
    """One digest string over an ELL matrix's (cols, vals, mask), built
    from the canonical per-array :func:`~repro.api.result.
    determinism_digest` so the two schemes cannot drift."""
    import hashlib

    from ..api.result import determinism_digest

    h = hashlib.sha256()
    for arr in (ell.cols, ell.vals, ell.mask):
        h.update(determinism_digest(arr).encode())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# coarsest-level solver (shared by both engines)
# ---------------------------------------------------------------------------

def resolve_coarse_dtype(coarse_dtype: Optional[str]) -> str:
    """Default coarse factorization dtype: float64 on CPU hosts (free and
    robust), float32 on accelerators (f64 is emulated or absent there)."""
    if coarse_dtype is not None:
        return str(coarse_dtype)
    from ..api.backend import accelerator_present

    return "float32" if accelerator_present() else "float64"


def _coarse_solver(dense, coarse_dtype: str):
    """Cached dense factorization in the requested dtype.

    ``dense`` may be a host or device array: the float32 branch factors
    on device (a device input never round-trips), the float64 branch
    factors on the host (scipy), pulling the capped coarse matrix once.
    """
    if coarse_dtype == "float64":
        import scipy.linalg as sla

        lu_piv = sla.lu_factor(np.asarray(dense, dtype=np.float64))

        def _host_solve(b):
            x = sla.lu_solve(lu_piv, np.asarray(b, dtype=np.float64))
            return x.astype(np.float32)

        def coarse_solve(b):
            # pure_callback keeps the f64 host solve traceable — Krylov
            # drivers apply the preconditioner inside a jitted step
            return jax.pure_callback(
                _host_solve,
                jax.ShapeDtypeStruct(b.shape, jnp.float32), b)

        return coarse_solve
    lu_piv = jax.scipy.linalg.lu_factor(jnp.asarray(dense, dtype=jnp.float32))

    @jax.jit
    def coarse_solve(b):
        return jax.scipy.linalg.lu_solve(lu_piv, b)

    return coarse_solve


def _jacobi_coarse_solver(a_ell: ELLMatrix, diag, weight: float, sweeps: int):
    """Fallback when the coarsest level exceeds ``dense_coarse_cap``:
    weighted-Jacobi sweeps instead of an O(n^2) dense factorization."""
    w = jnp.float32(weight)

    @jax.jit
    def coarse_solve(b):
        x = jnp.zeros_like(b)
        for _ in range(sweeps):
            ax = jnp.sum(a_ell.vals * x[a_ell.cols], axis=1)
            x = x + w * (b - ax) / diag
        return x

    return coarse_solve


@functools.partial(jax.jit, static_argnames=("n",))
def _ell_to_dense_device(cols, vals, mask, *, n: int):
    rid = jnp.arange(n, dtype=jnp.int32)[:, None]
    dense = jnp.zeros((n, n), jnp.float32)
    rows = jnp.where(mask, jnp.broadcast_to(rid, cols.shape), n)
    return dense.at[rows, cols].add(jnp.where(mask, vals, 0.0), mode="drop")


# ---------------------------------------------------------------------------
# per-level builders
# ---------------------------------------------------------------------------

def _host_level(cur: CSRMatrix, labels: np.ndarray, nagg: int, omega: float,
                timings: dict):
    """Host-engine level: scipy prolongator + canonical numpy Galerkin.

    Returns ``(level_without_sizes, a_next)``; three matrix-sized host
    round-trips, counted in ``SETUP_STATS``.
    """
    v = cur.num_rows
    t0 = time.perf_counter()
    pr, pc, pv = smoothed_prolongator_host(cur, labels, nagg, omega)
    _OBS.counter(SetupStats._SYNCS).inc()
    timings["prolongator"] = timings.get("prolongator", 0.0) \
        + time.perf_counter() - t0
    t0 = time.perf_counter()
    a_ell = csr_to_ell_matrix(cur)
    p_pad_cols, p_pad_vals = _pad_p_rows(pr, pc, pv, v)
    cr, cc, cv = galerkin_coo_host(a_ell, p_pad_cols, p_pad_vals, nagg)
    _OBS.counter(SetupStats._SYNCS).inc()
    indptr = np.zeros(nagg + 1, dtype=np.int64)
    np.add.at(indptr, cr + 1, 1)
    a_next = CSRMatrix(jnp.asarray(np.cumsum(indptr).astype(np.int32)),
                       jnp.asarray(cc.astype(np.int32)),
                       jnp.asarray(cv.astype(np.float32)))
    timings["galerkin"] = timings.get("galerkin", 0.0) \
        + time.perf_counter() - t0
    t0 = time.perf_counter()
    p_ell = rect_ell(pr, pc, pv.astype(np.float32), v)
    r_ell = rect_ell(pc, pr, pv.astype(np.float32), nagg)
    _OBS.counter(SetupStats._SYNCS).inc()
    level = AMGLevel(a_ell, extract_diagonal(cur), p_ell, r_ell,
                     v, cur.num_entries)
    timings["pack"] = timings.get("pack", 0.0) + time.perf_counter() - t0
    return level, a_next


def _resident_level(cur_ell: ELLMatrix, cur_nnz: int, labels: np.ndarray,
                    nagg: int, omega: float, timings: dict):
    """Resident-engine level: 7 jitted dispatches, zero matrix-sized host
    syncs (only the ELL width scalars come back to fix static shapes)."""
    v = cur_ell.num_rows
    with x64_context():
        t0 = time.perf_counter()
        labels_j = jnp.asarray(labels.astype(np.int32))
        p_cols, p_vals, p_keep, diag, dp_real, dr = _prolongator_device(
            cur_ell.cols, cur_ell.vals, cur_ell.mask, labels_j, float(omega))
        _OBS.counter(SetupStats._DISPATCHES).inc(2)   # scan + finish (FMA boundary)
        dp_real, dr = int(dp_real), int(dr)       # shape scalars only
        timings["prolongator"] = timings.get("prolongator", 0.0) \
            + time.perf_counter() - t0
        t0 = time.perf_counter()
        a_vals64 = jnp.where(cur_ell.mask, cur_ell.vals.astype(jnp.float64),
                             0.0)
        # key_base = v (shape-derived) so the expensive expansion/sort
        # kernels are compiled once per level shape, not once per
        # aggregate count — the key grouping and order are base-independent
        cpad = _bucket_pow2(nagg)
        if v * cpad <= DENSE_ACCUM_LIMIT:
            # sort-free dense-accumulator SpGEMM (same accumulation order
            # per entry as the sorted path -> bit-identical values)
            dense1, csum1, dq, nnz_q = _spgemm_stage1_dense_device(
                cur_ell.cols, a_vals64, p_cols, p_vals, num_cols=cpad)
            _OBS.counter(SetupStats._DISPATCHES).inc()
            dq, nnz_qi = int(dq), int(nnz_q)
            q_cols, q_vals = _dense_rows_extract_device(
                dense1, csum1, nnz_q, num_cols=cpad,
                width=_bucket_pow2(dq), nnz_bucket=_bucket_pow2(nnz_qi))
            _OBS.counter(SetupStats._DISPATCHES).inc()
            dense2, csum2, width_c, nnz_c = _spgemm_stage2_dense_device(
                p_cols, p_vals, q_cols, q_vals, num_cols=cpad)
            _OBS.counter(SetupStats._DISPATCHES).inc()
            width_c, nnz_c = int(width_c), int(nnz_c)
            ac_cols, ac_vals, ac_mask, _ = _dense_to_ell_device(
                dense2, csum2, jnp.int32(nnz_c), num_cols=cpad,
                num_rows=nagg, width=width_c,
                nnz_bucket=_bucket_pow2(nnz_c))
            _OBS.counter(SetupStats._DISPATCHES).inc()
        else:
            # sorted-COO fallback when the dense accumulator would not
            # fit; key_base = v (shape-derived) so the sort kernels
            # compile once per level shape
            k1, s1, kp1, dq = _spgemm_stage1_device(
                cur_ell.cols, a_vals64, p_cols, p_vals, key_base=v)
            _OBS.counter(SetupStats._DISPATCHES).inc()
            q_cols, q_vals = _coo_rows_repack_device(
                k1, s1, kp1, key_base=v, num_rows=v, width=int(dq))
            _OBS.counter(SetupStats._DISPATCHES).inc()
            keys, sums, keep, nnz_c, width_c = _spgemm_stage2_device(
                p_cols, p_vals, q_cols, q_vals, key_base=v)
            _OBS.counter(SetupStats._DISPATCHES).inc()
            nnz_c, width_c = int(nnz_c), int(width_c)
            ac_cols, ac_vals, ac_mask, _ = _coo_to_ell_device(
                keys, sums, keep, key_base=v, num_rows=nagg, width=width_c)
            _OBS.counter(SetupStats._DISPATCHES).inc()
        timings["galerkin"] = timings.get("galerkin", 0.0) \
            + time.perf_counter() - t0
        t0 = time.perf_counter()
        (pe_cols, pe_vals, pe_mask), (re_cols, re_vals, re_mask) = \
            _prolongator_pack_device(p_cols, p_vals, p_keep,
                                     num_aggregates=nagg, p_width=dp_real,
                                     r_width=dr)
        _OBS.counter(SetupStats._DISPATCHES).inc()
        timings["pack"] = timings.get("pack", 0.0) + time.perf_counter() - t0
    level = AMGLevel(cur_ell, diag,
                     ELLMatrix(pe_cols, pe_vals, pe_mask),
                     ELLMatrix(re_cols, re_vals, re_mask), v, cur_nnz)
    return level, ELLMatrix(ac_cols, ac_vals, ac_mask), nnz_c


# ---------------------------------------------------------------------------
# hierarchy build (both engines)
# ---------------------------------------------------------------------------

def _build_hierarchy_impl(a, aggregation: str = "two_phase",
                          max_levels: int = 10, coarse_size: int = 200,
                          omega: float = 2.0 / 3.0,
                          jacobi_weight: float = 2.0 / 3.0,
                          smoother_sweeps: int = 2,
                          options=None,
                          mis2_engine: Optional[str] = None,
                          interpret=None,
                          engine: str = "host",
                          coarse_dtype: Optional[str] = None,
                          dense_coarse_cap: Optional[int] = None,
                          explicit_restriction: bool = True,
                          first_agg=None) -> AMGHierarchy:
    """Build the SA-AMG hierarchy with the requested multilevel engine.

    ``dense_coarse_cap`` (default: ``coarse_size``) bounds the dense
    coarsest-level factorization: the factor never exceeds what the
    caller asked for, and a coarsening stall or ``max_levels`` cut that
    leaves the coarsest level above the cap falls back to weighted-Jacobi
    sweeps instead of an unrequested O(n^2) densification.

    ``explicit_restriction=False`` drops the stored ``R = P^T`` ELL
    matrices after the build; the V-cycle then restricts matrix-free
    through the transposed ELL SpMV (``kernels.spmv_ell.spmv_t``),
    halving steady-state transfer-operator memory.

    ``first_agg`` optionally injects a precomputed finest-level
    :class:`~repro.core.aggregation.AggregationResult` (the batched
    facade aggregates every finest level in one vmapped dispatch and
    finishes each hierarchy through here).
    """
    from ..api.registry import get_engine

    if engine not in ("host", "resident"):
        raise ValueError(f"unknown multilevel engine {engine!r} "
                         "(host | resident)")
    gh = as_graph(a) if not isinstance(a, Graph) else a
    coarse_dtype = resolve_coarse_dtype(coarse_dtype)
    if dense_coarse_cap is None:
        dense_coarse_cap = coarse_size
    t_setup = time.perf_counter()
    t_agg = 0.0
    timings: dict = {}
    dispatches0 = SETUP_STATS.resident_dispatches
    agg_fn = get_engine("aggregation", aggregation)
    agg_kwargs = dict(options=options, interpret=interpret)
    if mis2_engine is not None:
        agg_kwargs["mis2_engine"] = mis2_engine
    elif engine == "resident":
        # keep the aggregation fixed point device-resident too (labels are
        # bit-identical across mis2 engines, so this is purely execution
        # shape — the host engine keeps its host-driven default)
        agg_kwargs["mis2_engine"] = "compacted_resident"

    levels: List[AMGLevel] = []
    sizes = []
    if engine == "host":
        cur = gh.csr_matrix
        cur_graph, cur_n, cur_nnz = cur.graph, cur.num_rows, cur.num_entries
    else:
        cur_ell = gh.ell_matrix
        cur_graph, cur_n, cur_nnz = gh, gh.num_vertices, gh.num_entries
    while len(levels) < max_levels - 1 and cur_n > coarse_size:
        with _obs_span("multilevel.level", engine=engine,
                       level=len(levels), n=cur_n) as lvl_span:
            t0 = time.perf_counter()
            if first_agg is not None:
                agg, first_agg = first_agg, None
            else:
                agg = agg_fn(cur_graph, **agg_kwargs)
            dt = time.perf_counter() - t0
            t_agg += dt
            timings["aggregate"] = timings.get("aggregate", 0.0) + dt
            if agg.num_aggregates >= cur_n:
                break
            if engine == "host":
                level, cur = _host_level(cur, agg.labels,
                                         agg.num_aggregates, omega, timings)
                sizes.append((level.n, level.nnz))
                cur_graph, cur_n, cur_nnz = cur.graph, cur.num_rows, \
                    cur.num_entries
            else:
                level, cur_ell, cur_nnz = _resident_level(
                    cur_ell, cur_nnz, agg.labels, agg.num_aggregates, omega,
                    timings)
                sizes.append((level.n, level.nnz))
                cur_graph = Graph(cur_ell)
                cur_n = agg.num_aggregates
            lvl_span.annotate(num_aggregates=agg.num_aggregates)
        levels.append(level)

    # coarsest level
    if engine == "host":
        coarsest = AMGLevel(csr_to_ell_matrix(cur), extract_diagonal(cur),
                            None, None, cur.num_rows, cur.num_entries)
    else:
        diag_c = jnp.sum(jnp.where(
            (cur_ell.cols == jnp.arange(cur_n, dtype=jnp.int32)[:, None])
            & cur_ell.mask, cur_ell.vals, jnp.float32(0)), axis=1)
        coarsest = AMGLevel(cur_ell, diag_c, None, None, cur_n, cur_nnz)
    levels.append(coarsest)
    sizes.append((coarsest.n, coarsest.nnz))

    if coarsest.n <= dense_coarse_cap:
        if engine == "host":
            dense = np.asarray(matrix_to_scipy(cur).todense())
        else:
            # stays a device array: the float32 branch of _coarse_solver
            # factors it in place; only the float64/scipy branch pulls it
            dense = _ell_to_dense_device(
                coarsest.a_ell.cols, coarsest.a_ell.vals, coarsest.a_ell.mask,
                n=coarsest.n)
        coarse_solve = _coarse_solver(dense, coarse_dtype)
        coarse_kind = "lu"
    else:
        # the cap guards the O(n^2) densification when max_levels (or a
        # coarsening stall) leaves the coarsest level larger than the
        # caller asked for
        coarse_solve = _jacobi_coarse_solver(
            coarsest.a_ell, coarsest.diag, jacobi_weight,
            sweeps=8 * smoother_sweeps)
        coarse_kind = "jacobi"

    if not explicit_restriction:
        for lvl in levels:
            lvl.r_ell = None      # v_cycle restricts via spmv_t instead

    return AMGHierarchy(
        levels, coarse_solve, time.perf_counter() - t_setup, t_agg,
        aggregation, omega, jacobi_weight, smoother_sweeps, sizes,
        engine=engine, coarse_dtype=coarse_dtype, coarse_kind=coarse_kind,
        timings=timings,
        dispatches=SETUP_STATS.resident_dispatches - dispatches0)


# ---------------------------------------------------------------------------
# cluster-GS setup (both engines)
# ---------------------------------------------------------------------------

def _cluster_gs_setup_impl(a, aggregation: str = "two_phase", options=None,
                           coarsen_levels: int = 1, engine: str = "host",
                           mis2_engine: Optional[str] = None):
    """Aggregate -> color the coarse graph -> pack cluster rows.

    Returns ``(color_rows, num_colors, num_clusters, labels, colors,
    timings)`` with ``timings`` the structured setup-phase split
    ``{aggregate, color, pack}`` in seconds.
    """
    from ..api.registry import get_engine
    from ..core.coloring import _color_graph_impl
    from ..graphs.ops import coarse_graph_from_labels

    if engine not in ("host", "resident"):
        raise ValueError(f"unknown multilevel engine {engine!r} "
                         "(host | resident)")
    gh = as_graph(a)
    v = gh.num_vertices
    timings = {"aggregate": 0.0, "color": 0.0, "pack": 0.0}
    agg_fn = get_engine("aggregation", aggregation)
    agg_kwargs = dict(options=options)
    if mis2_engine is not None:
        agg_kwargs["mis2_engine"] = mis2_engine
    elif engine == "resident":
        agg_kwargs["mis2_engine"] = "compacted_resident"

    def coarse_structure(graph_handle, labels, nagg):
        if engine == "host":
            g = coarse_graph_from_labels(graph_handle.csr, labels, nagg)
            _OBS.counter(SetupStats._SYNCS).inc()
            return Graph(g)
        ell = graph_handle.ell
        with x64_context():     # int64 edge keys (la * V + lb)
            keys, keep, _, width = _coarse_graph_keys_device(
                ell.neighbors, ell.mask, jnp.asarray(labels.astype(np.int32)),
                key_base=ell.num_vertices)
            _OBS.counter(SetupStats._DISPATCHES).inc()
            nbrs, mask = _coarse_graph_ell_device(
                keys, keep, key_base=ell.num_vertices, num_rows=nagg,
                width=int(width))
        _OBS.counter(SetupStats._DISPATCHES).inc()
        from ..graphs.csr import ELLGraph

        return Graph(ELLGraph(nbrs, mask))

    t0 = time.perf_counter()
    agg = agg_fn(gh, **agg_kwargs)
    labels, nagg = agg.labels, agg.num_aggregates
    timings["aggregate"] += time.perf_counter() - t0
    for _ in range(coarsen_levels - 1):        # optional deeper clustering
        t0 = time.perf_counter()
        cg = coarse_structure(gh, labels, nagg)
        agg2 = agg_fn(cg, **agg_kwargs)
        labels = agg2.labels[labels]
        nagg = agg2.num_aggregates
        timings["aggregate"] += time.perf_counter() - t0

    t0 = time.perf_counter()
    coarse = coarse_structure(gh, labels, nagg)
    coloring = _color_graph_impl(coarse)
    timings["color"] += time.perf_counter() - t0
    if not coloring.converged:     # a partial coloring is unusable for GS
        raise RuntimeError("coarse-graph coloring did not converge")

    t0 = time.perf_counter()
    if engine == "host":
        color_rows = pack_clusters_host(labels, coloring.colors,
                                        coloring.num_colors, v)
        _OBS.counter(SetupStats._SYNCS).inc()
    else:
        with x64_context():     # int64 (color, cluster) sort keys
            color_rows = pack_clusters_device(labels, coloring.colors,
                                              coloring.num_colors, v)
        _OBS.counter(SetupStats._DISPATCHES).inc(2)
    timings["pack"] += time.perf_counter() - t0
    return color_rows, coloring.num_colors, nagg, labels, \
        coloring.colors, timings
