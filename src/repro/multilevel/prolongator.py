"""Tentative + damped-Jacobi-smoothed prolongator assembly.

Host backend: the legacy scipy path (moved verbatim from
``solvers/amg.py``): ``P0[v, agg(v)] = 1/sqrt(|agg|)``, then
``P = (I - omega D^-1 A) P0`` in f64 COO.

Device backend: the same P assembled from the aggregation labels with
fixed-shape sort/segment arithmetic — no scipy, no host round-trip.  The
per-entry f64 value is accumulated in exactly scipy's SMMP order (A-row
slot order within each prolongator column), so the two backends produce
**bit-identical** f64 values; exact-zero entries are dropped like scipy's
binop does.  The device rows come out sorted by column, matching the
canonical CSR layout of the host path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.csr import ELLMatrix

INT32_MAX = np.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# host backend (scipy; the reference)
# ---------------------------------------------------------------------------

def smoothed_prolongator_host(a, labels: np.ndarray, nagg: int,
                              omega: float):
    """``P = (I - omega D^-1 A) P0`` in COO (host scipy, f64)."""
    import scipy.sparse as sp

    from ..graphs.ops import matrix_to_scipy

    asp = matrix_to_scipy(a)
    v = a.num_rows
    sizes = np.bincount(labels, minlength=nagg).astype(np.float64)
    p0 = sp.csr_matrix(
        (1.0 / np.sqrt(sizes[labels]), (np.arange(v), labels)), shape=(v, nagg)
    )
    d_inv = 1.0 / asp.diagonal()
    p = p0 - omega * sp.diags(d_inv) @ (asp @ p0)
    p = p.tocoo()
    return p.row, p.col, p.data


def rect_ell(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
             nrows: int) -> ELLMatrix:
    """Rectangular ELL from COO (for P and R; padding col 0, val 0)."""
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    counts = np.bincount(rows, minlength=nrows)
    d = max(1, int(counts.max()) if len(counts) else 1)
    cmat = np.zeros((nrows, d), dtype=np.int32)
    vmat = np.zeros((nrows, d), dtype=np.float32)
    mmat = np.zeros((nrows, d), dtype=bool)
    slot = np.arange(len(rows)) - np.repeat(np.cumsum(counts) - counts, counts)
    cmat[rows, slot] = cols
    vmat[rows, slot] = vals
    mmat[rows, slot] = True
    return ELLMatrix(jnp.asarray(cmat), jnp.asarray(vmat), jnp.asarray(mmat))


# ---------------------------------------------------------------------------
# device backend (jitted, x64)
# ---------------------------------------------------------------------------

@jax.jit
def _prolongator_scan_device(a_cols, a_vals, a_mask, labels, omega):
    """First prolongator dispatch: per-row column-sorted candidate slots
    of ``X = A @ P0``, the smoothed term ``(omega*D^-1) * X`` and the
    tentative term.

    The smoothed term is a *function output* on purpose: scipy rounds the
    product ``(omega d_inv) * x`` to f64 before subtracting it from
    ``P0``, but inside one XLA computation LLVM contracts ``tent - w*s``
    into an FMA (skipping that rounding; ``lax.optimization_barrier``
    does not prevent it).  Materializing the product at a dispatch
    boundary forces the rounding, keeping the values bit-identical to the
    host path.
    """
    v, d = a_cols.shape
    rid = jnp.arange(v, dtype=jnp.int32)
    # aggregate sizes + tentative scaling (labels are all >= 0 here)
    aggsize = jnp.zeros(v, jnp.int32).at[labels].add(1)
    inv_sqrt = 1.0 / jnp.sqrt(aggsize.astype(jnp.float64))
    # diagonal: canonical rows hold at most one self entry
    diag = jnp.sum(jnp.where((a_cols == rid[:, None]) & a_mask,
                             a_vals, jnp.float32(0)), axis=1)
    dinv = 1.0 / diag.astype(jnp.float64)
    # per-slot candidates of X = A @ P0 (term order = CSR slot order)
    cand_col = jnp.where(a_mask, labels[a_cols], INT32_MAX)
    contrib = jnp.where(a_mask,
                        a_vals.astype(jnp.float64) * inv_sqrt[labels[a_cols]],
                        0.0)
    # stable sort by column keeps equal-column terms in slot order
    order = jnp.argsort(cand_col, axis=1, stable=True)
    col_s = jnp.take_along_axis(cand_col, order, axis=1)
    con_s = jnp.take_along_axis(contrib, order, axis=1)
    # sequential run sums (SMMP accumulation order): s_i = sum of the
    # following same-column slots, added one shift at a time
    pad_c = jnp.pad(col_s, ((0, 0), (0, d)), constant_values=-1)
    pad_v = jnp.pad(con_s, ((0, 0), (0, d)), constant_values=0.0)
    s = con_s
    for off in range(1, d):
        s = s + jnp.where(pad_c[:, off:off + d] == col_s,
                          pad_v[:, off:off + d], 0.0)
    tent = jnp.where(col_s == labels[:, None], inv_sqrt[labels][:, None], 0.0)
    # scipy's `omega * sp.diags(d_inv) @ X` binds as (omega*d_inv) @ X —
    # same association here keeps the f64 values bit-identical
    smoothed = (omega * dinv)[:, None] * s
    return col_s, tent, smoothed, diag


@jax.jit
def _prolongator_finish_device(col_s, tent, smoothed):
    """Second prolongator dispatch: ``P = P0 - smoothed`` on the run
    heads, zero-dropping like scipy's csr binop, plus the P/R width
    scalars the packing dispatch needs.

    Returns ``(p_cols[V, D], p_vals64[V, D], p_keep[V, D], dp_real, dr)``:
    slot ``i`` of row ``v`` is a run head carrying the full f64 value of
    ``P[v, p_cols[v, i]]`` iff ``p_keep[v, i]``; dead slots hold ``col 0,
    val 0.0`` so they are inert inside the Galerkin expansion.
    """
    v = col_s.shape[0]
    head = jnp.concatenate(
        [jnp.ones((v, 1), bool), col_s[:, 1:] != col_s[:, :-1]], axis=1)
    real = col_s != INT32_MAX
    pval = tent - smoothed
    keep = head & real & (pval != 0.0)          # scipy binop drops exact 0s
    p_cols = jnp.where(keep, col_s, 0)
    p_vals = jnp.where(keep, pval, 0.0)
    dp_real = jnp.max(jnp.sum(keep, axis=1))
    rcounts = jnp.zeros(v + 1, jnp.int32).at[
        jnp.where(keep, p_cols, v)].add(1)[:-1]
    return p_cols, p_vals, keep, dp_real, jnp.max(rcounts)


def _prolongator_device(a_cols, a_vals, a_mask, labels, omega):
    """Smoothed prolongator in padded row form, on device (2 dispatches:
    see :func:`_prolongator_scan_device` for why the smoothed product
    must cross a dispatch boundary)."""
    col_s, tent, smoothed, diag = _prolongator_scan_device(
        a_cols, a_vals, a_mask, labels, omega)
    p_cols, p_vals, keep, dp_real, dr = _prolongator_finish_device(
        col_s, tent, smoothed)
    return p_cols, p_vals, keep, diag, dp_real, dr


@functools.partial(jax.jit, static_argnames=("num_aggregates", "p_width",
                                             "r_width"))
def _prolongator_pack_device(p_cols, p_vals64, p_keep, *,
                             num_aggregates: int, p_width: int, r_width: int):
    """Pack the padded row form into the hierarchy's P and R ELL matrices
    (``rect_ell`` convention: padding col 0, val 0, mask False; rows
    sorted by column — bitwise the host layout)."""
    v, d = p_cols.shape
    pw, rw = max(1, p_width), max(1, r_width)
    vals32 = p_vals64.astype(jnp.float32)
    # P: within-row compaction of the kept heads (already column-sorted)
    slot = jnp.cumsum(p_keep.astype(jnp.int32), axis=1) - 1
    rows = jnp.where(p_keep, jnp.arange(v, dtype=jnp.int32)[:, None], v)
    sl = jnp.clip(slot, 0, pw - 1)
    pe_cols = jnp.zeros((v, pw), jnp.int32).at[rows, sl].set(
        p_cols, mode="drop")
    pe_vals = jnp.zeros((v, pw), jnp.float32).at[rows, sl].set(
        vals32, mode="drop")
    pe_mask = jnp.zeros((v, pw), bool).at[rows, sl].set(True, mode="drop")
    # R = P^T: entries sorted by (coarse row, fine col) via one stable sort
    vids = jnp.repeat(jnp.arange(v, dtype=jnp.int64)[:, None], d, axis=1)
    keys = jnp.where(p_keep, p_cols.astype(jnp.int64) * v + vids,
                     jnp.int64(num_aggregates) * v + v).reshape(-1)
    order = jnp.argsort(keys, stable=True)
    keys_s = keys[order]
    vals_s = vals32.reshape(-1)[order]
    kept = keys_s < jnp.int64(num_aggregates) * v
    crow = jnp.where(kept, (keys_s // v).astype(jnp.int32), num_aggregates)
    ccol = (keys_s % v).astype(jnp.int32)
    counts = jnp.zeros(num_aggregates + 1, jnp.int32).at[crow].add(1)[:-1]
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(keys_s.shape[0], dtype=jnp.int32)
    rslot = jnp.clip(rank - starts[jnp.clip(crow, 0, num_aggregates - 1)],
                     0, rw - 1)
    re_cols = jnp.zeros((num_aggregates, rw), jnp.int32).at[crow, rslot].set(
        ccol, mode="drop")
    re_vals = jnp.zeros((num_aggregates, rw), jnp.float32).at[
        crow, rslot].set(vals_s, mode="drop")
    re_mask = jnp.zeros((num_aggregates, rw), bool).at[crow, rslot].set(
        True, mode="drop")
    return (pe_cols, pe_vals, pe_mask), (re_cols, re_vals, re_mask)
