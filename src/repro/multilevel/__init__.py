"""``repro.multilevel`` — device-resident multilevel setup.

The construction previously scattered across ``solvers/amg.py``,
``solvers/multicolor_gs.py`` and ``graphs/ops.py``:

* :mod:`~repro.multilevel.hierarchy`    — engine orchestration
  (``host`` | ``resident``), :class:`AMGHierarchy`, ``SETUP_STATS``;
* :mod:`~repro.multilevel.prolongator`  — tentative + smoothed
  prolongator (scipy host path, fixed-shape device path);
* :mod:`~repro.multilevel.galerkin`     — ``P^T A P`` as a canonical
  padded sorted-COO SpGEMM (numpy and device backends, bit-identical);
* :mod:`~repro.multilevel.packing`      — cluster/color row packing for
  multicolor Gauss-Seidel.

Facade entries: ``repro.amg_setup(...)`` / ``repro.cluster_gs_setup(...)``.
"""
from .galerkin import galerkin, galerkin_coo_host
from .hierarchy import (
    SETUP_STATS,
    AMGHierarchy,
    AMGLevel,
    SetupStats,
    _build_hierarchy_impl,
    _cluster_gs_setup_impl,
    ell_matrix_digest,
    resolve_coarse_dtype,
    x64_context,
)
from .packing import pack_clusters_device, pack_clusters_host
from .prolongator import rect_ell, smoothed_prolongator_host

__all__ = [
    "AMGHierarchy", "AMGLevel", "SETUP_STATS", "SetupStats",
    "galerkin", "galerkin_coo_host", "ell_matrix_digest",
    "pack_clusters_host", "pack_clusters_device",
    "rect_ell", "smoothed_prolongator_host",
    "resolve_coarse_dtype", "x64_context",
    "_build_hierarchy_impl", "_cluster_gs_setup_impl",
]
