"""Device-side sparse operations used by MIS-2, aggregation and AMG.

Everything here is jit-friendly JAX (static shapes); host-side helpers that
materialize dynamic-size results (SpGEMM output, coarse graphs) return numpy
and are setup-time only — mirroring the paper's setup/solve split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph, CSRMatrix, ELLGraph, ELLMatrix, csr_from_coo


# ---------------------------------------------------------------------------
# SpMV (ELL): the AMG / Gauss-Seidel hot loop
# ---------------------------------------------------------------------------

def spmv_ell(m: ELLMatrix, x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x with A in ELL form. Padding has vals == 0 so no mask needed."""
    gathered = x[m.cols]                      # [V, D]
    return jnp.sum(m.vals * gathered, axis=1)


def spmv_csr_segment(m: CSRMatrix, x: jnp.ndarray) -> jnp.ndarray:
    """Segment-sum CSR SpMV — the 'no coalescing' baseline layout."""
    v = m.num_rows
    rows = jnp.repeat(
        jnp.arange(v, dtype=jnp.int32), jnp.diff(m.indptr),
        total_repeat_length=m.indices.shape[0],
    )
    contrib = m.values * x[m.indices]
    return jax.ops.segment_sum(contrib, rows, num_segments=v)


# ---------------------------------------------------------------------------
# Neighbor reductions (ELL) — the MIS-2 inner loops
# ---------------------------------------------------------------------------

def neighbor_min(ell: ELLGraph, t: jnp.ndarray) -> jnp.ndarray:
    """min_{w in N[v]} t[w] (closed: self-padding makes min include self)."""
    return jnp.min(t[ell.neighbors], axis=1)


def neighbor_all_eq(ell: ELLGraph, m: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """forall w in N[v]: m[w] == t[v] (closed; padding contributes m[v])."""
    return jnp.all(m[ell.neighbors] == t[:, None], axis=1)


def neighbor_any_eq(ell: ELLGraph, m: jnp.ndarray, value) -> jnp.ndarray:
    """exists w in N[v]: m[w] == value (closed)."""
    return jnp.any(m[ell.neighbors] == value, axis=1)


# ---------------------------------------------------------------------------
# Host-side structural ops (setup time)
# ---------------------------------------------------------------------------

def graph_power2(g: CSRGraph) -> CSRGraph:
    """G^2 (with self loops) via scipy — used only by tests/verification
    (Lemma IV.2: MIS-1(G^2) == MIS-2(G))."""
    import scipy.sparse as sp

    v = g.num_vertices
    indptr = np.asarray(g.indptr, dtype=np.int64)
    indices = np.asarray(g.indices, dtype=np.int64)
    a = sp.csr_matrix(
        (np.ones(len(indices), dtype=np.int8), indices, indptr), shape=(v, v)
    )
    a = a + sp.identity(v, dtype=np.int8, format="csr")
    a2 = (a @ a).tocsr()
    a2.sort_indices()
    return CSRGraph(
        jnp.asarray(a2.indptr.astype(np.int32)),
        jnp.asarray(a2.indices.astype(np.int32)),
    )


def coarse_graph_from_labels(g: CSRGraph, labels: np.ndarray,
                             num_aggregates: int) -> CSRGraph:
    """Coarse graph: aggregate a ~ aggregate b iff a fine edge links them.

    Includes self loops (diagonal), as the coarse matrix would.
    """
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    labels = np.asarray(labels)
    rows = np.repeat(np.arange(g.num_vertices), np.diff(indptr))
    cr, cc = labels[rows], labels[indices]
    keep = (cr >= 0) & (cc >= 0)
    cr, cc = cr[keep], cc[keep]
    diag = np.arange(num_aggregates, dtype=np.int64)
    cr = np.concatenate([cr.astype(np.int64), diag])
    cc = np.concatenate([cc.astype(np.int64), diag])
    return csr_from_coo(cr, cc, num_aggregates)


def galerkin_coarse_matrix(a: CSRMatrix, p_rows: np.ndarray, p_cols: np.ndarray,
                           p_vals: np.ndarray, num_aggregates: int) -> CSRMatrix:
    """A_c = P^T A P with P given in COO (host, scipy; setup-time)."""
    import scipy.sparse as sp

    v = a.num_rows
    indptr = np.asarray(a.indptr, dtype=np.int64)
    indices = np.asarray(a.indices, dtype=np.int64)
    values = np.asarray(a.values, dtype=np.float64)
    asp = sp.csr_matrix((values, indices, indptr), shape=(v, v))
    p = sp.csr_matrix(
        (p_vals.astype(np.float64), (p_rows, p_cols)), shape=(v, num_aggregates)
    )
    ac = (p.T @ asp @ p).tocsr()
    ac.sort_indices()
    ac.eliminate_zeros()
    return CSRMatrix(
        jnp.asarray(ac.indptr.astype(np.int32)),
        jnp.asarray(ac.indices.astype(np.int32)),
        jnp.asarray(ac.data.astype(np.float32)),
    )


def matrix_to_scipy(a: CSRMatrix):
    import scipy.sparse as sp

    v = a.num_rows
    return sp.csr_matrix(
        (np.asarray(a.values, dtype=np.float64),
         np.asarray(a.indices, dtype=np.int64),
         np.asarray(a.indptr, dtype=np.int64)),
        shape=(v, v),
    )


def extract_diagonal(a: CSRMatrix) -> jnp.ndarray:
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    values = np.asarray(a.values)
    v = a.num_rows
    rows = np.repeat(np.arange(v), np.diff(indptr))
    d = np.zeros(v, dtype=values.dtype)
    on_diag = rows == indices
    d[rows[on_diag]] = values[on_diag]
    return jnp.asarray(d)
