"""The ``Graph`` handle: one object, every structural format, computed once.

Every pipeline in this repo (MIS-2, MIS-k, coloring, aggregation,
partitioning, AMG, cluster-GS) consumes the same graph in one of a few
layouts: CSR for host-side structure walks and segment reductions, ELL for
lane-aligned device gathers, COO edge lists for ``csr_segment`` kernels,
degree-bucketed ELL for skewed graphs.  Before the facade existed each
entry point re-derived its layout per call (``csr_to_ell_graph`` on every
``mis2``); the handle makes conversion a cached, observable, setup-time
event — the paper's setup/solve split, enforced by the API.

The handle is the canonical argument type of ``repro.api``; all legacy
entry points also accept it (they coerce through :func:`as_graph`, so a
bare ``CSRGraph`` still works and simply gets a fresh, uncached handle).

Conversion counting: ``graph.conversions`` maps conversion name ->
number of times the *work* was actually performed.  Tests assert a second
``.ell`` access is a cache hit (count stays 1).  Each conversion is also
timed (``graph.conversion_timings``) and mirrored into the process-wide
``repro.obs`` registry as ``graph.conversions{kind=...}`` /
``graph.conversion_seconds{kind=...}`` so one ``obs.snapshot()`` sees
format churn next to dispatches and compiles.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Iterable

import jax
import numpy as np

from ..obs import metrics as _OBS

from .csr import (
    BucketedELL,
    CSRGraph,
    CSRMatrix,
    ELLGraph,
    ELLMatrix,
    csr_to_bucketed_ell,
    csr_to_ell_graph,
    csr_to_ell_matrix,
    ell_to_csr_graph,
    pad_ell_graph,
)
from . import hybrid as _hybrid
from .hybrid import HybridEllGraph, LayoutOverflowError, csr_to_hybrid_ell

_STRUCTS = (CSRGraph, CSRMatrix, ELLGraph, ELLMatrix)


class Graph:
    """Cached-format handle around one immutable graph (or square matrix).

    Construct from any structural container::

        g = Graph(laplace3d(32))          # CSRMatrix (keeps values)
        g = Graph(csr_graph)              # CSRGraph
        g = Graph.from_coo(rows, cols, n) # COO triples

    Formats are materialized lazily and cached: ``g.ell``, ``g.csr``,
    ``g.csr_matrix``, ``g.ell_matrix``, ``g.csr_edges``, ``g.bucketed()``.
    """

    def __init__(self, structure):
        if isinstance(structure, Graph):
            # share the cache: a handle of a handle is the same handle state
            self._cache = structure._cache
            self._counts = structure._counts
            self._timings = structure._timings
            return
        if not isinstance(structure, _STRUCTS):
            raise TypeError(
                f"Graph() expects CSRGraph/CSRMatrix/ELLGraph/ELLMatrix/Graph, "
                f"got {type(structure).__name__}"
            )
        self._cache: dict[str, Any] = {}
        self._counts: dict[str, int] = {}
        self._timings: dict[str, float] = {}
        if isinstance(structure, CSRGraph):
            self._cache["csr"] = structure
        elif isinstance(structure, CSRMatrix):
            self._cache["csr_matrix"] = structure
            self._cache["csr"] = structure.graph
        elif isinstance(structure, ELLGraph):
            self._cache["ell"] = structure
        else:  # ELLMatrix
            self._cache["ell_matrix"] = structure
            self._cache["ell"] = structure.graph

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_coo(cls, rows, cols, num_vertices: int, vals=None) -> "Graph":
        from .csr import csr_from_coo

        return cls(csr_from_coo(np.asarray(rows), np.asarray(cols),
                                num_vertices, vals))

    # -- cache plumbing -----------------------------------------------------

    @contextmanager
    def _convert(self, name: str):
        """Count + time one conversion's actual work and mirror it into the
        ``repro.obs`` registry.  Callers hoist prerequisite format accesses
        (e.g. ``self.csr``) *before* entering, so nested conversions are
        attributed to their own kind rather than the outermost one."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self._counts[name] = self._counts.get(name, 0) + 1
            self._timings[name] = self._timings.get(name, 0.0) + dt
            _OBS.counter("graph.conversions", labels={"kind": name}).inc()
            _OBS.histogram("graph.conversion_seconds",
                           labels={"kind": name}).observe(dt)

    @property
    def conversions(self) -> dict[str, int]:
        """Times each conversion's work actually ran (cache hits excluded)."""
        return dict(self._counts)

    @property
    def conversion_timings(self) -> dict[str, float]:
        """Cumulative seconds spent per conversion kind (this handle)."""
        return dict(self._timings)

    # -- structural formats -------------------------------------------------

    @property
    def has_values(self) -> bool:
        return "csr_matrix" in self._cache or "ell_matrix" in self._cache

    @property
    def csr(self) -> CSRGraph:
        if "csr" not in self._cache:
            with self._convert("ell_to_csr"):
                self._cache["csr"] = ell_to_csr_graph(self._cache["ell"])
        return self._cache["csr"]

    @property
    def ell(self) -> ELLGraph:
        if "ell" not in self._cache:
            csr = self.csr
            self._check_ell_budget(self.num_vertices, self.max_degree)
            with self._convert("csr_to_ell"):
                self._cache["ell"] = csr_to_ell_graph(csr)
        return self._cache["ell"]

    @property
    def csr_matrix(self) -> CSRMatrix:
        if "csr_matrix" not in self._cache:
            raise ValueError("this Graph carries structure only (no values)")
        return self._cache["csr_matrix"]

    @property
    def ell_matrix(self) -> ELLMatrix:
        if "ell_matrix" not in self._cache:
            csr_matrix = self.csr_matrix
            with self._convert("csr_to_ell_matrix"):
                self._cache["ell_matrix"] = csr_to_ell_matrix(csr_matrix)
        return self._cache["ell_matrix"]

    @property
    def csr_edges(self):
        """COO edge list ``(edge_rows, edge_cols)`` as device int32 arrays —
        the ``csr_segment`` layout consumed by segment-reduction kernels."""
        if "csr_edges" not in self._cache:
            csr = self.csr
            with self._convert("csr_edges"):
                import jax.numpy as jnp

                indptr = np.asarray(csr.indptr)
                indices = np.asarray(csr.indices)
                rows = np.repeat(np.arange(len(indptr) - 1, dtype=np.int32),
                                 np.diff(indptr))
                self._cache["csr_edges"] = (
                    jnp.asarray(rows),
                    jnp.asarray(indices.astype(np.int32)))
        return self._cache["csr_edges"]

    def padded_ell(self, num_rows: int, width: int) -> ELLGraph:
        """ELL padded to ``[num_rows, width]`` (self-loop slots, mask False),
        cached per target shape — repeated batched dispatches of the same
        graph into the same bucket shape reuse one padded copy."""
        key = f"padded_ell({num_rows},{width})"
        if key not in self._cache:
            self._check_ell_budget(num_rows, width)
            ell = self.ell
            with self._convert("pad_ell"):
                self._cache[key] = pad_ell_graph(ell, num_rows, width)
        return self._cache[key]

    # -- degree-aware layouts ------------------------------------------------

    @staticmethod
    def _check_ell_budget(num_rows: int, width: int) -> None:
        """Refuse a padded-ELL materialization whose bytes estimate exceeds
        ``repro.graphs.hybrid.ELL_BYTE_LIMIT`` *before* allocating anything
        (read at call time so tests and operators can tune the limit)."""
        est = _hybrid.ell_bytes_estimate(num_rows, width)
        limit = _hybrid.ELL_BYTE_LIMIT
        if est > limit:
            raise LayoutOverflowError(est, limit, num_rows, width)

    def ell_bytes_estimate(self) -> int:
        """Bytes the monolithic padded-ELL form would take — O(V) degree
        scan, no adjacency materialization.  This is what auto-selection
        (``engine=None``) and serve admission consult before committing to
        an ELL-bound engine."""
        return _hybrid.ell_bytes_estimate(self.num_vertices, self.max_degree)

    def hybrid(self, widths=None, spill_cap=None) -> HybridEllGraph:
        """Sliced-ELL + COO-spill layout (see ``graphs.hybrid``), cached per
        (widths, spill_cap) policy."""
        key = f"hybrid({widths},{spill_cap})"
        if key not in self._cache:
            csr = self.csr
            with self._convert("csr_to_hybrid"):
                self._cache[key] = csr_to_hybrid_ell(
                    csr, widths=widths, spill_cap=spill_cap)
        return self._cache[key]

    def bucketed(self, boundaries: Iterable[int] = (8, 32, 128)) -> BucketedELL:
        key = f"bucketed{tuple(boundaries)}"
        if key not in self._cache:
            csr = self.csr
            with self._convert("csr_to_bucketed_ell"):
                self._cache[key] = csr_to_bucketed_ell(csr, tuple(boundaries))
        return self._cache[key]

    @property
    def digest(self) -> str:
        """Canonical-format content digest (16 hex chars), cached.

        Hashes the CSR structure (indptr + indices bytes, shapes, dtypes)
        plus the values when the handle carries a matrix.  Because CSR
        construction is deterministic (sorted, deduplicated), two handles
        built from the same structure always share a digest — this is the
        key ingredient of the serving layer's digest-keyed result cache:
        equal graph digest + equal options means the cached result is
        *provably* the bytes a recomputation would produce (the repo-wide
        engine bit-identity invariant)."""
        if "digest" not in self._cache:
            csr = self.csr
            with self._convert("digest"):
                import hashlib

                h = hashlib.sha256()
                for arr in (csr.indptr, csr.indices):
                    a = np.asarray(arr)
                    h.update(str(a.dtype).encode())
                    h.update(str(a.shape).encode())
                    h.update(a.tobytes())
                if self.has_values:
                    a = np.asarray(self.csr_matrix.values)
                    h.update(str(a.dtype).encode())
                    h.update(str(a.shape).encode())
                    h.update(a.tobytes())
                self._cache["digest"] = h.hexdigest()[:16]
        return self._cache["digest"]

    # -- stats --------------------------------------------------------------

    @property
    def num_vertices(self) -> int:
        if "ell" in self._cache:
            return self._cache["ell"].num_vertices
        return self.csr.num_vertices

    @property
    def num_entries(self) -> int:
        if "csr" not in self._cache:   # ELL-seeded: count mask, don't convert
            return int(np.asarray(self._cache["ell"].mask).sum())
        return self.csr.num_entries

    @property
    def degrees(self) -> np.ndarray:
        if "degrees" not in self._cache:
            csr = self.csr
            with self._convert("degrees"):
                self._cache["degrees"] = np.diff(np.asarray(csr.indptr))
        return self._cache["degrees"]

    @property
    def max_degree(self) -> int:
        d = self.degrees
        return int(d.max()) if len(d) else 0

    def stats(self) -> dict:
        d = self.degrees
        return {
            "num_vertices": self.num_vertices,
            "num_entries": self.num_entries,
            "max_degree": self.max_degree,
            "avg_degree": float(d.mean()) if len(d) else 0.0,
            "has_values": self.has_values,
            "cached_formats": sorted(self._cache.keys()),
        }

    # -- device placement ---------------------------------------------------

    def place(self, device) -> "Graph":
        """Move every cached device array to ``device`` (in place; the
        handle's cache is shared, so all views see the placement)."""
        for key, val in list(self._cache.items()):
            if key in ("degrees", "device", "digest"):   # host-only entries
                continue
            if isinstance(val, HybridEllGraph):
                # keep the static int metadata out of device_put's pytree
                self._cache[key] = val._replace(
                    slices=jax.device_put(val.slices, device),
                    spill_rows=jax.device_put(val.spill_rows, device),
                    spill_seg=jax.device_put(val.spill_seg, device),
                    spill_cols=jax.device_put(val.spill_cols, device))
                continue
            self._cache[key] = jax.device_put(val, device)
        self._cache["device"] = device
        return self

    def __repr__(self) -> str:
        fmts = ",".join(sorted(k for k in self._cache if k != "device"))
        return (f"Graph(V={self.num_vertices}, E={self.num_entries}, "
                f"cached=[{fmts}])")


# ---------------------------------------------------------------------------
# coercion helpers — every pipeline entry point funnels through these, so
# passing a Graph handle reuses its cache and passing a bare container
# behaves exactly as before (fresh conversion).
# ---------------------------------------------------------------------------

def as_graph(obj) -> Graph:
    """Coerce any structural container (or handle) to a Graph handle."""
    return obj if isinstance(obj, Graph) else Graph(obj)


def as_ell_graph(obj) -> ELLGraph:
    if isinstance(obj, ELLGraph):
        return obj
    return as_graph(obj).ell


def as_csr_graph(obj) -> CSRGraph:
    if isinstance(obj, CSRGraph):
        return obj
    return as_graph(obj).csr
