"""Degree-aware hybrid sparse layout: sliced ELL + sorted-COO spill.

The padded ELL layout (``csr.ELLGraph``) pads *every* row to the max
degree.  On bounded-degree meshes (laplace3d) that wastes nothing; on a
power-law graph at paper scale one hub row of degree ~V^(1/(a-1)) forces a
``[V, max_degree]`` slab that cannot even be allocated (`Graph.ell` raises
:class:`LayoutOverflowError` past :data:`ELL_BYTE_LIMIT`).  TC-MIS and the
SELL-C-sigma family solve this with degree bucketing; this module is the
TPU-shaped version:

* rows are sorted into a small pow2 width ladder (8, 16, 32, ... up to
  the spill cap); each bucket becomes one **slice**: a ``[R_i, W_i]`` ELL
  slab padded only to its own bucket width, plus the global row ids that
  own the slab rows.  Kernels dispatch once per slice — compile count is
  O(#slices), not O(#distinct pow2 shapes) — and padding waste is bounded
  by 2x per slice instead of max_degree/avg_degree overall.
* rows past the **spill cap** (the heavy hitters that make padded ELL
  explode) go to a sorted-COO segment (``spill_rows``/``spill_seg``/
  ``spill_cols``) consumed by segment reductions — O(E_spill) work with
  zero padding, the right shape for a handful of huge rows.

Padding convention matches ``csr.ELLGraph``: padded slab slots hold the
row's own **global** vertex id with ``mask == False``, so closed-
neighborhood reductions (the MIS-2 min / forall / exists) are
semantically inert over padding and the Pallas kernels never need to
read the mask.

Memory thresholds (module-level so tests can monkeypatch them):

* :data:`ELL_BYTE_LIMIT` — hard cap: ``Graph.ell`` / ``Graph.padded_ell``
  raise :class:`LayoutOverflowError` instead of attempting an allocation
  whose bytes estimate exceeds it (the seed's behaviour was an opaque
  host OOM mid-``np.repeat``).
* :data:`HYBRID_AUTO_BYTES` — auto-selection: ``repro.api.mis2`` with
  ``engine=None`` routes to ``pallas_hybrid`` once the padded-ELL bytes
  estimate crosses this threshold (see ``api.backend.default_mis2_engine``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph

Array = jnp.ndarray

# int32 neighbor id + bool mask byte per ELL slot
ELL_BYTES_PER_SLOT = 5

#: hard allocation cap for the monolithic padded-ELL formats (2 GiB)
ELL_BYTE_LIMIT = 2 * 1024 ** 3

#: auto-selection threshold: engine=None prefers the hybrid layout once
#: the padded-ELL estimate crosses this (256 MiB)
HYBRID_AUTO_BYTES = 256 * 1024 ** 2

#: smallest slice width of the default pow2 ladder
MIN_SLICE_WIDTH = 8


class LayoutOverflowError(MemoryError):
    """A monolithic padded-ELL materialization was refused *before*
    allocation: the ``[V, max_degree]`` bytes estimate exceeds the
    configured limit.  The message names the degree-aware alternative
    (``mis2: pallas_hybrid`` over :class:`HybridEllGraph`), which handles
    exactly the skewed graphs that trip this."""

    def __init__(self, estimate: int, limit: int, v: int, max_degree: int):
        self.estimate = int(estimate)
        self.limit = int(limit)
        super().__init__(
            f"padded ELL [{v} x {max_degree}] needs ~{estimate:,} bytes "
            f"(> limit {limit:,}): the max-degree padding of a skewed graph "
            f"blows out memory before the solve starts. Use the hybrid "
            f"layout instead (engine='pallas_hybrid' / Graph.hybrid(): "
            f"sliced ELL + COO spill, O(E) memory), or raise "
            f"repro.graphs.hybrid.ELL_BYTE_LIMIT if the allocation is "
            f"intentional.")


def ell_bytes_estimate(num_vertices: int, max_degree: int) -> int:
    """Bytes a monolithic padded-ELL graph would allocate (neighbors int32
    + mask byte), without touching any adjacency data."""
    return int(num_vertices) * int(max_degree) * ELL_BYTES_PER_SLOT


class HybridSlice(NamedTuple):
    """One degree bucket: global row ids + an ELL slab padded to the
    bucket width.  ``neighbors[j]`` are the (global-id) neighbors of
    vertex ``rows[j]``; padded slots hold ``rows[j]`` itself, mask False."""

    rows: Array       # int32 [R]   global vertex ids (ascending)
    neighbors: Array  # int32 [R, W] global neighbor ids
    mask: Array       # bool  [R, W]

    @property
    def num_rows(self) -> int:
        return int(self.neighbors.shape[0])

    @property
    def width(self) -> int:
        return int(self.neighbors.shape[1])


class HybridEllGraph(NamedTuple):
    """Sliced-ELL + sorted-COO spill decomposition of one graph.

    Every vertex appears in exactly one slice or in the spill, so scatter
    targets are disjoint and per-row reductions are complete within their
    partition — which is what makes the hybrid MIS-2 / coloring /
    coarsening passes bit-identical to the monolithic ELL engines.
    """

    slices: tuple          # tuple[HybridSlice, ...], ascending widths
    spill_rows: Array      # int32 [H] heavy vertex ids (ascending)
    spill_seg: Array       # int32 [S] index into spill_rows per COO entry
    spill_cols: Array      # int32 [S] neighbor ids (CSR order: sorted)
    num_vertices: int
    spill_cap: int         # rows with degree > spill_cap went to the spill

    @property
    def num_slices(self) -> int:
        return len(self.slices)

    @property
    def slice_widths(self) -> tuple:
        return tuple(s.width for s in self.slices)

    @property
    def num_spill_rows(self) -> int:
        return int(self.spill_rows.shape[0])

    @property
    def num_spill_entries(self) -> int:
        return int(self.spill_cols.shape[0])

    @property
    def padded_bytes(self) -> int:
        """Bytes the slabs + spill actually hold (the number the padded
        monolith is compared against)."""
        slab = sum(s.num_rows * s.width for s in self.slices)
        return slab * ELL_BYTES_PER_SLOT + self.num_spill_entries * 2 * 4

    @property
    def padding_ratio(self) -> float:
        """Padded slab slots / real entries (1.0 = no waste); the spill
        segment is unpadded by construction."""
        padded = sum(s.num_rows * s.width for s in self.slices)
        real = sum(int(np.asarray(s.mask).sum()) for s in self.slices)
        real += self.num_spill_entries
        return (padded + self.num_spill_entries) / max(1, real)


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


def default_spill_cap(degrees: np.ndarray) -> int:
    """Spill-cap policy: the smallest pow2 >= 4x the mean degree (floor
    :data:`MIN_SLICE_WIDTH`).  Rows above it are the heavy hitters whose
    padding the slices must not pay; on bounded-degree meshes (max <=
    cap) the spill is empty and the layout degenerates to sliced ELL."""
    if len(degrees) == 0:
        return MIN_SLICE_WIDTH
    mean = float(degrees.mean())
    return max(MIN_SLICE_WIDTH, _next_pow2(int(np.ceil(4.0 * max(1.0, mean)))))


def slice_width_ladder(max_slab_degree: int,
                       min_width: int = MIN_SLICE_WIDTH) -> tuple:
    """Pow2 width ladder ``min_width, 2*min_width, ...`` covering every
    non-spill degree; the top rung is clamped to the actual max slab
    degree so a bounded-degree graph pays no ladder overshoot."""
    widths = []
    w = min_width
    while w < max_slab_degree:
        widths.append(w)
        w *= 2
    widths.append(min(w, max(max_slab_degree, min_width)))
    return tuple(widths)


def _build_slab(sel: np.ndarray, deg: np.ndarray, indptr: np.ndarray,
                indices: np.ndarray, width: int) -> HybridSlice:
    """Vectorized slab assembly for the selected rows (no per-row loop —
    this runs at V=1M)."""
    r = len(sel)
    nbrs = np.repeat(sel.astype(np.int32)[:, None], width, axis=1)
    mask = np.zeros((r, width), dtype=bool)
    dsel = deg[sel].astype(np.int64)
    flat_rows = np.repeat(np.arange(r), dsel)
    slot = np.arange(int(dsel.sum()), dtype=np.int64) \
        - np.repeat(np.cumsum(dsel) - dsel, dsel)
    src = np.repeat(indptr[sel].astype(np.int64), dsel) + slot
    nbrs[flat_rows, slot] = indices[src]
    mask[flat_rows, slot] = True
    return HybridSlice(jnp.asarray(sel.astype(np.int32)),
                       jnp.asarray(nbrs), jnp.asarray(mask))


def csr_to_hybrid_ell(g: CSRGraph, widths: Optional[Sequence[int]] = None,
                      spill_cap: Optional[int] = None) -> HybridEllGraph:
    """CSR -> hybrid layout.

    ``widths`` (ascending) overrides the pow2 ladder; ``spill_cap``
    overrides :func:`default_spill_cap`.  Empty buckets produce no slice
    (the kernel stack iterates actual slices, so a graph whose degrees
    all land in one bucket compiles exactly one slab pass).
    """
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    v = len(indptr) - 1
    deg = np.diff(indptr)
    max_deg = int(deg.max()) if v else 0

    if spill_cap is None:
        spill_cap = default_spill_cap(deg)
    spill_cap = int(spill_cap)
    heavy = deg > spill_cap
    max_slab_deg = int(deg[~heavy].max()) if (~heavy).any() else 0

    if widths is None:
        widths = slice_width_ladder(max(max_slab_deg, 1))
    widths = tuple(sorted(int(w) for w in widths))
    if max_slab_deg > widths[-1]:
        raise ValueError(
            f"explicit widths {widths} do not cover max non-spill degree "
            f"{max_slab_deg} (spill_cap={spill_cap})")

    slices = []
    lo = 0
    for w in widths:
        sel = np.flatnonzero((deg > lo) & (deg <= w) & ~heavy)
        lo = w
        if len(sel) == 0:
            continue                      # empty bucket: no slice
        slices.append(_build_slab(sel, deg, indptr, indices, w))
    # degree-0 rows (no entries, not even a self loop) ride in the first
    # bucket so every vertex is owned by exactly one partition
    zero = np.flatnonzero(deg == 0)
    if len(zero):
        slices.insert(0, _build_slab(zero, deg, indptr, indices, widths[0]))

    hsel = np.flatnonzero(heavy)
    hdeg = deg[hsel].astype(np.int64)
    spill_seg = np.repeat(np.arange(len(hsel), dtype=np.int32), hdeg)
    spill_cols = np.concatenate(
        [indices[indptr[r]:indptr[r + 1]] for r in hsel]) if len(hsel) \
        else np.zeros(0, dtype=np.int32)

    return HybridEllGraph(
        tuple(slices),
        jnp.asarray(hsel.astype(np.int32)),
        jnp.asarray(spill_seg),
        jnp.asarray(spill_cols.astype(np.int32)),
        v, spill_cap)
