from .csr import (
    BucketedELL,
    CSRGraph,
    CSRMatrix,
    ELLGraph,
    ELLMatrix,
    csr_from_coo,
    csr_to_bucketed_ell,
    csr_to_ell_graph,
    csr_to_ell_matrix,
    degrees,
    ell_to_csr_graph,
    ensure_self_loops,
    pad_ell_graph,
    symmetrize,
)
from .handle import Graph, as_csr_graph, as_ell_graph, as_graph
from .hybrid import (
    HybridEllGraph,
    HybridSlice,
    LayoutOverflowError,
    csr_to_hybrid_ell,
    ell_bytes_estimate,
)
from .generators import (
    elasticity3d,
    er_laplacian,
    laplace3d,
    paper_suite,
    path_graph,
    powerlaw_graph,
    random_skewed_graph,
    random_uniform_graph,
)
from .ops import (
    coarse_graph_from_labels,
    extract_diagonal,
    galerkin_coarse_matrix,
    graph_power2,
    matrix_to_scipy,
    neighbor_all_eq,
    neighbor_any_eq,
    neighbor_min,
    spmv_csr_segment,
    spmv_ell,
)

__all__ = [
    "Graph", "as_graph", "as_ell_graph", "as_csr_graph",
    "BucketedELL", "CSRGraph", "CSRMatrix", "ELLGraph", "ELLMatrix",
    "csr_from_coo", "csr_to_bucketed_ell", "csr_to_ell_graph", "csr_to_ell_matrix", "degrees",
    "ell_to_csr_graph", "ensure_self_loops", "pad_ell_graph", "symmetrize",
    "HybridEllGraph", "HybridSlice", "LayoutOverflowError",
    "csr_to_hybrid_ell", "ell_bytes_estimate",
    "elasticity3d", "er_laplacian", "laplace3d", "paper_suite", "path_graph",
    "powerlaw_graph", "random_skewed_graph", "random_uniform_graph",
    "coarse_graph_from_labels", "extract_diagonal", "galerkin_coarse_matrix",
    "graph_power2", "matrix_to_scipy",
    "neighbor_all_eq", "neighbor_any_eq", "neighbor_min",
    "spmv_csr_segment", "spmv_ell",
]
