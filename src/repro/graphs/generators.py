"""Graph / matrix generators reproducing the paper's generated problem suite.

The paper's Table III and Table V problems come from Trilinos' Galeri package:

* ``Laplace3D nx×ny×nz`` — 7-point stencil Poisson matrix (diag 6, offdiag -1).
* ``Elasticity3D nx×ny×nz`` — 27-point stencil with 3 dof per grid point
  (avg degree 78.33, max 81 at 60^3 — matches the paper's Table II row).

SuiteSparse downloads are unavailable offline, so the remaining experiment
graphs are random suites (uniform + skewed degree) standing in for the
unstructured matrices; EXPERIMENTS.md states the substitution explicitly.
Elasticity values are a synthetic SPD surrogate (structure exact, values
diagonally dominant) — the paper's solver experiments only need SPD.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, CSRMatrix, csr_from_coo


def _grid_offsets_7pt():
    return [(-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1)]


def _grid_offsets_27pt():
    offs = [
        (dx, dy, dz)
        for dx in (-1, 0, 1)
        for dy in (-1, 0, 1)
        for dz in (-1, 0, 1)
        if not (dx == dy == dz == 0)
    ]
    return offs


def _stencil_coo(nx: int, ny: int, nz: int, offsets) -> tuple[np.ndarray, np.ndarray]:
    """COO (row, col) pairs for a structured grid stencil (no diagonal)."""
    ids = np.arange(nx * ny * nz, dtype=np.int64).reshape(nx, ny, nz)
    rows_list, cols_list = [], []
    for dx, dy, dz in offsets:
        sx = slice(max(0, -dx), nx - max(0, dx))
        sy = slice(max(0, -dy), ny - max(0, dy))
        sz = slice(max(0, -dz), nz - max(0, dz))
        tx = slice(max(0, dx), nx - max(0, -dx))
        ty = slice(max(0, dy), ny - max(0, -dy))
        tz = slice(max(0, dz), nz - max(0, -dz))
        rows_list.append(ids[sx, sy, sz].ravel())
        cols_list.append(ids[tx, ty, tz].ravel())
    return np.concatenate(rows_list), np.concatenate(cols_list)


def laplace3d(nx: int, ny: int | None = None, nz: int | None = None) -> CSRMatrix:
    """Galeri-style Laplace3D: 7-point stencil, diag 6, offdiag -1.

    The graph includes the diagonal (self loop), matching the paper's
    matrix-as-graph setting.
    """
    ny = nx if ny is None else ny
    nz = nx if nz is None else nz
    v = nx * ny * nz
    rows, cols = _stencil_coo(nx, ny, nz, _grid_offsets_7pt())
    vals = np.full(len(rows), -1.0)
    diag = np.arange(v, dtype=np.int64)
    rows = np.concatenate([rows, diag])
    cols = np.concatenate([cols, diag])
    vals = np.concatenate([vals, np.full(v, 6.0)])
    return csr_from_coo(rows, cols, v, vals)


def elasticity3d(nx: int, ny: int | None = None, nz: int | None = None,
                 dof: int = 3) -> CSRMatrix:
    """Elasticity3D structure: 27-point stencil, ``dof`` dofs per grid point.

    Structure matches Galeri's Elasticity3D (81 entries/row interior at
    dof=3); values are a diagonally dominant SPD surrogate.
    """
    ny = nx if ny is None else ny
    nz = nx if nz is None else nz
    npts = nx * ny * nz
    prow, pcol = _stencil_coo(nx, ny, nz, _grid_offsets_27pt())
    # block expansion: point p adjacent to q -> dof x dof dense block
    d = dof
    pr = np.repeat(prow * d, d * d) + np.tile(np.repeat(np.arange(d), d), len(prow))
    pc = np.repeat(pcol * d, d * d) + np.tile(np.tile(np.arange(d), d), len(prow))
    # diagonal block (off-diagonal-within-block entries + self)
    diagp = np.arange(npts, dtype=np.int64)
    dr = np.repeat(diagp * d, d * d) + np.tile(np.repeat(np.arange(d), d), npts)
    dc = np.repeat(diagp * d, d * d) + np.tile(np.tile(np.arange(d), d), npts)
    rows = np.concatenate([pr, dr])
    cols = np.concatenate([pc, dc])
    vals = np.full(len(rows), -1.0)
    vals[len(pr):] = -0.25            # weaker intra-block coupling
    vals[len(pr):][dr == dc] = 0.0    # placeholder; set below
    m = csr_from_coo(rows, cols, npts * d, vals)
    # make diagonally dominant SPD: diag = sum |offdiag| + 1
    indptr = np.asarray(m.indptr)
    indices = np.asarray(m.indices)
    values = np.asarray(m.values).copy()
    r = np.repeat(np.arange(npts * d), np.diff(indptr))
    offd = r != indices
    rowsum = np.zeros(npts * d)
    np.add.at(rowsum, r[offd], np.abs(values[offd]))
    values[~offd] = rowsum[r[~offd]] + 1.0
    import jax.numpy as jnp
    return CSRMatrix(m.indptr, m.indices, jnp.asarray(values.astype(np.float32)))


def random_uniform_graph(num_vertices: int, avg_degree: float, seed: int = 0,
                         with_self_loops: bool = True) -> CSRGraph:
    """Erdos-Renyi-ish symmetric graph with ~avg_degree neighbors/vertex."""
    rng = np.random.default_rng(seed)
    m = int(num_vertices * avg_degree / 2)
    rows = rng.integers(0, num_vertices, size=m, dtype=np.int64)
    cols = rng.integers(0, num_vertices, size=m, dtype=np.int64)
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    all_rows = np.concatenate([rows, cols])
    all_cols = np.concatenate([cols, rows])
    if with_self_loops:
        diag = np.arange(num_vertices, dtype=np.int64)
        all_rows = np.concatenate([all_rows, diag])
        all_cols = np.concatenate([all_cols, diag])
    return csr_from_coo(all_rows, all_cols, num_vertices)


def er_laplacian(num_vertices: int, avg_degree: float,
                 seed: int = 0) -> CSRMatrix:
    """Graph Laplacian (+I, so it is SPD with a full diagonal) of an
    Erdos-Renyi graph — the random *matrix* companion of
    :func:`random_uniform_graph`, used by the multilevel digest-parity
    gate and ``benchmarks/setup_overhead.py``."""
    import scipy.sparse as sp

    import jax.numpy as jnp

    g = random_uniform_graph(num_vertices, avg_degree, seed=seed,
                             with_self_loops=False)
    ip, ix = np.asarray(g.indptr), np.asarray(g.indices)
    off = sp.csr_matrix((np.ones(len(ix)), ix, ip),
                        shape=(num_vertices, num_vertices))
    lap = sp.diags(np.asarray(off.sum(axis=1)).ravel() + 1.0) - off
    lap = lap.tocsr()
    lap.sort_indices()
    return CSRMatrix(jnp.asarray(lap.indptr.astype(np.int32)),
                     jnp.asarray(lap.indices.astype(np.int32)),
                     jnp.asarray(lap.data.astype(np.float32)))


def random_skewed_graph(num_vertices: int, avg_degree: float, seed: int = 0,
                        alpha: float = 1.5, with_self_loops: bool = True) -> CSRGraph:
    """Preferential-style skewed-degree graph (stress for ELL padding)."""
    rng = np.random.default_rng(seed)
    m = int(num_vertices * avg_degree / 2)
    # power-law endpoint sampling
    u = rng.random(size=2 * m)
    end = ((num_vertices ** (1 - alpha) - 1) * u + 1) ** (1 / (1 - alpha))
    end = np.minimum(num_vertices - 1, end.astype(np.int64))
    perm = rng.permutation(num_vertices)
    rows, cols = perm[end[:m]], perm[end[m:]]
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    all_rows = np.concatenate([rows, cols])
    all_cols = np.concatenate([cols, rows])
    if with_self_loops:
        diag = np.arange(num_vertices, dtype=np.int64)
        all_rows = np.concatenate([all_rows, diag])
        all_cols = np.concatenate([all_cols, diag])
    return csr_from_coo(all_rows, all_cols, num_vertices)


def powerlaw_graph(num_vertices: int, avg_degree: float = 8.0,
                   exponent: float = 2.5, seed: int = 0,
                   with_self_loops: bool = True) -> CSRGraph:
    """Deterministic Chung-Lu power-law graph (degree exponent ``exponent``).

    Endpoints of ``V * avg_degree / 2`` undirected edges are drawn i.i.d.
    with probability proportional to the Chung-Lu weights
    ``w_i = (i + 1)^(-1/(exponent - 1))``, which yields an expected degree
    distribution ``P(deg = k) ~ k^-exponent`` with a hub of expected degree
    ``~ V^(1/(exponent-1)) * avg_degree`` — at paper scale (V = 1M,
    exponent 2.5) that one hub row makes the monolithic padded-ELL layout
    infeasible while the total edge count stays modest, which is exactly
    the regime the hybrid (sliced-ELL + COO spill) layout exists for.

    Seeded (``np.random.default_rng``), symmetrized, deduplicated, and
    CSR-canonical via :func:`csr_from_coo`, so equal arguments produce
    bit-identical graphs on any host.  Self loops are added by default —
    the invariant every repro graph satisfies (closed-neighborhood
    semantics of the MIS-2 kernels rely on the diagonal being present).
    """
    rng = np.random.default_rng(seed)
    m = int(num_vertices * avg_degree / 2)
    w = (np.arange(1, num_vertices + 1, dtype=np.float64)
         ** (-1.0 / (exponent - 1.0)))
    p = w / w.sum()
    ends = rng.choice(num_vertices, size=2 * m, p=p)
    rows, cols = ends[:m], ends[m:]
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    all_rows = np.concatenate([rows, cols])
    all_cols = np.concatenate([cols, rows])
    if with_self_loops:
        diag = np.arange(num_vertices, dtype=np.int64)
        all_rows = np.concatenate([all_rows, diag])
        all_cols = np.concatenate([all_cols, diag])
    return csr_from_coo(all_rows, all_cols, num_vertices)


def path_graph(num_vertices: int) -> CSRGraph:
    r = np.arange(num_vertices - 1, dtype=np.int64)
    diag = np.arange(num_vertices, dtype=np.int64)
    rows = np.concatenate([r, r + 1, diag])
    cols = np.concatenate([r + 1, r, diag])
    return csr_from_coo(rows, cols, num_vertices)


# the suite used by benchmarks standing in for the paper's 17 matrices
def paper_suite(scale: str = "small"):
    """Named graph suite. 'small' for tests/benches, 'paper' for Table III."""
    if scale == "small":
        return {
            "laplace3d_16": laplace3d(16).graph,
            "elasticity3d_8": elasticity3d(8).graph,
            "uniform_50k": random_uniform_graph(50_000, 8.0, seed=1),
            "skewed_50k": random_skewed_graph(50_000, 8.0, seed=2),
        }
    if scale == "paper":
        return {
            "Laplace3D_100": laplace3d(100).graph,
            "Elasticity3D_60": elasticity3d(60).graph,
        }
    raise ValueError(scale)
