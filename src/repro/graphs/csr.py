"""Graph and sparse-matrix containers.

Two structural formats:

* ``CSRGraph`` / ``CSRMatrix`` — the paper's native format (compressed sparse
  row).  Used for host-side construction and as the interchange format.
* ``ELLGraph`` / ``ELLMatrix`` — the TPU-native format (padded ELLPACK).  Every
  vertex's adjacency row is padded to a common width ``D`` so that neighbor
  reductions become dense, lane-aligned gathers — the TPU analogue of the
  paper's warp-coalesced CRS row reads (DESIGN.md §3).

Padding convention: padded ``neighbors`` entries point at the row's own vertex
(self), with ``mask == False``.  Because the MIS-2 reductions (min / forall /
exists) are computed over *closed* neighborhoods, self-padding is semantically
inert for them; operations that must not see padding (coupling counts,
SpMV) consult ``mask``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


class CSRGraph(NamedTuple):
    """Symmetric graph in CSR form (structure only)."""

    indptr: Array   # int32 [V+1]
    indices: Array  # int32 [E]

    @property
    def num_vertices(self) -> int:
        return int(self.indptr.shape[0]) - 1

    @property
    def num_entries(self) -> int:
        return int(self.indices.shape[0])


class CSRMatrix(NamedTuple):
    """Square sparse matrix in CSR form."""

    indptr: Array   # int32 [V+1]
    indices: Array  # int32 [E]
    values: Array   # float [E]

    @property
    def num_rows(self) -> int:
        return int(self.indptr.shape[0]) - 1

    @property
    def num_entries(self) -> int:
        return int(self.indices.shape[0])

    @property
    def graph(self) -> CSRGraph:
        return CSRGraph(self.indptr, self.indices)


class ELLGraph(NamedTuple):
    """Padded (ELLPACK) graph. ``neighbors[v, j]`` is the j-th neighbor of v;
    padded slots hold ``v`` itself with ``mask`` False."""

    neighbors: Array  # int32 [V, D]
    mask: Array       # bool  [V, D]

    @property
    def num_vertices(self) -> int:
        return int(self.neighbors.shape[0])

    @property
    def width(self) -> int:
        return int(self.neighbors.shape[1])


class ELLMatrix(NamedTuple):
    """Padded (ELLPACK) matrix; padded slots hold column=row, value=0."""

    cols: Array    # int32 [V, D]
    vals: Array    # float [V, D]
    mask: Array    # bool  [V, D]

    @property
    def num_rows(self) -> int:
        return int(self.cols.shape[0])

    @property
    def width(self) -> int:
        return int(self.cols.shape[1])

    @property
    def graph(self) -> ELLGraph:
        return ELLGraph(self.cols, self.mask)


# ---------------------------------------------------------------------------
# Host-side (numpy) conversions.  Format conversion is setup-time work, like
# the CRS assembly the paper inherits from the application.
# ---------------------------------------------------------------------------

def csr_from_coo(
    rows: np.ndarray,
    cols: np.ndarray,
    num_vertices: int,
    vals: np.ndarray | None = None,
    *,
    sum_duplicates: bool = True,
):
    """Build CSR (graph or matrix) from COO triples, deduplicating."""
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    if vals is not None:
        vals = vals[order]
    if len(rows):
        keep = np.ones(len(rows), dtype=bool)
        keep[1:] = (rows[1:] != rows[:-1]) | (cols[1:] != cols[:-1])
        if vals is not None and sum_duplicates:
            seg = np.cumsum(keep) - 1
            vals = np.bincount(seg, weights=vals, minlength=int(keep.sum()))
        elif vals is not None:
            vals = vals[keep]
        rows, cols = rows[keep], cols[keep]
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    if vals is None:
        return CSRGraph(jnp.asarray(indptr), jnp.asarray(cols.astype(np.int32)))
    return CSRMatrix(
        jnp.asarray(indptr),
        jnp.asarray(cols.astype(np.int32)),
        jnp.asarray(vals.astype(np.float32)),
    )


def _csr_host(indptr, indices):
    return np.asarray(indptr), np.asarray(indices)


def csr_to_ell_graph(g: CSRGraph, width: int | None = None) -> ELLGraph:
    """CSR -> ELL. ``width`` defaults to the max degree (rows longer than
    ``width`` would be truncated; we require width >= max degree)."""
    indptr, indices = _csr_host(g.indptr, g.indices)
    v = len(indptr) - 1
    deg = np.diff(indptr)
    d = int(deg.max()) if width is None else int(width)
    if (deg > d).any():
        raise ValueError(f"ELL width {d} < max degree {int(deg.max())}")
    neighbors = np.repeat(np.arange(v, dtype=np.int32)[:, None], d, axis=1)
    mask = np.zeros((v, d), dtype=bool)
    # slot index of each CSR entry within its row
    slot = np.arange(len(indices)) - np.repeat(indptr[:-1], deg)
    rows = np.repeat(np.arange(v), deg)
    neighbors[rows, slot] = indices
    mask[rows, slot] = True
    return ELLGraph(jnp.asarray(neighbors), jnp.asarray(mask))


def csr_to_ell_matrix(m: CSRMatrix, width: int | None = None) -> ELLMatrix:
    indptr, indices = _csr_host(m.indptr, m.indices)
    values = np.asarray(m.values)
    v = len(indptr) - 1
    deg = np.diff(indptr)
    d = int(deg.max()) if width is None else int(width)
    if (deg > d).any():
        raise ValueError(f"ELL width {d} < max degree {int(deg.max())}")
    cols = np.repeat(np.arange(v, dtype=np.int32)[:, None], d, axis=1)
    vals = np.zeros((v, d), dtype=values.dtype)
    mask = np.zeros((v, d), dtype=bool)
    slot = np.arange(len(indices)) - np.repeat(indptr[:-1], deg)
    rows = np.repeat(np.arange(v), deg)
    cols[rows, slot] = indices
    vals[rows, slot] = values
    mask[rows, slot] = True
    return ELLMatrix(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(mask))


def pad_ell_graph(g: ELLGraph, num_rows: int, width: int) -> ELLGraph:
    """Pad an ELL graph to ``[num_rows, width]`` (both >= current shape).

    Follows the module's padding convention: every padded slot — the new
    width columns of real rows and all slots of the new rows — points at
    the row's own vertex with ``mask == False``, so closed-neighborhood
    reductions (MIS-2 min / forall / exists) are unaffected and mask-aware
    consumers skip the padding.  This is the shape-normalization step that
    lets ``repro.batch`` stack many graphs into one ``[B, rows, width]``
    bucket for a vmapped dispatch.
    """
    v, d = g.neighbors.shape
    if num_rows < v or width < d:
        raise ValueError(
            f"pad_ell_graph target [{num_rows}, {width}] smaller than "
            f"current [{v}, {d}]")
    if num_rows == v and width == d:
        return g
    neighbors = np.repeat(np.arange(num_rows, dtype=np.int32)[:, None],
                          width, axis=1)
    mask = np.zeros((num_rows, width), dtype=bool)
    neighbors[:v, :d] = np.asarray(g.neighbors)
    mask[:v, :d] = np.asarray(g.mask)
    return ELLGraph(jnp.asarray(neighbors), jnp.asarray(mask))


def ell_to_csr_graph(g: ELLGraph) -> CSRGraph:
    neighbors = np.asarray(g.neighbors)
    mask = np.asarray(g.mask)
    v, _ = neighbors.shape
    rows = np.repeat(np.arange(v), mask.sum(axis=1))
    cols = neighbors[mask]
    return csr_from_coo(rows, cols, v)


def ensure_self_loops(g: CSRGraph) -> CSRGraph:
    """Add any missing diagonal entries (closed-neighborhood semantics)."""
    indptr, indices = _csr_host(g.indptr, g.indices)
    v = len(indptr) - 1
    rows = np.repeat(np.arange(v), np.diff(indptr))
    has_self = np.zeros(v, dtype=bool)
    has_self[rows[rows == indices]] = True
    missing = np.flatnonzero(~has_self)
    rows = np.concatenate([rows, missing])
    cols = np.concatenate([indices, missing])
    return csr_from_coo(rows.astype(np.int64), cols.astype(np.int64), v)


def symmetrize(g: CSRGraph) -> CSRGraph:
    indptr, indices = _csr_host(g.indptr, g.indices)
    v = len(indptr) - 1
    rows = np.repeat(np.arange(v), np.diff(indptr))
    all_rows = np.concatenate([rows, indices])
    all_cols = np.concatenate([indices, rows])
    return csr_from_coo(all_rows, all_cols, v)


def degrees(g: CSRGraph) -> np.ndarray:
    indptr, _ = _csr_host(g.indptr, g.indices)
    return np.diff(indptr)


# ---------------------------------------------------------------------------
# Degree-bucketed ELL (DESIGN.md §3): one padded block per degree class, so
# a skewed graph does not pay max-degree padding for every row.  Reductions
# run per bucket and scatter back by the bucket's row permutation.
# ---------------------------------------------------------------------------

class BucketedELL(NamedTuple):
    """rows[i], graphs[i]: vertex ids + ELL block of bucket i."""

    rows: tuple       # tuple of int32 arrays
    graphs: tuple     # tuple of ELLGraph

    @property
    def num_vertices(self) -> int:
        return int(sum(len(r) for r in self.rows))

    @property
    def padding_ratio(self) -> float:
        """Padded slots / real entries (1.0 = no waste)."""
        padded = sum(g.neighbors.shape[0] * g.width for g in self.graphs)
        real = sum(int(np.asarray(g.mask).sum()) for g in self.graphs)
        return padded / max(1, real)


def csr_to_bucketed_ell(g: CSRGraph, boundaries=(8, 32, 128)) -> BucketedELL:
    """Split rows into degree buckets (<=8, <=32, <=128, rest)."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    v = len(indptr) - 1
    deg = np.diff(indptr)
    edges = [0] + [b for b in boundaries if b < deg.max()] + [int(deg.max())]
    rows_out, graphs_out = [], []
    for lo, hi in zip(edges[:-1], edges[1:]):
        sel = np.flatnonzero((deg > lo) & (deg <= hi))
        if len(sel) == 0:
            continue
        width = int(deg[sel].max())
        nbrs = np.repeat(sel.astype(np.int32)[:, None], width, axis=1)
        mask = np.zeros((len(sel), width), dtype=bool)
        for j, r in enumerate(sel):
            d = deg[r]
            nbrs[j, :d] = indices[indptr[r]:indptr[r] + d]
            mask[j, :d] = True
        rows_out.append(jnp.asarray(sel.astype(np.int32)))
        graphs_out.append(ELLGraph(jnp.asarray(nbrs), jnp.asarray(mask)))
    return BucketedELL(tuple(rows_out), tuple(graphs_out))
