"""RL101 — trace purity: no host syncs or Python control flow on traced
values inside jit-reachable code."""
from __future__ import annotations

import ast
from typing import List

from ..engine import Project, SourceFile
from ..findings import Finding
from . import Rule, register
from ._shared import TracedInference, iter_file_functions, iter_own_nodes, \
    resolve_chain, short_symbol

_SYNC_ATTRS = {"item": ".item() forces a device->host sync",
               "block_until_ready": ".block_until_ready() blocks on device "
                                    "execution"}
_HOST_FUNCS = {
    "numpy.asarray": "np.asarray materializes the traced value on the host",
    "numpy.array": "np.array materializes the traced value on the host",
    "numpy.copy": "np.copy materializes the traced value on the host",
    "jax.device_get": "jax.device_get pulls the traced value to the host",
}
_CASTS = {"int", "bool", "float"}


@register
class TracePurity(Rule):
    code = "RL101"
    name = "trace-purity"
    explain = """\
RL101 trace-purity — no host syncs inside jit-reachable code.

Inside any function reachable from a jax.jit site, a shard_map/pallas_call
wrapper, or a lax.while_loop/fori_loop/scan body, the following force a
device->host round trip (or simply fail to trace) and are flagged:

  * .item() / .block_until_ready() on a traced value
  * int(x) / bool(x) / float(x) where x is traced
  * np.asarray / np.array / np.copy / jax.device_get of a traced value
  * Python `if` / `while` whose condition reads a traced value
    (use lax.cond / lax.while_loop / jnp.where instead)

History: before PR 4 the MIS-2 fixed point hid host syncs inside what
looked like a jitted loop — the driver pulled T and M back every round to
rebuild worklists, costing 2 syncs/iteration; making the loop a single
lax.while_loop bought ~3x rounds/sec at V=4096.  The runtime half of this
invariant is tools/check_shape.py's `resident` gate (1 dispatch, 0 syncs
on a golden workload); RL101 is the static half that covers every code
path, including ones no benchmark runs.

Jit-reachability is computed over the project call graph, seeded from
@jax.jit decorators, functions passed to jax.jit/shard_map/pallas_call,
lax control-flow bodies, and Pallas kernel bodies (functions with *_ref
parameters).  Tracedness is inferred conservatively: loop/kernel bodies
trace all parameters, jit entries trace everything not in
static_argnames, helpers trace only values flowing from jnp/lax calls.

Suppress a deliberate host boundary (e.g. jax.pure_callback internals)
with `# repro-lint: ignore[RL101] <reason>`.
"""

    def check_file(self, src: SourceFile, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for info in iter_file_functions(project, src):
            if not project.is_jit_context(info.qualname):
                continue
            inf = TracedInference(info, src)
            symbol = short_symbol(info)
            for sub in iter_own_nodes(info.node):
                out.extend(self._check_node(sub, inf, src, symbol))
        return out

    def _check_node(self, sub: ast.AST, inf: TracedInference,
                    src: SourceFile, symbol: str) -> List[Finding]:
        out: List[Finding] = []

        def flag(node, msg):
            out.append(Finding(rule=self.code, path=src.relpath,
                               line=node.lineno, symbol=symbol, message=msg))

        if isinstance(sub, ast.Call):
            if isinstance(sub.func, ast.Attribute) and \
                    sub.func.attr in _SYNC_ATTRS and not sub.args:
                if inf.is_traced(sub.func.value):
                    flag(sub, f"{_SYNC_ATTRS[sub.func.attr]} inside a "
                              "jit-reachable function")
            chain = resolve_chain(src, sub.func)
            if chain in _HOST_FUNCS and sub.args and \
                    inf.is_traced(sub.args[0]):
                flag(sub, f"{_HOST_FUNCS[chain]} inside a jit-reachable "
                          "function")
            if isinstance(sub.func, ast.Name) and \
                    sub.func.id in _CASTS and len(sub.args) == 1 and \
                    inf.is_traced(sub.args[0]):
                flag(sub, f"{sub.func.id}() on a traced value forces a "
                          "concretization sync inside a jit-reachable "
                          "function")
        elif isinstance(sub, (ast.If, ast.While)):
            names = inf.traced_names_in(sub.test)
            if names:
                kw = "while" if isinstance(sub, ast.While) else "if"
                flag(sub, f"Python `{kw}` on traced value(s) "
                          f"{sorted(names)} inside a jit-reachable function "
                          "— use lax.cond/lax.while_loop/jnp.where")
        return out
