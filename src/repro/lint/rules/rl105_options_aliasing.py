"""RL105 — options aliasing: no mutable default arguments."""
from __future__ import annotations

import ast
from typing import List

from ..engine import Project, SourceFile, _name_chain
from ..findings import Finding
from . import Rule, register
from ._shared import short_symbol

#: constructor calls whose results are immutable values — safe defaults
_IMMUTABLE_CALLS = {"frozenset", "tuple", "frozendict", "MappingProxyType"}


@register
class OptionsAliasing(Rule):
    code = "RL105"
    name = "options-aliasing"
    explain = """\
RL105 options-aliasing — mutable default arguments are banned.

    def mis2(graph, options=Mis2Options()):   # RL105
        ...

Python evaluates the default ONCE, at def time: every call that omits
`options` shares the SAME object.  The first caller that mutates a field
(engines toggle `use_pallas`, ablations flip `worklists`) silently
reconfigures every later call in the process.

History (the PR 2 bug class): the seed-era core/solver signatures all
defaulted to `Mis2Options()` and the batch pipeline mutated its copy —
cross-call contamination that PR 2 swept out of core/ with the
None-sentinel idiom.  RL105 enforces that idiom everywhere:

    def mis2(graph, options=None):
        options = Mis2Options() if options is None else options

Flagged defaults: any constructor call, list/dict/set literal.  Immutable
constructors (tuple(), frozenset()) are exempt.  A frozen-dataclass
default is still flagged — freezing prevents mutation but not identity
aliasing across calls, and the None-sentinel is uniformly cheaper than
auditing frozenness.
"""

    def check_file(self, src: SourceFile, project: Project) -> List[Finding]:
        out: List[Finding] = []
        seen = set()
        for info in project.functions.values():
            if info.src is not src or not isinstance(
                    info.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if id(info.node) in seen:
                continue
            seen.add(id(info.node))
            a = info.node.args
            defaults = list(zip(reversed(a.posonlyargs + a.args),
                                reversed(a.defaults)))
            defaults += [(p, d) for p, d in zip(a.kwonlyargs, a.kw_defaults)
                         if d is not None]
            for param, default in defaults:
                bad = self._mutable_kind(default)
                if bad:
                    out.append(Finding(
                        rule=self.code, path=src.relpath,
                        line=default.lineno, symbol=short_symbol(info),
                        message=(f"mutable default `{param.arg}="
                                 f"{ast.unparse(default)}` is evaluated "
                                 "once and shared across every call (the "
                                 f"PR 2 options-aliasing bug class) — use "
                                 f"`{param.arg}=None` plus "
                                 f"`{param.arg} = {bad} if {param.arg} is "
                                 "None else ...`")))
        return out

    def _mutable_kind(self, default: ast.AST) -> str:
        if isinstance(default, (ast.List, ast.Dict, ast.Set)):
            return ast.unparse(default) or "..."
        if isinstance(default, ast.Call):
            chain = _name_chain(default.func) or ""
            if chain.rpartition(".")[2] in _IMMUTABLE_CALLS:
                return ""
            return ast.unparse(default)
        return ""
