"""RL103 — timing: durations use ``time.perf_counter``, never
``time.time``."""
from __future__ import annotations

import ast
from typing import List

from ..engine import Project, SourceFile
from ..findings import Finding
from . import Rule, register
from ._shared import resolve_chain, short_symbol


@register
class Timing(Rule):
    code = "RL103"
    name = "timing"
    explain = """\
RL103 timing — time.time() is banned; the repo standard is
time.perf_counter().

time.time() is wall-clock: it is subject to NTP slew and steps, so a
duration computed from two time.time() readings can be wrong by
milliseconds — or negative.  Every benchmark number, span duration, and
ServeStats window in this repo is a perf_counter delta (PR 3 moved the
solver setup timings, PR 5 the benchmark drivers, PR 7 standardized
serve on it after ServeStats was caught mixing time.monotonic in).

RL103 flags BOTH calls to time.time() and bare references to the
time.time function object.  The bare-reference case is deliberate:
genuinely epoch-based stamps (checkpoint manifests, trajectory records)
are still allowed, but must be written as an explicit module-level alias
carrying an inline suppression with a reason, e.g.

    _EPOCH_NOW = time.time  # repro-lint: ignore[RL103] manifest stamp is
                            # an epoch time, not a duration

so every surviving wall-clock read is self-documenting and greppable.
"""

    def check_file(self, src: SourceFile, project: Project) -> List[Finding]:
        out: List[Finding] = []
        symbols = _symbol_spans(src, project)
        for node in ast.walk(src.tree):
            if isinstance(node, (ast.Attribute, ast.Name)):
                chain = resolve_chain(src, node)
                if chain == "time.time":
                    out.append(Finding(
                        rule=self.code, path=src.relpath, line=node.lineno,
                        symbol=symbols.get(node.lineno, "<module>"),
                        message=("time.time is wall-clock — use "
                                 "time.perf_counter() for durations; for a "
                                 "deliberate epoch stamp, bind an explicit "
                                 "alias with an ignore[RL103] reason")))
        return _dedupe(out)


def _symbol_spans(src: SourceFile, project: Project) -> dict:
    """line -> enclosing function symbol (for finding identity)."""
    spans = {}
    for info in project.functions.values():
        if info.src is not src or not hasattr(info.node, "body"):
            continue
        end = getattr(info.node, "end_lineno", info.node.lineno)
        for line in range(info.node.lineno, end + 1):
            # innermost def wins: later (nested) defs overwrite
            cur = spans.get(line)
            if cur is None or len(short_symbol(info)) >= len(cur):
                spans[line] = short_symbol(info)
    return spans


def _dedupe(findings: List[Finding]) -> List[Finding]:
    seen = set()
    out = []
    for f in findings:
        k = (f.rule, f.path, f.line)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out
