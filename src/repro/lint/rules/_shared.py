"""Shared per-function analyses used by the RL rules.

:class:`LocalDataflow` is an intentionally approximate reaching-definition
map — straight-line, last-write-wins, control flow ignored — which is the
right fidelity for provenance questions ("does this argument descend from
a padded size?") where a false negative on a convoluted path is acceptable
and a false positive on ordinary code is not.

:class:`TracedInference` classifies names inside a jit-context function as
traced (device values) or static (Python values), seeding from the
function's role: loop/kernel bodies trace every parameter; ``jax.jit``
entries trace everything not named in ``static_argnames``; transitively
reachable helpers trace only what provably flows from ``jnp``/``lax``
expressions — precision over recall, again.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..engine import FunctionInfo, Project, SourceFile, _name_chain

#: attribute reads that yield static (host) values even on traced arrays
STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "itemsize", "weak_type"}

#: call-prefixes whose results are traced arrays
TRACED_CALL_PREFIXES = (
    "jax.numpy.", "jnp.", "jax.lax.", "lax.", "jax.nn.", "jax.random.",
    "jax.scipy.",
)


def iter_file_functions(project: Project,
                        src: SourceFile) -> Iterator[FunctionInfo]:
    for info in project.functions.values():
        if info.src is src:
            yield info


def short_symbol(info: FunctionInfo) -> str:
    """Module-relative symbol for findings/baseline keys."""
    qual = info.qualname
    if info.module and qual.startswith(info.module + "."):
        qual = qual[len(info.module) + 1:]
    return qual


def resolve_chain(src: SourceFile, node: ast.AST) -> str:
    return src.resolve(node) or _name_chain(node) or ""


class LocalDataflow:
    """name -> assigned value expressions, collected over one function."""

    def __init__(self, fn_node: ast.AST):
        self.defs: Dict[str, List[ast.AST]] = {}
        body = fn_node.body if isinstance(
            fn_node, (ast.FunctionDef, ast.AsyncFunctionDef)) else [fn_node]
        for stmt in body:
            for sub in ast.walk(stmt if isinstance(stmt, ast.stmt)
                                else ast.Expr(value=stmt)):
                self._collect(sub)

    def _collect(self, node: ast.AST) -> None:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                self._bind(tgt, node.value)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            self._bind(node.target, node.value)
        elif isinstance(node, ast.AugAssign):
            self._bind(node.target, node.value)
        elif isinstance(node, ast.For):
            self._bind(node.target, node.iter)
        elif isinstance(node, (ast.NamedExpr,)):
            self._bind(node.target, node.value)

    def _bind(self, target: ast.AST, value: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.defs.setdefault(target.id, []).append(value)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, value)

    def origin_tokens(self, expr: ast.AST, depth: int = 6) -> Set[str]:
        """Every name, dotted chain, and callee name in the transitive
        provenance of ``expr`` (bounded by ``depth`` hops)."""
        tokens: Set[str] = set()
        frontier: List[Tuple[ast.AST, int]] = [(expr, depth)]
        seen_names: Set[str] = set()
        while frontier:
            node, d = frontier.pop()
            for sub in ast.walk(node):
                if isinstance(sub, ast.Name):
                    tokens.add(sub.id)
                    if d > 0 and sub.id not in seen_names:
                        seen_names.add(sub.id)
                        for value in self.defs.get(sub.id, ()):
                            frontier.append((value, d - 1))
                elif isinstance(sub, ast.Attribute):
                    chain = _name_chain(sub)
                    if chain:
                        tokens.add(chain)
                elif isinstance(sub, ast.Call):
                    chain = _name_chain(sub.func)
                    if chain:
                        tokens.add(chain + "()")
        return tokens


class TracedInference:
    """Classify local names of one jit-context function as traced."""

    def __init__(self, info: FunctionInfo, src: SourceFile):
        self.src = src
        self.traced: Set[str] = set()
        if info.loop_body or info.kernel_body:
            self.traced |= set(info.params)
        elif info.jit_entry:
            self.traced |= {p for p in info.params
                            if p not in info.static_argnames}
        # params annotated with host types (bool/str/...) or defaulted to a
        # literal bool are closed over statically even in shard_map/jit
        # entries — `if single_gather:` on a bool kwarg is host control flow
        self.traced -= _static_params(info.node)
        # fixpoint over straight-line assignments (2 passes settle loops)
        node = info.node
        body = node.body if isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef)) else []
        for _ in range(2):
            for stmt in body:
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Assign):
                        if self.is_traced(sub.value):
                            for tgt in sub.targets:
                                self._mark(tgt)
                    elif isinstance(sub, ast.AugAssign):
                        if self.is_traced(sub.value) or \
                                self.is_traced(sub.target):
                            self._mark(sub.target)

    def _mark(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.traced.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._mark(elt)

    def is_traced(self, expr: ast.AST) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id in self.traced
        if isinstance(expr, ast.Attribute):
            if expr.attr in STATIC_ATTRS:
                return False
            return self.is_traced(expr.value)
        if isinstance(expr, ast.Subscript):
            # x.shape[0] is static even when x is traced
            if isinstance(expr.value, ast.Attribute) and \
                    expr.value.attr in STATIC_ATTRS:
                return False
            return self.is_traced(expr.value)
        if isinstance(expr, ast.Call):
            chain = resolve_chain(self.src, expr.func)
            if chain.startswith(TRACED_CALL_PREFIXES) or \
                    ".at." in chain:
                return True
            args = list(expr.args) + [kw.value for kw in expr.keywords]
            if chain.rpartition(".")[2] in _TRACED_PRESERVING and any(
                    self.is_traced(a) for a in args):
                return True
            return False
        if isinstance(expr, (ast.BinOp,)):
            return self.is_traced(expr.left) or self.is_traced(expr.right)
        if isinstance(expr, ast.UnaryOp):
            return self.is_traced(expr.operand)
        if isinstance(expr, ast.Compare):
            return self.is_traced(expr.left) or any(
                self.is_traced(c) for c in expr.comparators)
        if isinstance(expr, ast.BoolOp):
            return any(self.is_traced(v) for v in expr.values)
        if isinstance(expr, ast.IfExp):
            return self.is_traced(expr.body) or self.is_traced(expr.orelse)
        if isinstance(expr, (ast.Tuple, ast.List)):
            return any(self.is_traced(e) for e in expr.elts)
        if isinstance(expr, ast.Starred):
            return self.is_traced(expr.value)
        return False

    def traced_names_in(self, expr: ast.AST) -> Set[str]:
        """Traced names appearing in ``expr`` outside is/is-not checks."""
        out: Set[str] = set()

        def rec(node: ast.AST) -> None:
            if isinstance(node, ast.Compare) and all(
                    isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return                      # `x is None` guards are host-side
            if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
                return
            if isinstance(node, ast.Name) and node.id in self.traced:
                out.add(node.id)
            for child in ast.iter_child_nodes(node):
                rec(child)

        rec(expr)
        return out


def iter_own_nodes(fn_node: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested def/class
    bodies (those are indexed and checked as their own functions).
    Lambdas stay in — they are part of the enclosing function unless a
    jit/loop wrapper promoted them to entries."""
    body = fn_node.body if isinstance(
        fn_node, (ast.FunctionDef, ast.AsyncFunctionDef)) else [fn_node.body]

    def rec(node: ast.AST) -> Iterator[ast.AST]:
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            yield from rec(child)

    for stmt in body:
        yield from rec(stmt)


#: annotations naming host-side (never traced) parameter types
_STATIC_ANNOTATIONS = {"bool", "str", "bytes", "Mesh", "Path"}


def _static_params(fn_node: ast.AST) -> Set[str]:
    """Params whose annotation or default marks them as static Python
    values (not device arrays), regardless of how the function is traced."""
    if not isinstance(fn_node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return set()
    a = fn_node.args
    out: Set[str] = set()
    for p in a.posonlyargs + a.args + a.kwonlyargs:
        ann = p.annotation
        if ann is not None:
            name = _name_chain(ann) or ""
            if name.rpartition(".")[2] in _STATIC_ANNOTATIONS:
                out.add(p.arg)
    # positional/keyword params defaulted to a literal bool
    pos = a.posonlyargs + a.args
    for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if isinstance(d, ast.Constant) and isinstance(d.value, bool):
            out.add(p.arg)
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None and isinstance(d, ast.Constant) and \
                isinstance(d.value, bool):
            out.add(p.arg)
    return out


#: functions that return traced values when fed traced values
_TRACED_PRESERVING = {
    "where", "minimum", "maximum", "sum", "min", "max", "any", "all",
    "take", "reshape", "concatenate", "stack", "pack", "unpack_id",
    "is_undecided", "effective_priority", "astype", "clip", "cumsum",
    "searchsorted", "sort", "argsort", "dot", "matmul", "abs",
}
