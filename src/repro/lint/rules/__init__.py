"""Rule registry for ``repro.lint``.

Every rule is a class with a ``code`` (``RLxxx``), a one-line ``name``, a
long-form ``explain`` (shown by ``tools/repro_lint.py --explain RLxxx``,
including the historical bug the rule exists to prevent), and a
``check_file(src, project) -> list[Finding]`` method.  Registration is by
decorator; :func:`all_rules` returns one instance of each, sorted by code.
"""
from __future__ import annotations

from typing import Dict, List, Type

RULES: Dict[str, type] = {}


def register(cls: type) -> type:
    if not getattr(cls, "code", None):  # pragma: no cover
        raise ValueError(f"rule {cls.__name__} has no code")
    RULES[cls.code] = cls
    return cls


class Rule:
    code: str = ""
    name: str = ""
    explain: str = ""

    def check_file(self, src, project) -> list:  # pragma: no cover
        raise NotImplementedError


def all_rules() -> List[Rule]:
    # importing the rule modules populates the registry
    from . import (  # noqa: F401
        rl101_trace_purity,
        rl102_priority_provenance,
        rl103_timing,
        rl104_obs_hygiene,
        rl105_options_aliasing,
        rl106_kernel_masking,
    )
    return [RULES[code]() for code in sorted(RULES)]


def get_rule(code: str) -> Rule:
    all_rules()
    if code not in RULES:
        raise KeyError(code)
    return RULES[code]()
