"""RL106 — kernel masking: Pallas kernel bodies must guard the ragged
final grid block."""
from __future__ import annotations

import ast
from typing import List, Set

from ..engine import Project, SourceFile
from ..findings import Finding
from . import Rule, register
from ._shared import iter_file_functions, resolve_chain, short_symbol


@register
class KernelMasking(Rule):
    code = "RL106"
    name = "kernel-masking"
    explain = """\
RL106 kernel-masking — Pallas kernel bodies must handle the ragged final
grid block.

Every wrapper in kernels/ launches `grid = pl.cdiv(n, block)` steps, so
whenever `n % block != 0` the LAST step sees a partial tile.  Interpret
mode (the CPU CI path) pads that tile with zeros; COMPILED Pallas pads it
with unspecified values.  A kernel that gathers with those values
(`jnp.take(x, cols)` where cols came from the pad) reads out of bounds on
hardware while every CPU test stays green — the worst kind of
portability bug for a repo whose headline claim is bit-identity across
backends.

A kernel body (any function with *_ref parameters) that reads or writes
refs must therefore show one of:

  * a `pl.when` guard comparing the block position against a prefetched
    count (`@pl.when(i * block < count_ref[0])` — the SV-B worklist
    skipping shape), or
  * an explicit validity mask derived from `pl.program_id` /
    iota/arange vs a row bound
    (`valid = i * block + jnp.arange(block) < num_rows`).

Purely elementwise kernels whose tail lanes are dropped by the BlockSpec
write (no data-dependent indexing) may suppress with
`# repro-lint: ignore[RL106] <reason>` — the reason should say WHY the
tail cannot read through a gathered index.
"""

    def check_file(self, src: SourceFile, project: Project) -> List[Finding]:
        if not any("pallas" in q for q in src.imports.values()):
            return []
        out: List[Finding] = []
        for info in iter_file_functions(project, src):
            if not info.kernel_body or not isinstance(
                    info.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            refs = {p for p in info.params if p.endswith("_ref")}
            if not self._touches_refs(info.node, refs):
                continue
            if self._has_guard(info.node, src):
                continue
            out.append(Finding(
                rule=self.code, path=src.relpath, line=info.node.lineno,
                symbol=short_symbol(info),
                message=("Pallas kernel body indexes refs with no pl.when "
                         "guard or ragged-tail mask — the final grid block "
                         "reads unspecified pad values when compiled "
                         "(interpret mode hides it with zero padding)")))
        return out

    def _touches_refs(self, node: ast.AST, refs: Set[str]) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Subscript) and \
                    isinstance(sub.value, ast.Name) and \
                    sub.value.id in refs:
                return True
        return False

    def _has_guard(self, node: ast.AST, src: SourceFile) -> bool:
        # names bound from pl.program_id(...)
        pid_names: Set[str] = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Assign) and isinstance(
                    sub.value, ast.Call):
                chain = resolve_chain(src, sub.value.func)
                if chain.endswith("program_id"):
                    for tgt in sub.targets:
                        if isinstance(tgt, ast.Name):
                            pid_names.add(tgt.id)
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                chain = resolve_chain(src, sub.func)
                if chain.endswith(".when") or chain == "when":
                    return True
            if isinstance(sub, ast.Compare):
                tokens = {n.id for n in ast.walk(sub)
                          if isinstance(n, ast.Name)}
                if tokens & pid_names:
                    return True
                for call in ast.walk(sub):
                    if isinstance(call, ast.Call):
                        chain = resolve_chain(src, call.func)
                        if chain.endswith(("program_id", "iota",
                                           "broadcasted_iota", "arange")):
                            return True
        return False
