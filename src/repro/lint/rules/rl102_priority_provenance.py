"""RL102 — priority provenance: ``id_bits`` must be fed the REAL vertex
count, never a padded/bucketed size."""
from __future__ import annotations

import ast
import re
from typing import List, Set

from ..engine import Project, SourceFile, _name_chain
from ..findings import Finding
from . import Rule, register
from ._shared import LocalDataflow, iter_file_functions, short_symbol

#: provenance tokens that mean "this size includes padding"
_PADDED_NAME_RE = re.compile(
    r"(^|[._])(vp|vp_total|padded|pad|bucket|bucketed)([._]|\d|$)|"
    r"(^|[._])padded_|_padded([._]|$)")

#: calls in the provenance chain that *produce* padded/bucketed sizes
_PADDING_CALLS = re.compile(
    r"(pad_graph_for_mesh|pad_ell_graph|prepare_padded|_bucket|"
    r"next_pow2|pow2_bucket)\(\)$")

#: provenance tokens that positively mean "real vertex count" — their
#: presence alone never clears a padded token, but a *pure* real-count
#: argument is the documented good shape
_REAL_TOKENS = {"num_vertices", "v_real", "n_real", "real_v"}


@register
class PriorityProvenance(Rule):
    code = "RL102"
    name = "priority-provenance"
    explain = """\
RL102 priority-provenance — id_bits() must see the real vertex count.

The packed status tuple (paper SV-C) reserves b = ceil(log2(V + 2)) low
bits for the vertex id; the remaining 32-b bits hold the priority.  The
bit width b is therefore part of the *mathematical definition* of the
total order the MIS-2 fixed point resolves — feed id_bits() a padded or
bucketed vertex count and the effective priorities change, silently
diverging from every engine that used the real count.

History (the PR 3 bug, found as a real determinism break): core/dist.py
packed priorities with id_bits(vp_total) — the device-padded count —
so any graph whose mesh padding crossed a power of two (V=1022 on 8
devices pads to 1024: b goes 10 -> 11) produced a DIFFERENT maximal
independent set than the single-device dense engine.  At paper scale
(V=1M, 12 effective priority bits) divergence is near-certain.  The fix
threaded num_vertices=V_real through the sharded fixed point; RL102 keeps
the bug class out of the tree by flagging any id_bits()/pack-width
argument whose dataflow reaches:

  * a name matching vp/vp_total/padded_*/pad/bucket (padded sizes)
  * a call to pad_graph_for_mesh / pad_ell_graph / prepare_padded /
    _bucket (pow2 bucketing)
  * .shape[0] of a buffer whose own provenance is padded

Pass V_real / num_vertices / graph.num_vertices instead.  If a padded
width is genuinely intended (it never is for priorities), suppress with
`# repro-lint: ignore[RL102] <reason>`.
"""

    def check_file(self, src: SourceFile, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for info in iter_file_functions(project, src):
            flow = None
            for sub in ast.walk(info.node):
                if not isinstance(sub, ast.Call):
                    continue
                chain = src.resolve(sub.func) or _name_chain(sub.func) or ""
                if chain.rpartition(".")[2] != "id_bits" or not sub.args:
                    continue
                if flow is None:
                    flow = LocalDataflow(info.node)
                evidence = self._padded_evidence(flow, sub.args[0])
                if evidence:
                    out.append(Finding(
                        rule=self.code, path=src.relpath, line=sub.lineno,
                        symbol=short_symbol(info),
                        message=(f"id_bits({ast.unparse(sub.args[0])}) "
                                 f"descends from padded/bucketed size "
                                 f"{sorted(evidence)} — the packing bit "
                                 "width must come from the REAL vertex "
                                 "count (the PR 3 determinism bug)")))
        return out

    def _padded_evidence(self, flow: LocalDataflow,
                         arg: ast.AST) -> Set[str]:
        tokens = flow.origin_tokens(arg)
        bad: Set[str] = set()
        for tok in tokens:
            if tok.endswith("()"):
                if _PADDING_CALLS.search(tok):
                    bad.add(tok)
            elif _PADDED_NAME_RE.search(tok):
                bad.add(tok)
        return bad
