"""RL104 — obs hygiene: metric names follow the registry scheme, labels
stay bounded, legacy stats globals are never mutated directly."""
from __future__ import annotations

import ast
import re
from typing import List, Optional

from ..engine import Project, SourceFile, _name_chain
from ..findings import Finding
from . import Rule, register
from ._shared import resolve_chain
from .rl103_timing import _symbol_spans

#: dotted lowercase: subsystem prefix mandatory ("mis2.host_syncs",
#: "serve.cache.bytes_used") — matches every PR 7 registry name
_SCHEME_RE = re.compile(r"^[a-z][a-z0-9_]*(\.[a-z][a-z0-9_]*)+$")
#: an f-string name is tolerated iff its static prefix pins the subsystem
_FSTRING_PREFIX_RE = re.compile(r"^[a-z][a-z0-9_]*(\.[a-z0-9_]+)*\.$")

_REGISTRY_METHODS = {"counter", "gauge", "histogram"}
_LEGACY_GLOBALS = {"HOTLOOP_STATS", "SETUP_STATS"}
_DIGESTY = re.compile(r"digest|hexdigest|uuid|token_hex", re.IGNORECASE)


@register
class ObsHygiene(Rule):
    code = "RL104"
    name = "obs-hygiene"
    explain = """\
RL104 obs-hygiene — the observability registry stays queryable and
bounded.

Three sub-checks, all rooted in PR 7's registry contract:

1. Metric NAMES follow the scheme `subsystem.metric[_unit]` — dotted,
   lowercase, underscore words ("mis2.resident_dispatches",
   "serve.cache.bytes_used").  A literal name that breaks the scheme is
   flagged at parse time; an f-string name is allowed only when its
   static prefix already pins the subsystem (f"serve.cache.{name}").
   Names outside the scheme fracture dashboards and make
   tools/check_shape.py's snapshot diffs unreadable.

2. Label VALUES must be bounded: an f-string label value, or a value
   whose expression mentions digest/hexdigest/uuid, is the exact shape
   the registry's CardinalityError exists to reject at runtime — a raw
   graph digest or request id as a label value grows the registry
   without bound.  RL104 catches it before it runs; put unbounded
   identity in span attrs instead.

3. Legacy stats globals (HOTLOOP_STATS, SETUP_STATS) are VIEWS over the
   registry kept for API compatibility.  Writing through them
   (`HOTLOOP_STATS.host_syncs += 2`) is a non-atomic read-modify-write
   through a property setter — two threads lose increments — and hides
   the write from grep.  New code increments the registry counter:
   `_OBS.counter("mis2.host_syncs").inc(2)`.
"""

    def check_file(self, src: SourceFile, project: Project) -> List[Finding]:
        out: List[Finding] = []
        symbols = _symbol_spans(src, project)

        def flag(node, msg):
            out.append(Finding(
                rule=self.code, path=src.relpath, line=node.lineno,
                symbol=symbols.get(node.lineno, "<module>"), message=msg))

        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                self._check_registry_call(node, src, flag)
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    g = self._legacy_global(tgt, src)
                    if g:
                        out_kind = "augmented " if isinstance(
                            node, ast.AugAssign) else ""
                        flag(node, f"{out_kind}write through legacy stats "
                                   f"view {g} — a non-atomic "
                                   "read-modify-write; increment the "
                                   "registry counter instead "
                                   "(_OBS.counter(...).inc(n))")
        return out

    # -- helpers -----------------------------------------------------------

    def _check_registry_call(self, node: ast.Call, src: SourceFile,
                             flag) -> None:
        if not (isinstance(node.func, ast.Attribute)
                and node.func.attr in _REGISTRY_METHODS):
            return
        base = resolve_chain(src, node.func.value)
        base_txt = _name_chain(node.func.value) or ""
        if "obs" not in base and base_txt not in ("_OBS", "metrics") and \
                "obs" not in base_txt:
            return
        name_arg: Optional[ast.AST] = None
        if node.args:
            name_arg = node.args[0]
        for kw in node.keywords:
            if kw.arg == "name":
                name_arg = kw.value
        if isinstance(name_arg, ast.Constant) and \
                isinstance(name_arg.value, str):
            if not _SCHEME_RE.match(name_arg.value):
                flag(name_arg,
                     f"metric name {name_arg.value!r} breaks the registry "
                     "scheme `subsystem.metric` (dotted lowercase, e.g. "
                     "'mis2.host_syncs')")
        elif isinstance(name_arg, ast.JoinedStr):
            first = name_arg.values[0] if name_arg.values else None
            prefix = first.value if isinstance(first, ast.Constant) and \
                isinstance(first.value, str) else ""
            if not _FSTRING_PREFIX_RE.match(prefix):
                flag(name_arg,
                     "f-string metric name without a scheme-conforming "
                     "static subsystem prefix — the registry cannot be "
                     "audited statically; pin the prefix "
                     "(f\"serve.cache.{...}\")")
        for kw in node.keywords:
            if kw.arg != "labels" or not isinstance(kw.value, ast.Dict):
                continue
            for key, val in zip(kw.value.keys, kw.value.values):
                kname = getattr(key, "value", "?")
                if isinstance(val, ast.JoinedStr):
                    flag(val, f"f-string label value for {kname!r} — "
                              "unbounded cardinality (the CardinalityError "
                              "class, caught at parse time); use a bounded "
                              "token or a span attr")
                else:
                    txt = ast.unparse(val)
                    if _DIGESTY.search(txt):
                        flag(val, f"label value `{txt}` for {kname!r} looks "
                                  "digest/uuid-valued — unbounded "
                                  "cardinality; put identity in span attrs, "
                                  "never in metric labels")

    def _legacy_global(self, target: ast.AST,
                       src: SourceFile) -> Optional[str]:
        if not isinstance(target, ast.Attribute):
            return None
        base = target.value
        if isinstance(base, ast.Name) and base.id in _LEGACY_GLOBALS:
            return base.id
        chain = resolve_chain(src, base)
        for g in _LEGACY_GLOBALS:
            if chain.endswith("." + g):
                return g
        return None
