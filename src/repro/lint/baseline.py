"""Grandfathered-finding baseline for ``repro.lint``.

The committed ``tools/lint_baseline.json`` lists findings that predate the
analyzer and are allowed to stay — each entry carries a mandatory human
``reason``.  Two invariants keep the baseline honest:

* **Entries must still fire.**  ``--check`` fails on a *stale* entry (one
  matching no current finding): the debt it recorded was paid, so the
  entry must be deleted — baselines shrink monotonically, never rot.
* **Reasons are mandatory.**  An entry without a non-placeholder reason
  is itself an error; ``--write-baseline`` emits ``"FILLME"`` stubs
  precisely so an unedited baseline cannot pass CI.

Identity is ``(rule, path, symbol)`` — no line numbers, so findings that
merely move inside their function keep matching.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Tuple

from .findings import Finding

_PLACEHOLDER_REASONS = {"", "fillme", "todo", "tbd"}


@dataclass
class BaselineEntry:
    rule: str
    path: str
    symbol: str
    reason: str = ""
    tag: str = ""

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.symbol)

    @property
    def reason_ok(self) -> bool:
        return self.reason.strip().lower() not in _PLACEHOLDER_REASONS

    def to_dict(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "symbol": self.symbol,
             "reason": self.reason}
        if self.tag:
            d["tag"] = self.tag
        return d


@dataclass
class Baseline:
    entries: List[BaselineEntry] = field(default_factory=list)

    @classmethod
    def load(cls, path) -> "Baseline":
        path = Path(path)
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        entries = [BaselineEntry(rule=e["rule"], path=e["path"],
                                 symbol=e.get("symbol", "<module>"),
                                 reason=e.get("reason", ""),
                                 tag=e.get("tag", ""))
                   for e in data.get("entries", [])]
        return cls(entries=entries)

    def save(self, path) -> None:
        payload = {"entries": [e.to_dict() for e in
                               sorted(self.entries, key=lambda e: e.key)]}
        Path(path).write_text(json.dumps(payload, indent=2) + "\n")

    def apply(self, findings: List[Finding]):
        """Split findings into (live, baselined) and report baseline
        problems: stale entries and entries without a real reason."""
        index: Dict[Tuple[str, str, str], BaselineEntry] = {
            e.key: e for e in self.entries}
        hit = set()
        live, grandfathered = [], []
        for f in findings:
            entry = index.get(f.key)
            if entry is not None:
                hit.add(entry.key)
                grandfathered.append((f, entry))
            else:
                live.append(f)
        problems = []
        for e in self.entries:
            if e.key not in hit:
                problems.append(
                    f"stale baseline entry {e.rule} {e.path} [{e.symbol}]: "
                    "no current finding matches — the debt was paid, delete "
                    "the entry (baselines shrink monotonically)")
            elif not e.reason_ok:
                problems.append(
                    f"baseline entry {e.rule} {e.path} [{e.symbol}] has no "
                    "reason — every grandfathered finding needs one")
        return live, grandfathered, problems


def baseline_from_findings(findings: List[Finding]) -> Baseline:
    entries: Dict[Tuple[str, str, str], BaselineEntry] = {}
    for f in findings:
        entries.setdefault(f.key, BaselineEntry(
            rule=f.rule, path=f.path, symbol=f.symbol, reason="FILLME",
            tag=f.tag))
    return Baseline(entries=list(entries.values()))
