"""Finding and suppression value types for ``repro.lint``.

A :class:`Finding` is one rule violation anchored to (path, line) with a
stable identity key ``(rule, path, symbol)`` — line numbers are carried
for display but deliberately kept out of the identity, so a finding that
merely moves inside its function keeps matching its baseline entry.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

#: ``# repro-lint: ignore[RL101] reason`` / ``ignore[RL101,RL103] reason``
SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*ignore\[([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)\]"
    r"\s*(.*?)\s*$")

#: ``# repro-lint: legacy reason`` — file-level quarantine pragma.
LEGACY_RE = re.compile(r"#\s*repro-lint:\s*legacy\s+(.+?)\s*$")


@dataclass(frozen=True)
class Suppression:
    """One inline ``ignore[...]`` pragma (line it guards, codes, reason)."""
    line: int           # the source line the pragma applies to
    codes: tuple
    reason: str

    @property
    def valid(self) -> bool:
        return bool(self.reason.strip())


@dataclass
class Finding:
    rule: str                  # "RL101" ... "RL106" (or engine "RL00x")
    path: str                  # repo-relative, posix separators
    line: int
    symbol: str                # enclosing function/class qualname or "<module>"
    message: str
    tag: str = ""              # "legacy" for findings in quarantined files
    suppressed_by: Optional[Suppression] = field(default=None, repr=False)

    @property
    def key(self) -> tuple:
        """Baseline identity: stable across line-number churn."""
        return (self.rule, self.path, self.symbol)

    @property
    def suppressed(self) -> bool:
        return self.suppressed_by is not None and self.suppressed_by.valid

    def to_dict(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "line": self.line,
             "symbol": self.symbol, "message": self.message}
        if self.tag:
            d["tag"] = self.tag
        if self.suppressed_by is not None:
            d["suppressed"] = self.suppressed
            d["suppress_reason"] = self.suppressed_by.reason
        return d

    def render(self) -> str:
        loc = f"{self.path}:{self.line}"
        sym = f" [{self.symbol}]" if self.symbol != "<module>" else ""
        tag = f" ({self.tag})" if self.tag else ""
        return f"{loc}: {self.rule}{tag}{sym} {self.message}"


def _comment_tokens(text: str):
    """(line, col, comment_text) for every REAL comment token — pragmas
    quoted inside docstrings or string literals never count."""
    import io
    import tokenize
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.start[1], tok.string
    except (tokenize.TokenError, IndentationError):  # pragma: no cover
        return


def parse_suppressions(text: str) -> dict:
    """Map line number -> :class:`Suppression` for every inline pragma.

    A pragma on its own line guards the next line; a trailing pragma
    guards its own line.  Both entries are recorded so rules can anchor a
    finding at either the construct line or the pragma line.
    """
    out = {}
    n_lines = text.count("\n") + 1
    for lineno, col, comment in _comment_tokens(text):
        m = SUPPRESS_RE.search(comment)
        if not m:
            continue
        codes = tuple(c.strip() for c in m.group(1).split(","))
        sup = Suppression(line=lineno, codes=codes, reason=m.group(2))
        out[lineno] = sup
        if col == 0 and lineno + 1 <= n_lines:
            # standalone comment: also guards the next line
            out.setdefault(lineno + 1, Suppression(
                line=lineno + 1, codes=codes, reason=m.group(2)))
    return out


def parse_legacy_tag(text: str, scan_lines: int = 40) -> Optional[str]:
    """Return the quarantine reason if the file opens with a legacy pragma
    (a real comment within the first ``scan_lines`` lines)."""
    for lineno, _col, comment in _comment_tokens(text):
        if lineno > scan_lines:
            return None
        m = LEGACY_RE.match(comment)
        if m:
            return m.group(1)
    return None
