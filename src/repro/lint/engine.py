"""The ``repro.lint`` analysis engine.

One :class:`Project` is built per run: every target file is parsed once,
imports are resolved to qualified names, a call graph is grown over the
module-level functions, and the **jit context** — the set of functions
reachable from any ``jax.jit`` site, ``shard_map``/``pallas_call``
wrapper, or ``lax`` control-flow body — is computed by a breadth-first
walk.  Rules receive the project plus one :class:`SourceFile` at a time
and emit :class:`~repro.lint.findings.Finding` values; the engine owns
suppression matching, legacy quarantine tags, and the module-level
reachability report that backs the quarantine checks.

The analyzer is deliberately *syntactic*: it never imports the code under
analysis, so it runs in milliseconds, needs no jax, and can lint a file
that would crash on import.  The price is approximation — the call graph
is best-effort (dynamic dispatch through the engine registry is invisible
to it) and tracedness is inferred, not typed.  Rules are therefore tuned
for precision over recall and every rule supports inline suppression with
a mandatory reason (``# repro-lint: ignore[RLxxx] why``).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Set, Tuple

from .findings import Finding, parse_legacy_tag, parse_suppressions

# ---------------------------------------------------------------------------
# call sites that open a traced (jit) context for function-valued arguments
# ---------------------------------------------------------------------------

#: resolved callee suffixes whose function arguments are traced entry points
JIT_WRAPPER_SUFFIXES = (
    "jax.jit", "jax.pmap", "shard_map", "pallas_call", "jax.checkpoint",
    "jax.remat", "jax.grad", "jax.value_and_grad", "jax.vmap",
)

#: resolved callee suffixes whose function arguments are *loop bodies* —
#: every parameter of such a closure is a traced value by construction
LOOP_BODY_SUFFIXES = (
    "while_loop", "fori_loop", "scan", "cond", "switch", "associated_scan",
)


def _name_chain(node: ast.AST) -> Optional[str]:
    """``a.b.c`` -> "a.b.c"; returns None for non-trivial expressions."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclass
class FunctionInfo:
    qualname: str               # "repro.core.mis2.HotLoopStats.reset"
    module: str                 # "repro.core.mis2" (or pseudo-module)
    node: ast.AST               # FunctionDef | AsyncFunctionDef | Lambda
    src: "SourceFile"
    decorators: List[str] = field(default_factory=list)
    static_argnames: Set[str] = field(default_factory=set)
    jit_entry: bool = False     # directly decorated / passed to jax.jit
    loop_body: bool = False     # passed to lax.while_loop / scan / ...
    kernel_body: bool = False   # pallas kernel body (``*_ref`` params)
    calls: Set[str] = field(default_factory=set)     # resolved callees
    refs: Set[str] = field(default_factory=set)      # referenced functions

    @property
    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return names


@dataclass
class SourceFile:
    path: Path
    relpath: str                # repo-relative posix string
    module: str                 # dotted module name ("repro.core.mis2")
    text: str
    tree: ast.Module
    suppressions: dict          # line -> Suppression
    legacy: Optional[str]       # quarantine reason, or None
    imports: Dict[str, str] = field(default_factory=dict)  # alias -> qualified
    is_root: bool = False       # reachability seed (benchmarks/tools/examples)

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Resolve a Name/Attribute chain to a qualified dotted name."""
        chain = _name_chain(node)
        if chain is None:
            return None
        head, _, rest = chain.partition(".")
        base = self.imports.get(head)
        if base is None:
            # module-local symbol: qualify against this module
            base = f"{self.module}.{head}" if self.module else head
        return f"{base}.{rest}" if rest else base


def _module_name_for(path: Path, src_root: Path) -> Optional[str]:
    try:
        rel = path.resolve().relative_to(src_root.resolve())
    except ValueError:
        return None
    parts = list(rel.with_suffix("").parts)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else None


def _collect_imports(tree: ast.Module, module: str) -> Dict[str, str]:
    out: Dict[str, str] = {}
    pkg_parts = module.split(".")[:-1] if module else []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
                if a.asname:
                    out[a.asname] = a.name
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base_parts = pkg_parts[: len(pkg_parts) - (node.level - 1)] \
                    if node.level > 1 else list(pkg_parts)
                base = ".".join(base_parts + ([node.module] if node.module
                                              else []))
            else:
                base = node.module or ""
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{base}.{a.name}" if base else a.name
    return out


class Project:
    """Whole-target analysis context shared by every rule."""

    def __init__(self, files: List[SourceFile]):
        self.files = files
        self.by_module: Dict[str, SourceFile] = {
            f.module: f for f in files if f.module}
        self.functions: Dict[str, FunctionInfo] = {}
        self._jit_context: Set[str] = set()
        self._index_functions()
        self._build_call_graph()
        self._propagate_jit_context()

    # -- indexing ----------------------------------------------------------

    def _index_functions(self) -> None:
        for src in self.files:
            for qual, node, parents in _walk_functions(src.tree, src.module):
                info = FunctionInfo(qualname=qual, module=src.module,
                                    node=node, src=src)
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        name, statics = _decorator_jit(dec, src)
                        if name:
                            info.decorators.append(name)
                        if statics is not None:
                            info.jit_entry = True
                            info.static_argnames |= statics
                info.kernel_body = any(p.endswith("_ref")
                                       for p in info.params)
                self.functions[qual] = info

    def _build_call_graph(self) -> None:
        for src in self.files:
            for qual, node, _ in _walk_functions(src.tree, src.module):
                info = self.functions[qual]
                body = node.body if isinstance(node, (ast.FunctionDef,
                                                      ast.AsyncFunctionDef)) \
                    else [node.body]
                local_defs = {
                    n.name: f"{qual}.{n.name}" for n in ast.walk(node)
                    if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and n is not node}
                for stmt in body:
                    for sub in ast.walk(stmt):
                        if isinstance(sub, ast.Call):
                            self._record_call(info, sub, local_defs)
                        elif isinstance(sub, (ast.Name, ast.Attribute)):
                            target = self._resolve_function(sub, info.src,
                                                            local_defs)
                            if target:
                                info.refs.add(target)

    def _resolve_function(self, node, src: SourceFile,
                          local_defs: Dict[str, str]) -> Optional[str]:
        if isinstance(node, ast.Name) and node.id in local_defs:
            return local_defs[node.id]
        resolved = src.resolve(node)
        if resolved and resolved in self.functions:
            return resolved
        return None

    def _record_call(self, info: FunctionInfo, call: ast.Call,
                     local_defs: Dict[str, str]) -> None:
        callee = self._resolve_function(call.func, info.src, local_defs)
        if callee:
            info.calls.add(callee)
        resolved = info.src.resolve(call.func) or _name_chain(call.func) or ""
        fn_args = list(call.args) + [kw.value for kw in call.keywords]
        is_jit_wrapper = resolved.endswith(JIT_WRAPPER_SUFFIXES)
        is_loop = resolved.endswith(LOOP_BODY_SUFFIXES)
        if not (is_jit_wrapper or is_loop):
            return
        for arg in fn_args:
            arg = _unwrap_partial(arg)
            target = None
            if isinstance(arg, ast.Lambda):
                target = self._lambda_qual(arg, info)
            elif isinstance(arg, (ast.Name, ast.Attribute)):
                target = self._resolve_function(arg, info.src, local_defs)
            if target and target in self.functions:
                tgt = self.functions[target]
                if is_loop:
                    tgt.loop_body = True
                else:
                    tgt.jit_entry = True
                info.refs.add(target)

    def _lambda_qual(self, node: ast.Lambda, info: FunctionInfo) -> str:
        qual = f"{info.qualname}.<lambda@{node.lineno}>"
        if qual not in self.functions:
            self.functions[qual] = FunctionInfo(
                qualname=qual, module=info.module, node=node, src=info.src)
        return qual

    # -- jit-context propagation ------------------------------------------

    def _propagate_jit_context(self) -> None:
        seeds = [q for q, f in self.functions.items()
                 if f.jit_entry or f.loop_body or f.kernel_body]
        seen: Set[str] = set()
        frontier = list(seeds)
        while frontier:
            qual = frontier.pop()
            if qual in seen:
                continue
            seen.add(qual)
            f = self.functions.get(qual)
            if f is None:
                continue
            for callee in f.calls | f.refs:
                if callee not in seen:
                    frontier.append(callee)
            # nested defs of a jit function run traced when called
            prefix = qual + "."
            for other in self.functions:
                if other.startswith(prefix) and other not in seen:
                    frontier.append(other)
        self._jit_context = seen

    def is_jit_context(self, qualname: str) -> bool:
        return qualname in self._jit_context

    # -- module reachability ----------------------------------------------

    def module_reachability(self) -> Tuple[Set[str], Set[str]]:
        """(reachable, unreachable) repro modules, walked over static
        imports from the entry roots: ``repro.api``, ``repro.serve``,
        ``repro.obs``, ``repro.lint``, every non-``repro`` root file
        (benchmarks / examples / tools) handed to the engine, and every
        non-legacy module with an ``if __name__ == "__main__"`` guard
        (directly runnable via ``python -m``)."""
        graph = self.import_graph()
        roots: Set[str] = set()
        for src in self.files:
            if src.is_root:
                roots |= graph.get(src.module, set())
            elif src.module and (
                    src.module.startswith(("repro.api", "repro.serve",
                                           "repro.obs", "repro.lint"))
                    or src.module == "repro"):
                roots.add(src.module)
            elif src.module and src.legacy is None and _has_main_guard(
                    src.tree):
                roots.add(src.module)
        reachable = self.reachable_from(roots)
        tracked = {f.module for f in self.files
                   if f.module and f.module.startswith("repro") and not f.is_root}
        return reachable & tracked, tracked - reachable

    def import_graph(self) -> Dict[str, Set[str]]:
        """module -> tracked modules it statically imports."""
        graph: Dict[str, Set[str]] = {}
        for src in self.files:
            deps = set()
            for alias in src.imports.values():
                mod = self._owning_module(alias)
                if mod:
                    deps.add(mod)
            graph[src.module] = deps
        return graph

    def reachable_from(self, seeds: Set[str]) -> Set[str]:
        """Transitive import closure over tracked modules; importing any
        submodule also executes every ancestor package ``__init__``."""
        graph = self.import_graph()
        reachable: Set[str] = set()
        frontier = [m for m in seeds if m in graph]
        while frontier:
            mod = frontier.pop()
            if mod in reachable:
                continue
            reachable.add(mod)
            for dep in graph.get(mod, ()):
                if dep not in reachable:
                    frontier.append(dep)
            if "." in mod:
                pkg = mod.rsplit(".", 1)[0]
                if pkg not in reachable:
                    frontier.append(pkg)
        return reachable

    def _owning_module(self, qualified: str) -> Optional[str]:
        """Longest tracked-module prefix of a qualified name, if any."""
        parts = qualified.split(".")
        for cut in range(len(parts), 0, -1):
            cand = ".".join(parts[:cut])
            if cand in self.by_module:
                return cand
        return None


def _has_main_guard(tree: ast.Module) -> bool:
    """True iff the module has a top-level ``if __name__ == "__main__"``."""
    for node in tree.body:
        if isinstance(node, ast.If) and isinstance(node.test, ast.Compare):
            names = {n.id for n in ast.walk(node.test)
                     if isinstance(n, ast.Name)}
            if "__name__" in names:
                return True
    return False


def _unwrap_partial(node: ast.AST) -> ast.AST:
    """functools.partial(f, ...) -> f (so the wrapped function seeds)."""
    if isinstance(node, ast.Call):
        chain = _name_chain(node.func) or ""
        if chain.endswith("partial") and node.args:
            return node.args[0]
    return node


def _decorator_jit(dec: ast.AST, src: SourceFile):
    """(decorator name, static_argnames | None).  static set is non-None
    iff the decorator establishes a jit entry."""
    target = dec.func if isinstance(dec, ast.Call) else dec
    chain = src.resolve(target) or _name_chain(target) or ""
    if chain.endswith("partial") and isinstance(dec, ast.Call) and dec.args:
        inner = dec.args[0]
        inner_chain = src.resolve(inner) or _name_chain(inner) or ""
        if inner_chain.endswith(JIT_WRAPPER_SUFFIXES):
            statics: Set[str] = set()
            for kw in dec.keywords:
                if kw.arg in ("static_argnames", "static_argnums"):
                    statics |= _literal_strings(kw.value)
            return inner_chain, statics
        return chain, None
    if chain.endswith(JIT_WRAPPER_SUFFIXES):
        statics = set()
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg in ("static_argnames", "static_argnums"):
                    statics |= _literal_strings(kw.value)
        return chain, statics
    return chain or None, None


def _literal_strings(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            out.add(sub.value)
    return out


def _walk_functions(tree: ast.Module, module: str) -> Iterator[tuple]:
    """Yield (qualname, node, parent_chain) for every def in the module."""
    def rec(node, prefix, parents):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                yield qual, child, parents
                yield from rec(child, qual, parents + [child])
            elif isinstance(child, ast.ClassDef):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                yield from rec(child, qual, parents + [child])
            elif isinstance(child, (ast.If, ast.Try, ast.With)):
                # defs behind conditionals/try/with at any nesting
                yield from rec(child, prefix, parents)
    yield from rec(tree, module, [])


# ---------------------------------------------------------------------------
# engine entry points
# ---------------------------------------------------------------------------

def load_file(path: Path, repo_root: Path, src_root: Optional[Path] = None,
              is_root: bool = False) -> Optional[SourceFile]:
    try:
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError) as e:  # pragma: no cover
        raise LintError(f"cannot parse {path}: {e}") from e
    module = None
    if src_root is not None:
        module = _module_name_for(path, src_root)
    if module is None:
        module = f"<root:{path.stem}>"
    try:
        rel = str(path.resolve().relative_to(repo_root.resolve()).as_posix())
    except ValueError:
        rel = str(path)
    src = SourceFile(
        path=path, relpath=rel, module=module, text=text, tree=tree,
        suppressions=parse_suppressions(text),
        legacy=parse_legacy_tag(text), is_root=is_root)
    src.imports = _collect_imports(tree, module if not is_root else "")
    return src


class LintError(RuntimeError):
    pass


def discover(targets: List[Path], repo_root: Path,
             roots: Optional[List[Path]] = None) -> Project:
    """Parse ``targets`` (files or directories) plus reachability ``roots``
    into a :class:`Project`.  The src root is inferred so module names come
    out as ``repro.x.y`` (targets under ``.../src/repro/...``)."""
    files: List[SourceFile] = []
    seen: Set[Path] = set()

    def src_root_for(p: Path) -> Optional[Path]:
        for parent in [p] + list(p.parents):
            if parent.name == "src":
                return parent
        return None

    def add(path: Path, is_root: bool) -> None:
        path = path.resolve()
        if path in seen or path.name.startswith("."):
            return
        seen.add(path)
        files.append(load_file(path, repo_root, src_root_for(path),
                               is_root=is_root))

    for target in targets:
        target = Path(target)
        if target.is_dir():
            for p in sorted(target.rglob("*.py")):
                add(p, is_root=False)
        elif target.suffix == ".py":
            add(target, is_root=False)
        else:
            raise LintError(f"not a python file or directory: {target}")
    for root in roots or []:
        root = Path(root)
        if root.is_dir():
            for p in sorted(root.glob("*.py")):
                add(p, is_root=True)
        elif root.suffix == ".py" and root.exists():
            add(root, is_root=True)
    return Project(files)


def run_rules(project: Project, rules) -> List[Finding]:
    """Run every rule over every non-root file; attach suppressions and
    legacy tags.  An invalid suppression (missing reason) does NOT
    suppress — the finding stays live with the problem appended."""
    findings: List[Finding] = []
    for src in project.files:
        if src.is_root:
            continue
        for rule in rules:
            for f in rule.check_file(src, project):
                sup = src.suppressions.get(f.line)
                if sup is not None and f.rule in sup.codes:
                    if sup.valid:
                        f.suppressed_by = sup
                    else:
                        f.message += ("  [suppression ignored: a reason is "
                                      "mandatory after ignore[...]]")
                if src.legacy is not None:
                    f.tag = "legacy"
                findings.append(f)
    findings.extend(quarantine_findings(project))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def quarantine_findings(project: Project) -> List[Finding]:
    """RL001: a legacy-quarantined module is reachable from the live entry
    points — the quarantine is violated and must be resolved explicitly."""
    out: List[Finding] = []
    reachable, _ = project.module_reachability()
    for src in project.files:
        if src.legacy is None or src.is_root:
            continue
        if src.module in reachable:
            out.append(Finding(
                rule="RL001", path=src.relpath, line=1, symbol="<module>",
                message=(f"legacy-quarantined module {src.module} is "
                         "reachable from a facade/serve/bench entry point — "
                         "either un-quarantine it or cut the import")))
    return out
