"""``repro.lint`` — AST-level determinism & execution-shape analyzer.

The static half of the repo's invariant set (``tools/check_shape.py`` is
the runtime half): six named rules that each encode a bug class this
repo actually shipped —

* **RL101 trace-purity** — host syncs / Python control flow on traced
  values inside jit-reachable code (the pre-PR-4 hidden-sync class).
* **RL102 priority-provenance** — ``id_bits`` fed a padded/bucketed
  vertex count (the PR 3 determinism bug, now a lint).
* **RL103 timing** — ``time.time`` where durations need
  ``time.perf_counter``.
* **RL104 obs-hygiene** — metric names off the registry scheme,
  unbounded (f-string/digest) label values, direct mutation of legacy
  stats globals.
* **RL105 options-aliasing** — mutable default arguments (the PR 2
  shared-``Mis2Options()`` class).
* **RL106 kernel-masking** — Pallas kernel bodies without a ragged-tail
  guard (compiled-only OOB reads the CPU CI cannot see).

Usage::

    from repro.lint import lint_paths, check
    result = check(["src/repro"], baseline="tools/lint_baseline.json")
    result.ok          # False if any live finding / baseline problem
    result.findings    # live (unsuppressed, non-baselined) findings

CLI: ``python tools/repro_lint.py --check src/repro`` (see --help).

Inline suppression (reason mandatory)::

    x = time.time  # repro-lint: ignore[RL103] epoch stamp, not a duration

File-level quarantine for retired seed-era modules::

    # repro-lint: legacy seed-era LM driver, unreachable from the facade
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import List, Optional, Sequence

from .baseline import Baseline, BaselineEntry, baseline_from_findings
from .engine import LintError, Project, discover, run_rules
from .findings import Finding, Suppression
from .rules import all_rules, get_rule

#: reachability roots outside src/ (parsed, never linted)
DEFAULT_ROOT_DIRS = ("benchmarks", "examples", "tools")


@dataclass
class LintResult:
    findings: List[Finding] = field(default_factory=list)      # live
    suppressed: List[Finding] = field(default_factory=list)
    grandfathered: list = field(default_factory=list)          # (f, entry)
    legacy: List[Finding] = field(default_factory=list)
    baseline_problems: List[str] = field(default_factory=list)
    unreachable: List[str] = field(default_factory=list)       # informational
    test_only: List[str] = field(default_factory=list)         # informational
    quarantined: List[str] = field(default_factory=list)
    project: Optional[Project] = None

    @property
    def ok(self) -> bool:
        return not self.findings and not self.baseline_problems

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "grandfathered": [
                dict(f.to_dict(), reason=e.reason)
                for f, e in self.grandfathered],
            "legacy": [f.to_dict() for f in self.legacy],
            "baseline_problems": list(self.baseline_problems),
            "reachability": {
                "unreachable_modules": sorted(self.unreachable),
                "test_only_modules": sorted(self.test_only),
                "quarantined_modules": sorted(self.quarantined),
            },
        }


def lint_paths(targets: Sequence, repo_root=None,
               roots: Optional[Sequence] = None) -> List[Finding]:
    """Run every rule over ``targets``; returns ALL findings (suppressed
    and legacy-tagged included — callers filter)."""
    repo_root = Path(repo_root) if repo_root else _infer_repo_root(targets)
    if roots is None:
        roots = [repo_root / d for d in DEFAULT_ROOT_DIRS]
    project = discover([Path(t) for t in targets], repo_root,
                       [Path(r) for r in roots])
    return run_rules(project, all_rules())


def check(targets: Sequence, baseline=None, repo_root=None,
          roots: Optional[Sequence] = None) -> LintResult:
    """The CI entry point: lint, apply suppressions + baseline, classify."""
    repo_root = Path(repo_root) if repo_root else _infer_repo_root(targets)
    if roots is None:
        roots = [repo_root / d for d in DEFAULT_ROOT_DIRS]
    project = discover([Path(t) for t in targets], repo_root,
                       [Path(r) for r in roots])
    findings = run_rules(project, all_rules())

    result = LintResult(project=project)
    active: List[Finding] = []
    for f in findings:
        if f.suppressed:
            result.suppressed.append(f)
        elif f.tag == "legacy" and f.rule != "RL001":
            # findings inside quarantined files are reported, not fatal —
            # RL001 (quarantine violation) stays fatal
            result.legacy.append(f)
        else:
            active.append(f)

    bl = baseline if isinstance(baseline, Baseline) else Baseline.load(
        baseline) if baseline else Baseline()
    live, grandfathered, problems = bl.apply(active)
    result.findings = live
    result.grandfathered = grandfathered
    result.baseline_problems = problems

    reachable, unreachable = project.module_reachability()
    test_reach = project.reachable_from(_test_imports(repo_root, project))
    for src in project.files:
        if src.is_root or not src.module.startswith("repro"):
            continue
        if src.legacy is not None:
            result.quarantined.append(src.module)
        elif src.module in unreachable:
            if src.module in test_reach:
                result.test_only.append(src.module)
            else:
                result.unreachable.append(src.module)
    return result


def _test_imports(repo_root: Path, project: Project) -> set:
    """Tracked modules the test suite imports (statically) — used to
    split 'unreachable' into parity/reference modules the tests consume
    vs genuinely dead code."""
    import ast as _ast
    seeds = set()
    tests = Path(repo_root) / "tests"
    if not tests.is_dir():
        return seeds
    for p in sorted(tests.glob("*.py")):
        try:
            tree = _ast.parse(p.read_text())
        except (SyntaxError, OSError):        # pragma: no cover
            continue
        for node in _ast.walk(tree):
            if isinstance(node, _ast.Import):
                names = [a.name for a in node.names]
            elif isinstance(node, _ast.ImportFrom) and node.module:
                names = [node.module] + [
                    f"{node.module}.{a.name}" for a in node.names]
            else:
                continue
            for name in names:
                mod = project._owning_module(name)
                if mod:
                    seeds.add(mod)
    return seeds


def _infer_repo_root(targets: Sequence) -> Path:
    t = Path(next(iter(targets))).resolve()
    for parent in [t] + list(t.parents):
        if (parent / "ROADMAP.md").exists() or (parent / ".git").exists():
            return parent
    return Path.cwd()


__all__ = [
    "Baseline", "BaselineEntry", "Finding", "LintError", "LintResult",
    "Project", "Suppression", "all_rules", "baseline_from_findings",
    "check", "get_rule", "lint_paths",
]
