"""Deprecation plumbing shared by the legacy entry points.

Bottom-layer module (imports nothing from ``repro``) so that ``core/`` and
``solvers/`` can emit migration warnings without importing ``repro.api``
(which imports them back).  The old->new mapping lives in API.md.
"""
from __future__ import annotations

import warnings

REMOVAL_POLICY = "kept at least until 0.3; see API.md for the migration table"


def warn_deprecated(old: str, new: str, *, stacklevel: int = 3) -> None:
    """Emit the standard migration DeprecationWarning for an old entry point."""
    warnings.warn(
        f"{old} is deprecated ({REMOVAL_POLICY}); use {new} instead",
        DeprecationWarning,
        stacklevel=stacklevel,
    )


class DeprecatedMapping(dict):
    """A dict that warns on access — for legacy registry dicts like
    ``solvers.amg.AGGREGATORS`` whose role moved to ``repro.api.registry``."""

    def __init__(self, data, old: str, new: str):
        super().__init__(data)
        self._old = old
        self._new = new

    def __getitem__(self, key):
        warn_deprecated(self._old, self._new, stacklevel=4)
        return super().__getitem__(key)
