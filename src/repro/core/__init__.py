"""Core: the paper's contribution — deterministic parallel MIS-2,
MIS-2-based aggregation/coarsening, coloring, multilevel partitioning,
and the distributed (shard_map) MIS-2 extension."""
from .aggregation import (
    AggregationResult,
    aggregate_basic,
    aggregate_serial_greedy,
    aggregate_two_phase,
)
from .coloring import ColoringResult, check_coloring, color_graph
from .hashing import (
    PRIORITY_FNS,
    priorities_fixed,
    priorities_xorshift,
    priorities_xorshift_star,
)
from .mis2 import (
    ABLATION_CHAIN,
    Mis2Options,
    Mis2Result,
    mis2,
    mis2_compacted,
    mis2_dense,
    mis2_dense_fixed_point,
    mis2_dense_jittable,
    run_mis2,
)
from .misk import mis_k
from .partition import PartitionResult, edge_cut, partition
from .tuples import IN, OUT, id_bits, is_undecided, pack

__all__ = [
    "AggregationResult", "aggregate_basic", "aggregate_serial_greedy",
    "aggregate_two_phase",
    "ColoringResult", "check_coloring", "color_graph",
    "PRIORITY_FNS", "priorities_fixed", "priorities_xorshift",
    "priorities_xorshift_star",
    "ABLATION_CHAIN", "Mis2Options", "Mis2Result", "mis2", "mis2_compacted",
    "mis2_dense", "mis2_dense_fixed_point", "mis2_dense_jittable", "run_mis2",
    "mis_k",
    "PartitionResult", "edge_cut", "partition",
    "IN", "OUT", "id_bits", "is_undecided", "pack",
]
