"""Deterministic parallel greedy distance-1 coloring.

Used by cluster multicolor Gauss-Seidel (paper Alg. 4) to color the coarse
graph, and by point multicolor GS to color the fine graph.  Luby-style
rounds with the same xorshift* priorities as MIS-2: in each round, every
uncolored vertex that holds the minimum packed tuple among its uncolored
neighbors picks its smallest feasible color.  Deterministic across devices
and runs, like everything else in core/.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .._compat import warn_deprecated
from ..graphs.handle import as_ell_graph
from .hashing import priorities_xorshift_star
from .tuples import id_bits, pack

MAX_COLORS = 64


@dataclass
class ColoringResult:
    colors: np.ndarray      # int32 [V]; uncolored (unconverged) hold -1
    num_colors: int
    rounds: int
    converged: bool = True  # every vertex colored within the round limit

    def __post_init__(self):
        # Result-protocol guarantee: host numpy payloads on every engine.
        self.colors = np.asarray(self.colors)


def _color_round_rows(neighbors_rows, mask_rows, row_ids, colors, prio):
    """Rowwise body of one Luby round over a row block: ``colors`` and
    ``prio`` are global ``[V]`` vectors, the adjacency covers just the
    block's rows.  Shared verbatim by the monolithic round and the hybrid
    (sliced) round, which is what keeps their colors bit-identical."""
    uncolored = colors < 0
    own = colors[row_ids]
    # local-min among uncolored real neighbors (excluding self)
    real = mask_rows & (neighbors_rows != row_ids[:, None])
    pn = prio[neighbors_rows]
    un = uncolored[neighbors_rows]
    contender = real & un
    is_min = jnp.all(jnp.where(contender, prio[row_ids][:, None] < pn, True),
                     axis=1)
    # forbidden colors bitmask (two uint32 words -> up to 64 colors)
    cn = colors[neighbors_rows]
    has = real & (cn >= 0)
    lo_bits = jnp.where(has & (cn < 32),
                        jnp.uint32(1) << jnp.clip(cn, 0, 31).astype(jnp.uint32),
                        jnp.uint32(0))
    hi_bits = jnp.where(has & (cn >= 32),
                        jnp.uint32(1) << jnp.clip(cn - 32, 0, 31).astype(jnp.uint32),
                        jnp.uint32(0))
    forb_lo = jnp.bitwise_or.reduce(lo_bits, axis=1)
    forb_hi = jnp.bitwise_or.reduce(hi_bits, axis=1)
    chosen = _smallest_free_color(forb_lo, forb_hi)
    return jnp.where((own < 0) & is_min, chosen, own)


def _smallest_free_color(forb_lo, forb_hi):
    """Smallest color whose bit is clear in the 64-bit forbidden mask."""
    free_lo = ~forb_lo
    low_idx = _lowest_set_bit(free_lo)
    free_hi = ~forb_hi
    high_idx = _lowest_set_bit(free_hi) + 32
    return jnp.where(free_lo != 0, low_idx, high_idx).astype(jnp.int32)


def _round_priorities(v: int, rnd, b):
    vids = jnp.arange(v, dtype=jnp.uint32)
    return pack(priorities_xorshift_star(rnd, vids), vids, b)


def _color_round_masked(neighbors, mask, colors, rnd, b):
    """One Luby round; ``b`` is a traced uint32 scalar so the function is
    vmappable over padded ``[B, rows, deg]`` buckets (each graph keeps its
    own ``b = id_bits(V_real)``, preserving single-graph priorities).
    Padded rows must enter pre-colored so they are never contenders."""
    v = neighbors.shape[0]
    prio = _round_priorities(v, rnd, b)
    row_ids = jnp.arange(v, dtype=neighbors.dtype)
    return _color_round_rows(neighbors, mask, row_ids, colors, prio)


@jax.jit
def _color_round(neighbors, mask, colors, rnd):
    b = jnp.uint32(id_bits(neighbors.shape[0]))
    return _color_round_masked(neighbors, mask, colors, rnd, b)


def _lowest_set_bit(x: jnp.ndarray) -> jnp.ndarray:
    """Index of lowest set bit of uint32 (x != 0 assumed where used)."""
    isolated = x & (~x + jnp.uint32(1))
    f = isolated.astype(jnp.float32)
    exp = (jax.lax.bitcast_convert_type(f, jnp.uint32) >> jnp.uint32(23)) - jnp.uint32(127)
    return exp.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_rounds",))
def _color_fixed_point(neighbors, mask, max_rounds: int):
    """Device-resident Luby round loop: one jitted ``lax.while_loop``
    instead of a per-round host sync of ``colors`` (the hot-loop pattern
    shared with the resident MIS-2 engines).  Round-for-round identical to
    the old host-driven loop, including its do-while shape (at least one
    round always runs)."""
    v = neighbors.shape[0]
    b = jnp.uint32(id_bits(v))
    colors0 = jnp.full(v, -1, dtype=jnp.int32)

    def cond(state):
        colors, rnd = state
        return (rnd == 0) | (jnp.any(colors < 0) & (rnd < max_rounds))

    def body(state):
        colors, rnd = state
        colors = _color_round_masked(neighbors, mask, colors,
                                     rnd.astype(jnp.uint32), b)
        return colors, rnd + jnp.int32(1)

    return jax.lax.while_loop(cond, body, (colors0, jnp.int32(0)))


def _spill_color_round(spill_rows, spill_seg, spill_cols, colors, prio):
    """Spill-side Luby round: the heavy rows' slots live in sorted COO, so
    the rowwise reductions become segment reductions.  Bit-matches
    :func:`_color_round_rows` on the same rows: ``all(own < pn)`` over
    contenders is ``own < segment_min(pn)`` (vacuously true on empty
    segments), and the forbidden-color OR becomes a one-hot scatter-max
    summed against distinct powers of two (sum == OR for distinct bits)."""
    h = spill_rows.shape[0]
    own = colors[spill_rows]
    prio_own = prio[spill_rows]
    cn = colors[spill_cols]
    pn = prio[spill_cols]
    real = spill_cols != spill_rows[spill_seg]
    contender = real & (cn < 0)
    n_cont = jax.ops.segment_sum(contender.astype(jnp.int32), spill_seg,
                                 num_segments=h)
    min_pn = jax.ops.segment_min(
        jnp.where(contender, pn, jnp.uint32(0xFFFFFFFF)), spill_seg,
        num_segments=h)
    is_min = (n_cont == 0) | (prio_own < min_pn)
    has = real & (cn >= 0)
    onehot = jnp.zeros((h, MAX_COLORS), dtype=jnp.bool_)
    onehot = onehot.at[spill_seg, jnp.clip(cn, 0, MAX_COLORS - 1)].max(has)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    forb_lo = jnp.sum(jnp.where(onehot[:, :32], weights[None, :],
                                jnp.uint32(0)), axis=1, dtype=jnp.uint32)
    forb_hi = jnp.sum(jnp.where(onehot[:, 32:], weights[None, :],
                                jnp.uint32(0)), axis=1, dtype=jnp.uint32)
    chosen = _smallest_free_color(forb_lo, forb_hi)
    return jnp.where((own < 0) & is_min, chosen, own)


@functools.partial(jax.jit, static_argnames=("v", "max_rounds"))
def _color_fixed_point_hybrid(slices, spill_rows, spill_seg, spill_cols,
                              v: int, max_rounds: int):
    """Hybrid-layout twin of :func:`_color_fixed_point`: one resident
    ``while_loop``, each round touching every slice slab plus the COO
    spill.  All reads within a round come from the frozen round-start
    ``colors``; writes land in a fresh buffer — the slice/spill partition
    is disjoint and covering, so the round is exactly the monolithic
    round's gather/update evaluated piecewise."""
    b = jnp.uint32(id_bits(v))
    colors0 = jnp.full(v, -1, dtype=jnp.int32)
    h = spill_rows.shape[0]

    def cond(state):
        colors, rnd = state
        return (rnd == 0) | (jnp.any(colors < 0) & (rnd < max_rounds))

    def body(state):
        colors, rnd = state
        prio = _round_priorities(v, rnd.astype(jnp.uint32), b)
        new_colors = colors
        for sl in slices:
            vals = _color_round_rows(sl.neighbors, sl.mask, sl.rows,
                                     colors, prio)
            new_colors = new_colors.at[sl.rows].set(vals)
        if h > 0:
            vals = _spill_color_round(spill_rows, spill_seg, spill_cols,
                                      colors, prio)
            new_colors = new_colors.at[spill_rows].set(vals)
        return new_colors, rnd + jnp.int32(1)

    return jax.lax.while_loop(cond, body, (colors0, jnp.int32(0)))


def _coloring_result(colors, rounds) -> ColoringResult:
    c = np.asarray(colors)
    rnd = int(rounds)
    num = int(c.max()) + 1 if (c >= 0).any() else 0
    if num > MAX_COLORS:
        raise RuntimeError(f"{num} colors exceed MAX_COLORS={MAX_COLORS}")
    # hitting max_rounds is reported, not raised: callers get the partial
    # coloring (uncolored vertices = -1) with converged=False
    return ColoringResult(c, num, rnd, converged=not (c < 0).any())


def _color_graph_impl(graph, max_rounds: int = 256) -> ColoringResult:
    ell = as_ell_graph(graph)
    colors, rounds = _color_fixed_point(ell.neighbors, ell.mask, max_rounds)
    return _coloring_result(colors, rounds)


def _color_hybrid_impl(graph, max_rounds: int = 256) -> ColoringResult:
    """Luby coloring over the degree-aware hybrid layout (sliced-ELL +
    COO spill).  Never materializes the monolithic padded ELL, so it runs
    on skewed graphs whose ``.ell`` would blow the byte budget; colors are
    bit-identical to the ``luby`` engine's."""
    from ..graphs.handle import as_graph

    gh = as_graph(graph)
    hyb = gh.hybrid()
    colors, rounds = _color_fixed_point_hybrid(
        tuple(hyb.slices), hyb.spill_rows, hyb.spill_seg, hyb.spill_cols,
        gh.num_vertices, max_rounds)
    return _coloring_result(colors, rounds)


def color_graph(graph, max_rounds: int = 256) -> ColoringResult:
    """Deprecated entry point — use :func:`repro.api.color`."""
    warn_deprecated("repro.core.coloring.color_graph", "repro.api.color")
    return _color_graph_impl(graph, max_rounds)


def check_coloring(graph, colors: np.ndarray) -> bool:
    """Validity: no two adjacent distinct vertices share a color."""
    ell = as_ell_graph(graph)
    nbrs = np.asarray(ell.neighbors)
    mask = np.asarray(ell.mask)
    v = nbrs.shape[0]
    self_ids = np.arange(v)[:, None]
    real = mask & (nbrs != self_ids)
    return not (real & (colors[nbrs] == colors[:, None])).any()
