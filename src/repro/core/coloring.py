"""Deterministic parallel greedy distance-1 coloring.

Used by cluster multicolor Gauss-Seidel (paper Alg. 4) to color the coarse
graph, and by point multicolor GS to color the fine graph.  Luby-style
rounds with the same xorshift* priorities as MIS-2: in each round, every
uncolored vertex that holds the minimum packed tuple among its uncolored
neighbors picks its smallest feasible color.  Deterministic across devices
and runs, like everything else in core/.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .._compat import warn_deprecated
from ..graphs.handle import as_ell_graph
from .hashing import priorities_xorshift_star
from .tuples import id_bits, pack

MAX_COLORS = 64


@dataclass
class ColoringResult:
    colors: np.ndarray      # int32 [V]; uncolored (unconverged) hold -1
    num_colors: int
    rounds: int
    converged: bool = True  # every vertex colored within the round limit

    def __post_init__(self):
        # Result-protocol guarantee: host numpy payloads on every engine.
        self.colors = np.asarray(self.colors)


def _color_round_masked(neighbors, mask, colors, rnd, b):
    """One Luby round; ``b`` is a traced uint32 scalar so the function is
    vmappable over padded ``[B, rows, deg]`` buckets (each graph keeps its
    own ``b = id_bits(V_real)``, preserving single-graph priorities).
    Padded rows must enter pre-colored so they are never contenders."""
    v = neighbors.shape[0]
    vids = jnp.arange(v, dtype=jnp.uint32)
    prio = pack(priorities_xorshift_star(rnd, vids), vids, b)
    uncolored = colors < 0
    # local-min among uncolored real neighbors (excluding self)
    self_ids = jnp.arange(v, dtype=neighbors.dtype)[:, None]
    real = mask & (neighbors != self_ids)
    pn = prio[neighbors]
    un = uncolored[neighbors]
    contender = real & un
    is_min = jnp.all(jnp.where(contender, prio[:, None] < pn, True), axis=1)
    # forbidden colors bitmask (two uint32 words -> up to 64 colors)
    cn = colors[neighbors]
    has = real & (cn >= 0)
    lo_bits = jnp.where(has & (cn < 32),
                        jnp.uint32(1) << jnp.clip(cn, 0, 31).astype(jnp.uint32),
                        jnp.uint32(0))
    hi_bits = jnp.where(has & (cn >= 32),
                        jnp.uint32(1) << jnp.clip(cn - 32, 0, 31).astype(jnp.uint32),
                        jnp.uint32(0))
    forb_lo = jnp.bitwise_or.reduce(lo_bits, axis=1)
    forb_hi = jnp.bitwise_or.reduce(hi_bits, axis=1)
    # smallest zero bit
    free_lo = ~forb_lo
    low_idx = _lowest_set_bit(free_lo)
    free_hi = ~forb_hi
    high_idx = _lowest_set_bit(free_hi) + 32
    chosen = jnp.where(free_lo != 0, low_idx, high_idx).astype(jnp.int32)
    return jnp.where(uncolored & is_min, chosen, colors)


@jax.jit
def _color_round(neighbors, mask, colors, rnd):
    b = jnp.uint32(id_bits(neighbors.shape[0]))
    return _color_round_masked(neighbors, mask, colors, rnd, b)


def _lowest_set_bit(x: jnp.ndarray) -> jnp.ndarray:
    """Index of lowest set bit of uint32 (x != 0 assumed where used)."""
    isolated = x & (~x + jnp.uint32(1))
    f = isolated.astype(jnp.float32)
    exp = (jax.lax.bitcast_convert_type(f, jnp.uint32) >> jnp.uint32(23)) - jnp.uint32(127)
    return exp.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_rounds",))
def _color_fixed_point(neighbors, mask, max_rounds: int):
    """Device-resident Luby round loop: one jitted ``lax.while_loop``
    instead of a per-round host sync of ``colors`` (the hot-loop pattern
    shared with the resident MIS-2 engines).  Round-for-round identical to
    the old host-driven loop, including its do-while shape (at least one
    round always runs)."""
    v = neighbors.shape[0]
    b = jnp.uint32(id_bits(v))
    colors0 = jnp.full(v, -1, dtype=jnp.int32)

    def cond(state):
        colors, rnd = state
        return (rnd == 0) | (jnp.any(colors < 0) & (rnd < max_rounds))

    def body(state):
        colors, rnd = state
        colors = _color_round_masked(neighbors, mask, colors,
                                     rnd.astype(jnp.uint32), b)
        return colors, rnd + jnp.int32(1)

    return jax.lax.while_loop(cond, body, (colors0, jnp.int32(0)))


def _color_graph_impl(graph, max_rounds: int = 256) -> ColoringResult:
    ell = as_ell_graph(graph)
    colors, rounds = _color_fixed_point(ell.neighbors, ell.mask, max_rounds)
    c = np.asarray(colors)
    rnd = int(rounds)
    num = int(c.max()) + 1 if (c >= 0).any() else 0
    if num > MAX_COLORS:
        raise RuntimeError(f"{num} colors exceed MAX_COLORS={MAX_COLORS}")
    # hitting max_rounds is reported, not raised: callers get the partial
    # coloring (uncolored vertices = -1) with converged=False
    return ColoringResult(c, num, rnd, converged=not (c < 0).any())


def color_graph(graph, max_rounds: int = 256) -> ColoringResult:
    """Deprecated entry point — use :func:`repro.api.color`."""
    warn_deprecated("repro.core.coloring.color_graph", "repro.api.color")
    return _color_graph_impl(graph, max_rounds)


def check_coloring(graph, colors: np.ndarray) -> bool:
    """Validity: no two adjacent distinct vertices share a color."""
    ell = as_ell_graph(graph)
    nbrs = np.asarray(ell.neighbors)
    mask = np.asarray(ell.mask)
    v = nbrs.shape[0]
    self_ids = np.arange(v)[:, None]
    real = mask & (nbrs != self_ids)
    return not (real & (colors[nbrs] == colors[:, None])).any()
