"""Distributed MIS-2 and coarsening under shard_map (beyond-paper: the paper
is single device; we vertex-partition across a device mesh axis).

Layout: vertices are block-partitioned over the flattened mesh axis; each
device owns a contiguous row block of the ELL adjacency ``[V/P, D]`` and the
local slice of the tuple vector ``T``.  Neighbor ids are *global*, so every
iteration all-gathers the 4-byte/vertex tuple vectors ``T`` and ``M`` —
exactly 2·V·4 bytes of collective traffic per iteration, independent of |E|
(the compressed-tuple optimization §V-C is also a *communication*
optimization here: unpacked tuples would triple the collective bytes).  The
``single_gather`` variant halves that to V·4 bytes by recomputing the
distance-1 minima locally from the gathered T.
:func:`collective_bytes_per_iteration` is the analytic form of this model;
:func:`write_mis2_dryrun_record` persists it as the
``artifacts/dryrun_graph/mis2_*.json`` records that
``benchmarks/figs4_5_scaling.py`` axis B consumes (the HLO-derived
equivalent is ``repro.launch.graph_dryrun``).

A halo-exchange variant (send only boundary tuples) is sketched in §Perf;
for the paper's mesh-like graphs with bandwidth-reducing orderings the halo
is O(V^(2/3)) per device, but the all-gather version is the robust default
for arbitrary vertex orderings.

Determinism: priorities depend only on (iteration, global vertex id) and are
packed with ``b = id_bits(V_real)`` — the *real* vertex count, NOT the
device-padded one.  Packing with the padded count silently changed the
truncated priority bits whenever padding crossed a power-of-two boundary
(e.g. V=1022 on 8 devices pads to 1024: b jumps 10 -> 11), breaking bit
identity with the single-device dense engine — exactly the cross-platform
determinism the paper demonstrates.  The real V is threaded through every
entry point here; tested in tests/test_distributed.py via subprocess with 8
host devices.

Coarsening: the sharded helpers (:func:`join_adjacent_root_distributed`,
:func:`count_unagg_neighbors_distributed`, :func:`phase3_join_distributed`)
run the paper Alg. 2/3 label-propagation rounds as one label all-gather +
local rowwise joins per round, sharing the exact rowwise arithmetic with
``core.aggregation`` so distributed labels are bit-identical to the
single-device engines.
"""
from __future__ import annotations

import functools
import json
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..graphs.csr import ELLGraph
from ..graphs.handle import as_ell_graph, as_graph
from ..obs import metrics as _OBS
from .hashing import PRIORITY_FNS
from .mis2 import Mis2Options, Mis2Result
from .tuples import IN, OUT, id_bits, is_undecided, pack

try:                                   # jax >= 0.5 promotes it to jax.*
    _shard_map_raw = jax.shard_map
    _NOREP_KWARGS = ({"check_vma": False}, {"check_rep": False}, {})
except AttributeError:                 # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_raw
    # the while_loop fixpoint has no replication rule in 0.4.x shard_map
    _NOREP_KWARGS = ({"check_rep": False}, {})

TUPLE_BYTES = 4                        # one packed §V-C tuple

DRYRUN_GRAPH_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun_graph"


def _shard_map(fn, *, mesh, in_specs, out_specs):
    for kw in _NOREP_KWARGS:
        try:
            return _shard_map_raw(fn, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, **kw)
        except TypeError:              # kwarg renamed across jax versions
            continue
    raise RuntimeError("no compatible shard_map signature found")


def _resolve_mesh(mesh: Optional[Mesh], axis):
    """Default mesh = every device on one flat axis; returns (mesh, axis, P)."""
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), ("x",))
        axis = "x"
    if axis is None:
        names = mesh.axis_names
        axis = names[0] if len(names) == 1 else tuple(names)
    axes = axis if isinstance(axis, tuple) else (axis,)
    nd = int(np.prod([mesh.shape[a] for a in axes]))
    return mesh, axis, nd


def pad_graph_for_mesh(ell: ELLGraph, num_devices: int):
    """Pad V to a multiple of num_devices with isolated, inactive vertices."""
    v = ell.num_vertices
    vp = ((v + num_devices - 1) // num_devices) * num_devices
    if vp == v:
        return ell, v
    neighbors = np.asarray(ell.neighbors)
    mask = np.asarray(ell.mask)
    extra = vp - v
    pad_nbrs = np.repeat(np.arange(v, vp, dtype=neighbors.dtype)[:, None],
                         ell.width, axis=1)
    pad_mask = np.zeros((extra, ell.width), dtype=bool)
    return ELLGraph(
        jnp.asarray(np.concatenate([neighbors, pad_nbrs])),
        jnp.asarray(np.concatenate([mask, pad_mask])),
    ), v


def prepare_padded(graph, mesh: Optional[Mesh] = None, axis=None):
    """Pad once and place the row-sharded adjacency on the mesh.

    Multi-call pipelines (distributed coarsening: 2 MIS-2 runs + up to ~6
    label-propagation rounds) pass the result through every sharded call so
    the O(V·D) host padding and the host->device upload happen exactly once
    — ``jax.device_put`` of an already-placed array is a no-op.
    """
    ell = as_graph(graph).ell
    mesh, axis, nd = _resolve_mesh(mesh, axis)
    padded, v = pad_graph_for_mesh(ell, nd)
    spec = NamedSharding(mesh, P(axis))
    return ELLGraph(jax.device_put(padded.neighbors, spec),
                    jax.device_put(padded.mask, spec)), v


def _mis2_local_fixpoint(neighbors_local, active_local, axis: str,
                         num_vertices: int, priority: str, max_iters: int,
                         single_gather: bool = False,
                         neighbors_global=None):
    """shard_map body: each device owns a row block; T (and M) all-gathered.

    ``num_vertices`` is the REAL vertex count — the packing bit width is
    ``b = id_bits(num_vertices)``, matching the single-device dense engine
    regardless of how much device padding the mesh forced (padded vertices
    are inactive and never pack a tuple, so ids >= num_vertices never hit
    the packer).

    ``single_gather=True`` (§Perf beyond-paper optimization): gather T once
    per iteration and recompute the distance-1 minima ``M`` for the whole
    graph locally from the gathered T (requires the full ELL adjacency
    ``neighbors_global`` replicated).  Trades O(V*D) redundant VPU mins —
    essentially free on mesh graphs — for HALF the collective bytes per
    iteration.
    """
    vp = neighbors_local.shape[0]
    b = id_bits(num_vertices)
    idx = jax.lax.axis_index(axis)
    vids = (idx * vp + jnp.arange(vp, dtype=jnp.uint32)).astype(jnp.uint32)
    prio_fn = PRIORITY_FNS[priority]

    t0 = jnp.where(active_local, jnp.uint32(1), OUT)
    # the active mask is loop-invariant: gather it ONCE, outside the
    # fixed-point body, so steady-state traffic is exactly the T (+ M)
    # gathers that collective_bytes_per_iteration() models
    a_global = jax.lax.all_gather(active_local, axis, tiled=True)
    an = a_global[neighbors_local]                                 # [Vp, D]

    def cond(state):
        t_local, it = state
        n_und = jnp.sum((is_undecided(t_local) & active_local).astype(jnp.int32))
        n_und = jax.lax.psum(n_und, axis)
        return (n_und > 0) & (it < max_iters)

    def body(state):
        t_local, it = state
        und = is_undecided(t_local) & active_local
        t_local = jnp.where(und, pack(prio_fn(it, vids), vids, b), t_local)
        # collective 1: global tuple vector for the distance-1 min
        t_global = jax.lax.all_gather(t_local, axis, tiled=True)   # [V]
        if single_gather:
            # recompute M for ALL vertices locally: no second gather
            tn_all = t_global[neighbors_global]                    # [V, D]
            m_global = jnp.min(tn_all, axis=1)
            m_global = jnp.where(m_global == IN, OUT, m_global)
        else:
            tn = t_global[neighbors_local]                         # [Vp, D]
            m_local = jnp.min(tn, axis=1)
            m_local = jnp.where(m_local == IN, OUT, m_local)
            # collective 2: global M for the distance-2 decision
            m_global = jax.lax.all_gather(m_local, axis, tiled=True)
        mn = m_global[neighbors_local]
        any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
        all_eq = jnp.all(jnp.where(an, mn, t_local[:, None]) == t_local[:, None],
                         axis=1)
        t_local = jnp.where(und & any_out, OUT, t_local)
        t_local = jnp.where(und & ~any_out & all_eq, IN, t_local)
        return t_local, it + 1

    t_local, iters = jax.lax.while_loop(cond, body, (t0, jnp.uint32(0)))
    return t_local, jnp.full((1,), iters, jnp.uint32)


# ===========================================================================
# collective-traffic accounting (the §V-C communication model, per iteration)
# ===========================================================================

def collective_bytes_per_iteration(num_vertices: int, num_devices: int,
                                   single_gather: bool = False) -> dict:
    """Analytic per-iteration collective volume of the sharded fixed point.

    Each iteration all-gathers the packed tuple vector T (``two_gather``
    also gathers the distance-1 minima M): result bytes = Vp * 4 per gather,
    ring wire bytes per device = result * (P-1)/P.  The loop-invariant
    active-mask gather is hoisted out of the fixed point and excluded.
    """
    vp = ((num_vertices + num_devices - 1) // num_devices) * num_devices
    gathers = 1 if single_gather else 2
    result_bytes = vp * TUPLE_BYTES * gathers
    wire = result_bytes * (num_devices - 1) / max(1, num_devices)
    return {
        "gathers_per_iteration": gathers,
        "result_bytes_per_iteration": result_bytes,
        "wire_bytes_per_device_per_iteration": wire,
    }


def write_mis2_dryrun_record(v: int, d: int, num_devices: int,
                             single_gather: bool, max_iters: int = 16,
                             mesh_shape: Optional[str] = None,
                             out_dir=None) -> Path:
    """Write one analytic ``artifacts/dryrun_graph/mis2_*.json`` record in
    the schema ``benchmarks/figs4_5_scaling.py`` axis B consumes (same
    headline keys as the HLO-derived ``launch.graph_dryrun`` records;
    ``wire_bytes_per_device`` totals ``max_iters`` iterations).  The
    default mesh tag is ``p<N>`` so analytic files never collide with the
    ``AxB``-tagged HLO records; ``source`` records the provenance."""
    variant = "single_gather" if single_gather else "two_gather"
    mesh = mesh_shape or f"p{num_devices}"
    per = collective_bytes_per_iteration(v, num_devices, single_gather)
    rec = {
        "variant": variant, "V": v, "D": d, "mesh": mesh,
        "num_devices": num_devices, "max_iters": max_iters,
        "source": "analytic_model",
        "per_iteration": per,
        "wire_bytes_per_device":
            per["wire_bytes_per_device_per_iteration"] * max_iters,
    }
    out = Path(out_dir) if out_dir is not None else DRYRUN_GRAPH_DIR
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"mis2_{variant}__{mesh}.json"
    path.write_text(json.dumps(rec, indent=2) + "\n")
    return path


# ===========================================================================
# distributed MIS-2 (production engines: 'distributed', 'distributed_single_gather')
# ===========================================================================

def _mis2_distributed_impl(graph, active=None,
                           options: Optional[Mis2Options] = None, *,
                           mesh: Optional[Mesh] = None, axis=None,
                           single_gather: bool = False,
                           padded: Optional[ELLGraph] = None,
                           neighbors_replicated=None) -> Mis2Result:
    """Sharded MIS-2 returning a core :class:`Mis2Result` — bit-identical
    to ``engine="dense"`` for any device count (equal determinism digest).
    ``result.collectives`` carries the per-run collective-byte accounting.
    ``padded`` short-circuits the mesh padding (see :func:`prepare_padded`);
    ``neighbors_replicated`` short-circuits the ``single_gather`` variant's
    fully-replicated adjacency upload the same way."""
    options = Mis2Options() if options is None else options
    ell = as_ell_graph(graph)
    v = ell.num_vertices
    mesh, axis, nd = _resolve_mesh(mesh, axis)

    if padded is None:
        padded, _ = pad_graph_for_mesh(ell, nd)
    vp_total = padded.num_vertices
    if active is None:
        active_arr = jnp.arange(vp_total) < v
    else:
        active_arr = jnp.concatenate(
            [jnp.asarray(active), jnp.zeros(vp_total - v, bool)])

    spec_rows = P(axis)
    in_specs = [spec_rows, spec_rows]
    args = [jax.device_put(padded.neighbors, NamedSharding(mesh, spec_rows)),
            jax.device_put(active_arr, NamedSharding(mesh, spec_rows))]
    if single_gather:
        fn_core = lambda nbrs, act, nbrs_g: _mis2_local_fixpoint(  # noqa: E731
            nbrs, act, axis=axis, num_vertices=v, priority=options.priority,
            max_iters=options.max_iters, single_gather=True,
            neighbors_global=nbrs_g)
        in_specs.append(P())
        args.append(neighbors_replicated if neighbors_replicated is not None
                    else jax.device_put(padded.neighbors,
                                        NamedSharding(mesh, P())))
    else:
        fn_core = functools.partial(
            _mis2_local_fixpoint, axis=axis, num_vertices=v,
            priority=options.priority, max_iters=options.max_iters)
    fn = _shard_map(fn_core, mesh=mesh, in_specs=tuple(in_specs),
                    out_specs=(spec_rows, P(axis)))
    t, iters = fn(*args)
    t_np = np.asarray(t)[:v]
    act_np = np.asarray(active_arr)[:v]
    iterations = int(np.asarray(iters)[0])
    undecided = is_undecided(t_np) & act_np
    per = collective_bytes_per_iteration(v, nd, single_gather)
    variant = "single_gather" if single_gather else "two_gather"
    collectives = {
        "variant": variant,
        "num_devices": nd,
        "iterations": iterations,
        **per,
        "result_bytes_total": per["result_bytes_per_iteration"] * iterations,
        "wire_bytes_per_device":
            per["wire_bytes_per_device_per_iteration"] * iterations,
    }
    # mirror the analytic accounting into the process-wide registry so one
    # obs.snapshot() carries collective volume next to dispatches/compiles
    _OBS.counter("dist.collective_bytes", labels={"variant": variant}).inc(
        collectives["result_bytes_total"])
    _OBS.counter("dist.rounds", labels={"variant": variant}).inc(iterations)
    return Mis2Result(t_np == np.uint32(IN), iterations,
                      not undecided.any(), collectives)


def mis2_distributed(graph, mesh: Mesh | None = None, axis: str | None = None,
                     active=None, priority: str = "xorshift_star",
                     max_iters: int = 128, single_gather: bool = False):
    """Legacy tuple-returning entry point; prefer
    ``repro.api.mis2(g, engine="distributed")``.

    Returns (in_set bool [V], iterations). Bit-identical to mis2_dense.
    """
    r = _mis2_distributed_impl(
        graph, active, Mis2Options(priority=priority, max_iters=max_iters),
        mesh=mesh, axis=axis, single_gather=single_gather)
    return r.in_set, r.iterations


def lower_mis2_distributed(ell_spec, mesh: Mesh, axis: str, *,
                           num_vertices: int,
                           priority: str = "xorshift_star",
                           max_iters: int = 128):
    """Dry-run hook: lower+compile the distributed fixpoint from
    ShapeDtypeStructs (no allocation). Returns the lowered object.

    ``num_vertices`` is REQUIRED and must be the REAL vertex count — the
    id_bits packing width; ``ell_spec.shape[0]`` is the device-padded row
    count, and defaulting to it would re-introduce the padded-V
    determinism bug whenever padding crosses a power of two."""
    spec_rows = P(axis)
    fn = _shard_map(
        functools.partial(
            _mis2_local_fixpoint, axis=axis, num_vertices=num_vertices,
            priority=priority, max_iters=max_iters),
        mesh=mesh,
        in_specs=(spec_rows, spec_rows),
        out_specs=(spec_rows, P(axis)),
    )
    active_spec = jax.ShapeDtypeStruct((ell_spec.shape[0],), jnp.bool_)
    return jax.jit(fn).lower(ell_spec, active_spec)


# ===========================================================================
# distributed coarsening rounds (paper Alg. 2/3 label propagation, sharded)
# ===========================================================================
#
# Each helper is one shard_map call: all-gather the global label vector
# (V·4 bytes), then run the SAME rowwise join arithmetic as the
# single-device helpers in core.aggregation on the local row block — so
# the labels (and therefore the coarse graph) are bit-identical.

def _sharded_rows(body, mesh, axis, padded_ell, *row_arrays,
                  replicated=()):
    """Run ``body(neighbors_local, mask_local, row_ids, *locals, *reps)``
    over the row-sharded padded ELL; returns the gathered [Vp] result.

    Each call builds a fresh shard_map closure, so JAX re-traces per
    invocation (the padded adjacency upload IS cached via prepare_padded).
    A compile cache keyed on (mesh, axis, shapes) would amortize the ~8
    traces a distributed coarsen performs — follow-up work; at production
    graph sizes data movement, not tracing, dominates."""
    spec_rows = P(axis)
    vp = padded_ell.num_vertices

    def fn(nbrs_local, mask_local, *rest):
        vloc = nbrs_local.shape[0]
        idx = jax.lax.axis_index(axis)
        row_ids = (idx * vloc
                   + jnp.arange(vloc, dtype=nbrs_local.dtype))
        return body(nbrs_local, mask_local, row_ids, *rest)

    in_specs = [spec_rows, spec_rows] + [spec_rows] * len(row_arrays) \
        + [P()] * len(replicated)
    args = [jax.device_put(padded_ell.neighbors, NamedSharding(mesh, spec_rows)),
            jax.device_put(padded_ell.mask, NamedSharding(mesh, spec_rows))]
    for a in row_arrays:
        args.append(jax.device_put(jnp.asarray(a),
                                   NamedSharding(mesh, spec_rows)))
    for a in replicated:
        args.append(jax.device_put(jnp.asarray(a), NamedSharding(mesh, P())))
    sharded = _shard_map(fn, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=spec_rows)
    out = sharded(*args)
    assert out.shape[0] == vp
    return out


def _pad_labels(arr: np.ndarray, vp: int, fill) -> np.ndarray:
    out = np.full(vp, fill, dtype=np.int32)
    out[: len(arr)] = arr
    return out


def join_adjacent_root_distributed(graph, root_label: np.ndarray,
                                   mesh: Optional[Mesh] = None,
                                   axis=None, padded=None) -> np.ndarray:
    """Sharded ``core.aggregation._join_adjacent_root``: one root-label
    all-gather + local rowwise min per call."""
    from .aggregation import INT32_MAX, _join_rows

    ell = as_graph(graph).ell
    v = ell.num_vertices
    mesh, axis, nd = _resolve_mesh(mesh, axis)
    if padded is None:
        padded, _ = pad_graph_for_mesh(ell, nd)
    rl = _pad_labels(np.asarray(root_label, dtype=np.int32),
                     padded.num_vertices, INT32_MAX)

    def body(nbrs_local, mask_local, row_ids, rl_local):
        rl_global = jax.lax.all_gather(rl_local, axis, tiled=True)
        return _join_rows(nbrs_local, rl_global)

    out = _sharded_rows(body, mesh, axis, padded, rl)
    return np.asarray(out)[:v]


def count_unagg_neighbors_distributed(graph, labels: np.ndarray,
                                      mesh: Optional[Mesh] = None,
                                      axis=None, padded=None) -> np.ndarray:
    """Sharded ``core.aggregation._count_unagg_neighbors``."""
    from .aggregation import _count_unagg_rows

    ell = as_graph(graph).ell
    v = ell.num_vertices
    mesh, axis, nd = _resolve_mesh(mesh, axis)
    if padded is None:
        padded, _ = pad_graph_for_mesh(ell, nd)
    lab = _pad_labels(np.asarray(labels, dtype=np.int32),
                      padded.num_vertices, 0)

    def body(nbrs_local, mask_local, row_ids, lab_local):
        lab_global = jax.lax.all_gather(lab_local, axis, tiled=True)
        return _count_unagg_rows(nbrs_local, mask_local, row_ids, lab_global)

    out = _sharded_rows(body, mesh, axis, padded, lab)
    return np.asarray(out)[:v]


def phase3_join_distributed(graph, labels: np.ndarray, aggsize: np.ndarray,
                            mesh: Optional[Mesh] = None,
                            axis=None, padded=None) -> np.ndarray:
    """Sharded ``core.aggregation._phase3_join`` (max-coupling leftover
    join against frozen tentative labels): label all-gather + local
    rowwise lexicographic argmin; aggregate sizes ride replicated."""
    from .aggregation import _phase3_rows

    ell = as_graph(graph).ell
    v = ell.num_vertices
    mesh, axis, nd = _resolve_mesh(mesh, axis)
    if padded is None:
        padded, _ = pad_graph_for_mesh(ell, nd)
    lab = _pad_labels(np.asarray(labels, dtype=np.int32),
                      padded.num_vertices, 0)

    def body(nbrs_local, mask_local, row_ids, lab_local, aggsize_rep):
        lab_global = jax.lax.all_gather(lab_local, axis, tiled=True)
        return _phase3_rows(nbrs_local, mask_local, row_ids, lab_global,
                            lab_local, aggsize_rep)

    out = _sharded_rows(body, mesh, axis, padded, lab,
                        replicated=(np.asarray(aggsize, dtype=np.int32),))
    return np.asarray(out)[:v]
