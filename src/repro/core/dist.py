"""Distributed MIS-2 under shard_map (beyond-paper: the paper is single
device; we vertex-partition across a device mesh axis).

Layout: vertices are block-partitioned over the flattened mesh axis; each
device owns a contiguous row block of the ELL adjacency ``[V/P, D]`` and the
local slice of the tuple vector ``T``.  Neighbor ids are *global*, so every
iteration all-gathers the 4-byte/vertex tuple vectors ``T`` and ``M`` —
exactly 2·V·4 bytes of collective traffic per iteration, independent of |E|
(the compressed-tuple optimization §V-C is also a *communication*
optimization here: unpacked tuples would triple the collective bytes, which
is the beyond-paper measurement in EXPERIMENTS.md §Perf).

A halo-exchange variant (send only boundary tuples) is sketched in §Perf;
for the paper's mesh-like graphs with bandwidth-reducing orderings the halo
is O(V^(2/3)) per device, but the all-gather version is the robust default
for arbitrary vertex orderings.

Determinism: priorities depend only on (iteration, global vertex id), so the
result is bit-identical to the single-device dense engine for any device
count — tested in tests/test_distributed.py via subprocess with 8 host
devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..graphs.csr import ELLGraph
from ..graphs.handle import as_ell_graph
from .hashing import PRIORITY_FNS
from .tuples import IN, OUT, id_bits, is_undecided, pack

try:                                   # jax >= 0.5 promotes it to jax.*
    _shard_map_raw = jax.shard_map
    _NOREP_KWARGS = ({"check_vma": False}, {"check_rep": False}, {})
except AttributeError:                 # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_raw
    # the while_loop fixpoint has no replication rule in 0.4.x shard_map
    _NOREP_KWARGS = ({"check_rep": False}, {})

U32MAX = np.uint32(0xFFFFFFFF)


def _shard_map(fn, *, mesh, in_specs, out_specs):
    for kw in _NOREP_KWARGS:
        try:
            return _shard_map_raw(fn, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, **kw)
        except TypeError:              # kwarg renamed across jax versions
            continue
    raise RuntimeError("no compatible shard_map signature found")


def pad_graph_for_mesh(ell: ELLGraph, num_devices: int):
    """Pad V to a multiple of num_devices with isolated, inactive vertices."""
    v = ell.num_vertices
    vp = ((v + num_devices - 1) // num_devices) * num_devices
    if vp == v:
        return ell, v
    neighbors = np.asarray(ell.neighbors)
    mask = np.asarray(ell.mask)
    extra = vp - v
    pad_nbrs = np.repeat(np.arange(v, vp, dtype=neighbors.dtype)[:, None],
                         ell.width, axis=1)
    pad_mask = np.zeros((extra, ell.width), dtype=bool)
    return ELLGraph(
        jnp.asarray(np.concatenate([neighbors, pad_nbrs])),
        jnp.asarray(np.concatenate([mask, pad_mask])),
    ), v


def _mis2_local_fixpoint(neighbors_local, active_local, axis: str,
                         total_v: int, priority: str, max_iters: int,
                         single_gather: bool = False,
                         neighbors_global=None):
    """shard_map body: each device owns a row block; T (and M) all-gathered.

    ``single_gather=True`` (§Perf beyond-paper optimization): gather T once
    per iteration and recompute the distance-1 minima ``M`` for the whole
    graph locally from the gathered T (requires the full ELL adjacency
    ``neighbors_global`` replicated).  Trades O(V*D) redundant VPU mins —
    essentially free on mesh graphs — for HALF the collective bytes per
    iteration (confirmed: see EXPERIMENTS.md §Perf).
    """
    vp = neighbors_local.shape[0]
    b = id_bits(total_v)
    idx = jax.lax.axis_index(axis)
    vids = (idx * vp + jnp.arange(vp, dtype=jnp.uint32)).astype(jnp.uint32)
    prio_fn = PRIORITY_FNS[priority]

    t0 = jnp.where(active_local, jnp.uint32(1), OUT)

    def cond(state):
        t_local, it = state
        n_und = jnp.sum((is_undecided(t_local) & active_local).astype(jnp.int32))
        n_und = jax.lax.psum(n_und, axis)
        return (n_und > 0) & (it < max_iters)

    def body(state):
        t_local, it = state
        und = is_undecided(t_local) & active_local
        t_local = jnp.where(und, pack(prio_fn(it, vids), vids, b), t_local)
        # collective 1: global tuple vector for the distance-1 min
        t_global = jax.lax.all_gather(t_local, axis, tiled=True)   # [V]
        a_global = jax.lax.all_gather(active_local, axis, tiled=True)
        if single_gather:
            # recompute M for ALL vertices locally: no second gather
            tn_all = t_global[neighbors_global]                    # [V, D]
            m_global = jnp.min(tn_all, axis=1)
            m_global = jnp.where(m_global == IN, OUT, m_global)
        else:
            tn = t_global[neighbors_local]                         # [Vp, D]
            m_local = jnp.min(tn, axis=1)
            m_local = jnp.where(m_local == IN, OUT, m_local)
            # collective 2: global M for the distance-2 decision
            m_global = jax.lax.all_gather(m_local, axis, tiled=True)
        mn = m_global[neighbors_local]
        an = a_global[neighbors_local]
        any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
        all_eq = jnp.all(jnp.where(an, mn, t_local[:, None]) == t_local[:, None],
                         axis=1)
        t_local = jnp.where(und & any_out, OUT, t_local)
        t_local = jnp.where(und & ~any_out & all_eq, IN, t_local)
        return t_local, it + 1

    t_local, iters = jax.lax.while_loop(cond, body, (t0, jnp.uint32(0)))
    return t_local, jnp.full((1,), iters, jnp.uint32)


def mis2_distributed(graph, mesh: Mesh | None = None, axis: str | None = None,
                     active=None, priority: str = "xorshift_star",
                     max_iters: int = 128, single_gather: bool = False):
    """Run MIS-2 sharded over a mesh axis (all axes flattened if axis=None).

    Returns (in_set bool [V], iterations). Bit-identical to mis2_dense.
    """
    ell = as_ell_graph(graph)
    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs, ("x",))
        axis = "x"
    if axis is None:
        axis = mesh.axis_names[0]
    nd = int(np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]))

    padded, v = pad_graph_for_mesh(ell, nd)
    vp_total = padded.num_vertices
    if active is None:
        active_arr = jnp.arange(vp_total) < v
    else:
        active_arr = jnp.concatenate(
            [jnp.asarray(active), jnp.zeros(vp_total - v, bool)])

    spec_rows = P(axis)
    in_specs = [spec_rows, spec_rows]
    args = [jax.device_put(padded.neighbors, NamedSharding(mesh, spec_rows)),
            jax.device_put(active_arr, NamedSharding(mesh, spec_rows))]
    if single_gather:
        fn_core = lambda nbrs, act, nbrs_g: _mis2_local_fixpoint(  # noqa: E731
            nbrs, act, axis=axis, total_v=vp_total, priority=priority,
            max_iters=max_iters, single_gather=True, neighbors_global=nbrs_g)
        in_specs.append(P())
        args.append(jax.device_put(padded.neighbors,
                                   NamedSharding(mesh, P())))
    else:
        fn_core = functools.partial(
            _mis2_local_fixpoint, axis=axis, total_v=vp_total,
            priority=priority, max_iters=max_iters)
    fn = _shard_map(fn_core, mesh=mesh, in_specs=tuple(in_specs),
                    out_specs=(spec_rows, P(axis)))
    t, iters = fn(*args)
    t_np = np.asarray(t)[:v]
    return t_np == np.uint32(IN), int(np.asarray(iters)[0])


def lower_mis2_distributed(ell_spec, mesh: Mesh, axis: str,
                           priority: str = "xorshift_star", max_iters: int = 128):
    """Dry-run hook: lower+compile the distributed fixpoint from
    ShapeDtypeStructs (no allocation). Returns the lowered object."""
    spec_rows = P(axis)
    fn = _shard_map(
        functools.partial(_mis2_local_fixpoint, axis=axis,
                          total_v=ell_spec.shape[0], priority=priority,
                          max_iters=max_iters),
        mesh=mesh,
        in_specs=(spec_rows, spec_rows),
        out_specs=(spec_rows, P(axis)),
    )
    active_spec = jax.ShapeDtypeStruct((ell_spec.shape[0],), jnp.bool_)
    return jax.jit(fn).lower(ell_spec, active_spec)
