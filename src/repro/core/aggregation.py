"""MIS-2 based graph coarsening (paper Algorithms 2 and 3).

* ``aggregate_basic``   — Algorithm 2 (Bell-style): MIS-2 roots + direct
  neighbors; leftovers join an adjacent aggregate (deterministically: the
  minimum adjacent label, standing in for the paper's "arbitrarily").
* ``aggregate_two_phase`` — Algorithm 3 (ML-style, the paper's contribution):
  phase 1 = MIS-2 roots + neighbors; phase 2 = second MIS-2 on the induced
  unaggregated subgraph, roots with >= 2 unaggregated neighbors form
  secondary aggregates; phase 3 = leftovers join the max-coupling adjacent
  aggregate (ties -> smaller aggregate -> smaller label), computed against
  frozen "tentative" labels for determinism.
* ``aggregate_serial_greedy`` — host-sequential reference (MueLu "Serial
  Agg" stand-in for Table V).

All device phases are vectorized over ELL adjacency.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .._compat import warn_deprecated
from ..graphs.csr import ELLGraph
from ..graphs.handle import as_graph
from .mis2 import Mis2Options, run_mis2

INT32_MAX = np.int32(2**31 - 1)


@dataclass
class AggregationResult:
    labels: np.ndarray       # int32 [V] aggregate id (all >= 0 on success)
    num_aggregates: int
    roots: np.ndarray        # bool [V] (phase-1 + phase-2 roots)
    phase: np.ndarray        # uint8 [V]: phase that aggregated each vertex
    mis2_iterations: int     # total MIS-2 iterations spent
    converged: bool = True   # every underlying MIS-2 reached its fixed point

    def __post_init__(self):
        # Result-protocol guarantee: host numpy payloads on every engine.
        self.labels = np.asarray(self.labels)
        self.roots = np.asarray(self.roots)
        self.phase = np.asarray(self.phase)

    @property
    def coarsening_ratio(self) -> float:
        return len(self.labels) / max(1, self.num_aggregates)


# ---------------------------------------------------------------------------
# vectorized helpers
#
# Each helper is split into a rowwise body over an explicit row block
# (``*_rows``) plus the single-device full-graph wrapper: the distributed
# coarsening in ``core.dist`` runs the SAME rowwise body on shard_map row
# blocks (with gathered global label vectors), which is what makes the
# sharded labels bit-identical to the single-device engines.
# ---------------------------------------------------------------------------

def _join_rows(neighbors_rows: jnp.ndarray, root_label_global: jnp.ndarray):
    """Rowwise body of :func:`_join_adjacent_root` over a row block."""
    cand = root_label_global[neighbors_rows]   # [rows, D] (self-pad: own label)
    lab = jnp.min(cand, axis=1)
    return jnp.where(lab == INT32_MAX, jnp.int32(-1), lab)


@jax.jit
def _join_adjacent_root(neighbors: jnp.ndarray, root_label: jnp.ndarray):
    """label[v] = root label of the (unique) adjacent root, else -1.

    ``root_label`` is int32 [V]: aggregate id for roots, INT32_MAX otherwise.
    A vertex adjacent to two distinct roots would contradict distance-2
    independence, so min() is exact, not a tie-break.
    """
    return _join_rows(neighbors, root_label)


def _count_unagg_rows(neighbors_rows, mask_rows, row_ids, labels_global):
    """Rowwise body of :func:`_count_unagg_neighbors` over a row block."""
    real = mask_rows & (neighbors_rows != row_ids[:, None])
    unagg = labels_global[neighbors_rows] < 0
    return jnp.sum(real & unagg, axis=1)


@jax.jit
def _count_unagg_neighbors(neighbors, mask, labels):
    """# real neighbors (excluding self) that are unaggregated."""
    v = neighbors.shape[0]
    row_ids = jnp.arange(v, dtype=neighbors.dtype)
    return _count_unagg_rows(neighbors, mask, row_ids, labels)


def _phase3_keys(labels_n, valid, aggsize):
    """Per-slot (coupling, aggsize, label) selection keys (lower = better);
    coupling negated so a single lexicographic min picks max coupling."""
    d = labels_n.shape[1]
    coupling = jnp.zeros(labels_n.shape, jnp.int32)
    for k in range(d):
        same = (labels_n == labels_n[:, k:k + 1]) & valid[:, k:k + 1] & valid
        coupling = coupling + same.astype(jnp.int32)
    size_n = aggsize[jnp.clip(labels_n, 0, aggsize.shape[0] - 1)]
    return coupling, size_n


def _phase3_rows(neighbors_rows, mask_rows, row_ids, labels_global,
                 labels_rows, aggsize):
    """Rowwise body of :func:`_phase3_join` over a row block (neighbor
    labels looked up in ``labels_global``, joins applied to
    ``labels_rows``)."""
    labels_n = labels_global[neighbors_rows]         # tentative labels
    valid = mask_rows & (neighbors_rows != row_ids[:, None]) & (labels_n >= 0)
    coupling, size_n = _phase3_keys(labels_n, valid, aggsize)
    d = neighbors_rows.shape[1]

    # lexicographic argmin over slots of (-coupling, size, label); invalid last
    best_c = jnp.where(valid[:, 0], coupling[:, 0], -1)
    best_s = size_n[:, 0]
    best_l = jnp.where(valid[:, 0], labels_n[:, 0], INT32_MAX)
    for j in range(1, d):
        cj = jnp.where(valid[:, j], coupling[:, j], -1)
        sj = size_n[:, j]
        lj = jnp.where(valid[:, j], labels_n[:, j], INT32_MAX)
        better = (cj > best_c) | ((cj == best_c) & ((sj < best_s) |
                 ((sj == best_s) & (lj < best_l))))
        best_c = jnp.where(better, cj, best_c)
        best_s = jnp.where(better, sj, best_s)
        best_l = jnp.where(better, lj, best_l)
    joined = (best_c > 0) & (best_l != INT32_MAX)
    return jnp.where((labels_rows < 0) & joined, best_l, labels_rows)


@jax.jit
def _phase3_join(neighbors, mask, labels, aggsize):
    """Leftovers join max-coupling adjacent aggregate (Alg 3 phase 3)."""
    v = neighbors.shape[0]
    row_ids = jnp.arange(v, dtype=neighbors.dtype)
    return _phase3_rows(neighbors, mask, row_ids, labels, labels, aggsize)


def _labels_from_roots(ell: ELLGraph, roots: np.ndarray):
    """Phase-1 style aggregate formation: roots + direct neighbors."""
    v = ell.num_vertices
    agg_ids = np.cumsum(roots) - 1
    root_label = np.where(roots, agg_ids, INT32_MAX).astype(np.int32)
    labels = np.asarray(_join_adjacent_root(ell.neighbors, jnp.asarray(root_label)))
    return labels, int(roots.sum())


# ---------------------------------------------------------------------------
# device-resident join loops (the hot-loop pattern of core.mis2's resident
# engines applied to the Alg. 2/3 label propagation): each multi-round
# host loop below used to sync ``labels`` device<->host every round — one
# jitted ``lax.while_loop`` replaces up to 4 round trips per phase while
# running the exact same rowwise arithmetic (labels stay bit-identical).
# ---------------------------------------------------------------------------

@jax.jit
def _cleanup_join_resident(neighbors, labels, phase):
    """Alg. 2 leftover cleanup: up to 4 min-adjacent-label join rounds,
    early exit once every vertex is labeled, phase marks applied on
    device."""
    def cond(state):
        labels, _, rounds = state
        return jnp.any(labels < 0) & (rounds < 4)

    def body(state):
        labels, phase, rounds = state
        lab_j = jnp.where(labels >= 0, labels, INT32_MAX).astype(jnp.int32)
        adj = _join_rows(neighbors, lab_j)
        newly = (labels < 0) & (adj >= 0)
        labels = jnp.where(newly, adj, labels)
        phase = jnp.where(newly, jnp.uint8(3), phase)
        return labels, phase, rounds + jnp.int32(1)

    labels, phase, _ = jax.lax.while_loop(
        cond, body, (labels, phase, jnp.int32(0)))
    return labels, phase


@functools.partial(jax.jit, static_argnames=("min_secondary",))
def _phase2_join_resident(neighbors, mask, labels, in_set2, nagg,
                          min_secondary: int):
    """Alg. 3 phase 2 on device: unaggregated-neighbor counting, secondary
    root selection, cumsum aggregate ids and the root join — one dispatch
    instead of three label round trips.  ``nagg`` is traced (no
    recompilation per aggregate count)."""
    v = neighbors.shape[0]
    row_ids = jnp.arange(v, dtype=neighbors.dtype)
    n_unagg = _count_unagg_rows(neighbors, mask, row_ids, labels)
    roots2 = in_set2 & (n_unagg >= min_secondary)
    agg_ids2 = nagg + jnp.cumsum(roots2.astype(jnp.int32)) - 1
    rl2 = jnp.where(roots2, agg_ids2, INT32_MAX).astype(jnp.int32)
    adj2 = _join_rows(neighbors, rl2)
    newly = (labels < 0) & (adj2 >= 0)
    labels = jnp.where(newly, adj2, labels)
    return labels, roots2, newly


@jax.jit
def _phase3_resident(neighbors, mask, labels, phase):
    """Alg. 3 phase 3 on device: up to 4 max-coupling join rounds against
    frozen tentative labels, aggregate sizes recomputed per round via a
    scatter-add histogram (slot ``v`` is the dump for unlabeled vertices,
    so entries ``0..nagg-1`` match ``np.bincount`` exactly; labels never
    reference the padding slots, making the join bit-identical to the
    host-driven rounds)."""
    v = neighbors.shape[0]
    row_ids = jnp.arange(v, dtype=neighbors.dtype)

    def cond(state):
        labels, _, rounds = state
        return jnp.any(labels < 0) & (rounds < 4)

    def body(state):
        labels, phase, rounds = state
        aggsize = jnp.zeros(v + 1, jnp.int32).at[
            jnp.where(labels >= 0, labels, v)].add(1)
        new_labels = _phase3_rows(neighbors, mask, row_ids, labels, labels,
                                  aggsize)
        newly = (labels < 0) & (new_labels >= 0)
        phase = jnp.where(newly, jnp.uint8(3), phase)
        return new_labels, phase, rounds + jnp.int32(1)

    labels, phase, _ = jax.lax.while_loop(
        cond, body, (labels, phase, jnp.int32(0)))
    return labels, phase


# ---------------------------------------------------------------------------
# hybrid-layout join loops (degree-aware sliced-ELL + COO spill)
#
# Twins of the resident loops above for graphs whose monolithic padded ELL
# is infeasible: each round runs the SAME rowwise bodies per slice slab
# (``row_ids = slice.rows``) plus a segment-reduce pass over the sorted-COO
# spill.  Within a round every read comes from the frozen round-start
# labels and writes accumulate into a fresh buffer — the slice/spill
# partition is disjoint and covering, so each round is exactly the
# monolithic round's gather/update evaluated piecewise (labels stay
# bit-identical to the ELL engines).
# ---------------------------------------------------------------------------

def _hybrid_join_labels(slices, spill_rows, spill_seg, spill_cols,
                        root_label):
    """Hybrid twin of :func:`_join_adjacent_root` (min label over the
    closed neighborhood; INT32_MAX -> -1).  The spill's explicit self min
    mirrors the ELL padding slots, which hold the row's own id."""
    v = root_label.shape[0]
    adj = jnp.full(v, -1, dtype=jnp.int32)
    for sl in slices:
        adj = adj.at[sl.rows].set(_join_rows(sl.neighbors, root_label))
    h = spill_rows.shape[0]
    if h > 0:
        mn = jax.ops.segment_min(root_label[spill_cols], spill_seg,
                                 num_segments=h)
        mn = jnp.minimum(mn, root_label[spill_rows])
        adj = adj.at[spill_rows].set(
            jnp.where(mn == INT32_MAX, jnp.int32(-1), mn))
    return adj


@jax.jit
def _hybrid_join_jit(slices, spill_rows, spill_seg, spill_cols, root_label):
    return _hybrid_join_labels(slices, spill_rows, spill_seg, spill_cols,
                               root_label)


def _labels_from_roots_hybrid(hyb, roots: np.ndarray):
    """Hybrid twin of :func:`_labels_from_roots` (same host cumsum)."""
    agg_ids = np.cumsum(roots) - 1
    root_label = np.where(roots, agg_ids, INT32_MAX).astype(np.int32)
    labels = np.asarray(_hybrid_join_jit(
        tuple(hyb.slices), hyb.spill_rows, hyb.spill_seg, hyb.spill_cols,
        jnp.asarray(root_label)))
    return labels, int(roots.sum())


@jax.jit
def _cleanup_join_resident_hybrid(slices, spill_rows, spill_seg, spill_cols,
                                  labels, phase):
    """Hybrid twin of :func:`_cleanup_join_resident`."""
    def cond(state):
        labels, _, rounds = state
        return jnp.any(labels < 0) & (rounds < 4)

    def body(state):
        labels, phase, rounds = state
        lab_j = jnp.where(labels >= 0, labels, INT32_MAX).astype(jnp.int32)
        adj = _hybrid_join_labels(slices, spill_rows, spill_seg, spill_cols,
                                  lab_j)
        newly = (labels < 0) & (adj >= 0)
        labels = jnp.where(newly, adj, labels)
        phase = jnp.where(newly, jnp.uint8(3), phase)
        return labels, phase, rounds + jnp.int32(1)

    labels, phase, _ = jax.lax.while_loop(
        cond, body, (labels, phase, jnp.int32(0)))
    return labels, phase


@functools.partial(jax.jit, static_argnames=("min_secondary",))
def _phase2_join_resident_hybrid(slices, spill_rows, spill_seg, spill_cols,
                                 labels, in_set2, nagg, min_secondary: int):
    """Hybrid twin of :func:`_phase2_join_resident`: the per-row
    unaggregated-neighbor count runs rowwise per slice and as a segment
    sum over the spill; root selection/cumsum/join are unchanged (they
    operate on global [V] vectors)."""
    v = labels.shape[0]
    n_unagg = jnp.zeros(v, dtype=jnp.int32)
    for sl in slices:
        n_unagg = n_unagg.at[sl.rows].set(
            _count_unagg_rows(sl.neighbors, sl.mask, sl.rows, labels))
    h = spill_rows.shape[0]
    if h > 0:
        real = spill_cols != spill_rows[spill_seg]
        unagg_e = labels[spill_cols] < 0
        n_sp = jax.ops.segment_sum((real & unagg_e).astype(jnp.int32),
                                   spill_seg, num_segments=h)
        n_unagg = n_unagg.at[spill_rows].set(n_sp)
    roots2 = in_set2 & (n_unagg >= min_secondary)
    agg_ids2 = nagg + jnp.cumsum(roots2.astype(jnp.int32)) - 1
    rl2 = jnp.where(roots2, agg_ids2, INT32_MAX).astype(jnp.int32)
    adj2 = _hybrid_join_labels(slices, spill_rows, spill_seg, spill_cols, rl2)
    newly = (labels < 0) & (adj2 >= 0)
    labels = jnp.where(newly, adj2, labels)
    return labels, roots2, newly


def _phase3_spill(spill_rows, spill_seg, spill_cols, labels, aggsize):
    """Phase-3 body over the sorted-COO spill: pick the max-coupling
    adjacent aggregate (ties -> smaller size -> smaller label).

    Coupling counts need a per-(row, label) histogram, which the ELL body
    gets by an O(d^2) slot comparison.  Here entries are sorted by
    (segment, label) — ``lax.sort`` with two keys — so equal-label entries
    form runs whose length IS the coupling; the lexicographic argmin then
    becomes a three-step segment-reduce cascade (max coupling, then min
    size among those, then min label among those).  Valid slots are
    distinct real neighbors in both layouts, so the counts — and therefore
    the chosen labels — are bit-identical to :func:`_phase3_rows`."""
    h = spill_rows.shape[0]
    s = spill_cols.shape[0]
    lab_n = labels[spill_cols]
    real = spill_cols != spill_rows[spill_seg]
    valid = real & (lab_n >= 0)
    key_lab = jnp.where(valid, lab_n, INT32_MAX)
    seg_s, lab_s = jax.lax.sort((spill_seg, key_lab), num_keys=2)
    start = jnp.concatenate([
        jnp.ones(1, dtype=bool),
        (seg_s[1:] != seg_s[:-1]) | (lab_s[1:] != lab_s[:-1])])
    run_id = jnp.cumsum(start.astype(jnp.int32)) - 1
    run_len = jax.ops.segment_sum(jnp.ones(s, jnp.int32), run_id,
                                  num_segments=s)
    c_e = jnp.where(lab_s < INT32_MAX, run_len[run_id], -1)
    size_e = aggsize[jnp.clip(lab_s, 0, aggsize.shape[0] - 1)]
    best_c = jax.ops.segment_max(c_e, seg_s, num_segments=h)
    on_c = c_e == best_c[seg_s]
    best_s = jax.ops.segment_min(jnp.where(on_c, size_e, INT32_MAX), seg_s,
                                 num_segments=h)
    on_s = on_c & (size_e == best_s[seg_s])
    best_l = jax.ops.segment_min(jnp.where(on_s, lab_s, INT32_MAX), seg_s,
                                 num_segments=h)
    joined = (best_c > 0) & (best_l < INT32_MAX)
    own = labels[spill_rows]
    return jnp.where((own < 0) & joined, best_l, own)


@jax.jit
def _phase3_resident_hybrid(slices, spill_rows, spill_seg, spill_cols,
                            labels, phase):
    """Hybrid twin of :func:`_phase3_resident` (same frozen-tentative-label
    rounds; aggregate sizes recomputed per round on the global vector)."""
    v = labels.shape[0]
    h = spill_rows.shape[0]

    def cond(state):
        labels, _, rounds = state
        return jnp.any(labels < 0) & (rounds < 4)

    def body(state):
        labels, phase, rounds = state
        aggsize = jnp.zeros(v + 1, jnp.int32).at[
            jnp.where(labels >= 0, labels, v)].add(1)
        new_labels = labels
        for sl in slices:
            vals = _phase3_rows(sl.neighbors, sl.mask, sl.rows, labels,
                                labels[sl.rows], aggsize)
            new_labels = new_labels.at[sl.rows].set(vals)
        if h > 0:
            vals = _phase3_spill(spill_rows, spill_seg, spill_cols, labels,
                                 aggsize)
            new_labels = new_labels.at[spill_rows].set(vals)
        newly = (labels < 0) & (new_labels >= 0)
        phase = jnp.where(newly, jnp.uint8(3), phase)
        return new_labels, phase, rounds + jnp.int32(1)

    labels, phase, _ = jax.lax.while_loop(
        cond, body, (labels, phase, jnp.int32(0)))
    return labels, phase


def _aggregate_basic_hybrid_impl(graph, options: Mis2Options | None = None,
                                 interpret=None) -> AggregationResult:
    """Algorithm 2 over the hybrid layout — never touches ``gh.ell``."""
    gh = as_graph(graph)
    hyb = gh.hybrid()
    parts = (tuple(hyb.slices), hyb.spill_rows, hyb.spill_seg, hyb.spill_cols)
    r = run_mis2(gh, options=options, engine="pallas_hybrid",
                 interpret=interpret)
    labels, nagg = _labels_from_roots_hybrid(hyb, r.in_set)
    phase = np.where(labels >= 0, 1, 0).astype(np.uint8)
    labels_j, phase_j = _cleanup_join_resident_hybrid(
        *parts, jnp.asarray(labels.astype(np.int32)), jnp.asarray(phase))
    labels, phase = np.asarray(labels_j), np.array(phase_j)
    labels, nagg = _finalize_singletons(labels, nagg, phase)
    return AggregationResult(labels.astype(np.int32), nagg, r.in_set, phase,
                             r.iterations, r.converged)


def _aggregate_two_phase_hybrid_impl(
        graph, options: Mis2Options | None = None,
        min_secondary_neighbors: int = 2,
        interpret=None) -> AggregationResult:
    """Algorithm 3 over the hybrid layout — never touches ``gh.ell``."""
    gh = as_graph(graph)
    hyb = gh.hybrid()
    parts = (tuple(hyb.slices), hyb.spill_rows, hyb.spill_seg, hyb.spill_cols)
    v = gh.num_vertices

    r1 = run_mis2(gh, options=options, engine="pallas_hybrid",
                  interpret=interpret)
    labels, nagg = _labels_from_roots_hybrid(hyb, r1.in_set)
    phase = np.where(labels >= 0, 1, 0).astype(np.uint8)
    total_iters = r1.iterations
    converged = r1.converged

    unagg = labels < 0
    roots2 = np.zeros(v, dtype=bool)
    if unagg.any():
        r2 = run_mis2(gh, active=jnp.asarray(unagg), options=options,
                      engine="pallas_hybrid", interpret=interpret)
        total_iters += r2.iterations
        converged = converged and r2.converged
        labels_j, roots2_j, newly_j = _phase2_join_resident_hybrid(
            *parts, jnp.asarray(labels.astype(np.int32)),
            jnp.asarray(r2.in_set), jnp.int32(nagg),
            min_secondary_neighbors)
        labels, roots2 = np.asarray(labels_j), np.asarray(roots2_j)
        phase[np.asarray(newly_j)] = 2
        nagg += int(roots2.sum())

    labels_j, phase_j = _phase3_resident_hybrid(
        *parts, jnp.asarray(labels.astype(np.int32)), jnp.asarray(phase))
    labels, phase = np.asarray(labels_j), np.array(phase_j)

    labels, nagg = _finalize_singletons(labels, nagg, phase)
    return AggregationResult(labels.astype(np.int32), nagg,
                             r1.in_set | roots2, phase, total_iters,
                             converged)


# ---------------------------------------------------------------------------
# Algorithm 2
# ---------------------------------------------------------------------------

def _aggregate_basic_impl(graph, options: Mis2Options | None = None,
                          engine: str = "compacted",
                          interpret=None, mesh=None,
                          axis=None) -> AggregationResult:
    if engine == "pallas_hybrid":
        return _aggregate_basic_hybrid_impl(graph, options,
                                            interpret=interpret)
    gh = as_graph(graph)
    ell = gh.ell
    r = run_mis2(gh, options=options, engine=engine, interpret=interpret,
                 mesh=mesh, axis=axis)
    labels, nagg = _labels_from_roots(ell, r.in_set)
    phase = np.where(labels >= 0, 1, 0).astype(np.uint8)

    # leftovers: join min adjacent aggregate (deterministic "arbitrary");
    # the whole multi-round loop is one resident dispatch
    labels_j, phase_j = _cleanup_join_resident(
        ell.neighbors, jnp.asarray(labels.astype(np.int32)),
        jnp.asarray(phase))
    # np.array (not asarray): _finalize_singletons mutates phase in place
    labels, phase = np.asarray(labels_j), np.array(phase_j)
    labels, nagg = _finalize_singletons(labels, nagg, phase)
    return AggregationResult(labels.astype(np.int32), nagg, r.in_set, phase,
                             r.iterations, r.converged)


# ---------------------------------------------------------------------------
# Algorithm 3
# ---------------------------------------------------------------------------

def _aggregate_two_phase_impl(graph, options: Mis2Options | None = None,
                              engine: str = "compacted",
                              min_secondary_neighbors: int = 2,
                              interpret=None, mesh=None,
                              axis=None) -> AggregationResult:
    if engine == "pallas_hybrid":
        return _aggregate_two_phase_hybrid_impl(
            graph, options, min_secondary_neighbors, interpret=interpret)
    gh = as_graph(graph)
    ell = gh.ell
    v = ell.num_vertices

    # Phase 1: MIS-2 roots + direct neighbors
    r1 = run_mis2(gh, options=options, engine=engine, interpret=interpret,
                  mesh=mesh, axis=axis)
    labels, nagg = _labels_from_roots(ell, r1.in_set)
    phase = np.where(labels >= 0, 1, 0).astype(np.uint8)
    total_iters = r1.iterations
    converged = r1.converged

    # Phase 2: MIS-2 on the induced unaggregated subgraph.  The label join
    # (unagg-neighbor count, secondary-root cumsum, root join) runs as one
    # resident dispatch instead of three label round trips.
    unagg = labels < 0
    roots2 = np.zeros(v, dtype=bool)
    if unagg.any():
        r2 = run_mis2(gh, active=jnp.asarray(unagg), options=options,
                      engine=engine, interpret=interpret, mesh=mesh,
                      axis=axis)
        total_iters += r2.iterations
        converged = converged and r2.converged
        labels_j, roots2_j, newly_j = _phase2_join_resident(
            ell.neighbors, ell.mask, jnp.asarray(labels.astype(np.int32)),
            jnp.asarray(r2.in_set), jnp.int32(nagg),
            min_secondary_neighbors)
        labels, roots2 = np.asarray(labels_j), np.asarray(roots2_j)
        phase[np.asarray(newly_j)] = 2
        nagg += int(roots2.sum())

    # Phase 3: max-coupling join against frozen tentative labels — the
    # whole up-to-4-round loop is one resident dispatch
    labels_j, phase_j = _phase3_resident(
        ell.neighbors, ell.mask, jnp.asarray(labels.astype(np.int32)),
        jnp.asarray(phase))
    labels, phase = np.asarray(labels_j), np.array(phase_j)

    labels, nagg = _finalize_singletons(labels, nagg, phase)
    return AggregationResult(labels.astype(np.int32), nagg,
                             r1.in_set | roots2, phase, total_iters,
                             converged)


# ---------------------------------------------------------------------------
# Algorithm 3, sharded (paper Alg. 2/3 rounds over the mesh — see core.dist)
# ---------------------------------------------------------------------------

def _aggregate_two_phase_distributed_impl(
        graph, options: Mis2Options | None = None,
        min_secondary_neighbors: int = 2, *, mesh=None, axis=None,
        single_gather: bool = False) -> AggregationResult:
    """Distributed ML-style coarsening: both MIS-2 phases run the sharded
    fixed point, and every label-propagation round (root join, unaggregated
    count, max-coupling phase 3) is one label all-gather + local rowwise
    join per round (V·4 bytes of collective traffic each).  Labels are
    bit-identical to the single-device ``two_phase`` engine: the sharded
    rounds share the exact rowwise arithmetic via the ``*_rows`` helpers.
    """
    from .dist import (
        _mis2_distributed_impl,
        _resolve_mesh,
        count_unagg_neighbors_distributed,
        join_adjacent_root_distributed,
        phase3_join_distributed,
        prepare_padded,
    )

    gh = as_graph(graph)
    v = gh.ell.num_vertices
    # pad + place the sharded adjacency ONCE for the whole pipeline (2
    # MIS-2 fixed points + up to ~6 label-propagation rounds reuse it);
    # ditto the replicated copy the single_gather schedule needs
    mesh, axis, _ = _resolve_mesh(mesh, axis)
    padded, _ = prepare_padded(gh, mesh, axis)
    dist_kw = {"mesh": mesh, "axis": axis, "padded": padded}
    mis2_kw = dict(dist_kw, single_gather=single_gather)
    if single_gather:
        import jax
        from jax.sharding import NamedSharding, PartitionSpec

        mis2_kw["neighbors_replicated"] = jax.device_put(
            padded.neighbors, NamedSharding(mesh, PartitionSpec()))

    # Phase 1: sharded MIS-2 roots + direct neighbors (sharded root join)
    r1 = _mis2_distributed_impl(gh, options=options, **mis2_kw)
    agg_ids = np.cumsum(r1.in_set) - 1
    root_label = np.where(r1.in_set, agg_ids, INT32_MAX).astype(np.int32)
    labels = join_adjacent_root_distributed(gh, root_label, **dist_kw)
    nagg = int(r1.in_set.sum())
    phase = np.where(labels >= 0, 1, 0).astype(np.uint8)
    total_iters = r1.iterations
    converged = r1.converged

    # Phase 2: sharded MIS-2 on the induced unaggregated subgraph
    unagg = labels < 0
    roots2 = np.zeros(v, dtype=bool)
    if unagg.any():
        r2 = _mis2_distributed_impl(gh, active=jnp.asarray(unagg),
                                    options=options, **mis2_kw)
        total_iters += r2.iterations
        converged = converged and r2.converged
        n_unagg_nbrs = count_unagg_neighbors_distributed(gh, labels, **dist_kw)
        roots2 = r2.in_set & (n_unagg_nbrs >= min_secondary_neighbors)
        if roots2.any():
            agg_ids2 = nagg + np.cumsum(roots2) - 1
            rl2 = np.where(roots2, agg_ids2, INT32_MAX).astype(np.int32)
            adj2 = join_adjacent_root_distributed(gh, rl2, **dist_kw)
            newly = (labels < 0) & (adj2 >= 0)
            labels = np.where(newly, adj2, labels)
            phase[newly] = 2
            nagg += int(roots2.sum())

    # Phase 3: sharded max-coupling join against frozen tentative labels
    rounds = 0
    while (labels < 0).any() and rounds < 4:
        aggsize = np.bincount(labels[labels >= 0], minlength=max(nagg, 1))
        new_labels = phase3_join_distributed(
            gh, labels.astype(np.int32), aggsize.astype(np.int32), **dist_kw)
        newly = (labels < 0) & (new_labels >= 0)
        phase[newly] = 3
        labels = new_labels
        rounds += 1

    labels, nagg = _finalize_singletons(labels, nagg, phase)
    return AggregationResult(labels.astype(np.int32), nagg,
                             r1.in_set | roots2, phase, total_iters,
                             converged)


def _finalize_singletons(labels: np.ndarray, nagg: int, phase: np.ndarray):
    """Isolated leftovers (no aggregated neighbor at all) become singletons."""
    left = np.flatnonzero(labels < 0)
    if len(left):
        labels = labels.copy()
        labels[left] = nagg + np.arange(len(left))
        phase[left] = 3
        nagg += len(left)
    return labels, nagg


# ---------------------------------------------------------------------------
# host-sequential reference (Table V "Serial Agg" stand-in)
# ---------------------------------------------------------------------------

def _aggregate_serial_greedy_impl(graph) -> AggregationResult:
    csr = as_graph(graph).csr
    indptr = np.asarray(csr.indptr)
    indices = np.asarray(csr.indices)
    v = csr.num_vertices
    labels = np.full(v, -1, dtype=np.int32)
    roots = np.zeros(v, dtype=bool)
    nagg = 0
    for u in range(v):
        if labels[u] >= 0:
            continue
        nbrs = indices[indptr[u]:indptr[u + 1]]
        nbrs = nbrs[nbrs != u]
        free = nbrs[labels[nbrs] < 0]
        if len(free) >= 2:
            labels[u] = nagg
            labels[free] = nagg
            roots[u] = True
            nagg += 1
    for u in range(v):   # cleanup: join first aggregated neighbor
        if labels[u] < 0:
            nbrs = indices[indptr[u]:indptr[u + 1]]
            agg = nbrs[labels[nbrs] >= 0]
            if len(agg):
                labels[u] = labels[agg[0]]
            else:
                labels[u] = nagg
                nagg += 1
    phase = np.ones(v, dtype=np.uint8)
    return AggregationResult(labels, nagg, roots, phase, 0)


# ---------------------------------------------------------------------------
# legacy public entry points (deprecated — use repro.api.coarsen)
# ---------------------------------------------------------------------------

def aggregate_basic(graph, options: Mis2Options | None = None,
                    engine: str = "compacted") -> AggregationResult:
    """Deprecated entry point — use ``repro.api.coarsen(method="basic")``."""
    warn_deprecated("repro.core.aggregation.aggregate_basic",
                    'repro.api.coarsen(..., method="basic")')
    return _aggregate_basic_impl(graph, options, engine)


def aggregate_two_phase(graph, options: Mis2Options | None = None,
                        engine: str = "compacted",
                        min_secondary_neighbors: int = 2) -> AggregationResult:
    """Deprecated entry point — use ``repro.api.coarsen(method="two_phase")``."""
    warn_deprecated("repro.core.aggregation.aggregate_two_phase",
                    'repro.api.coarsen(..., method="two_phase")')
    return _aggregate_two_phase_impl(graph, options, engine,
                                     min_secondary_neighbors)


def aggregate_serial_greedy(graph) -> AggregationResult:
    """Deprecated entry point — use ``repro.api.coarsen(method="serial")``."""
    warn_deprecated("repro.core.aggregation.aggregate_serial_greedy",
                    'repro.api.coarsen(..., method="serial")')
    return _aggregate_serial_greedy_impl(graph)
