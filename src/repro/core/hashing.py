"""Deterministic pseudo-random priorities (paper §V-A).

The paper uses Marsaglia 64-bit hashes: ``h(iter, v) = f(f(iter) ^ f(v))``
with ``f`` either xorshift64 ("Xor Hash", shown to be *worse* than fixed
priorities) or xorshift64* ("Xor* Hash", the production choice).

TPU adaptation (DESIGN.md §3): TPUs have no native 64-bit integers, so all
64-bit arithmetic is emulated on uint32 limb pairs ``(hi, lo)`` — xor/shift
are limbwise, and the xorshift* multiply uses 16-bit partial products.  This
is bit-exact with the reference 64-bit math (tested against numpy uint64) and
lowers to plain VPU ops.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
_MASK16 = np.uint32(0xFFFF)

# xorshift64* multiplier, Vigna / Marsaglia
_MUL_HI = np.uint32(0x2545F491)
_MUL_LO = np.uint32(0x4F6CDD1D)


class U64(NamedTuple):
    hi: jnp.ndarray
    lo: jnp.ndarray


def u64(x) -> U64:
    """Lift uint32 array (or python int) to a u64 limb pair."""
    x = jnp.asarray(x, dtype=U32)
    return U64(jnp.zeros_like(x), x)


def _xor(a: U64, b: U64) -> U64:
    return U64(a.hi ^ b.hi, a.lo ^ b.lo)


def _shr(a: U64, n: int) -> U64:
    n = int(n)
    if n == 0:
        return a
    if n >= 32:
        return U64(jnp.zeros_like(a.hi), a.hi >> U32(n - 32) if n > 32 else a.hi)
    return U64(a.hi >> U32(n), (a.lo >> U32(n)) | (a.hi << U32(32 - n)))


def _shl(a: U64, n: int) -> U64:
    n = int(n)
    if n == 0:
        return a
    if n >= 32:
        return U64(a.lo << U32(n - 32) if n > 32 else a.lo, jnp.zeros_like(a.lo))
    return U64((a.hi << U32(n)) | (a.lo >> U32(32 - n)), a.lo << U32(n))


def _mulhi32(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """High 32 bits of a 32x32 -> 64 product, via 16-bit partials."""
    al, ah = a & _MASK16, a >> U32(16)
    bl, bh = b & _MASK16, b >> U32(16)
    ll = al * bl
    lh = al * bh
    hl = ah * bl
    hh = ah * bh
    mid = (ll >> U32(16)) + (lh & _MASK16) + (hl & _MASK16)
    return hh + (lh >> U32(16)) + (hl >> U32(16)) + (mid >> U32(16))


def _mul64(a: U64, mhi: np.uint32, mlo: np.uint32) -> U64:
    """(a * m) mod 2^64 with constant multiplier m = (mhi, mlo)."""
    lo = a.lo * mlo
    hi = _mulhi32(a.lo, jnp.full_like(a.lo, mlo)) + a.hi * mlo + a.lo * mhi
    return U64(hi, lo)


def xorshift64(x: U64) -> U64:
    """Marsaglia xorshift64 (13, 7, 17 triple)."""
    x = _xor(x, _shl(x, 13))
    x = _xor(x, _shr(x, 7))
    x = _xor(x, _shl(x, 17))
    return x


def xorshift64_star(x: U64) -> U64:
    """xorshift64* = xorshift (12, 25, 27) then multiply (Vigna)."""
    x = _xor(x, _shr(x, 12))
    x = _xor(x, _shl(x, 25))
    x = _xor(x, _shr(x, 27))
    return _mul64(x, _MUL_HI, _MUL_LO)


def _combine(f, iteration, vertex_ids: jnp.ndarray) -> jnp.ndarray:
    """h(iter, v) = f(f(iter+1) ^ f(v+1)); returns the *high* 32 bits.

    +1 offsets keep the all-zero fixed point of xorshift out of the domain.
    """
    it = f(u64(jnp.asarray(iteration, dtype=U32) + U32(1)))
    it = U64(jnp.broadcast_to(it.hi, vertex_ids.shape),
             jnp.broadcast_to(it.lo, vertex_ids.shape))
    vx = f(u64(vertex_ids.astype(U32) + U32(1)))
    out = f(_xor(it, vx))
    return out.hi


def priorities_xorshift_star(iteration, vertex_ids: jnp.ndarray) -> jnp.ndarray:
    """The paper's production hash ('Xor* Hash')."""
    return _combine(xorshift64_star, iteration, vertex_ids)


def priorities_xorshift(iteration, vertex_ids: jnp.ndarray) -> jnp.ndarray:
    """'Xor Hash' — kept for the Table I comparison (it is *worse*)."""
    return _combine(xorshift64, iteration, vertex_ids)


def priorities_fixed(iteration, vertex_ids: jnp.ndarray) -> jnp.ndarray:
    """Bell-style fixed priorities: hashed once, ignoring the iteration."""
    del iteration
    return _combine(xorshift64_star, 0, vertex_ids)


PRIORITY_FNS = {
    "xorshift_star": priorities_xorshift_star,
    "xorshift": priorities_xorshift,
    "fixed": priorities_fixed,
}


# ---------------------------------------------------------------------------
# numpy uint64 oracle (for bit-exactness tests of the limb emulation)
# ---------------------------------------------------------------------------

def _np_xorshift64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    x ^= np.left_shift(x, np.uint64(13))
    x ^= np.right_shift(x, np.uint64(7))
    x ^= np.left_shift(x, np.uint64(17))
    return x


def _np_xorshift64_star(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    x ^= np.right_shift(x, np.uint64(12))
    x ^= np.left_shift(x, np.uint64(25))
    x ^= np.right_shift(x, np.uint64(27))
    return x * np.uint64(0x2545F4914F6CDD1D)


def np_priorities(kind: str, iteration: int, vertex_ids: np.ndarray) -> np.ndarray:
    f = {"xorshift": _np_xorshift64, "xorshift_star": _np_xorshift64_star,
         "fixed": _np_xorshift64_star}[kind]
    it = 0 if kind == "fixed" else iteration
    with np.errstate(over="ignore"):
        h = f(f(np.uint64(it + 1)) ^ f(vertex_ids.astype(np.uint64) + np.uint64(1)))
    return (h >> np.uint64(32)).astype(np.uint32)
