"""General distance-k MIS (Bell/Dalton/Olson formulation) — the paper's
baseline computes MIS-k for arbitrary k>=1 by k-fold min-propagation; the
paper's Algorithm 1 is the k=2 specialization.  We provide the general
version for completeness (k=1 gives Luby-style MIS-1; k=2 must agree with
Algorithm 1's *invariants*, asserted in tests).

Semantics per iteration (fresh priorities, like Alg. 1):
  M^0 = T;  M^j_v = min_{w in N[v]} M^(j-1)_w  (j = 1..k)
  v IN  if T_v == M^k_v  (v is the minimum of its distance-k neighborhood)
  v OUT if M^k_v is IN-adjacent (an IN vertex within distance k)
The IN-poisoning trick generalizes: after deciding IN vertices, propagate
OUT-ness k hops so every vertex within distance k of an IN is removed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .._compat import warn_deprecated
from ..graphs.handle import as_ell_graph
from .hashing import PRIORITY_FNS
from .mis2 import Mis2Result
from .tuples import IN, OUT, id_bits, is_undecided, pack


@functools.partial(jax.jit, static_argnames=("k", "priority", "max_iters"))
def _misk_fixpoint(neighbors, k: int, priority: str, max_iters: int):
    v = neighbors.shape[0]
    b = id_bits(v)
    vids = jnp.arange(v, dtype=jnp.uint32)
    prio_fn = PRIORITY_FNS[priority]
    t0 = jnp.full((v,), jnp.uint32(1))

    def cond(state):
        t, it = state
        return jnp.any(is_undecided(t)) & (it < max_iters)

    def body(state):
        t, it = state
        und = is_undecided(t)
        t = jnp.where(und, pack(prio_fn(it, vids), vids, b), t)
        # k-fold closed-neighborhood min
        m = t
        for _ in range(k):
            m = jnp.min(m[neighbors], axis=1)
        new_in = und & (m == t)
        t = jnp.where(new_in, IN, t)
        # propagate OUT-ness k hops from IN vertices
        near_in = (t == IN)
        for _ in range(k):
            near_in = jnp.any(near_in[neighbors], axis=1) | near_in
        t = jnp.where(is_undecided(t) & near_in, OUT, t)
        return t, it + 1

    t, iters = jax.lax.while_loop(cond, body, (t0, jnp.uint32(0)))
    return t, iters


def _mis_k_impl(graph, k: int = 2, priority: str = "xorshift_star",
                max_iters: int = 256) -> Mis2Result:
    if k < 1:
        raise ValueError("k >= 1")
    ell = as_ell_graph(graph)
    t, iters = _misk_fixpoint(ell.neighbors, k, priority, max_iters)
    t_np = np.asarray(t)
    und = (t_np != np.uint32(IN)) & (t_np != np.uint32(OUT))
    return Mis2Result(t_np == np.uint32(IN), int(iters), not und.any())


# ---------------------------------------------------------------------------
# resident engine (the PR-4 hot-loop pattern applied to distance-k): the
# same per-round arithmetic, but the row refresh runs through an on-device
# compacted worklist (sentinel-V scatter-drop) and the whole fixed point is
# one jitted dispatch accounted in HOTLOOP_STATS — bit-identical to the
# dense engine (the refresh scatter touches exactly the undecided set).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "priority", "max_iters"))
def _misk_resident_fixpoint(neighbors, k: int, priority: str, max_iters: int):
    from .mis2 import compact_worklist

    v = neighbors.shape[0]
    b = id_bits(v)
    prio_fn = PRIORITY_FNS[priority]
    t0 = jnp.full((v,), jnp.uint32(1))
    wl0, n0 = compact_worklist(is_undecided(t0))

    def cond(state):
        _, _, n, it = state
        return (n > 0) & (it < max_iters)

    def body(state):
        t, wl, _, it = state
        rows = jnp.clip(wl, 0, v - 1)
        ids = rows.astype(jnp.uint32)
        told = t[rows]
        newt = pack(prio_fn(it, ids), ids, b)
        newt = jnp.where(is_undecided(told), newt, told)
        t = t.at[wl].set(newt, mode="drop")
        # k-fold closed-neighborhood min
        m = t
        for _ in range(k):
            m = jnp.min(m[neighbors], axis=1)
        new_in = is_undecided(t) & (m == t)
        t = jnp.where(new_in, IN, t)
        # propagate OUT-ness k hops from IN vertices
        near_in = (t == IN)
        for _ in range(k):
            near_in = jnp.any(near_in[neighbors], axis=1) | near_in
        t = jnp.where(is_undecided(t) & near_in, OUT, t)
        wl, n = compact_worklist(is_undecided(t))
        return t, wl, n, it + jnp.uint32(1)

    t, _, n, iters = jax.lax.while_loop(cond, body, (t0, wl0, n0,
                                                     jnp.uint32(0)))
    return t, iters, n


def _misk_resident_impl(graph, k: int = 2, priority: str = "xorshift_star",
                        max_iters: int = 256) -> Mis2Result:
    """Engine entry for ``misk: resident`` — one jitted dispatch per solve
    (counted in ``mis2.resident_dispatches``)."""
    from ..obs import metrics as _obs
    from .mis2 import HotLoopStats

    if k < 1:
        raise ValueError("k >= 1")
    ell = as_ell_graph(graph)
    t, iters, n = _misk_resident_fixpoint(ell.neighbors, k, priority,
                                          max_iters)
    _obs.counter(HotLoopStats._DISPATCHES).inc()
    t_np = np.asarray(t)
    return Mis2Result(t_np == np.uint32(IN), int(iters), int(n) == 0,
                      num_compiles=1)


def mis_k(graph, k: int = 2, priority: str = "xorshift_star",
          max_iters: int = 256) -> Mis2Result:
    """Distance-k maximal independent set (deterministic, jitted).

    Deprecated entry point — use :func:`repro.api.misk`."""
    warn_deprecated("repro.core.misk.mis_k", "repro.api.misk")
    return _mis_k_impl(graph, k, priority, max_iters)
