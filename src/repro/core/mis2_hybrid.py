"""Device-resident MIS-2 over the hybrid (sliced-ELL + COO spill) layout.

This is the ``mis2: pallas_hybrid`` engine: the PR 4 resident
``lax.while_loop`` (one dispatch, zero in-loop host syncs, on-device
worklist compaction), re-plumbed for the degree-aware layout of
``graphs.hybrid``.  Each round unrolls statically over the layout's
degree-bucket slices — one fused Pallas pass per slice per phase, the
slice worklist compacted on device from the global live/undecided masks —
and finishes the heavy-hitter rows with XLA segment reductions over the
sorted-COO spill.  Because every vertex lives in exactly one slice or the
spill, the per-partition scatters into the global ``[V]`` T/M state are
disjoint, and because refresh/decide of a row depend only on global state
reads plus that row's own adjacency, the final T is **bit-identical** to
the monolithic engines (``dense``, ``pallas_resident``) for equal options
— the standing digest-parity gate extends over adversarial degree
distributions in ``tests/test_hybrid.py``.

Traffic accounting: the loop state carries one int32 counter per slice
(live worklist rows processed, both phases), and the spill contributes
two segment sweeps per round.  The ``ELL_ROW_TRAFFIC``-style model
(``kernels.minprop_ell.ops.hybrid_row_traffic_bytes``) converts those
counts to bytes; the engine mirrors the total into the ``repro.obs``
registry (``mis2.hybrid_row_bytes``) and onto the result, and the
``hybrid_traffic`` gate in ``tools/check_shape.py`` asserts all three
agree.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.handle import as_graph
from ..obs import metrics as _OBS
from ..obs import span as _obs_span
from .mis2 import (
    U32MAX,
    HotLoopStats,
    Mis2Options,
    Mis2Result,
    compact_worklist,
)
from .tuples import IN, id_bits, is_undecided

HYBRID_ROW_BYTES = "mis2.hybrid_row_bytes"


@functools.partial(jax.jit, static_argnames=(
    "priority", "max_iters", "b", "interpret"))
def _hybrid_fixed_point(slices, spill_rows, spill_seg, spill_cols, active,
                        *, priority: str, max_iters: int, b: int,
                        interpret: bool = True):
    """One jitted while_loop over global [V] state; rounds unroll over the
    slices (static: one compiled Pallas body per slice shape) and close
    with the spill segment passes.  Returns ``(t, iterations, undecided,
    slice_rows_processed)``."""
    from ..kernels.minprop_ell import ops as minprop_ops

    v = active.shape[0]
    num_slices = len(slices)
    h = spill_rows.shape[0]

    t0 = jnp.where(active, jnp.uint32(1), U32MAX)
    m0 = jnp.full(v, U32MAX, dtype=jnp.uint32)
    und0 = jnp.asarray(active)
    live0 = jnp.ones(v, dtype=bool)          # iteration 0: refresh every row
    acc0 = jnp.zeros(max(num_slices, 1), dtype=jnp.int32)
    state0 = (t0, m0, und0, live0, jnp.sum(und0, dtype=jnp.int32),
              jnp.uint32(0), acc0)

    def cond(state):
        _, _, _, _, n1, it, _ = state
        return (n1 > 0) & (it < max_iters)

    def body(state):
        t, m, und, live, _, it, acc = state
        # phase 1: M <- poisoned closed min, per slice then spill.  All
        # refresh passes read the same pre-round T (only M is written), so
        # partition order is immaterial.
        for i, sl in enumerate(slices):
            wl2, n2 = compact_worklist(live[sl.rows])
            m = minprop_ops.sliced_refresh_columns(
                t, m, sl.rows, sl.neighbors.reshape(-1), wl2, n2, it,
                priority=priority, b=b, d=sl.neighbors.shape[1],
                interpret=interpret)
            acc = acc.at[i].add(n2)
        if h > 0:
            m = minprop_ops.spill_refresh_columns(
                t, m, spill_rows, spill_seg, spill_cols, live, it,
                priority=priority, b=b)
        # phase 2: T <- IN/OUT decision.  Decide reads T only at its own
        # partition's rows and writes the same rows, so the per-slice
        # scatters never observe each other.
        for i, sl in enumerate(slices):
            wl1, n1_i = compact_worklist(und[sl.rows])
            t = minprop_ops.sliced_decide(
                t, m, active, sl.rows, sl.neighbors.reshape(-1), wl1, n1_i,
                it, priority=priority, b=b, d=sl.neighbors.shape[1],
                interpret=interpret)
            acc = acc.at[i].add(n1_i)
        if h > 0:
            t = minprop_ops.spill_decide(
                t, m, active, spill_rows, spill_seg, spill_cols, it,
                priority=priority, b=b)
        und = is_undecided(t)
        live = m != U32MAX
        return (t, m, und, live, jnp.sum(und, dtype=jnp.int32),
                it + jnp.uint32(1), acc)

    t, _, _, _, n1, it, acc = jax.lax.while_loop(cond, body, state0)
    return t, it, n1, acc


def _mis2_hybrid_impl(graph, active: Optional[np.ndarray] = None,
                      options: Optional[Mis2Options] = None, *,
                      interpret: Optional[bool] = None) -> Mis2Result:
    """Engine entry for ``pallas_hybrid``: one dispatch per solve over the
    degree-aware layout; works where the monolithic padded ELL cannot even
    be allocated."""
    from ..kernels._interpret import resolve_interpret
    from ..kernels.minprop_ell.ops import hybrid_row_traffic_bytes

    options = Mis2Options() if options is None else options
    if not options.worklists:
        raise ValueError(
            "pallas_hybrid implements §V-B worklist compaction by "
            "construction; use engine='dense' for the no-worklist ablation")
    if not (options.packed and options.layout == "ell"):
        raise ValueError(
            "pallas_hybrid requires packed tuples + the ELL-family layout "
            "(the hybrid format is a degree-bucketed ELL)")

    gh = as_graph(graph)
    hyb = gh.hybrid()
    v = hyb.num_vertices
    active_j = jnp.ones(v, dtype=bool) if active is None \
        else jnp.asarray(active)
    b = id_bits(v)
    interp = resolve_interpret(interpret)

    with _obs_span("mis2.hybrid_fixed_point", layout="hybrid",
                   num_slices=hyb.num_slices,
                   spill_rows=hyb.num_spill_rows, v=v) as sp:
        t, it, n1, acc = _hybrid_fixed_point(
            hyb.slices, hyb.spill_rows, hyb.spill_seg, hyb.spill_cols,
            active_j, priority=options.priority, max_iters=options.max_iters,
            b=b, interpret=interp)
        _OBS.counter(HotLoopStats._DISPATCHES).inc()
        jax.block_until_ready(t)    # span duration covers device execution
        sp.annotate(iterations=int(it))

    iterations = int(it)
    rows_processed = [int(x) for x in np.asarray(acc)[:hyb.num_slices]]
    spill_passes = 2 * iterations if hyb.num_spill_rows else 0
    row_bytes = hybrid_row_traffic_bytes(
        hyb.slice_widths, rows_processed, hyb.num_spill_entries, spill_passes)
    _OBS.counter(HYBRID_ROW_BYTES).inc(row_bytes)

    t_np = np.asarray(t)
    return Mis2Result(
        t_np == np.uint32(IN), iterations, int(n1) == 0,
        collectives={
            "variant": "hybrid",
            "row_bytes_total": row_bytes,
            "slice_widths": list(hyb.slice_widths),
            "slice_rows_processed": rows_processed,
            "spill_entries": hyb.num_spill_entries,
            "spill_passes": spill_passes,
        },
        num_compiles=1)
