"""Parallel, deterministic distance-2 maximal independent set (paper Alg. 1).

Three execution strategies, bit-identical results:

* ``mis2_dense``  — a single ``lax.while_loop`` fixed point over dense vertex
  arrays.  Fully jittable, usable inside larger jitted programs (distributed
  MIS-2, dry-run lowering).  Worklists degenerate to masks here: on a vector
  machine masked lanes cost bandwidth, not serialization (DESIGN.md §3).
* ``mis2_compacted`` — host-orchestrated iteration with *real* worklist
  compaction (paper §V-B): per-iteration work is proportional to the live
  worklists, padded to power-of-two buckets so XLA caches a handful of
  compiled step sizes.  This is the legacy host-driven path and the engine
  behind the Fig. 2 ablation.
* ``compacted_resident`` / ``pallas_resident`` — the production hot loop:
  the *same* per-round passes as ``mis2_compacted``, but the whole fixed
  point is one jitted ``lax.while_loop`` over fixed ``[V]``-shaped state.
  Worklists are compacted **on device** (cumsum-based stream compaction
  producing ``(indices[V], count)`` pairs; dead slots hold the sentinel
  ``V`` and are scatter-dropped), and the live ``count`` feeds the Pallas
  ``pl.when`` block-skip logic instead of a host-side ``len(wl)``.  Zero
  host round-trips inside the fixed point, one dispatch per solve, no jit
  churn across worklist sizes — and results stay bit-identical to the
  host-driven engines (enforced by the digest-parity matrix in
  ``tests/test_resident.py``).

The Fig. 2 optimization chain is exposed through ``Mis2Options`` — each knob
is one of the paper's four optimizations:

=================  =========================================================
``priority``       §V-A fresh pseudo-random priorities (fixed | xorshift |
                   xorshift_star)
``worklists``      §V-B worklist compaction
``packed``         §V-C compressed 32-bit status tuples (False = 3-field
                   tuples: status uint8 / rand uint32 / id uint32 — the
                   unpacked lexicographic min costs three reduction passes)
``layout``         §V-D 'ell' = padded lane-aligned gathers (TPU analogue of
                   warp-coalesced rows) | 'csr_segment' = segment reductions
=================  =========================================================

Cumulative chain reproduced by ``benchmarks/fig2_optimizations.py``:
baseline(Bell: fixed, no worklists, unpacked, csr) -> +priorities ->
+worklists -> +packed -> +ELL('SIMD') == production defaults.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .._compat import warn_deprecated
from ..graphs.handle import as_graph
from ..obs import metrics as _OBS
from ..obs import span as _obs_span
from .hashing import PRIORITY_FNS
from .tuples import IN, OUT, effective_priority, id_bits, is_undecided, pack

MAX_ITERS_DEFAULT = 128

U32MAX = np.uint32(0xFFFFFFFF)
S_IN, S_UND, S_OUT = np.uint8(0), np.uint8(1), np.uint8(2)


@dataclass(frozen=True)
class Mis2Options:
    priority: str = "xorshift_star"     # fixed | xorshift | xorshift_star
    worklists: bool = True              # §V-B
    packed: bool = True                 # §V-C
    layout: str = "ell"                 # ell | csr_segment  (§V-D)
    max_iters: int = MAX_ITERS_DEFAULT
    use_pallas: bool = False            # deprecated: use engine="pallas"

    def __post_init__(self):
        if self.use_pallas:
            warn_deprecated("Mis2Options(use_pallas=True)",
                            'repro.api.mis2(..., engine="pallas")')


@dataclass
class Mis2Result:
    in_set: np.ndarray        # bool [V]
    iterations: int
    converged: bool
    collectives: Optional[dict] = None  # distributed engines: §V-C traffic
    num_compiles: Optional[int] = None  # distinct jitted step shapes this
    #                                     solve required (resident: always 1;
    #                                     legacy compacted: pow2 bucket pairs)

    def __post_init__(self):
        # Result-protocol guarantee: payloads are host numpy arrays
        # regardless of which engine produced them.
        self.in_set = np.asarray(self.in_set)

    @property
    def size(self) -> int:
        return int(self.in_set.sum())


# ===========================================================================
# dense (fully jitted) engine — packed tuples, ELL layout
# ===========================================================================

def mis2_dense_fixed_point(neighbors: jnp.ndarray, active: jnp.ndarray,
                           b: jnp.ndarray, priority: str = "xorshift_star",
                           max_iters: int = MAX_ITERS_DEFAULT):
    """Mask-aware MIS-2 fixed point over one (possibly padded) graph.

    ``b`` is the packing id-bit count as a *traced* uint32 scalar rather
    than a Python int derived from ``neighbors.shape[0]``.  That makes the
    function vmappable over stacked ``[B, rows, deg]`` buckets whose member
    graphs have different real vertex counts: each graph keeps its own
    ``b = id_bits(V_real)``, so priorities — and therefore the resulting
    set — are bit-identical to the single-graph run at shape ``[V_real]``.
    Padded rows ride along inactive (T pinned to OUT, self-loop adjacency)
    and cannot influence real rows.

    The iteration counter doubles as the §V-A priority round, so it only
    advances while this graph still has undecided vertices — under vmap a
    converged graph stops counting (and its state is a fixed point of
    ``body``) while its bucket mates continue.
    """
    v = neighbors.shape[0]
    vids = jnp.arange(v, dtype=jnp.uint32)
    prio_fn = PRIORITY_FNS[priority]

    # inactive vertices are invisible: T pinned to OUT, never refreshed
    t0 = jnp.where(active, jnp.uint32(1), OUT)

    def cond(state):
        t, it = state
        return jnp.any(is_undecided(t) & active) & (it < max_iters)

    def body(state):
        t, it = state
        und = is_undecided(t) & active
        live = jnp.any(und)
        # refresh row (§V-A)
        t = jnp.where(und, pack(prio_fn(it, vids), vids, b), t)
        # refresh column: closed-neighborhood min (§V-D layout)
        tn = t[neighbors]                       # [V, D]
        m = jnp.min(tn, axis=1)
        m = jnp.where(m == IN, OUT, m)          # IN-adjacent poison
        # decide (distance-2 via neighbors' minima)
        mn = m[neighbors]                       # [V, D]
        an = active[neighbors]
        any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
        all_eq = jnp.all(jnp.where(an, mn, t[:, None]) == t[:, None], axis=1)
        t = jnp.where(und & any_out, OUT, t)
        t = jnp.where(und & ~any_out & all_eq, IN, t)
        return t, it + live.astype(jnp.uint32)

    t, iters = jax.lax.while_loop(cond, body, (t0, jnp.uint32(0)))
    return t, iters


@functools.partial(jax.jit, static_argnames=("priority", "max_iters"))
def mis2_dense_jittable(neighbors: jnp.ndarray, active: jnp.ndarray,
                        priority: str = "xorshift_star",
                        max_iters: int = MAX_ITERS_DEFAULT):
    """Core fixed point; returns (packed tuple vector T, iterations).

    Safe to call inside larger jitted programs (e.g. AMG setup dry-runs).
    """
    b = jnp.uint32(id_bits(neighbors.shape[0]))
    return mis2_dense_fixed_point(neighbors, active, b, priority, max_iters)


def _mis2_dense_impl(graph, active: Optional[jnp.ndarray] = None,
                     options: Optional[Mis2Options] = None) -> Mis2Result:
    options = Mis2Options() if options is None else options
    ell = as_graph(graph).ell
    v = ell.num_vertices
    if active is None:
        active = jnp.ones(v, dtype=bool)
    else:
        active = jnp.asarray(active)
    t, iters = mis2_dense_jittable(ell.neighbors, active,
                                   options.priority, options.max_iters)
    t_np = np.asarray(t)
    act_np = np.asarray(active)
    undecided = is_undecided(t_np) & act_np
    return Mis2Result(t_np == np.uint32(IN), int(iters), not undecided.any())


# ===========================================================================
# incremental repair (repro.serve streaming mode)
# ===========================================================================

@functools.partial(jax.jit, static_argnames=("priority", "max_iters"))
def mis2_repair_fixed_point(neighbors: jnp.ndarray, t_init: jnp.ndarray,
                            b: jnp.ndarray, priority: str = "fixed",
                            max_iters: int = MAX_ITERS_DEFAULT):
    """Warm-started MIS-2 fixed point: the dense body, seeded from a prior
    solution instead of all-undecided.

    ``t_init`` holds ``IN`` / ``OUT`` on *frozen* vertices (carried over
    from the pre-delta solution) and the undecided seed ``1`` on the
    reactivated region.  Frozen vertices are never refreshed — frozen
    ``IN`` poisons its distance-2 neighborhood exactly like a decided
    vertex mid-run, frozen ``OUT`` is invisible (the same encoding the
    dense engine uses for inactive rows) — so per-round work is
    proportional to the reactivated region, not ``V``.

    Only meaningful with a round-independent priority (``"fixed"``): the
    result is then the unique lexicographically-first MIS-2, so a repaired
    solution that satisfies the lex-first recurrence everywhere (see
    :func:`lexfirst_violations`) is *bit-identical* to a from-scratch run.
    Round-varying priorities make the fixed point history-dependent and
    repair inexact; ``repro.serve`` falls back to recomputation there.
    """
    vids = jnp.arange(neighbors.shape[0], dtype=jnp.uint32)
    prio_fn = PRIORITY_FNS[priority]

    def cond(state):
        t, it = state
        return jnp.any(is_undecided(t)) & (it < max_iters)

    def body(state):
        t, it = state
        und = is_undecided(t)
        live = jnp.any(und)
        t = jnp.where(und, pack(prio_fn(it, vids), vids, b), t)
        tn = t[neighbors]
        m = jnp.min(tn, axis=1)
        m = jnp.where(m == IN, OUT, m)
        mn = m[neighbors]
        any_out = jnp.any(mn == OUT, axis=1)
        all_eq = jnp.all(mn == t[:, None], axis=1)
        t = jnp.where(und & any_out, OUT, t)
        t = jnp.where(und & ~any_out & all_eq, IN, t)
        return t, it + live.astype(jnp.uint32)

    return jax.lax.while_loop(cond, body, (t_init, jnp.uint32(0)))


@jax.jit
def lexfirst_violations(neighbors: jnp.ndarray, in_set: jnp.ndarray,
                        p: jnp.ndarray) -> jnp.ndarray:
    """Vertices violating the lex-first MIS-2 recurrence (bool ``[V]``).

    The lexicographically-first MIS-2 under the packed priority total
    order ``p`` is the unique assignment with: ``v IN`` iff no member
    within distance <= 2 has strictly smaller priority.  Two closed-
    neighborhood min-propagations of the members' priorities check it
    globally: ``m2[v]`` is the smallest member priority within distance 2
    of ``v`` (inclusive), so ``v IN`` must see ``m2 == p[v]`` (itself) and
    ``v OUT`` must see ``m2 < p[v]`` (a strictly earlier member justifies
    the exclusion — this also covers maximality: no member at all means
    ``m2 == OUT > p[v]``).  An all-clear certifies the assignment *is*
    the lex-first solution; violations tell the repair loop which frozen
    vertices to reactivate.
    """
    pin = jnp.where(in_set, p, OUT)
    m1 = jnp.minimum(jnp.min(pin[neighbors], axis=1), pin)
    m2 = jnp.minimum(jnp.min(m1[neighbors], axis=1), m1)
    return ~jnp.where(in_set, m2 == p, m2 < p)


def fixed_packed_priorities(num_vertices: int) -> jnp.ndarray:
    """The packed ``"fixed"``-priority total order (uint32 ``[V]``) — the
    order under which the MIS-2 fixed point computes the lex-first set."""
    vids = jnp.arange(num_vertices, dtype=jnp.uint32)
    b = jnp.uint32(id_bits(num_vertices))
    return pack(PRIORITY_FNS["fixed"](jnp.uint32(0), vids), vids, b)


# ===========================================================================
# hot-loop accounting (test-only observability; no effect on results)
# ===========================================================================

class HotLoopStats:
    """Compatibility view over the MIS-2 hot-loop registry counters.

    ``host_syncs`` counts device->host transfers issued *inside* a fixed
    point (the legacy compacted driver pays 2 per iteration to rebuild its
    worklists); ``resident_dispatches`` counts whole-fixed-point jitted
    dispatches (the resident engines pay exactly 1 per solve).

    The numbers live in the process-wide :mod:`repro.obs` registry
    (``mis2.host_syncs`` / ``mis2.resident_dispatches``), so one
    ``obs.snapshot()`` sees them alongside every other subsystem; this
    shim keeps the legacy attribute surface (including ``+=`` writes)
    working.  Tests should prefer ``obs.capture()`` over :meth:`reset` —
    capture is scoped, reset is process-global and order-dependent.
    """

    _SYNCS = "mis2.host_syncs"
    _DISPATCHES = "mis2.resident_dispatches"

    @property
    def host_syncs(self) -> int:
        return int(_OBS.counter(self._SYNCS).value)

    @host_syncs.setter
    def host_syncs(self, v: int) -> None:
        _OBS.counter(self._SYNCS).set_(v)

    @property
    def resident_dispatches(self) -> int:
        return int(_OBS.counter(self._DISPATCHES).value)

    @resident_dispatches.setter
    def resident_dispatches(self, v: int) -> None:
        _OBS.counter(self._DISPATCHES).set_(v)

    def reset(self) -> None:
        _OBS.reset(self._SYNCS)
        _OBS.reset(self._DISPATCHES)


HOTLOOP_STATS = HotLoopStats()


# ===========================================================================
# step kernels for the compacted / ablation engine
#   worklists are padded int32 index buffers; sentinel == V (scatter-dropped)
# ===========================================================================

def _bucket(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _pad_worklist(idx: np.ndarray, v: int) -> jnp.ndarray:
    size = _bucket(len(idx))
    out = np.full(size, v, dtype=np.int32)
    out[: len(idx)] = idx
    return jnp.asarray(out)


class _WorklistPadCache:
    """Per-solve bucket-shape cache for the host-driven driver.

    ``shape_pairs`` records the distinct ``(len(wl1), len(wl2))`` pow2
    bucket pairs the solve dispatched — the jit-churn metric surfaced as
    ``Mis2Result.num_compiles`` (each new pair is a fresh XLA
    specialization of the step kernels; the resident engines hold this at
    1 by construction).  Conversion itself stays :func:`_pad_worklist`
    with a fresh host buffer per call: staging through a reused mutable
    buffer is unsafe, because ``jnp.asarray`` of an aligned numpy array
    can be zero-copy on CPU, and a later refill would silently rewrite
    the live device worklist.
    """

    def __init__(self, v: int):
        self.v = v
        self.shape_pairs: set[tuple[int, int]] = set()

    def pad(self, idx: np.ndarray) -> jnp.ndarray:
        return _pad_worklist(idx, self.v)


# ---- packed representation ----

@functools.partial(jax.jit, static_argnames=("priority", "b"))
def _refresh_rows_packed(t, wl1, it, priority: str, b: int):
    v = t.shape[0]
    rows = jnp.clip(wl1, 0, v - 1)
    ids = rows.astype(jnp.uint32)
    told = t[rows]
    newt = pack(PRIORITY_FNS[priority](it, ids), ids, b)
    newt = jnp.where(is_undecided(told), newt, told)   # idempotent on decided
    return t.at[wl1].set(newt, mode="drop")


@jax.jit
def _refresh_cols_packed_ell(t, m, wl2, neighbors):
    v = neighbors.shape[0]
    rows = jnp.clip(wl2, 0, v - 1)
    tn = t[neighbors[rows]]
    mv = jnp.min(tn, axis=1)
    mv = jnp.where(mv == IN, OUT, mv)
    return m.at[wl2].set(mv, mode="drop")


@jax.jit
def _decide_packed_ell(t, m, wl1, neighbors, active):
    v = neighbors.shape[0]
    rows = jnp.clip(wl1, 0, v - 1)
    nb = neighbors[rows]
    mn = m[nb]
    an = active[nb]
    tv = t[rows]
    any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
    all_eq = jnp.all(jnp.where(an, mn, tv[:, None]) == tv[:, None], axis=1)
    newt = jnp.where(any_out, OUT, jnp.where(all_eq, IN, tv))
    newt = jnp.where(is_undecided(tv), newt, tv)
    return t.at[wl1].set(newt, mode="drop")


@functools.partial(jax.jit, static_argnames=("v",))
def _refresh_cols_packed_csr(t, m, wl2_mask, edge_rows, edge_cols, v: int):
    te = t[edge_cols]
    mv = jax.ops.segment_min(te, edge_rows, num_segments=v)
    mv = jnp.minimum(mv, t)                    # closed neighborhood
    mv = jnp.where(mv == IN, OUT, mv)
    return jnp.where(wl2_mask, mv, m)


@functools.partial(jax.jit, static_argnames=("v",))
def _decide_packed_csr(t, m, wl1_mask, edge_rows, edge_cols, active, v: int):
    mn = m[edge_cols]
    an = active[edge_cols]
    te = t[edge_rows]
    has_out = jax.ops.segment_max(
        ((an & (mn == OUT)).astype(jnp.int32)), edge_rows, num_segments=v
    ) > 0
    has_out = has_out | (m == OUT)             # closed (self term)
    neq = jax.ops.segment_max(
        (an & (mn != te)).astype(jnp.int32), edge_rows, num_segments=v
    ) > 0
    all_eq = ~neq & (m == t)                   # closed (self term)
    newt = jnp.where(has_out, OUT, jnp.where(all_eq, IN, t))
    newt = jnp.where(is_undecided(t), newt, t)
    return jnp.where(wl1_mask, newt, t)


# ---- unpacked (3-field) representation (§V-C ablation) ----

def _lex_lt(s1, r1, i1, s2, r2, i2):
    return (s1 < s2) | ((s1 == s2) & ((r1 < r2) | ((r1 == r2) & (i1 < i2))))


@functools.partial(jax.jit, static_argnames=("priority", "b"))
def _refresh_rows_unpacked(ts, tr, ti, wl1, it, priority: str, b: int):
    v = ts.shape[0]
    rows = jnp.clip(wl1, 0, v - 1)
    ids = rows.astype(jnp.uint32)
    und = ts[rows] == S_UND
    prio = effective_priority(PRIORITY_FNS[priority](it, ids), b)
    newr = jnp.where(und, prio, tr[rows])
    tr = tr.at[wl1].set(newr, mode="drop")
    return ts, tr, ti


@jax.jit
def _refresh_cols_unpacked_ell(ts, tr, ti, ms, mr, mi, wl2, neighbors):
    v = neighbors.shape[0]
    rows = jnp.clip(wl2, 0, v - 1)
    nb = neighbors[rows]                      # [W, D]
    cs, cr, ci = ts[nb], tr[nb], ti[nb]
    bs, br, bi = cs[:, 0], cr[:, 0], ci[:, 0]
    for j in range(1, nb.shape[1]):           # unrolled lexicographic min
        lt = _lex_lt(cs[:, j], cr[:, j], ci[:, j], bs, br, bi)
        bs = jnp.where(lt, cs[:, j], bs)
        br = jnp.where(lt, cr[:, j], br)
        bi = jnp.where(lt, ci[:, j], bi)
    poisoned = bs == S_IN                     # IN-adjacent poison
    bs = jnp.where(poisoned, S_OUT, bs)
    ms = ms.at[wl2].set(bs, mode="drop")
    mr = mr.at[wl2].set(br, mode="drop")
    mi = mi.at[wl2].set(bi, mode="drop")
    return ms, mr, mi


@functools.partial(jax.jit, static_argnames=("v",))
def _refresh_cols_unpacked_csr(ts, tr, ti, ms, mr, mi, wl2_mask,
                               edge_rows, edge_cols, v: int):
    """Three segment passes — the traffic cost packing removes (§V-C)."""
    es, er, ei = ts[edge_cols], tr[edge_cols], ti[edge_cols]
    smin = jax.ops.segment_min(es, edge_rows, num_segments=v)
    smin = jnp.minimum(smin, ts)
    on_s = es == smin[edge_rows]
    rmin = jax.ops.segment_min(jnp.where(on_s, er, U32MAX), edge_rows,
                               num_segments=v)
    rmin = jnp.where(ts == smin, jnp.minimum(rmin, tr), rmin)
    on_r = on_s & (er == rmin[edge_rows])
    imin = jax.ops.segment_min(jnp.where(on_r, ei, U32MAX), edge_rows,
                               num_segments=v)
    imin = jnp.where((ts == smin) & (tr == rmin), jnp.minimum(imin, ti), imin)
    poisoned = smin == S_IN
    smin = jnp.where(poisoned, S_OUT, smin)
    ms = jnp.where(wl2_mask, smin, ms)
    mr = jnp.where(wl2_mask, rmin, mr)
    mi = jnp.where(wl2_mask, imin, mi)
    return ms, mr, mi


@jax.jit
def _decide_unpacked_ell(ts, tr, ti, ms, mr, mi, wl1, neighbors, active):
    v = neighbors.shape[0]
    rows = jnp.clip(wl1, 0, v - 1)
    nb = neighbors[rows]
    an = active[nb]
    cs, cr, ci = ms[nb], mr[nb], mi[nb]
    tvs, tvr, tvi = ts[rows], tr[rows], ti[rows]
    any_out = jnp.any(an & (cs == S_OUT), axis=1)
    eq = (cs == S_UND) & (cr == tvr[:, None]) & (ci == tvi[:, None])
    all_eq = jnp.all(jnp.where(an, eq, True), axis=1)
    news = jnp.where(any_out, S_OUT, jnp.where(all_eq, S_IN, tvs))
    news = jnp.where(tvs == S_UND, news, tvs)
    return ts.at[wl1].set(news, mode="drop")


@functools.partial(jax.jit, static_argnames=("v",))
def _decide_unpacked_csr(ts, tr, ti, ms, mr, mi, wl1_mask,
                         edge_rows, edge_cols, active, v: int):
    an = active[edge_cols]
    cs, cr, ci = ms[edge_cols], mr[edge_cols], mi[edge_cols]
    any_out = jax.ops.segment_max(
        (an & (cs == S_OUT)).astype(jnp.int32), edge_rows, num_segments=v
    ) > 0
    any_out = any_out | (ms == S_OUT)
    neq = (cs != S_UND) | (cr != tr[edge_rows]) | (ci != ti[edge_rows])
    some_neq = jax.ops.segment_max(
        (an & neq).astype(jnp.int32), edge_rows, num_segments=v
    ) > 0
    self_eq = (ms == S_UND) & (mr == tr) & (mi == ti)
    all_eq = ~some_neq & self_eq
    news = jnp.where(any_out, S_OUT, jnp.where(all_eq, S_IN, ts))
    news = jnp.where(ts == S_UND, news, ts)
    return jnp.where(wl1_mask, news, ts)


# ===========================================================================
# compacted / ablation driver
# ===========================================================================

def _mis2_compacted_impl(graph, active: Optional[np.ndarray] = None,
                         options: Optional[Mis2Options] = None, *,
                         pallas: Optional[bool] = None,
                         interpret: Optional[bool] = None) -> Mis2Result:
    options = Mis2Options() if options is None else options
    gh = as_graph(graph)
    if options.layout == "ell":
        ell = gh.ell
        v = ell.num_vertices
    elif options.layout == "csr_segment":
        edge_rows, edge_cols = gh.csr_edges
        v = gh.num_vertices
    else:
        raise ValueError(options.layout)

    active_np = np.ones(v, bool) if active is None else np.asarray(active)
    active_j = jnp.asarray(active_np)
    b = id_bits(v)

    use_pallas = options.use_pallas if pallas is None else pallas
    minprop_ops = None
    if use_pallas:
        if not (options.layout == "ell" and options.packed):
            raise ValueError("pallas path requires packed tuples + ELL layout")
        from ..kernels.minprop_ell import ops as minprop_ops  # noqa: F811

    if options.packed:
        t = jnp.where(active_j, jnp.uint32(1), U32MAX)
        m = jnp.full(v, U32MAX, dtype=jnp.uint32)
    else:
        ts = jnp.where(active_j, S_UND, S_OUT).astype(jnp.uint8)
        tr = jnp.zeros(v, dtype=jnp.uint32)
        ti = jnp.arange(v, dtype=jnp.uint32)
        ms = jnp.full(v, S_OUT, dtype=jnp.uint8)
        mr = jnp.full(v, U32MAX, dtype=jnp.uint32)
        mi = jnp.full(v, U32MAX, dtype=jnp.uint32)

    pads = _WorklistPadCache(v)
    wl1_np = np.flatnonzero(active_np).astype(np.int32)
    wl2_np = np.arange(v, dtype=np.int32)
    it = 0
    while len(wl1_np) and it < options.max_iters:
        if options.worklists or it == 0:
            wl1 = pads.pad(wl1_np)
            wl2 = pads.pad(wl2_np)
            if options.layout == "csr_segment":
                wl1_mask = jnp.zeros(v, bool).at[wl1].set(True, mode="drop")
                wl2_mask = jnp.zeros(v, bool).at[wl2].set(True, mode="drop")
        # without worklists, the full it==0 buffers are reused every iteration
        pads.shape_pairs.add((len(wl1), len(wl2)))

        if options.packed:
            t = _refresh_rows_packed(t, wl1, np.uint32(it), options.priority, b)
            if options.layout == "ell":
                if minprop_ops is not None:
                    m = minprop_ops.refresh_columns(t, m, wl2, ell.neighbors,
                                                    len(wl2_np),
                                                    interpret=interpret)
                    t = minprop_ops.decide(t, m, wl1, ell.neighbors, active_j,
                                           len(wl1_np), interpret=interpret)
                else:
                    m = _refresh_cols_packed_ell(t, m, wl2, ell.neighbors)
                    t = _decide_packed_ell(t, m, wl1, ell.neighbors, active_j)
            else:
                m = _refresh_cols_packed_csr(t, m, wl2_mask, edge_rows,
                                             edge_cols, v)
                t = _decide_packed_csr(t, m, wl1_mask, edge_rows, edge_cols,
                                       active_j, v)
            t_np = np.asarray(t)
            und = is_undecided(t_np)
            live = np.asarray(m) != U32MAX
            _OBS.counter(HotLoopStats._SYNCS).inc(2)  # t + m pulled to rebuild worklists
        else:
            ts, tr, ti = _refresh_rows_unpacked(ts, tr, ti, wl1, np.uint32(it),
                                                options.priority, b)
            if options.layout == "ell":
                ms, mr, mi = _refresh_cols_unpacked_ell(
                    ts, tr, ti, ms, mr, mi, wl2, ell.neighbors)
                ts = _decide_unpacked_ell(ts, tr, ti, ms, mr, mi, wl1,
                                          ell.neighbors, active_j)
            else:
                ms, mr, mi = _refresh_cols_unpacked_csr(
                    ts, tr, ti, ms, mr, mi, wl2_mask, edge_rows, edge_cols, v)
                ts = _decide_unpacked_csr(ts, tr, ti, ms, mr, mi, wl1_mask,
                                          edge_rows, edge_cols, active_j, v)
            t_np = np.asarray(ts)
            und = t_np == S_UND
            live = np.asarray(ms) != S_OUT
            _OBS.counter(HotLoopStats._SYNCS).inc(2)  # ts + ms pulled to rebuild worklists
        wl1_np = np.flatnonzero(und).astype(np.int32)
        wl2_np = np.flatnonzero(live).astype(np.int32)
        it += 1

    in_set = (np.asarray(t) == np.uint32(IN)) if options.packed \
        else (np.asarray(ts) == S_IN)
    return Mis2Result(in_set, it, len(wl1_np) == 0,
                      num_compiles=max(1, len(pads.shape_pairs)))


# ===========================================================================
# device-resident engine: the whole §V-B fixed point is ONE jitted
# lax.while_loop — worklists compacted on device, zero host round-trips
# ===========================================================================

def compact_worklist(mask: jnp.ndarray):
    """Cumsum-based stream compaction of a live-vertex mask.

    Returns ``(indices[V] int32, count int32)``: the first ``count`` slots
    hold the indices of the set bits in ascending order (exactly
    ``np.flatnonzero`` order, so the device worklists match the host-driven
    driver's buffers element for element); dead slots hold the sentinel
    ``V`` and are dropped by every downstream ``.at[wl].set(..., 'drop')``
    scatter — the same convention as :func:`_pad_worklist`.
    """
    v = mask.shape[0]
    vids = jnp.arange(v, dtype=jnp.int32)
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    wl = jnp.full(v, v, dtype=jnp.int32)
    wl = wl.at[jnp.where(mask, pos, v)].set(vids, mode="drop")
    return wl, jnp.sum(mask, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "priority", "packed", "max_iters", "b", "use_pallas", "interpret"))
def _resident_ell_fixed_point(neighbors, active, *, priority: str,
                              packed: bool, max_iters: int, b: int,
                              use_pallas: bool = False,
                              interpret: bool = True):
    """Device-resident compacted fixed point, ELL layout.

    Identical per-round passes to the host-driven driver (same step
    kernels, same ``[V]``-sentinel worklist convention), but worklist
    rebuilding happens on device via :func:`compact_worklist` and the whole
    loop is one ``lax.while_loop`` — a single dispatch per solve.  With
    ``use_pallas`` the round runs the *fused* Pallas passes
    (``kernels.minprop_ell.ops.fused_refresh_columns`` / ``fused_decide``):
    the §V-A rank packing is recomputed on the fly from the gathered
    neighbor ids, so no separate refresh_rows pass runs and each round
    reads the ELL rows once per pass, with the live ``count`` feeding the
    ``pl.when`` block-skip logic.
    """
    v = neighbors.shape[0]
    if use_pallas:
        from ..kernels.minprop_ell import ops as minprop_ops

    if packed:
        t0 = jnp.where(active, jnp.uint32(1), U32MAX)
        m0 = jnp.full(v, U32MAX, dtype=jnp.uint32)
        tup0 = (t0, m0)
    else:
        ts0 = jnp.where(active, S_UND, S_OUT).astype(jnp.uint8)
        tr0 = jnp.zeros(v, dtype=jnp.uint32)
        ti0 = jnp.arange(v, dtype=jnp.uint32)
        ms0 = jnp.full(v, S_OUT, dtype=jnp.uint8)
        mr0 = jnp.full(v, U32MAX, dtype=jnp.uint32)
        mi0 = jnp.full(v, U32MAX, dtype=jnp.uint32)
        tup0 = (ts0, tr0, ti0, ms0, mr0, mi0)

    wl1_0, n1_0 = compact_worklist(active)
    wl2_0 = jnp.arange(v, dtype=jnp.int32)   # iteration 0: refresh every M row
    state0 = (tup0, wl1_0, n1_0, wl2_0, jnp.int32(v), jnp.uint32(0))

    def cond(state):
        _, _, n1, _, _, it = state
        return (n1 > 0) & (it < max_iters)

    def body(state):
        tup, wl1, n1, wl2, n2, it = state
        if packed:
            t, m = tup
            if use_pallas:
                m = minprop_ops.fused_refresh_columns(
                    t, m, wl2, n2, neighbors, it, priority=priority, b=b,
                    interpret=interpret)
                t = minprop_ops.fused_decide(
                    t, m, wl1, n1, neighbors, active, it, priority=priority,
                    b=b, interpret=interpret)
            else:
                t = _refresh_rows_packed(t, wl1, it, priority, b)
                m = _refresh_cols_packed_ell(t, m, wl2, neighbors)
                t = _decide_packed_ell(t, m, wl1, neighbors, active)
            und = is_undecided(t)
            live = m != U32MAX
            tup = (t, m)
        else:
            ts, tr, ti, ms, mr, mi = tup
            ts, tr, ti = _refresh_rows_unpacked(ts, tr, ti, wl1, it,
                                                priority, b)
            ms, mr, mi = _refresh_cols_unpacked_ell(ts, tr, ti, ms, mr, mi,
                                                    wl2, neighbors)
            ts = _decide_unpacked_ell(ts, tr, ti, ms, mr, mi, wl1,
                                      neighbors, active)
            und = ts == S_UND
            live = ms != S_OUT
            tup = (ts, tr, ti, ms, mr, mi)
        wl1, n1 = compact_worklist(und)
        wl2, n2 = compact_worklist(live)
        return tup, wl1, n1, wl2, n2, it + jnp.uint32(1)

    tup, _, n1, _, _, it = jax.lax.while_loop(cond, body, state0)
    return tup[0], it, n1


@functools.partial(jax.jit, static_argnames=(
    "priority", "packed", "max_iters", "b", "v"))
def _resident_csr_fixed_point(edge_rows, edge_cols, active, *, priority: str,
                              packed: bool, max_iters: int, b: int, v: int):
    """Device-resident compacted fixed point, ``csr_segment`` layout.

    The segment kernels already consume ``[V]`` worklist *masks*, so
    compaction degenerates to mask recomputation — the loop state stays
    fixed-shape and the whole fixed point is one dispatch, like the ELL
    variant.  The row refresh is applied through the mask (the wl1 set is
    exactly the undecided set, so this matches the host driver's
    index-buffer scatter bit for bit).
    """
    vids = jnp.arange(v, dtype=jnp.uint32)
    prio_fn = PRIORITY_FNS[priority]

    if packed:
        t0 = jnp.where(active, jnp.uint32(1), U32MAX)
        m0 = jnp.full(v, U32MAX, dtype=jnp.uint32)
        tup0 = (t0, m0)
    else:
        ts0 = jnp.where(active, S_UND, S_OUT).astype(jnp.uint8)
        tup0 = (ts0, jnp.zeros(v, dtype=jnp.uint32),
                jnp.arange(v, dtype=jnp.uint32),
                jnp.full(v, S_OUT, dtype=jnp.uint8),
                jnp.full(v, U32MAX, dtype=jnp.uint32),
                jnp.full(v, U32MAX, dtype=jnp.uint32))

    # iteration 0: wl1 = active rows, wl2 = every row (host-driver parity)
    state0 = (tup0, active, jnp.ones(v, dtype=bool), jnp.uint32(0))

    def cond(state):
        _, wl1_mask, _, it = state
        return jnp.any(wl1_mask) & (it < max_iters)

    def body(state):
        tup, wl1_mask, wl2_mask, it = state
        if packed:
            t, m = tup
            newt = pack(prio_fn(it, vids), vids, b)
            t = jnp.where(wl1_mask & is_undecided(t), newt, t)
            m = _refresh_cols_packed_csr(t, m, wl2_mask, edge_rows,
                                         edge_cols, v)
            t = _decide_packed_csr(t, m, wl1_mask, edge_rows, edge_cols,
                                   active, v)
            und = is_undecided(t)
            live = m != U32MAX
            tup = (t, m)
        else:
            ts, tr, ti, ms, mr, mi = tup
            prio = effective_priority(prio_fn(it, vids), b)
            tr = jnp.where(wl1_mask & (ts == S_UND), prio, tr)
            ms, mr, mi = _refresh_cols_unpacked_csr(
                ts, tr, ti, ms, mr, mi, wl2_mask, edge_rows, edge_cols, v)
            ts = _decide_unpacked_csr(ts, tr, ti, ms, mr, mi, wl1_mask,
                                      edge_rows, edge_cols, active, v)
            und = ts == S_UND
            live = ms != S_OUT
            tup = (ts, tr, ti, ms, mr, mi)
        return tup, und, live, it + jnp.uint32(1)

    tup, wl1_mask, _, it = jax.lax.while_loop(cond, body, state0)
    return tup[0], it, jnp.sum(wl1_mask, dtype=jnp.int32)


def _mis2_resident_impl(graph, active: Optional[np.ndarray] = None,
                        options: Optional[Mis2Options] = None, *,
                        pallas: bool = False,
                        interpret: Optional[bool] = None) -> Mis2Result:
    """Engine entry for ``compacted_resident`` / ``pallas_resident``.

    Exactly one jitted dispatch per solve (counted in
    ``HOTLOOP_STATS.resident_dispatches``); the only device->host transfer
    is the final result pull after the fixed point has converged.
    """
    options = Mis2Options() if options is None else options
    if not options.worklists:
        raise ValueError(
            "resident engines implement §V-B worklist compaction by "
            "construction; use engine='dense' (masked lanes) or the "
            "host-driven 'compacted' driver for the no-worklist ablation")
    gh = as_graph(graph)
    if pallas and not (options.layout == "ell" and options.packed):
        raise ValueError("pallas path requires packed tuples + ELL layout")

    if options.layout == "ell":
        v = gh.ell.num_vertices
    elif options.layout == "csr_segment":
        v = gh.num_vertices
    else:
        raise ValueError(options.layout)
    active_j = jnp.ones(v, dtype=bool) if active is None \
        else jnp.asarray(active)
    b = id_bits(v)

    with _obs_span("mis2.resident_fixed_point", layout=options.layout,
                   pallas=pallas, packed=options.packed, v=v) as sp:
        if options.layout == "ell":
            if pallas:
                from ..kernels._interpret import resolve_interpret
                interpret = resolve_interpret(interpret)
            t, it, n1 = _resident_ell_fixed_point(
                gh.ell.neighbors, active_j, priority=options.priority,
                packed=options.packed, max_iters=options.max_iters, b=b,
                use_pallas=pallas, interpret=bool(interpret))
        else:
            edge_rows, edge_cols = gh.csr_edges
            t, it, n1 = _resident_csr_fixed_point(
                edge_rows, edge_cols, active_j, priority=options.priority,
                packed=options.packed, max_iters=options.max_iters, b=b, v=v)
        _OBS.counter(HotLoopStats._DISPATCHES).inc()
        jax.block_until_ready(t)    # span duration covers device execution
        sp.annotate(iterations=int(it))

    t_np = np.asarray(t)
    in_set = (t_np == np.uint32(IN)) if options.packed else (t_np == S_IN)
    return Mis2Result(in_set, int(it), int(n1) == 0, num_compiles=1)


# ===========================================================================
# engine dispatch (internal, warning-free) + legacy public entry points
# ===========================================================================

def run_mis2(graph, active=None, options: Optional[Mis2Options] = None,
             engine: str = "compacted",
             interpret: Optional[bool] = None,
             mesh=None, axis=None) -> Mis2Result:
    """Warning-free engine dispatch used by ``repro.api`` and by the other
    core pipelines (aggregation, partitioning).  Engines ``'compacted'``
    (host-driven §V-B worklists), ``'compacted_resident'`` (the same fixed
    point as one jitted ``while_loop`` with on-device worklist compaction),
    ``'dense'`` (single jitted ``while_loop`` over masks), ``'pallas'`` /
    ``'pallas_resident'`` (the Pallas min-propagation kernels on the
    measured hot loop; the resident variant runs the fused single-row-read
    passes) and the sharded ``'distributed'``/``'distributed_single_gather'``
    (which honor ``mesh``/``axis``, defaulting to all attached devices)
    produce bit-identical sets for equal options."""
    options = Mis2Options() if options is None else options
    if engine == "dense":
        return _mis2_dense_impl(graph, active, options)
    if engine == "compacted":
        return _mis2_compacted_impl(graph, active, options,
                                    interpret=interpret)
    if engine == "pallas":
        return _mis2_compacted_impl(graph, active, options, pallas=True,
                                    interpret=interpret)
    if engine in ("compacted_resident", "pallas_resident"):
        return _mis2_resident_impl(graph, active, options,
                                   pallas=engine.startswith("pallas"),
                                   interpret=interpret)
    if engine == "pallas_hybrid":
        from .mis2_hybrid import _mis2_hybrid_impl
        return _mis2_hybrid_impl(graph, active, options, interpret=interpret)
    if engine in ("distributed", "distributed_single_gather"):
        from .dist import _mis2_distributed_impl
        return _mis2_distributed_impl(
            graph, active, options, mesh=mesh, axis=axis,
            single_gather=engine.endswith("single_gather"))
    raise ValueError(
        f"unknown mis2 engine {engine!r} (dense | compacted | "
        "compacted_resident | pallas | pallas_resident | pallas_hybrid | "
        "distributed | distributed_single_gather)")


def mis2(graph, active=None, options: Optional[Mis2Options] = None,
         engine: str = "compacted") -> Mis2Result:
    """Deprecated entry point — use :func:`repro.api.mis2`."""
    warn_deprecated("repro.core.mis2.mis2", "repro.api.mis2")
    return run_mis2(graph, active, options, engine)


def mis2_dense(graph, active: Optional[jnp.ndarray] = None,
               options: Optional[Mis2Options] = None) -> Mis2Result:
    """Deprecated entry point — use ``repro.api.mis2(..., engine="dense")``."""
    warn_deprecated("repro.core.mis2.mis2_dense",
                    'repro.api.mis2(..., engine="dense")')
    return _mis2_dense_impl(graph, active, options)


def mis2_compacted(graph, active: Optional[np.ndarray] = None,
                   options: Optional[Mis2Options] = None) -> Mis2Result:
    """Deprecated entry point — use ``repro.api.mis2`` (default engine)."""
    warn_deprecated("repro.core.mis2.mis2_compacted",
                    'repro.api.mis2(..., engine="compacted")')
    return _mis2_compacted_impl(graph, active, options)


# Fig. 2 cumulative ablation chain (benchmarks/fig2_optimizations.py)
ABLATION_CHAIN = {
    "baseline_bell": Mis2Options(priority="fixed", worklists=False,
                                 packed=False, layout="csr_segment"),
    "+rand_priority": Mis2Options(priority="xorshift_star", worklists=False,
                                  packed=False, layout="csr_segment"),
    "+worklists": Mis2Options(priority="xorshift_star", worklists=True,
                              packed=False, layout="csr_segment"),
    "+packed_status": Mis2Options(priority="xorshift_star", worklists=True,
                                  packed=True, layout="csr_segment"),
    "+simd_ell": Mis2Options(priority="xorshift_star", worklists=True,
                             packed=True, layout="ell"),
}
