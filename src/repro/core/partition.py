"""Multilevel graph partitioning via MIS-2 aggregation.

This is the paper's own forward-looking use case (§VII, Gilbert et al.):
replace heavy-edge matching with MIS-2 coarsening in a multilevel
partitioner.  The launcher uses it for device placement (pipeline stages /
expert clusters) in examples/partition_demo.py — the honest integration of
the paper's technique with the LM-architecture substrate (DESIGN.md
§Arch-applicability).

Pipeline: coarsen with Algorithm 3 until <= coarse_target vertices, greedy
balanced partition of the coarsest graph, project labels back up, one
boundary-refinement sweep per level (deterministic: vertices move only to
strictly better parts, processed in index order via vectorized gain +
capacity check).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .._compat import warn_deprecated
from ..graphs.csr import CSRGraph
from ..graphs.handle import as_graph
from ..graphs.ops import coarse_graph_from_labels
from .aggregation import _aggregate_two_phase_impl
from .mis2 import Mis2Options


@dataclass
class PartitionResult:
    parts: np.ndarray          # int32 [V] part id
    num_parts: int
    edge_cut: int
    levels: int
    history: list = field(default_factory=list)   # (V, E) per level
    converged: bool = True   # every per-level MIS-2 reached its fixed point

    def __post_init__(self):
        # Result-protocol guarantee: host numpy payloads on every engine.
        self.parts = np.asarray(self.parts)


def _edge_list(g):
    csr = as_graph(g).csr
    indptr = np.asarray(csr.indptr)
    indices = np.asarray(csr.indices)
    rows = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    keep = rows != indices
    return rows[keep], indices[keep]


def edge_cut(g, parts: np.ndarray) -> int:
    r, c = _edge_list(g)
    return int((parts[r] != parts[c]).sum()) // 2


def _greedy_coarse_partition(g: CSRGraph, k: int, w: np.ndarray) -> np.ndarray:
    """BFS-ish weight-balanced greedy partition of a small graph (host)."""
    v = g.num_vertices
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    total = int(w.sum())
    parts = np.full(v, -1, dtype=np.int32)
    order = np.argsort(-np.diff(indptr))          # high degree first seeds
    loads = np.zeros(k, dtype=np.int64)
    cur = 0
    remaining = total
    for seed in order:
        if parts[seed] >= 0:
            continue
        if cur >= k:
            cur = int(loads.argmin())
        frontier = [int(seed)]
        while frontier:
            tgt = (remaining + loads[cur]) / max(1, k - cur) if cur < k \
                else total / k
            if loads[cur] >= tgt:
                break
            u = frontier.pop(0)
            if parts[u] >= 0:
                continue
            # avoid chunky overshoot: push to next part instead
            if cur < k - 1 and loads[cur] > 0.7 * tgt \
                    and loads[cur] + int(w[u]) > 1.1 * tgt:
                break
            parts[u] = cur
            loads[cur] += int(w[u])
            remaining -= int(w[u])
            for nb in indices[indptr[u]:indptr[u + 1]]:
                if parts[nb] < 0:
                    frontier.append(int(nb))
        tgt = (remaining + loads[cur]) / max(1, k - cur) if cur < k else 0
        if cur < k and loads[cur] >= 0.9 * tgt:
            cur += 1
    for u in np.flatnonzero(parts < 0):            # stragglers -> lightest
        p = int(loads.argmin())
        parts[u] = p
        loads[p] += int(w[u])
    return parts


def _refine(g: CSRGraph, parts: np.ndarray, k: int, w: np.ndarray,
            rounds: int = 2) -> np.ndarray:
    """Boundary refinement: move to the majority neighbor part if it strictly
    reduces cut and keeps weighted balance within 10%."""
    v = g.num_vertices
    r, c = _edge_list(g)
    cap = int(np.ceil(w.sum() / k * 1.10))
    for _ in range(rounds):
        counts = np.zeros((v, k), dtype=np.int32)
        np.add.at(counts, (r, parts[c]), 1)
        best = counts.argmax(axis=1).astype(np.int32)
        gain = counts[np.arange(v), best] - counts[np.arange(v), parts]
        loads = np.bincount(parts, weights=w, minlength=k).astype(np.int64)
        moved = False
        for u in np.flatnonzero(gain > 0):       # index order => deterministic
            b = best[u]
            if b != parts[u] and loads[b] + w[u] <= cap and loads[parts[u]] > w[u]:
                loads[parts[u]] -= w[u]
                loads[b] += w[u]
                parts[u] = b
                moved = True
        if not moved:
            break
    return parts


def _partition_impl(g, num_parts: int, coarse_target: int | None = None,
                    options: Mis2Options | None = None,
                    engine: str = "compacted",
                    interpret=None) -> PartitionResult:
    options = Mis2Options() if options is None else options
    gh = as_graph(g)
    g = gh.csr
    coarse_target = coarse_target or max(16 * num_parts, 256)
    levels = []
    graphs = [gh]
    weights = [np.ones(g.num_vertices, dtype=np.int64)]
    label_maps = []
    cur = gh
    converged = True
    while cur.num_vertices > coarse_target and len(levels) < 20:
        agg = _aggregate_two_phase_impl(cur, options=options, engine=engine,
                                        interpret=interpret)
        converged = converged and agg.converged
        if agg.num_aggregates >= cur.num_vertices:   # no progress
            break
        label_maps.append(agg.labels)
        weights.append(np.bincount(agg.labels, weights=weights[-1],
                                   minlength=agg.num_aggregates).astype(np.int64))
        cur = as_graph(coarse_graph_from_labels(cur.csr, agg.labels,
                                                agg.num_aggregates))
        graphs.append(cur)
        levels.append((cur.num_vertices, cur.num_entries))

    parts = _greedy_coarse_partition(cur.csr, num_parts, weights[-1])
    parts = _refine(cur, parts, num_parts, weights[-1])
    # project back up
    for labels, fine_g, fine_w in zip(reversed(label_maps), reversed(graphs[:-1]),
                                      reversed(weights[:-1])):
        parts = parts[labels]
        parts = _refine(fine_g, parts, num_parts, fine_w)

    return PartitionResult(parts.astype(np.int32), num_parts,
                           edge_cut(g, parts), len(label_maps) + 1, levels,
                           converged)


def partition(g, num_parts: int, coarse_target: int | None = None,
              options: Mis2Options | None = None) -> PartitionResult:
    """Deprecated entry point — use :func:`repro.api.partition`."""
    warn_deprecated("repro.core.partition.partition", "repro.api.partition")
    return _partition_impl(g, num_parts, coarse_target, options)
