"""Compressed status tuples (paper §V-C).

The 3-field tuple ``(status, rand, id)`` is packed into one ``uint32``:

* ``IN  = 0``
* ``OUT = 0xFFFFFFFF``
* undecided: ``(priority << b) | (id + 1)`` where ``b = ceil(log2(V + 2))``.

Equation (1) of the paper guarantees at least one zero bit among the low ``b``
bits, so no undecided packing collides with IN or OUT, and the ordering
``IN < UNDECIDED < OUT`` holds.  Lexicographic tuple comparison becomes a
single integer compare; the unique id is an implicit tiebreak.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32

IN = np.uint32(0)
OUT = np.uint32(0xFFFFFFFF)


def id_bits(num_vertices: int) -> int:
    """b = ceil(log2(V + 2)) — bits reserved for the id component."""
    return max(1, math.ceil(math.log2(num_vertices + 2)))


def effective_priority(priority: jnp.ndarray, b: int) -> jnp.ndarray:
    """Truncate a 32-bit hash to the 32-b priority bits that fit the packing.

    We keep the *high* bits (xorshift* has the strongest high bits); both the
    packed and unpacked representations compare this same truncated value, so
    the two representations produce bit-identical MIS-2 sets.
    """
    return priority.astype(U32) >> U32(b)


def pack(priority: jnp.ndarray, vertex_ids: jnp.ndarray, b: int) -> jnp.ndarray:
    """(priority' << b) | (id + 1) on uint32, priority' = high 32-b hash bits."""
    pr = effective_priority(priority, b) << U32(b)
    return pr | (vertex_ids.astype(U32) + U32(1))


def unpack_id(t: jnp.ndarray, b: int) -> jnp.ndarray:
    """Recover the vertex id from an undecided packed tuple."""
    mask = U32((1 << b) - 1)
    return (t & mask) - U32(1)


def is_undecided(t: jnp.ndarray) -> jnp.ndarray:
    return (t != IN) & (t != OUT)
