# repro-lint: legacy seed-era LM train step/optimizer, test-only surface
"""Train / eval step construction for every architecture family.

``make_train_step(model, cfg, opt_cfg)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with in/out shardings — the exact object the multi-pod dry-run
lowers.

Loss: next-token cross entropy in fp32 over the padded vocab (padded ids
never occur as labels).  MoE aux (load-balance) loss is added with a small
coefficient.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_init, adamw_update

AUX_COEF = 0.01


def cross_entropy(logits, labels, mask=None):
    """logits [B,S,V] (any float), labels int32 [B,S] -> scalar mean CE."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(model, cfg: ModelConfig) -> Callable:
    if cfg.family in ("encdec", "audio"):
        def loss_fn(params, batch):
            logits, aux = model.forward(
                params, {"frames": batch["frames"], "tokens": batch["tokens"]})
            loss = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
            return loss + AUX_COEF * aux, {"ce": loss, "aux": aux}
    else:
        def loss_fn(params, batch):
            logits, aux = model.forward(params, batch["tokens"])
            loss = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
            return loss + AUX_COEF * aux, {"ce": loss, "aux": aux}
    return loss_fn


def make_train_step(model, cfg: ModelConfig,
                    opt_cfg: Optional[AdamWConfig] = None,
                    num_microbatches: int = 1,
                    grad_shardings=None) -> Callable:
    """num_microbatches > 1: batch leaves carry a leading microbatch axis
    [k, B/k, ...]; gradients are accumulated over a ``lax.scan`` so live
    activation memory is one microbatch's worth (the standard fit-in-HBM
    lever for the train_4k cells)."""
    if opt_cfg is None:
        opt_cfg = AdamWConfig()
    loss_fn = make_loss_fn(model, cfg)

    if num_microbatches == 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            params, opt_state, opt_metrics = adamw_update(
                params, grads, opt_state, opt_cfg)
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return params, opt_state, metrics
        return train_step

    def _constrain(g):
        if grad_shardings is None:
            return g
        return jax.lax.with_sharding_constraint(g, grad_shardings)

    def train_step(params, opt_state, batch):
        def micro(gsum, mb):
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            # reshard the (bf16) grads to the accumulator's (ZeRO-1)
            # sharding BEFORE the f32 upcast: the full-size f32 grad tree
            # never materializes (buffer-assignment-verified)
            g = _constrain(g)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return gsum, dict(metrics, loss=loss)

        g0 = _constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        gsum, ms = jax.lax.scan(micro, g0, batch)
        grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
        metrics = jax.tree.map(jnp.mean, ms)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, dict(metrics, **opt_metrics)

    return train_step


def make_eval_step(model, cfg: ModelConfig) -> Callable:
    loss_fn = make_loss_fn(model, cfg)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return dict(metrics, loss=loss)

    return eval_step


def init_train_state(model, cfg: ModelConfig, key):
    params = model.init(key)
    return params, adamw_init(params)
