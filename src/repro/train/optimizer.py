# repro-lint: legacy seed-era LM train step/optimizer, test-only surface
"""Pure-JAX AdamW with warmup+cosine schedule (no external deps).

Optimizer state is a pytree congruent with params, so the same
NamedSharding tree shards it (optionally ZeRO-1 style over the data axis —
see launch/sharding.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    """m/v are always fp32 (params may be stored bf16 — 'pure bf16 +
    fp32 moments' TPU recipe; the fp32 master-copy variant is a §Perf
    iteration)."""
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) +
                          cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
