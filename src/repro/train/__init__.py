# repro-lint: legacy seed-era LM train step/optimizer, test-only surface
from .optimizer import AdamWConfig, adamw_init, adamw_update, schedule
from .step import (
    cross_entropy,
    init_train_state,
    make_eval_step,
    make_loss_fn,
    make_train_step,
)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "schedule",
           "cross_entropy", "init_train_state", "make_eval_step",
           "make_loss_fn", "make_train_step"]
