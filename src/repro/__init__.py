"""repro — parallel, portable distance-2 MIS and graph coarsening
(Kelley & Rajamanickam 2022) on JAX/Pallas.

``import repro`` presents the facade directly (``repro.mis2``,
``repro.Graph``, ...); ``repro.api`` is the same surface with the full
registry/backend toolkit; ``repro.serve`` is the persistent graph
service (continuous batching + digest-keyed caching + streaming repair);
``repro.obs`` is the process-wide observability layer (metrics registry,
span tracing, exporters).  Subpackages (``graphs``, ``core``,
``solvers``, ``kernels``, ``launch``) remain importable for power users.

Facade attributes resolve lazily (PEP 562): tooling that must configure
``XLA_FLAGS`` before anything touches jax (``python -m
repro.launch.dryrun`` forces 512 host devices) still works, because
importing the bare ``repro`` package pulls in nothing.
"""
from importlib import import_module

__version__ = "0.2.0"

_FACADE = {
    "Graph", "GraphBatch", "Backend", "Mis2Options", "BatchResult",
    "mis2", "misk", "color", "coarsen", "partition", "amg",
    "amg_setup", "cluster_gs_setup",
    "mis2_batch", "color_batch", "coarsen_batch", "amg_setup_batch",
}

__all__ = ["api", "serve", "obs", "__version__", *sorted(_FACADE)]


def __getattr__(name: str):
    if name in ("api", "serve", "obs"):
        return import_module(f".{name}", __name__)
    if name in _FACADE:
        return getattr(import_module(".api", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
