# repro-lint: legacy seed-era LM checkpointing, exercised only by tests
"""Checkpointing with atomic commit, keep-k retention, and elastic
re-sharding on restore.

Layout::

    <dir>/step_<N>/
        arrays.npz          # one entry per tree leaf, keyed by "/"-path
        manifest.json       # step, keys, shapes, dtypes, user metadata
    <dir>/LATEST            # text file holding the committed step number

Write protocol (fault-tolerant): write into ``step_<N>.tmp``, fsync,
``os.replace`` to final name, then update LATEST — a crash at any point
leaves either the old or the new checkpoint fully intact, never a torn one.

Restore accepts target shardings: leaves are ``jax.device_put`` to the
*current* mesh — loading a checkpoint written under a different mesh shape
re-shards transparently (elastic scaling).  Multi-host note: this writer
stores full arrays (single-host gather); the 1000-node deployment writes
one ``arrays-<process>.npz`` per host with the same manifest — the format
and restore path already key leaves by name, so that extension is additive.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

#: manifest stamps are wall-clock epochs by design (compared across hosts)
_EPOCH_NOW = time.time  # repro-lint: ignore[RL103] epoch stamp for the manifest, not a duration

SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_key_str(p) for p in path)
        flat[key] = leaf
    return flat


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten_like(template, flat: dict):
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_and_leaves[0]:
        key = SEP.join(_key_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(paths_and_leaves[1], leaves)


def save_checkpoint(directory, step: int, state: Any, *,
                    keep: int = 3, metadata: Optional[dict] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step}"
    tmp = directory / f"step_{step}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat = _flatten(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "time": _EPOCH_NOW(),
        "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                 for k, a in arrays.items()},
        "metadata": metadata or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    # fsync the directory contents before the atomic rename
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    (directory / "LATEST.tmp").write_text(str(step))
    os.replace(directory / "LATEST.tmp", directory / "LATEST")

    # retention
    steps = sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*")
                   if p.name.split("_")[1].isdigit())
    for old in steps[:-keep]:
        shutil.rmtree(directory / f"step_{old}", ignore_errors=True)
    return final


def latest_step(directory) -> Optional[int]:
    f = Path(directory) / "LATEST"
    if not f.exists():
        return None
    return int(f.read_text().strip())


def restore_checkpoint(directory, template: Any, *, step: Optional[int] = None,
                       shardings: Any = None):
    """Restore into the structure of ``template``; optionally re-shard.

    ``shardings``: tree congruent with template (NamedSharding leaves) — the
    elastic-scaling path: a checkpoint saved under any mesh loads onto the
    current one.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = directory / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}
    state = _unflatten_like(template, flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    else:
        state = jax.tree.map(jax.numpy.asarray, state)
    return step, state, manifest
