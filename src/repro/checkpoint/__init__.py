# repro-lint: legacy seed-era LM checkpointing, exercised only by tests
from .manager import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["latest_step", "restore_checkpoint", "save_checkpoint"]
