"""Admission control: bounded queue, per-caller quotas, deadline shedding.

The PR 6 server accepted everything and let the Poisson p99 run away once
offered load passed batched capacity — the queue grew without bound and
every request eventually "succeeded", seconds late.  Admission control
inverts that: requests the server cannot serve *well* are failed *fast*
with a typed error at submit time, so callers see backpressure instead of
latency.

Three independent checks, applied to every cache-missing submit (cache
hits are served unconditionally — they cost a dict lookup):

1. **Per-caller token bucket** (:class:`QuotaConfig`): each caller
   refills at ``rate`` tokens/sec up to ``burst``; a submit costs one
   token; an empty bucket raises :class:`~repro.serve.errors.QuotaExceeded`.
   One hot caller cannot starve the rest of the queue.
2. **Bounded queue**: more than ``max_pending`` queued requests raises
   :class:`~repro.serve.errors.ServerOverloaded`.  Requests *joining* an
   in-flight computation (dedup) skip this check — they add zero queue
   pressure.
3. **Deadline feasibility**: a request whose ``deadline_s`` is already
   spent, or smaller than the EWMA-estimated queue wait, raises
   :class:`~repro.serve.errors.DeadlineExceeded` immediately instead of
   queueing work that will be evicted unserved.

The controller is pure policy — it raises, the server counts
(``serve.shed{reason=...}``).  The clock is injectable so quota refill is
unit-testable without sleeping.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional

from .errors import DeadlineExceeded, QuotaExceeded, ServerOverloaded

#: callers tracked before the least-recently-*used* bucket is recycled (a
#: caller id is a caller-chosen string; an unbounded set must not grow
#: server memory).  LRU, not FIFO: an active caller's bucket is refreshed
#: on every submit, so churn in one-shot caller ids cannot evict a hot
#: caller and hand it a fresh bucket at full burst
MAX_TRACKED_CALLERS = 4096


@dataclass(frozen=True)
class QuotaConfig:
    """Per-caller token-bucket quota: ``rate`` tokens/sec refill, up to
    ``burst`` capacity; every admitted submit costs one token."""

    rate: float = 50.0
    burst: float = 100.0

    def __post_init__(self):
        if self.rate < 0 or self.burst <= 0:
            raise ValueError("quota needs rate >= 0 and burst > 0")


class TokenBucket:
    """The classic leaky-bucket dual: continuous refill, capped at burst."""

    __slots__ = ("rate", "burst", "tokens", "last")

    def __init__(self, rate: float, burst: float, now: float):
        self.rate = rate
        self.burst = burst
        self.tokens = burst
        self.last = now

    def try_take(self, now: float, n: float = 1.0) -> bool:
        self.tokens = min(self.burst,
                          self.tokens + max(0.0, now - self.last) * self.rate)
        self.last = now
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False


class AdmissionController:
    """Stateful admission policy for one server (not thread-safe on its
    own; the server serializes access under its lock).

    ``denials`` exposes per-caller quota-denial counts for the
    ``server_stats()`` dashboard view — per-caller identity deliberately
    stays *out* of metric labels (an unbounded caller set would trip the
    registry's cardinality bound); the process-wide aggregate is
    ``serve.shed{reason=quota}``.
    """

    def __init__(self, max_pending: Optional[int] = None,
                 quota: Optional[QuotaConfig] = None,
                 clock=time.perf_counter):
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        self.max_pending = max_pending
        self.quota = quota
        self.clock = clock
        self._buckets: OrderedDict[str, TokenBucket] = OrderedDict()
        self.denials: dict[str, int] = {}

    def admit(self, *, caller: str = "default", pending: int = 0,
              deadline_s: Optional[float] = None,
              est_wait_s: Optional[float] = None,
              joining: bool = False) -> None:
        """Raise a typed error if the request must be shed; else return.

        ``pending`` is the current queue depth, ``est_wait_s`` the
        server's EWMA queue-wait estimate (None until it has data), and
        ``joining`` marks a dedup join (no new queue pressure: the
        bounded-queue and wait-estimate checks are skipped, the quota
        still charges — rate limits meter callers, not computes).
        """
        now = self.clock()
        if self.quota is not None:
            bucket = self._buckets.get(caller)
            if bucket is None:
                if len(self._buckets) >= MAX_TRACKED_CALLERS:
                    self._buckets.popitem(last=False)   # least recently used
                bucket = self._buckets[caller] = TokenBucket(
                    self.quota.rate, self.quota.burst, now)
            else:
                self._buckets.move_to_end(caller)       # LRU refresh
            if not bucket.try_take(now):
                self.denials[caller] = self.denials.get(caller, 0) + 1
                raise QuotaExceeded(
                    f"caller {caller!r} exhausted its token bucket "
                    f"(rate={self.quota.rate}/s, burst={self.quota.burst})")
        if deadline_s is not None and deadline_s <= 0:
            raise DeadlineExceeded(
                f"deadline_s={deadline_s} already expired at submit")
        if joining:
            return
        if self.max_pending is not None and pending >= self.max_pending:
            raise ServerOverloaded(
                f"{pending} requests pending >= max_pending="
                f"{self.max_pending}; resubmit after backoff")
        if deadline_s is not None and est_wait_s is not None \
                and deadline_s < est_wait_s:
            raise DeadlineExceeded(
                f"deadline_s={deadline_s:.4f} below the estimated queue "
                f"wait {est_wait_s:.4f}s — shedding instead of queueing "
                "work that would expire unserved")
