"""Request queue + continuous batcher: deadline-or-full group dispatch.

Requests accumulate in *groups* — one per (kind, options, requested
engine, placement) coalescing key — and a group dispatches as one batched
call when it reaches ``max_batch`` members (full) or its oldest member
has waited ``max_delay_s`` (deadline).  That is the classic continuous-
batching contract: an isolated request pays at most the latency budget,
a burst is coalesced into the PR 2 vmapped bucket pipelines at full
occupancy.

Only auto-engine requests (``engine=None``) coalesce freely: an explicit
engine is a caller's statement about *how* to execute, so those requests
group per engine and dispatch through the single-graph facade path.
Results are bit-identical either way (the repo invariant) — grouping
affects throughput, never bytes.

Per-request deadlines: a request may carry an absolute ``deadline`` (same
timebase as ``now``).  :meth:`Batcher.pop_expired` evicts expired requests
*before* they can be dispatched — the server fails them with
``DeadlineExceeded`` and the engine never burns compute on an answer
nobody is waiting for.  ``next_deadline`` accounts for both the batching
latency budget and the earliest request deadline, so the pump loop wakes
in time to evict.

Timebase: every entry point takes an explicit ``now`` so tests drive the
deadline logic with a manual clock; the server passes
``time.perf_counter()``.
"""
from __future__ import annotations

from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Optional


@dataclass
class PendingRequest:
    """One queued request (kind + graph + normalized parameters)."""

    kind: str
    graph: Any                      # repro Graph handle
    params: dict                    # kind-specific kwargs (normalized)
    engine: Optional[str]           # None = auto-select per request backend
    backend: Any                    # Backend or None
    cache_key: tuple
    future: Future = field(default_factory=Future)
    enqueued_at: float = 0.0
    deadline: Optional[float] = None    # absolute, server clock; None = none
    caller: str = "default"             # admission-control caller identity


def _freeze(obj) -> tuple:
    """Canonical hashable token for options/params of any supported shape."""
    import dataclasses

    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return (type(obj).__name__,) + tuple(
            (f.name, _freeze(getattr(obj, f.name)))
            for f in dataclasses.fields(obj))
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    return repr(obj)


def group_key(req: PendingRequest) -> tuple:
    """The coalescing key: requests sharing it may dispatch as one batch."""
    placement = id(req.backend.device) if (
        req.backend is not None and req.backend.device is not None) else None
    return (req.kind, _freeze(req.params),
            req.engine if req.engine is not None else "auto", placement)


class Batcher:
    """Accumulates PendingRequests into dispatch groups (not thread-safe;
    the server serializes access under its own lock)."""

    def __init__(self, max_batch: int = 8, max_delay_s: float = 0.01):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self._groups: dict[tuple, list[PendingRequest]] = {}

    def __len__(self) -> int:
        return sum(len(g) for g in self._groups.values())

    def add(self, req: PendingRequest, now: float) -> None:
        req.enqueued_at = now
        self._groups.setdefault(group_key(req), []).append(req)

    def due(self, now: float, force: bool = False
            ) -> list[tuple[tuple, list[PendingRequest]]]:
        """Pop and return every group that must dispatch now.

        Full groups always dispatch (chunked to ``max_batch``); a partial
        group dispatches once its oldest member has waited out the latency
        budget, or unconditionally under ``force`` (flush/shutdown).
        """
        out: list[tuple[tuple, list[PendingRequest]]] = []
        for key in list(self._groups):
            reqs = self._groups[key]
            while len(reqs) >= self.max_batch:
                out.append((key, reqs[: self.max_batch]))
                reqs = reqs[self.max_batch:]
            expired = reqs and (now - reqs[0].enqueued_at >= self.max_delay_s)
            if reqs and (force or expired):
                out.append((key, reqs))
                reqs = []
            if reqs:
                self._groups[key] = reqs
            else:
                del self._groups[key]
        return out

    def pop_expired(self, now: float) -> list[PendingRequest]:
        """Remove and return every queued request whose deadline passed.

        Called by the pump before :meth:`due` so expired work is never
        dispatched — the server fails these futures with a typed
        ``DeadlineExceeded`` instead of computing answers late.
        """
        expired: list[PendingRequest] = []
        for key in list(self._groups):
            reqs = self._groups[key]
            keep = [r for r in reqs
                    if r.deadline is None or r.deadline > now]
            if len(keep) != len(reqs):
                expired.extend(r for r in reqs
                               if r.deadline is not None
                               and r.deadline <= now)
                if keep:
                    self._groups[key] = keep
                else:
                    del self._groups[key]
        return expired

    def drain(self) -> list[PendingRequest]:
        """Remove and return everything still queued (terminal shutdown:
        the server fails these with ``ServerClosed``)."""
        out = [r for reqs in self._groups.values() for r in reqs]
        self._groups.clear()
        return out

    def next_deadline(self, now: float) -> Optional[float]:
        """Seconds until the earliest pending event — a group's batching
        deadline or a request's own deadline, whichever comes first
        (None if empty)."""
        marks = [reqs[0].enqueued_at + self.max_delay_s
                 for reqs in self._groups.values() if reqs]
        marks.extend(r.deadline for reqs in self._groups.values()
                     for r in reqs if r.deadline is not None)
        if not marks:
            return None
        return max(0.0, min(marks) - now)
