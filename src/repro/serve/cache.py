"""Digest-keyed result cache: LRU over a byte budget, hits provably safe.

The cache key is ``(kind, graph.digest, engine token, options token)``.
Because every engine in this repo is bit-identical for equal inputs and
options (the standing determinism gate), two requests that collide on a
key would compute byte-equal ``Result`` payloads — so returning the cached
object *is* recomputation, minus the work.  The parity assertion mode
makes that claim self-checking in production: a configurable fraction of
hits is recomputed through the direct facade path and the digests
compared; any mismatch raises (and is counted) instead of being served.

Sampling is deterministic (an error-diffusion accumulator, not an RNG) so
a given hit sequence always checks the same hits — CI can force
``parity_fraction=1.0`` and count the checks exactly.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from ..obs import metrics as _OBS


class CacheParityError(AssertionError):
    """A sampled cache hit did not match its recomputation bit-for-bit."""


def _result_nbytes(result) -> int:
    """Byte footprint of a Result for the LRU budget (payload-dominated)."""
    total = 256  # object overhead / scalar fields
    payload = getattr(result, "payload", None)
    if payload is not None:
        total += int(np.asarray(payload).nbytes)
    hierarchy = getattr(result, "hierarchy", None)
    if hierarchy is not None:       # AmgSetup: the levels dominate, not the
        for lvl in getattr(hierarchy, "levels", ()):   # level-size payload
            for mat in (lvl.a_ell, lvl.p_ell, lvl.r_ell):
                for arr in (mat or ()):
                    total += int(np.asarray(arr).nbytes)
            total += int(np.asarray(lvl.diag).nbytes)
    return total


@dataclass
class CacheStats:
    """Per-cache counters, mirrored into the process-wide ``repro.obs``
    registry (``serve.cache.*`` counters + ``serve.cache.bytes_used``
    gauge) so one ``obs.snapshot()`` sees cache traffic next to
    dispatches and compiles.  The instance fields remain the per-cache
    truth (two caches in one process split cleanly); the registry carries
    the process aggregate."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    inserts: int = 0
    parity_checks: int = 0
    parity_failures: int = 0
    bytes_used: int = 0

    def bump(self, name: str, n: int = 1) -> None:
        setattr(self, name, getattr(self, name) + n)
        _OBS.counter(f"serve.cache.{name}").inc(n)

    def set_bytes(self, used: int) -> None:
        self.bytes_used = used
        _OBS.gauge("serve.cache.bytes_used").set(used)

    def as_dict(self) -> dict:
        return {
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions, "inserts": self.inserts,
            "parity_checks": self.parity_checks,
            "parity_failures": self.parity_failures,
            "bytes_used": self.bytes_used,
            "hit_rate": self.hits / max(1, self.hits + self.misses),
        }


@dataclass
class ResultCache:
    """LRU result cache with a byte budget and sampled parity assertions.

    ``max_bytes <= 0`` disables caching entirely (every lookup misses,
    nothing is stored).  ``parity_fraction`` in ``[0, 1]`` recomputes that
    fraction of hits through ``recompute`` (provided per lookup by the
    server — it is the direct facade call for the request) and asserts
    digest equality.
    """

    max_bytes: int = 64 << 20
    parity_fraction: float = 0.0
    stats: CacheStats = field(default_factory=CacheStats)
    #: optional PersistTier under this cache: memory misses fall through
    #: to disk (digest-verified on load), inserts write through
    persist: Optional[Any] = None

    def __post_init__(self):
        self._entries: OrderedDict[tuple, tuple[Any, int]] = OrderedDict()
        self._parity_acc = 0.0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key: tuple,
               recompute: Optional[Callable[[], Any]] = None):
        """Return the cached Result for ``key`` or None (a miss).

        On a hit the entry is refreshed (LRU) and, per the sampling
        accumulator, optionally parity-checked against ``recompute()``.
        A memory miss falls through to the persistent tier (if any):
        a digest-verified disk hit counts as a cache hit and is promoted
        into memory (without re-writing disk).
        """
        if self.max_bytes <= 0 or key not in self._entries:
            if self.persist is not None and self.max_bytes > 0:
                try:
                    rehydrated = self.persist.load(key)
                except Exception:   # noqa: BLE001 - disk-tier failures are
                    # misses, never exceptions out of submit()'s cache lookup
                    _OBS.counter("serve.persist.load_errors").inc()
                    rehydrated = None
                if rehydrated is not None:
                    self.stats.bump("hits")
                    self.insert(key, rehydrated, write_persist=False)
                    return rehydrated
            self.stats.bump("misses")
            return None
        result, _ = self._entries[key]
        self._entries.move_to_end(key)
        self.stats.bump("hits")
        if recompute is not None and self.parity_fraction > 0.0:
            self._parity_acc += min(1.0, self.parity_fraction)
            if self._parity_acc >= 1.0:
                self._parity_acc -= 1.0
                self.stats.bump("parity_checks")
                fresh = recompute()
                if fresh.digest != result.digest:
                    self.stats.bump("parity_failures")
                    raise CacheParityError(
                        f"cache parity violation for {key}: cached digest "
                        f"{result.digest} != recomputed {fresh.digest}")
        return result

    def insert(self, key: tuple, result, write_persist: bool = True) -> None:
        if self.max_bytes <= 0:
            return
        if write_persist and self.persist is not None:
            try:
                self.persist.store(key, result)
            except Exception:   # noqa: BLE001 - a broken disk tier must
                # never break the response path (insert runs while the
                # server is resolving futures); the entry stays memory-only
                _OBS.counter("serve.persist.store_errors").inc()
        nbytes = _result_nbytes(result)
        if nbytes > self.max_bytes:
            return  # would evict everything and still not fit
        if key in self._entries:
            _, old = self._entries.pop(key)
            self.stats.set_bytes(self.stats.bytes_used - old)
        self._entries[key] = (result, nbytes)
        self.stats.set_bytes(self.stats.bytes_used + nbytes)
        self.stats.bump("inserts")
        while self.stats.bytes_used > self.max_bytes and self._entries:
            _, (_, evicted) = self._entries.popitem(last=False)
            self.stats.set_bytes(self.stats.bytes_used - evicted)
            self.stats.bump("evictions")

    def clear(self) -> None:
        self._entries.clear()
        self.stats.set_bytes(0)
