"""Deterministic seeded fault injection + the retry/fallback policy.

A :class:`FaultPlan` names *sites* — fixed points in the serving code
where a failure can be injected — and gives each one a :class:`Fault`
descriptor (kind, rate, firing budget).  Sampling is a per-site seeded
``numpy`` stream derived from ``(seed, sha256(site))``, so a plan is
fully deterministic: the same seed and the same visit sequence fire the
same faults, which is what lets the chaos suite and the CI ``serve-chaos``
step assert exact retry/fallback/corruption counts instead of flaky
probabilistic bounds.

Named sites (the serving code consults exactly these):

========================  ====================================================
``dispatch``              before a batch dispatch (``slow`` models a stalled
                          device queue; ``error`` a dispatch-path crash)
``engine``                around the primary engine compute (``error`` with
                          ``transient=True`` models a recoverable engine
                          blip -> retried; ``transient=False`` a persistent
                          failure -> immediate fallback)
``repair``                inside streaming incremental repair (``error``
                          degrades the delta to a from-scratch recompute)
``persist_write``         persistent-cache commit (simulated crash: the tmp
                          directory is left behind, nothing is committed)
``persist_corrupt``       persistent-cache payload bytes flipped on write
                          (digest re-verification must drop the entry on load)
========================  ====================================================

Every firing increments ``serve.faults.injected{site}`` so chaos runs
leave an auditable trail next to the retry/fallback/shed counters they
provoke.
"""
from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..obs import metrics as _OBS
from .errors import ServeError

FAULT_KINDS = ("error", "slow", "corrupt")


class InjectedFault(ServeError):
    """An exception raised by a ``kind="error"`` fault at a named site."""

    reason = "injected"

    def __init__(self, site: str, transient: bool = True):
        super().__init__(f"injected fault at site {site!r} "
                         f"({'transient' if transient else 'persistent'})")
        self.site = site
        self.transient = transient
        self.retryable = transient


@dataclass(frozen=True)
class Fault:
    """One site's failure mode.

    ``rate`` is the per-visit firing probability (1.0 = every visit);
    ``count`` caps total firings (None = unlimited) — ``count=1`` with
    ``rate=1.0`` is the deterministic "fail exactly once, then recover"
    shape most retry tests want.  ``transient`` only matters for
    ``error`` faults: transient errors are retried under the
    :class:`RetryPolicy`, persistent ones go straight to fallback.
    ``delay_s`` only matters for ``slow`` faults.
    """

    kind: str
    rate: float = 1.0
    count: Optional[int] = None
    transient: bool = True
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in {FAULT_KINDS}")


class FaultPlan:
    """Seeded, thread-safe fault injector over named sites.

    ``sites`` maps site name -> :class:`Fault`.  ``fired`` exposes the
    per-site firing counts (the deterministic trail tests assert on).
    """

    def __init__(self, seed: int = 0, sites: Optional[dict] = None):
        self.seed = int(seed)
        self.sites: dict[str, Fault] = dict(sites or {})
        self.fired: dict[str, int] = {}
        self._rngs: dict[str, np.random.Generator] = {}
        self._lock = threading.Lock()

    def _rng(self, site: str) -> np.random.Generator:
        rng = self._rngs.get(site)
        if rng is None:
            # stable per-site stream: independent of dict order and of
            # visits to other sites, so firing sequences are reproducible
            tag = int.from_bytes(
                hashlib.sha256(site.encode()).digest()[:4], "big")
            rng = self._rngs[site] = np.random.default_rng([self.seed, tag])
        return rng

    def should_fire(self, site: str) -> Optional[Fault]:
        """Consume one visit at ``site``; return the Fault iff it fires."""
        fault = self.sites.get(site)
        if fault is None:
            return None
        with self._lock:
            if fault.count is not None and \
                    self.fired.get(site, 0) >= fault.count:
                return None
            rng = self._rng(site)
            hit = fault.rate >= 1.0 or rng.random() < fault.rate
            if not hit:
                return None
            self.fired[site] = self.fired.get(site, 0) + 1
        _OBS.counter("serve.faults.injected", labels={"site": site}).inc()
        return fault

    def fire(self, site: str) -> None:
        """Inject at ``site``: sleep for ``slow`` faults, raise
        :class:`InjectedFault` for ``error`` faults.  ``corrupt`` faults
        are polled by their call site via :meth:`corrupts` instead."""
        fault = self.should_fire(site)
        if fault is None or fault.kind == "corrupt":
            return
        if fault.kind == "slow":
            time.sleep(fault.delay_s)
            return
        raise InjectedFault(site, transient=fault.transient)

    def corrupts(self, site: str) -> bool:
        """True iff a ``corrupt`` fault fires at ``site`` on this visit."""
        fault = self.sites.get(site)
        if fault is None or fault.kind != "corrupt":
            return False
        return self.should_fire(site) is not None


@dataclass(frozen=True)
class RetryPolicy:
    """Retry/fallback semantics for a failed dispatch.

    *Transient* engine failures (``InjectedFault(transient=True)`` — the
    recoverable-blip model) are retried up to ``max_attempts`` total
    attempts with capped exponential backoff.  Any other engine failure,
    or an exhausted retry budget, degrades to the **fallback engine** —
    the host/dense referent every parity gate in the repo anchors on
    (``mis2 -> dense``, ``amg_setup -> host``, coloring/coarsening -> the
    default facade path).  Fallback results flow through the same digest
    ledger as every response, so degraded answers are held to the same
    bit-identity contract.
    """

    max_attempts: int = 3
    base_backoff_s: float = 0.001
    max_backoff_s: float = 0.05
    fallback: bool = True

    def backoff_s(self, attempt: int) -> float:
        """Capped exponential backoff before retry number ``attempt``
        (1-based)."""
        return min(self.max_backoff_s,
                   self.base_backoff_s * (2.0 ** (attempt - 1)))


#: the engine-contract referent each kind degrades to (None = the facade
#: default path, which on a failure of an explicit engine is itself the
#: fallback)
FALLBACK_ENGINES = {
    "mis2": "dense",
    "amg_setup": "host",
    "color": None,
    "coarsen": None,
}
