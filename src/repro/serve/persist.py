"""Content-addressed persistent cache tier: digest-verified, atomic, LRU.

The in-memory :class:`~repro.serve.cache.ResultCache` dies with the
process; this tier sits *under* it and survives restarts.  Each entry is
one directory named by the hash of its cache key::

    <root>/entry_<sha256(repr(key))[:32]>/
        arrays.npz       payload (+ roots/phase for coarsening results)
        manifest.json    version, kind, repr(key), per-array digests,
                         scalar Result fields

Three properties make rehydrating from disk as safe as recomputing:

* **Atomic commit** — the checkpoint-manager pattern: build the entry in
  a ``.tmp`` sibling, fsync every file, then ``os.replace`` into place.
  A crash mid-write leaves a ``.tmp`` orphan (swept and counted as
  ``torn_cleaned`` on the next open), never a half-entry that could load.
* **Digest re-verification on load** — every array is re-hashed with
  :func:`~repro.api.result.determinism_digest` and compared against the
  manifest, and the manifest key must match the requested key exactly.
  Any mismatch (bit rot, a truncated write that still parses, an injected
  ``persist_corrupt`` fault) drops the entry and counts
  ``serve.persist.corrupt`` — a corrupt entry is *never* served.
* **Byte-budget LRU** — entries beyond ``max_bytes`` are evicted oldest-
  mtime-first (loads touch the entry's mtime, so recently-served entries
  survive).  Ordering keys off filesystem mtimes rather than wall-clock
  reads in code.

Only result kinds whose payloads fully round-trip through ``.npz`` are
persisted (``mis2`` / ``color`` / ``coarsen``); ``amg_setup`` carries a
live hierarchy object graph and stays memory-only — ``store`` returns
False and the server keeps working.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from ..api.result import (AggregationResult, ColoringResult, Mis2Result,
                          determinism_digest)
from ..obs import metrics as _OBS

PERSIST_VERSION = 1

#: kinds whose Result round-trips losslessly through arrays + JSON scalars
PERSISTABLE_KINDS = ("mis2", "color", "coarsen")

_ENTRY_PREFIX = "entry_"
_TMP_SUFFIX = ".tmp"


def entry_name(key: tuple) -> str:
    """Content address for a cache key (stable across processes: the key
    is built from digests, engine tokens and frozen option tuples, so its
    ``repr`` is deterministic)."""
    return _ENTRY_PREFIX + hashlib.sha256(repr(key).encode()).hexdigest()[:32]


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _dir_nbytes(path: str) -> int:
    total = 0
    for name in os.listdir(path):
        total += os.path.getsize(os.path.join(path, name))
    return total


@dataclass
class PersistStats:
    """Per-tier counters mirrored into ``repro.obs`` (``serve.persist.*``
    counters + ``serve.persist.bytes_used`` gauge), same split as
    :class:`~repro.serve.cache.CacheStats`: instance fields are per-tier
    truth, the registry carries the process aggregate."""

    hits: int = 0
    misses: int = 0
    writes: int = 0
    corrupt: int = 0
    evictions: int = 0
    torn_cleaned: int = 0
    io_errors: int = 0
    bytes_used: int = 0

    def bump(self, name: str, n: int = 1) -> None:
        setattr(self, name, getattr(self, name) + n)
        _OBS.counter(f"serve.persist.{name}").inc(n)

    def set_bytes(self, used: int) -> None:
        self.bytes_used = used
        _OBS.gauge("serve.persist.bytes_used").set(used)

    def as_dict(self) -> dict:
        return {
            "hits": self.hits, "misses": self.misses, "writes": self.writes,
            "corrupt": self.corrupt, "evictions": self.evictions,
            "torn_cleaned": self.torn_cleaned, "io_errors": self.io_errors,
            "bytes_used": self.bytes_used,
        }


@dataclass
class PersistTier:
    """Digest-verified disk tier under the in-memory result cache.

    ``faults`` (a :class:`~repro.serve.faults.FaultPlan` or None) is
    consulted at the ``persist_write`` site (simulated crash: the tmp
    build is abandoned uncommitted) and the ``persist_corrupt`` site
    (payload bytes are flipped *on disk* while the manifest keeps the
    true digests — exercising exactly the verification path that guards
    against real bit rot).
    """

    directory: str
    max_bytes: int = 256 << 20
    faults: Any = None
    stats: PersistStats = field(default_factory=PersistStats)

    def __post_init__(self):
        self._lock = threading.Lock()
        os.makedirs(self.directory, exist_ok=True)
        swept = 0
        total = 0
        for name in os.listdir(self.directory):
            path = os.path.join(self.directory, name)
            if name.endswith(_TMP_SUFFIX):
                shutil.rmtree(path, ignore_errors=True)
                swept += 1
            elif name.startswith(_ENTRY_PREFIX) and os.path.isdir(path):
                total += _dir_nbytes(path)
        if swept:
            self.stats.bump("torn_cleaned", swept)
        self.stats.set_bytes(total)

    def __len__(self) -> int:
        return sum(1 for name in os.listdir(self.directory)
                   if name.startswith(_ENTRY_PREFIX))

    # ------------------------------------------------------------- store
    def store(self, key: tuple, result) -> bool:
        """Persist ``result`` under ``key``; True iff committed.

        Non-persistable kinds, oversized entries, injected
        ``persist_write`` crashes, and real I/O failures (ENOSPC, yanked
        permissions, a directory deleted underfoot — counted
        ``serve.persist.io_errors``) all return False — the tier degrades
        to memory-only for that entry, never blocks the response path.
        """
        kind = key[0] if key else None
        if kind not in PERSISTABLE_KINDS:
            return False
        arrays = {"payload": np.asarray(result.payload)}
        if kind == "coarsen":
            if result.roots is not None:
                arrays["roots"] = np.asarray(result.roots)
            if result.phase is not None:
                arrays["phase"] = np.asarray(result.phase)
        manifest = {
            "version": PERSIST_VERSION,
            "kind": kind,
            "key": repr(key),
            "digest": result.digest,
            "array_digests": {n: determinism_digest(a)
                              for n, a in arrays.items()},
            "fields": self._scalar_fields(kind, result),
        }
        if self.faults is not None and self.faults.corrupts("persist_corrupt"):
            # flip one payload byte on disk; the manifest keeps the true
            # digests, so load-time re-verification must catch this
            buf = arrays["payload"].copy()
            flat = buf.view(np.uint8).reshape(-1)
            flat[0] ^= 0xFF
            arrays["payload"] = buf
        name = entry_name(key)
        final = os.path.join(self.directory, name)
        tmp = final + _TMP_SUFFIX
        try:
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as fh:
                json.dump(manifest, fh, indent=1)
                fh.flush()
                os.fsync(fh.fileno())
            _fsync_file(os.path.join(tmp, "arrays.npz"))
        except OSError:
            shutil.rmtree(tmp, ignore_errors=True)
            self.stats.bump("io_errors")
            return False
        if self.faults is not None:
            try:
                self.faults.fire("persist_write")
            except Exception:
                # simulated crash between build and commit: the tmp
                # orphan stays for the next open's sweep to find
                return False
        try:
            nbytes = _dir_nbytes(tmp)
            if nbytes > self.max_bytes:
                shutil.rmtree(tmp, ignore_errors=True)
                return False
            with self._lock:
                replaced = _dir_nbytes(final) if os.path.isdir(final) else 0
                if replaced:
                    # os.replace cannot clobber a non-empty dir target
                    shutil.rmtree(final, ignore_errors=True)
                os.replace(tmp, final)
                self.stats.set_bytes(
                    self.stats.bytes_used - replaced + nbytes)
                self.stats.bump("writes")
                self._evict_over_budget(keep=name)
        except OSError:
            shutil.rmtree(tmp, ignore_errors=True)
            self.stats.bump("io_errors")
            return False
        return True

    @staticmethod
    def _scalar_fields(kind: str, result) -> dict:
        fields = {
            "iterations": int(result.iterations),
            "converged": bool(result.converged),
            "wall_time_s": float(result.wall_time_s),
        }
        if kind == "mis2":
            fields["engine"] = result.engine
            fields["num_compiles"] = result.num_compiles
            collectives = result.collectives
            try:
                json.dumps(collectives)
            except (TypeError, ValueError):
                collectives = None
            fields["collectives"] = collectives
        elif kind == "color":
            fields["num_colors"] = int(result.num_colors)
        elif kind == "coarsen":
            fields["num_aggregates"] = int(result.num_aggregates)
        return fields

    # -------------------------------------------------------------- load
    def load(self, key: tuple):
        """Return the rehydrated Result for ``key``, or None.

        Every array is re-digested against the manifest and the manifest
        key/kind/version must match the request; any discrepancy drops
        the entry (counted ``serve.persist.corrupt``) and misses.
        """
        final = os.path.join(self.directory, entry_name(key))
        if not os.path.isdir(final):
            self.stats.bump("misses")
            return None
        try:
            with open(os.path.join(final, "manifest.json")) as fh:
                manifest = json.load(fh)
            ok = (manifest.get("version") == PERSIST_VERSION
                  and manifest.get("key") == repr(key)
                  and manifest.get("kind") == (key[0] if key else None))
            arrays = {}
            if ok:
                with np.load(os.path.join(final, "arrays.npz")) as npz:
                    expected = manifest["array_digests"]
                    ok = set(npz.files) == set(expected)
                    if ok:
                        for name in npz.files:
                            arr = npz[name]
                            if determinism_digest(arr) != expected[name]:
                                ok = False
                                break
                            arrays[name] = arr
                    # the Result digest is by contract the payload digest;
                    # pin the manifest's top-level digest to the *verified*
                    # payload digest so a corrupted digest field can never
                    # rehydrate a Result that disagrees with its own bytes
                    # (and later poison the ledger or parity checks)
                    ok = ok and manifest.get("digest") == expected["payload"]
        except Exception:   # noqa: BLE001 - unparseable == corrupt: any
            ok = False      # bit rot that breaks zip/json parsing lands here
        if not ok:
            self._drop(final, corrupt=True)
            self.stats.bump("misses")
            return None
        try:
            os.utime(final)  # LRU touch: loads keep hot entries off the
            #                  eviction frontier
        except OSError:      # entry evicted/removed underfoot (shared
            self.stats.bump("misses")   # persist_dir): it is gone — a miss
            return None
        self.stats.bump("hits")
        return self._rebuild(manifest, arrays)

    @staticmethod
    def _rebuild(manifest: dict, arrays: dict):
        kind = manifest["kind"]
        fields = manifest["fields"]
        common = dict(payload=arrays["payload"],
                      iterations=fields["iterations"],
                      converged=fields["converged"],
                      wall_time_s=fields["wall_time_s"],
                      digest=manifest["digest"])
        if kind == "mis2":
            return Mis2Result(engine=fields.get("engine", ""),
                              collectives=fields.get("collectives"),
                              num_compiles=fields.get("num_compiles"),
                              **common)
        if kind == "color":
            return ColoringResult(num_colors=fields.get("num_colors", 0),
                                  **common)
        return AggregationResult(
            num_aggregates=fields.get("num_aggregates", 0),
            roots=arrays.get("roots"), phase=arrays.get("phase"), **common)

    # --------------------------------------------------------- retention
    def _drop(self, path: str, corrupt: bool = False) -> None:
        try:
            nbytes = _dir_nbytes(path) if os.path.isdir(path) else 0
        except OSError:     # entry vanished mid-measure: nothing to subtract
            nbytes = 0
        shutil.rmtree(path, ignore_errors=True)
        with self._lock:
            self.stats.set_bytes(max(0, self.stats.bytes_used - nbytes))
        if corrupt:
            self.stats.bump("corrupt")

    def _evict_over_budget(self, keep: Optional[str] = None) -> None:
        # caller holds self._lock
        if self.stats.bytes_used <= self.max_bytes:
            return
        entries = []
        for name in os.listdir(self.directory):
            if not name.startswith(_ENTRY_PREFIX) or name == keep:
                continue
            path = os.path.join(self.directory, name)
            try:
                if os.path.isdir(path):
                    entries.append((os.stat(path).st_mtime_ns, path))
            except OSError:     # entry vanished between listdir and stat
                continue
        entries.sort()
        for _, path in entries:
            if self.stats.bytes_used <= self.max_bytes:
                break
            try:
                nbytes = _dir_nbytes(path)
            except OSError:
                nbytes = 0
            shutil.rmtree(path, ignore_errors=True)
            self.stats.set_bytes(max(0, self.stats.bytes_used - nbytes))
            self.stats.bump("evictions")

    def clear(self) -> None:
        for name in os.listdir(self.directory):
            if name.startswith(_ENTRY_PREFIX) or name.endswith(_TMP_SUFFIX):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)
        self.stats.set_bytes(0)
