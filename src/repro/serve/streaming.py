"""Streaming MIS-2: apply edge deltas, repair locally, stay bit-exact.

``StreamSession.apply_delta(edge_adds, edge_removes)`` updates a live
MIS-2 solution without recomputing from scratch: only the closed 2-hop
neighborhood of the touched endpoints is reactivated (re-seeded
undecided), everything else keeps its previous T state (``IN``/``OUT``
frozen), and the warm-started fixed point
(:func:`repro.core.mis2.mis2_repair_fixed_point`) re-decides the region.

Exactness — why the repaired set is bit-identical to from-scratch:

* With the round-independent ``"fixed"`` priority, the MIS-2 fixed point
  computes the unique *lexicographically-first* MIS-2 under the packed
  priority order ``p``; that set is characterized pointwise by the
  recurrence "``v IN`` iff no member within distance 2 has smaller
  ``p``" (unique by induction along the priority order — the repo's
  port of Blelloch–Fineman–Shun's deterministic-reservation argument).
* After each repair solve, :func:`repro.core.mis2.lexfirst_violations`
  checks that recurrence *globally* with two closed-neighborhood min
  propagations.  Any violation necessarily implicates a frozen vertex
  within distance 2 (inside the reactivated region the fixed point is
  already consistent), so the violators' closed 2-hop is reactivated and
  the solve repeats; the region grows monotonically, hence terminates —
  in practice one or two expansions.  An all-clear certifies the
  assignment satisfies the recurrence everywhere, and the unique such
  assignment *is* the from-scratch answer.

With a round-varying priority (the ``xorshift_star`` default elsewhere)
the fixed point is history-dependent and no warm start can be exact, so
``apply_delta`` falls back to a full recompute (``mode="recompute"``) —
the documented streaming caveat.  ``check_fraction`` additionally
digest-checks sampled deltas against an actual from-scratch run
(belt-and-braces on top of the recurrence certificate).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core.mis2 import (
    Mis2Options,
    fixed_packed_priorities,
    lexfirst_violations,
    mis2_dense_jittable,
    mis2_repair_fixed_point,
)
from ..core.tuples import IN, OUT, id_bits, is_undecided
from ..graphs.csr import CSRGraph, csr_from_coo, ensure_self_loops
from ..graphs.handle import Graph, as_graph
from ..api.backend import backend_platform
from ..api.result import Mis2Result
from ..obs import Provenance
from ..obs import metrics as _OBS
from ..obs import span as _obs_span
from .faults import InjectedFault


@dataclass
class RepairStats:
    """Observability for one ``apply_delta`` call."""

    mode: str                   # "repair" | "recompute"
    touched: int = 0            # endpoints named by the delta
    reactivated: int = 0        # vertices re-seeded undecided (final region)
    expansions: int = 0         # recurrence-check driven region growths
    iterations: int = 0         # fixed-point rounds across all solves
    checked: bool = False       # from-scratch digest check ran
    degraded: bool = False      # repair path failed; served via recompute
    wall_time_s: float = 0.0


def _two_hop(mask: np.ndarray, rows: np.ndarray, cols: np.ndarray,
             hops: int = 2) -> np.ndarray:
    """Closed ``hops``-neighborhood of ``mask`` over COO edges (host)."""
    reach = mask.copy()
    for _ in range(hops):
        nxt = reach.copy()
        np.logical_or.at(nxt, rows, reach[cols])
        reach = nxt
    return reach


def _edge_keys(pairs, num_vertices: int) -> np.ndarray:
    """Symmetric (u, v) pairs -> sorted unique int64 ``u * V + v`` keys."""
    if pairs is None or len(pairs) == 0:
        return np.empty(0, dtype=np.int64)
    arr = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    if (arr < 0).any() or (arr >= num_vertices).any():
        raise ValueError("delta edge endpoint out of range")
    keys = np.concatenate([arr[:, 0] * num_vertices + arr[:, 1],
                           arr[:, 1] * num_vertices + arr[:, 0]])
    return np.unique(keys)


class StreamSession:
    """A live MIS-2 solution over a mutating graph (fixed vertex set).

    ``options.priority == "fixed"`` (the default here) enables exact
    incremental repair; any other priority downgrades ``apply_delta`` to
    full recomputation.  ``check_fraction`` in ``[0, 1]`` recomputes that
    fraction of deltas from scratch and asserts digest equality
    (deterministic error-diffusion sampling, like the result cache).

    ``faults`` (a :class:`~repro.serve.faults.FaultPlan` or None)
    consults the ``repair`` site: an injected repair failure degrades the
    delta to a from-scratch recompute (``last_repair.degraded``, counted
    ``serve.fallbacks{from=repair,to=recompute}``) — the session stays
    live and bit-exact, it just pays full price for that delta.
    """

    def __init__(self, graph, *, options: Optional[Mis2Options] = None,
                 check_fraction: float = 0.0, faults=None):
        self.options = options if options is not None else \
            Mis2Options(priority="fixed")
        self.faults = faults
        self.check_fraction = float(check_fraction)
        self._check_acc = 0.0
        gh = as_graph(graph)
        csr = ensure_self_loops(gh.csr)
        self._v = csr.num_vertices
        indptr = np.asarray(csr.indptr)
        self._cols = np.asarray(csr.indices).astype(np.int64)
        self._rows = np.repeat(np.arange(self._v, dtype=np.int64),
                               np.diff(indptr))
        self.graph = Graph(CSRGraph(csr.indptr, csr.indices))
        self._p = None
        if self.options.priority == "fixed":
            self._p = fixed_packed_priorities(self._v)
        self.result = self._solve_scratch(self.graph)
        self.in_set = np.asarray(self.result.payload)
        self.last_repair: Optional[RepairStats] = None

    # -- internals ----------------------------------------------------------

    def _solve_scratch(self, gh: Graph) -> Mis2Result:
        t0 = time.perf_counter()
        t, iters = mis2_dense_jittable(
            gh.ell.neighbors, jnp.ones(self._v, dtype=bool),
            self.options.priority, self.options.max_iters)
        t_np = np.asarray(t)
        if is_undecided(t_np).any():
            raise RuntimeError("MIS-2 fixed point hit max_iters during "
                               "streaming solve; raise Mis2Options.max_iters")
        return Mis2Result(t_np == np.uint32(IN), int(iters), True,
                          time.perf_counter() - t0, engine="dense")

    def _recompute_full(self, gh: Graph, touched: np.ndarray,
                        t_start: float, degraded: bool = False) -> Mis2Result:
        """Serve one delta by full recomputation (the round-varying-
        priority path and the degraded fallback when incremental repair
        faults)."""
        self.result = self._solve_scratch(gh)
        self.in_set = np.asarray(self.result.payload)
        self.graph = gh
        self.last_repair = RepairStats(
            mode="recompute", touched=int(touched.sum()),
            reactivated=self._v,
            iterations=self.result.iterations,
            degraded=degraded,
            wall_time_s=time.perf_counter() - t_start)
        return self.result

    def _apply_keys(self, adds: np.ndarray, removes: np.ndarray) -> Graph:
        cur = self._rows * self._v + self._cols
        new = np.union1d(cur, adds)
        if len(removes):
            new = np.setdiff1d(new, removes, assume_unique=False)
        diag = np.arange(self._v, dtype=np.int64) * (self._v + 1)
        new = np.union1d(new, diag)     # self-loops are structural here
        rows, cols = new // self._v, new % self._v
        csr = csr_from_coo(rows, cols, self._v)
        self._rows, self._cols = rows, cols
        return Graph(csr)

    # -- public -------------------------------------------------------------

    def apply_delta(self, edge_adds=None, edge_removes=None) -> Mis2Result:
        """Apply symmetric edge insertions/removals and repair the set.

        Returns the updated facade ``Mis2Result`` (also stored as
        ``self.result``); per-call accounting lands in ``self.last_repair``
        and mirrors into the ``repro.obs`` registry (``serve.repair.*``);
        the result carries a span-tree ``provenance`` like facade results.
        Self-loops cannot be removed (closed-neighborhood semantics) and
        the vertex set is fixed — grow-by-vertex is a resize, not a delta.
        """
        with _obs_span("serve.repair") as sp:
            result = self._apply_delta_impl(edge_adds, edge_removes)
            st = self.last_repair
            sp.annotate(mode=st.mode, touched=st.touched,
                        reactivated=st.reactivated, expansions=st.expansions)
            _OBS.counter("serve.repair.deltas",
                         labels={"mode": st.mode}).inc()
            _OBS.counter("serve.repair.reactivated").inc(st.reactivated)
            _OBS.counter("serve.repair.expansions").inc(st.expansions)
            _OBS.counter("serve.repair.iterations").inc(st.iterations)
        result.provenance = Provenance(
            "mis2", result.engine, backend_platform(), result.digest,
            sp.to_dict())
        return result

    def _apply_delta_impl(self, edge_adds=None, edge_removes=None):
        t_start = time.perf_counter()
        adds = _edge_keys(edge_adds, self._v)
        removes = _edge_keys(edge_removes, self._v)
        old_rows, old_cols = self._rows, self._cols
        gh = self._apply_keys(adds, removes)

        touched_keys = np.concatenate([adds, removes])
        touched = np.zeros(self._v, dtype=bool)
        touched[np.unique(touched_keys // self._v)] = True
        touched[np.unique(touched_keys % self._v)] = True

        if self._p is None:     # round-varying priority: repair is inexact
            return self._recompute_full(gh, touched, t_start)

        try:
            if self.faults is not None:
                self.faults.fire("repair")
            # reactivate the closed 2-hop of touched endpoints, under the
            # union of old and new adjacency (a removed edge still
            # mediated influence)
            u_rows = np.concatenate([old_rows, self._rows])
            u_cols = np.concatenate([old_cols, self._cols])
            region = _two_hop(touched, u_rows, u_cols)

            neighbors = gh.ell.neighbors
            b = jnp.uint32(id_bits(self._v))
            prev_in = self.in_set
            stats = RepairStats(mode="repair", touched=int(touched.sum()))
            while True:
                t0 = jnp.asarray(np.where(
                    region, np.uint32(1), np.where(prev_in, IN, OUT)))
                t, iters = mis2_repair_fixed_point(
                    neighbors, t0, b, self.options.priority,
                    self.options.max_iters)
                stats.iterations += int(iters)
                t_np = np.asarray(t)
                if is_undecided(t_np).any():
                    raise RuntimeError(
                        "repair fixed point hit max_iters; raise "
                        "Mis2Options.max_iters")
                in_set = t_np == np.uint32(IN)
                viol = np.asarray(lexfirst_violations(neighbors, jnp.asarray(
                    in_set), self._p))
                if not viol.any():
                    break
                # violations implicate frozen vertices within distance 2:
                # reactivate their closed 2-hop and re-solve (region grows)
                region = region | _two_hop(viol, self._rows, self._cols)
                stats.expansions += 1
                if stats.expansions > self._v:      # unreachable; safety net
                    raise RuntimeError("repair failed to converge")
        except InjectedFault:
            # degraded but live: the delta is served via full recompute,
            # which is exact by construction — the session never emits a
            # wrong set, it just pays full price for this delta
            _OBS.counter("serve.fallbacks",
                         labels={"from": "repair", "to": "recompute"}).inc()
            return self._recompute_full(gh, touched, t_start, degraded=True)
        stats.reactivated = int(region.sum())

        result = Mis2Result(in_set, stats.iterations, True,
                            time.perf_counter() - t_start,
                            engine="stream_repair")
        if self.check_fraction > 0.0:
            self._check_acc += min(1.0, self.check_fraction)
            if self._check_acc >= 1.0:
                self._check_acc -= 1.0
                stats.checked = True
                scratch = self._solve_scratch(gh)
                if scratch.digest != result.digest:
                    raise AssertionError(
                        f"incremental repair diverged from from-scratch: "
                        f"{result.digest} != {scratch.digest}")
        self.graph = gh
        self.in_set = in_set
        self.result = result
        stats.wall_time_s = time.perf_counter() - t_start
        self.last_repair = stats
        return result
