"""Typed error taxonomy for ``repro.serve``.

Every way a served request can fail maps to exactly one exception class
here, so callers can branch on type instead of parsing messages, and the
failure-mode matrix in API.md is checkable: each class carries

* ``reason``     the bounded token used as the ``serve.shed{reason=...}``
  metric label (admission-path errors) — one place ties the exception a
  caller sees to the counter an operator watches;
* ``retryable``  whether resubmitting the same request later can succeed
  (``ServerOverloaded``/``QuotaExceeded``: yes, pressure subsides;
  ``DeadlineExceeded``: only with a fresh deadline; ``ServerClosed``:
  only against a new server; parity/ledger violations: never — they
  indicate a determinism bug, not a transient condition).

The hardening contract (tests/test_serve_resilience.py): under overload,
injected faults, and shutdown races, every submitted future resolves
either with a digest-correct ``Result`` or with one of these types —
never a hang, never a silent wrong answer.
"""
from __future__ import annotations


class ServeError(RuntimeError):
    """Base class for every typed serving failure."""

    reason: str = "error"
    retryable: bool = False


class ServerClosed(ServeError):
    """The server was stopped: queued futures are failed with this and
    every later ``submit`` returns a future already carrying it."""

    reason = "closed"
    retryable = False


class ServerOverloaded(ServeError):
    """Admission control shed the request: the bounded queue is full.
    Back off and resubmit — the queue drains at batched capacity."""

    reason = "overloaded"
    retryable = True


class QuotaExceeded(ServeError):
    """The caller's token bucket is empty (per-caller rate limit).
    Retry after the bucket refills (``QuotaConfig.rate`` tokens/sec)."""

    reason = "quota"
    retryable = True


class DeadlineExceeded(ServeError):
    """The request's deadline expired — at admission (the queue-wait
    estimate already exceeds it) or in the queue (evicted before
    dispatch; expired work is never dispatched)."""

    reason = "deadline"
    retryable = False


class LayoutInfeasible(ServeError):
    """Admission control shed the request because the engine it names (or
    defaults to) would materialize the monolithic padded-ELL layout, and
    the graph's ``[V, max_degree]`` bytes estimate exceeds
    ``repro.graphs.hybrid.ELL_BYTE_LIMIT`` — the compute would die in a
    host OOM after queueing, so it is refused up front with a typed error
    naming the fix.  Not retryable as-is: resubmit with a degree-aware
    engine (``mis2``/``coarsen`` with ``engine=None`` or
    ``'pallas_hybrid'``, ``color`` with ``'luby_hybrid'``), which handles
    exactly these skewed graphs."""

    reason = "layout"
    retryable = False


class EngineFailure(ServeError):
    """Compute failed after the retry budget and the fallback engine.
    The original engine error is chained as ``__cause__``."""

    reason = "engine"
    retryable = False


class DigestMismatch(ServeError):
    """A response's digest conflicts with the digest previously served
    for the same ``(kind, graph digest, engine, options)`` key.  The
    determinism invariant says equal keys produce bit-identical payloads,
    so a conflict means corruption or a determinism bug — the response is
    failed rather than served."""

    reason = "digest"
    retryable = False
