"""The persistent graph service: one process, many callers, zero rebits.

``Server`` accepts concurrent ``mis2`` / ``color`` / ``coarsen`` /
``amg_setup`` requests and serves every one with a result bit-identical
to the direct facade call — batching, caching, warm executables, request
dedup, and fallback engines are throughput/robustness machinery, never
semantics (the repo's determinism invariant is what makes every one of
those compositions safe).

Request path::

    submit() -> closed check (typed ServerClosed after stop())
             -> cache lookup (memory LRU, then digest-verified disk tier)
             -> admission control (quota / bounded queue / deadline
                feasibility -> typed shed errors)
             -> in-flight dedup (same-key concurrent requests join the
                primary's future; exactly one compute per unique key)
             -> batcher group (deadline-or-full continuous batching)
    pump()   -> expired-request eviction (never dispatched)
             -> batched dispatch over GraphBatch buckets, under the
                retry/fallback policy (transient faults retried with
                capped backoff; persistent failures degrade to the
                host/dense referent engine)
             -> digest ledger check -> cache insert + future resolution

The **digest ledger** is the last line of the robustness contract: the
server remembers the digest it served for each key and refuses (typed
``DigestMismatch``) to ever serve a second, different digest for the same
key — so retries, fallbacks, and rehydrated cache entries are all held to
the engine contract, not trusted.

``pump()`` is the explicit event-loop step (deterministic for tests and
CI); ``start()`` runs it on a daemon thread for real concurrent callers.
``stop()`` is terminal: queued futures fail with ``ServerClosed``, later
submits return already-failed futures — nothing ever hangs.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from ..api import facade
from ..api.backend import (
    Backend,
    backend_platform,
    default_mis2_engine,
    default_multilevel_engine,
    resolve_backend,
)
from ..obs import Provenance
from ..obs import metrics as _OBS
from ..obs import span as _obs_span
from ..api.result import Mis2Result
from ..batch.container import bucket_shape
from ..core.mis2 import IN, Mis2Options, is_undecided
from ..core.tuples import id_bits
from ..graphs.handle import as_graph
from .admission import AdmissionController, QuotaConfig
from .batcher import Batcher, PendingRequest, _freeze
from .cache import ResultCache
from .errors import (DeadlineExceeded, DigestMismatch, EngineFailure,
                     LayoutInfeasible, ServeError, ServerClosed)
from .faults import FALLBACK_ENGINES, FaultPlan, InjectedFault, RetryPolicy
from .persist import PersistTier
from .streaming import StreamSession
from .warm import WarmRegistry, WarmSpec

KINDS = ("mis2", "color", "coarsen", "amg_setup")

#: digest-ledger retention: enough for every key a long-lived server
#: plausibly serves, bounded so the ledger cannot grow without limit
LEDGER_CAP = 65536


@dataclass(frozen=True)
class ServerConfig:
    """Serving policy: batching budget, cache budget, warm shapes,
    admission limits, fault/retry semantics, persistence.

    ``warm_buckets`` lists ``(rows, width)`` bucket shapes (the
    ``repro.batch`` power-of-two classes) to AOT-compile at startup at
    batch capacity ``max_batch`` for the configured mis2 options; live
    shapes outside the list still work, they just pay a counted runtime
    compile.  ``parity_fraction`` recomputes that fraction of cache hits
    and asserts digest equality; ``delta_check_fraction`` does the same
    for streaming repairs.

    Hardening knobs (all off by default — a default server behaves like
    the PR 6 server, minus the dangling futures):

    * ``dedup``              coalesce concurrent same-key requests onto
      one in-flight future (exactly one compute per unique key),
    * ``max_pending``        bounded queue; beyond it submits fail with
      ``ServerOverloaded`` (None = unbounded),
    * ``quota``              per-caller token-bucket rate limit
      (:class:`~repro.serve.admission.QuotaConfig`; None = no quotas),
    * ``default_deadline_s`` deadline applied to requests that don't pass
      their own ``deadline_s`` (None = no deadline); expired queued work
      is evicted, never dispatched,
    * ``retry``              :class:`~repro.serve.faults.RetryPolicy` for
      transient-fault retries and engine fallback,
    * ``faults``             a seeded :class:`~repro.serve.faults.FaultPlan`
      for chaos runs (None in production),
    * ``persist_dir``        directory for the digest-verified disk cache
      tier (None = memory-only), ``persist_bytes`` its byte budget.
    """

    max_batch: int = 8
    max_delay_s: float = 0.01
    cache_bytes: int = 64 << 20
    parity_fraction: float = 0.0
    warm_buckets: tuple = ()
    mis2_options: Optional[Mis2Options] = None
    delta_check_fraction: float = 0.0
    single_fast_path: bool = True
    backend: Optional[Backend] = None
    poll_interval_s: float = 0.002
    dedup: bool = True
    max_pending: Optional[int] = None
    quota: Optional[QuotaConfig] = None
    default_deadline_s: Optional[float] = None
    retry: RetryPolicy = RetryPolicy()
    faults: Optional[FaultPlan] = None
    persist_dir: Optional[str] = None
    persist_bytes: int = 256 << 20


@dataclass
class ServeStats:
    """Per-server counters, mirrored into the ``repro.obs`` registry
    (``serve.requests`` / ``serve.dispatches`` / ``serve.batched_graphs``
    / ``serve.single_dispatches`` / ``serve.dedup_hits`` / ``serve.shed``
    / ``serve.expired`` / ``serve.retries`` / ``serve.fallbacks``).  All
    timestamps come from ``time.perf_counter()`` — the one clock every
    timing in this repo reports on (uptime windows, cache timings, span
    durations), so derived intervals are mutually comparable and
    monotone."""

    requests: int = 0
    dispatches: int = 0
    batched_graphs: int = 0
    single_dispatches: int = 0
    dedup_hits: int = 0
    shed: int = 0
    expired: int = 0
    retries: int = 0
    fallbacks: int = 0
    started_at: float = field(default_factory=time.perf_counter)
    window_started_at: float = field(default_factory=time.perf_counter)

    def bump(self, name: str, n: int = 1) -> None:
        setattr(self, name, getattr(self, name) + n)
        _OBS.counter(f"serve.{name}").inc(n)


class Server:
    """Persistent graph-algorithm service over the ``repro`` facade."""

    def __init__(self, config: Optional[ServerConfig] = None):
        self.config = config if config is not None else ServerConfig()
        self.persist: Optional[PersistTier] = None
        if self.config.persist_dir is not None:
            self.persist = PersistTier(self.config.persist_dir,
                                       max_bytes=self.config.persist_bytes,
                                       faults=self.config.faults)
        self.cache = ResultCache(max_bytes=self.config.cache_bytes,
                                 parity_fraction=self.config.parity_fraction,
                                 persist=self.persist)
        self.batcher = Batcher(max_batch=self.config.max_batch,
                               max_delay_s=self.config.max_delay_s)
        self.admission = AdmissionController(
            max_pending=self.config.max_pending, quota=self.config.quota)
        self.warm = WarmRegistry()
        self.stats = ServeStats()
        # two-lock discipline: ``_lock`` guards queue/cache/ledger state
        # and is only ever held for O(bookkeeping); ``_dispatch_lock``
        # serializes the compute side of pump() (engine calls, retries,
        # backoff sleeps, injected slow faults) so a degraded dispatch
        # can never block submit() or cache-hit lookups
        self._lock = threading.RLock()
        self._dispatch_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._closed = False
        # key -> primary future, while queued-or-dispatching (dedup joins)
        self._inflight: dict[tuple, object] = {}
        # key -> digest already served for that key (never contradicted)
        self._ledger: OrderedDict[tuple, str] = OrderedDict()
        # EWMA of seconds per dispatch, for the admission wait estimate
        self._service_ewma: Optional[float] = None
        opts = self.config.mis2_options or Mis2Options()
        self.warm.warm(WarmSpec(self.config.max_batch, rows, width,
                                opts.priority, opts.max_iters)
                       for rows, width in self.config.warm_buckets)

    # -- request intake -----------------------------------------------------

    def _normalize(self, kind: str, params: dict) -> dict:
        if kind == "mis2":
            options = params.get("options")
            if options is None:
                options = self.config.mis2_options or Mis2Options()
            return {"options": options}
        if kind == "color":
            return {"max_rounds": params.get("max_rounds", 256)}
        if kind == "coarsen":
            return {"method": params.get("method", "two_phase"),
                    "options": params.get("options"),
                    "min_secondary_neighbors":
                        params.get("min_secondary_neighbors", 2)}
        if kind == "amg_setup":
            out = dict(params)
            out.setdefault("aggregation", "two_phase")
            return out
        raise ValueError(f"unknown request kind {kind!r} (one of {KINDS})")

    #: engines per kind that never materialize the monolithic padded ELL
    #: (``None`` = auto-selection, which routes past HYBRID_AUTO_BYTES to
    #: the hybrid layout on its own)
    _DEGREE_AWARE = {"mis2": (None, "pallas_hybrid"),
                     "coarsen": (None, "pallas_hybrid"),
                     "color": ("luby_hybrid",)}

    def _layout_guard(self, req: PendingRequest) -> Optional[LayoutInfeasible]:
        """Admission-side layout feasibility: a request whose engine is
        ELL-bound on a graph whose padded-ELL estimate exceeds
        ``ELL_BYTE_LIMIT`` would die in a host OOM *after* consuming queue
        capacity — shed it up front with the typed error instead."""
        from ..graphs.hybrid import ELL_BYTE_LIMIT

        if req.graph.ell_bytes_estimate() <= ELL_BYTE_LIMIT:
            return None
        if req.engine in self._DEGREE_AWARE.get(req.kind, ()):
            return None
        return LayoutInfeasible(
            f"{req.kind} request with engine={req.engine!r} needs the "
            f"monolithic padded ELL "
            f"(~{req.graph.ell_bytes_estimate():,} bytes > limit "
            f"{ELL_BYTE_LIMIT:,}); resubmit with a degree-aware engine "
            f"({self._DEGREE_AWARE.get(req.kind) or 'none for this kind'})")

    def _count_shed(self, reason: str) -> None:
        self.stats.shed += 1
        _OBS.counter("serve.shed", labels={"reason": reason}).inc()

    def _rejected(self, req: PendingRequest, err: ServeError):
        """Fail a request at admission: typed error on its future,
        ``serve.shed{reason=...}`` counted — the caller sees the error on
        ``result()``, never an exception out of ``submit`` itself."""
        self._count_shed(err.reason)
        req.future.set_exception(err)
        return req.future

    def submit(self, kind: str, graph, *, engine: Optional[str] = None,
               backend: Optional[Backend] = None,
               deadline_s: Optional[float] = None,
               caller: str = "default", **params):
        """Enqueue one request; returns a ``concurrent.futures.Future``.

        The returned future always resolves — with a digest-correct
        ``Result`` or a typed :class:`~repro.serve.errors.ServeError`
        (shed, expired, closed, failed).  ``submit`` itself only raises
        for malformed requests (unknown kind/params).

        A cache hit resolves the future immediately (optionally parity-
        checked) and bypasses admission.  Otherwise the request passes
        admission control, then — under ``dedup`` — coalesces onto any
        in-flight computation for the same ``(kind, digest, engine,
        options)`` key (joiners share the primary's future, including its
        deadline fate), else joins its continuous-batching group.

        ``deadline_s`` is a relative deadline (falls back to
        ``config.default_deadline_s``); expired queued requests are
        evicted with ``DeadlineExceeded``, never dispatched.  ``caller``
        is the per-caller quota identity.
        """
        gh = as_graph(graph)
        norm = self._normalize(kind, params)
        be = backend if backend is not None else self.config.backend
        engine_token = engine if engine is not None else "auto"
        key = (kind, gh.digest, engine_token, _freeze(norm))
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        req = PendingRequest(kind=kind, graph=gh, params=norm, engine=engine,
                             backend=be, cache_key=key, caller=caller)
        with self._lock, _obs_span("serve.submit", kind=kind) as sp:
            self.stats.bump("requests")
            if self._closed:
                sp.annotate(outcome="closed")
                return self._rejected(req, ServerClosed(
                    "server is stopped; submit on a new Server"))
            with _obs_span("serve.cache_lookup", kind=kind):
                cached = self.cache.lookup(
                    key, recompute=lambda: self._parity_referent(req))
            if cached is not None:
                sp.annotate(cache="hit")
                req.future.set_result(cached)
                return req.future
            sp.annotate(cache="miss")
            layout_err = self._layout_guard(req)
            if layout_err is not None:
                sp.annotate(outcome=f"shed:{layout_err.reason}")
                return self._rejected(req, layout_err)
            joining = self.config.dedup and key in self._inflight
            try:
                self.admission.admit(
                    caller=caller, pending=len(self.batcher),
                    deadline_s=deadline_s, est_wait_s=self._est_wait(),
                    joining=joining)
            except ServeError as err:
                sp.annotate(outcome=f"shed:{err.reason}")
                return self._rejected(req, err)
            if joining:
                sp.annotate(outcome="dedup")
                self.stats.bump("dedup_hits")
                return self._inflight[key]
            now = time.perf_counter()
            if deadline_s is not None:
                req.deadline = now + deadline_s
            if self.config.dedup:
                self._inflight[key] = req.future
            self.batcher.add(req, now)
        return req.future

    def request(self, kind: str, graph, *, engine: Optional[str] = None,
                backend: Optional[Backend] = None, **params):
        """Synchronous convenience: submit, flush, return the Result."""
        fut = self.submit(kind, graph, engine=engine, backend=backend,
                          **params)
        self.flush()
        return fut.result()

    def open_stream(self, graph, *,
                    options: Optional[Mis2Options] = None) -> StreamSession:
        """A streaming MIS-2 session governed by this server's config
        (``delta_check_fraction`` and the fault plan taken from the
        serving config)."""
        if self._closed:
            raise ServerClosed("server is stopped; open streams on a "
                               "new Server")
        return StreamSession(
            graph, options=options,
            check_fraction=self.config.delta_check_fraction,
            faults=self.config.faults)

    # -- event loop ---------------------------------------------------------

    def pump(self, now: Optional[float] = None, force: bool = False) -> int:
        """Evict expired requests, dispatch every due group; returns the
        number of groups served.

        Queue surgery happens under the state lock; the dispatches
        themselves run holding only the dispatch lock, so concurrent
        submits and cache hits proceed even while a dispatch is deep in
        retry backoff or an injected slow fault."""
        with self._dispatch_lock:
            with self._lock:
                if self._closed:
                    return 0
                t = time.perf_counter() if now is None else now
                for req in self.batcher.pop_expired(t):
                    self.stats.bump("expired")
                    self._finish_error(req, DeadlineExceeded(
                        f"deadline expired after {t - req.enqueued_at:.4f}s "
                        "in queue; request evicted before dispatch"),
                        shed_reason="expired")
                groups = self.batcher.due(t, force=force)
            for _, reqs in groups:
                self._dispatch(reqs)
            return len(groups)

    def flush(self) -> int:
        """Dispatch everything pending regardless of deadlines."""
        return self.pump(force=True)

    def start(self) -> "Server":
        """Run the pump on a daemon thread (idempotent)."""
        with self._lock:
            if self._closed:
                raise ServerClosed("cannot start a stopped server")
            if self._thread is not None:
                return self
            self._stop.clear()
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="repro-serve")
            self._thread.start()
        return self

    def stop(self) -> None:
        """Terminal shutdown: the pump thread stops, every queued future
        fails with :class:`~repro.serve.errors.ServerClosed`, and every
        later ``submit`` returns a future already carrying it.  Requests
        mid-dispatch complete normally — their group already left the
        queue, the drain cannot touch them, and in-flight retry backoff
        is cut short by the stop event.  Idempotent."""
        with self._lock:
            first = not self._closed
            self._closed = True
            thread = self._thread
            self._thread = None
        self._stop.set()
        if thread is not None:
            thread.join()
        if not first:
            return
        with self._lock:
            for req in self.batcher.drain():
                self._finish_error(req, ServerClosed(
                    "server stopped with the request still queued"),
                    shed_reason="closed")
            self._inflight.clear()

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self.pump()
            except Exception as err:  # noqa: BLE001 - the pump thread must
                self._pump_crashed(err)     # outlive any single failure
            with self._lock:
                delay = self.batcher.next_deadline(time.perf_counter())
            if delay is None:
                delay = self.config.poll_interval_s
            self._stop.wait(min(delay, self.config.poll_interval_s)
                            if delay > 0 else 0.0)

    def _pump_crashed(self, err: Exception) -> None:
        """Last-ditch pump-thread containment: an exception that escapes
        ``pump()`` (anything outside dispatch's own typed fan-out) would
        otherwise kill the daemon thread silently — every queued future
        then hangs forever and so does all later work.  Instead: fail
        everything queued with a typed error, count it, keep pumping."""
        _OBS.counter("serve.pump_errors").inc()
        wrapped = err if isinstance(err, ServeError) else EngineFailure(
            f"pump loop crashed: {err}")
        if wrapped is not err:
            wrapped.__cause__ = err
        with self._lock:
            for req in self.batcher.drain():
                self._finish_error(req, wrapped)

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- dispatch -----------------------------------------------------------

    def _est_wait(self) -> Optional[float]:
        """EWMA-based queue-wait estimate for deadline-aware shedding
        (None until the server has dispatched at least once)."""
        if self._service_ewma is None:
            return None
        depth = len(self.batcher)
        return self._service_ewma * (1.0 + depth / self.config.max_batch)

    def _resolve_engine(self, req: PendingRequest) -> Optional[str]:
        """Per-request engine auto-selection (at dispatch time, with the
        request's own backend — never a server-global choice)."""
        if req.engine is not None:
            return req.engine
        be = resolve_backend(req.backend)
        if req.kind == "mis2":
            return default_mis2_engine(be, req.params.get("options"),
                                       req.graph)
        if req.kind == "amg_setup":
            return default_multilevel_engine(be)
        return None     # color/coarsen: the facade default is the engine

    def _direct(self, req: PendingRequest):
        """The direct facade call for one request — the bit-identity
        referent (used for single dispatch and parity recomputation)."""
        kw = dict(req.params)
        kw["backend"] = req.backend
        if req.kind == "mis2":
            return facade.mis2(req.graph, engine=req.engine, **kw)
        if req.kind == "color":
            if req.engine is not None:
                kw["engine"] = req.engine
            return facade.color(req.graph, **kw)
        if req.kind == "coarsen":
            if req.engine is not None:
                kw["mis2_engine"] = req.engine
            return facade.coarsen(req.graph, **kw)
        if req.kind == "amg_setup":
            return facade.amg_setup(req.graph, engine=req.engine, **kw)
        raise ValueError(req.kind)

    def _parity_referent(self, req: PendingRequest):
        """Recompute a cache hit for the parity assertion.

        For engine-agnostic mis2 requests the referent is the ``dense``
        engine: every engine is digest-identical (the invariant the cache
        relies on), and dense pads to pow2 buckets, so parity checks over
        arbitrary graph shapes reuse a bounded set of compiled programs
        instead of jit-specializing per exact adjacency shape.
        """
        if req.kind == "mis2" and req.engine is None:
            from ..graphs.hybrid import ELL_BYTE_LIMIT

            kw = dict(req.params)
            kw["backend"] = req.backend
            # the dense referent pads to [V, max_degree]: on a graph past
            # the ELL budget recompute with the request's own (hybrid)
            # engine path instead — parity then checks run-to-run
            # determinism rather than cross-engine agreement
            if req.graph.ell_bytes_estimate() > ELL_BYTE_LIMIT:
                return self._direct(req)
            return facade.mis2(req.graph, engine="dense", **kw)
        return self._direct(req)

    def _dispatch(self, reqs: list[PendingRequest]) -> None:
        """One group's compute + fan-out (runs under the dispatch lock
        only; the state lock is taken just around the finish loops)."""
        self.stats.bump("dispatches")
        t0 = time.perf_counter()
        try:
            with _obs_span("serve.dispatch", kind=reqs[0].kind,
                           group=len(reqs)):
                results = self._compute_resilient(reqs)
        except Exception as err:    # typed fan-out: callers never see a hang
            wrapped = err if isinstance(err, ServeError) else EngineFailure(
                f"dispatch failed for kind={reqs[0].kind!r}: {err}")
            if wrapped is not err:
                wrapped.__cause__ = err
            with self._lock:
                for req in reqs:
                    self._finish_error(req, wrapped)
            return
        except BaseException as err:  # noqa: BLE001 - KeyboardInterrupt etc:
            with self._lock:          # fan out raw, then re-raise
                for req in reqs:
                    self._finish_error(req, err)
            raise
        sample = time.perf_counter() - t0
        with self._lock:
            self._service_ewma = sample if self._service_ewma is None else (
                0.3 * sample + 0.7 * self._service_ewma)
            for req, res in zip(reqs, results):
                try:
                    self._finish_result(req, res)
                except Exception as err:  # noqa: BLE001 - delivery failures
                    # (cache/persist/ledger) must fail THIS future typed,
                    # not leak out of pump() and strand the rest
                    wrapped = EngineFailure(
                        f"result delivery failed for kind={req.kind!r}: "
                        f"{err}")
                    wrapped.__cause__ = err
                    self._finish_error(req, wrapped)

    def _compute_resilient(self, reqs: list[PendingRequest]) -> list:
        """The compute body under the retry/fallback policy.

        Transient injected faults (the recoverable-blip model) retry the
        whole group with capped exponential backoff, counted per site in
        ``serve.retries{site}``.  A persistent fault, an exhausted retry
        budget, or a real engine exception degrades each request to its
        fallback engine (``serve.fallbacks{from,to}``) — the host/dense
        referent of the engine contract.  Only if the fallback *also*
        fails does the group error (wrapped ``EngineFailure``).
        """
        faults = self.config.faults
        policy = self.config.retry
        attempt = 1
        while True:
            try:
                if faults is not None:
                    faults.fire("dispatch")
                    faults.fire("engine")
                return self._compute(reqs)
            except InjectedFault as err:
                if err.transient and attempt < policy.max_attempts:
                    self.stats.retries += 1
                    _OBS.counter("serve.retries",
                                 labels={"site": err.site}).inc()
                    # interruptible backoff (holds the dispatch lock, never
                    # the state lock): stop() cuts the wait short and the
                    # retry then completes the in-flight group normally
                    self._stop.wait(policy.backoff_s(attempt))
                    attempt += 1
                    continue
                if policy.fallback:
                    return [self._fallback(req, err) for req in reqs]
                raise
            except ServeError:
                raise
            except Exception as err:
                if policy.fallback:
                    return [self._fallback(req, err) for req in reqs]
                raise

    def _fallback(self, req: PendingRequest, cause: Exception):
        """Degrade one request to its fallback engine (the engine-contract
        referent).  The result flows through the same digest ledger as
        every response, so a degraded answer is held to bit-identity with
        whatever this key served before."""
        from_token = req.engine if req.engine is not None else "auto"
        to_engine = FALLBACK_ENGINES.get(req.kind)
        to_token = to_engine if to_engine is not None else "default"
        self.stats.fallbacks += 1
        _OBS.counter("serve.fallbacks",
                     labels={"from": from_token, "to": to_token}).inc()
        fb_req = dataclasses.replace(req, engine=to_engine)
        try:
            return self._direct(fb_req)
        except Exception as err:
            failure = EngineFailure(
                f"kind={req.kind!r} failed on the primary engine "
                f"({cause}) and again on fallback {to_token!r}: {err}")
            failure.__cause__ = cause
            raise failure from err

    def _finish_result(self, req: PendingRequest, res) -> None:
        """Resolve one future: digest-ledger check, cache insert, result.

        The ledger refuses to serve two different digests for one key —
        under the determinism invariant equal keys must produce equal
        bytes, so a conflict means corruption (the response is failed
        with ``DigestMismatch``, never served)."""
        prev = self._ledger.get(req.cache_key)
        if prev is not None and prev != res.digest:
            self._finish_error(req, DigestMismatch(
                f"key {req.cache_key[:3]} previously served digest {prev}, "
                f"this compute produced {res.digest}"))
            return
        if prev is None:
            self._ledger[req.cache_key] = res.digest
            if len(self._ledger) > LEDGER_CAP:
                self._ledger.popitem(last=False)
        else:
            self._ledger.move_to_end(req.cache_key)
        if self._inflight.get(req.cache_key) is req.future:
            del self._inflight[req.cache_key]
        self.cache.insert(req.cache_key, res)
        if not req.future.done():
            req.future.set_result(res)

    def _finish_error(self, req: PendingRequest, err: BaseException,
                      shed_reason: Optional[str] = None) -> None:
        if self._inflight.get(req.cache_key) is req.future:
            del self._inflight[req.cache_key]
        if shed_reason is not None:
            self._count_shed(shed_reason)
        if not req.future.done():
            req.future.set_exception(err)

    def _compute(self, reqs: list[PendingRequest]) -> list:
        if len(reqs) == 1 and self.config.single_fast_path:
            self.stats.bump("single_dispatches")
            return [self._direct(reqs[0])]
        self.stats.bump("batched_graphs", len(reqs))
        return self._batched(reqs)

    def _batched(self, reqs: list[PendingRequest]) -> list:
        """One batched dispatch for a homogeneous group (same kind/params,
        guaranteed by the batcher's group key)."""
        kind, params = reqs[0].kind, reqs[0].params
        graphs = [r.graph for r in reqs]
        backend = reqs[0].backend
        if kind == "mis2":
            return self._mis2_batched(graphs, params["options"])
        if kind == "color":
            batch = facade.color_batch(graphs, backend=backend, **params)
            return list(batch.results)
        if kind == "coarsen":
            batch = facade.coarsen_batch(graphs, backend=backend, **params)
            return list(batch.results)
        if kind == "amg_setup":
            kw = dict(params)
            engine = self._resolve_engine(reqs[0])
            batch = facade.amg_setup_batch(graphs, engine=engine,
                                           backend=backend, **kw)
            return list(batch.results)
        raise ValueError(kind)

    @staticmethod
    def _padded_np(gh, rows: int, width: int) -> np.ndarray:
        """Host copy of the padded ELL adjacency, cached on the handle —
        the request path stacks buckets in numpy (one device transfer per
        dispatch, inside the AOT call) instead of paying eager jnp.stack
        primitive dispatches per request."""
        key = f"serve_padded_np({rows},{width})"
        if key not in gh._cache:
            gh._cache[key] = np.asarray(gh.padded_ell(rows, width).neighbors)
        return gh._cache[key]

    def _mis2_batched(self, graphs: Sequence,
                      options: Mis2Options) -> list[Mis2Result]:
        """Bucketed mis2 dispatch through the warm AOT executables.

        Mirrors ``batch.pipeline._mis2_batch_impl`` — same bucket policy,
        same per-graph ``id_bits``, same fixed point — so per-graph
        results are bit-identical to every single-graph engine; but each
        bucket runs through :class:`WarmRegistry`, so a configured shape
        costs zero request-path compiles at any occupancy.
        """
        t0 = time.perf_counter()
        by_shape: dict[tuple[int, int], list[int]] = {}
        for i, gh in enumerate(graphs):
            by_shape.setdefault(bucket_shape(gh), []).append(i)
        out: list = [None] * len(graphs)
        with _obs_span("serve.batch_mis2", graphs=len(graphs),
                       buckets=len(by_shape)) as sp:
            for (rows, width), idxs in sorted(by_shape.items()):
                nv = [graphs[i].num_vertices for i in idxs]
                nbrs = np.stack([self._padded_np(graphs[i], rows, width)
                                 for i in idxs])
                valid = np.arange(rows)[None, :] < np.asarray(nv)[:, None]
                bits = np.asarray([id_bits(v) for v in nv], dtype=np.uint32)
                t, iters = self.warm.run_mis2_bucket(
                    nbrs, valid, bits, options.priority, options.max_iters)
                t_np, iters_np = np.asarray(t), np.asarray(iters)
                for j, gi in enumerate(idxs):
                    tj = t_np[j, :nv[j]]
                    out[gi] = (tj == np.uint32(IN), int(iters_np[j]),
                               not is_undecided(tj).any())
        per = (time.perf_counter() - t0) / max(1, len(out))
        results = [Mis2Result(in_set, iters, conv, per,
                              engine="dense_batched")
                   for in_set, iters, conv in out]
        span_dict = sp.to_dict()
        platform = backend_platform(resolve_backend(self.config.backend))
        for r in results:
            r.provenance = Provenance("mis2", "dense_batched", platform,
                                      r.digest, span_dict)
        return results

    # -- observability ------------------------------------------------------

    def reset_window(self) -> None:
        """Start a new uptime accounting window (compile churn counters)."""
        with self._lock:
            self.warm.reset_window()
            self.stats.window_started_at = time.perf_counter()

    def server_stats(self) -> dict:
        """Counters for dashboards/tests: requests, batching, dedup,
        shedding, retries/fallbacks, cache (memory + persistent tier),
        jit churn (total and since ``reset_window()``).

        Every counter here is also live in the process-wide ``repro.obs``
        registry (``serve.*`` / ``serve.cache.*`` / ``serve.persist.*`` /
        ``serve.warm.*``) — ``obs.snapshot()`` or the Prometheus exporter
        sees the same numbers without going through a ``Server``
        reference; this dict is the per-instance view.  All intervals are
        ``perf_counter`` deltas (monotone, same clock as spans and cache
        timings)."""
        with self._lock:
            now = time.perf_counter()
            out = {
                "requests": self.stats.requests,
                "dispatches": self.stats.dispatches,
                "batched_graphs": self.stats.batched_graphs,
                "single_dispatches": self.stats.single_dispatches,
                "dedup_hits": self.stats.dedup_hits,
                "shed": self.stats.shed,
                "expired": self.stats.expired,
                "retries": self.stats.retries,
                "fallbacks": self.stats.fallbacks,
                "pending": len(self.batcher),
                "inflight_keys": len(self._inflight),
                "ledger_keys": len(self._ledger),
                "closed": self._closed,
                "quota_denials": dict(self.admission.denials),
                "uptime_s": now - self.stats.started_at,
                "cache": self.cache.stats.as_dict(),
                "compiles": {
                    "startup_aot": self.warm.startup_compiles,
                    "warmed_shapes": self.warm.num_executables,
                    "runtime_cold": self.warm.runtime_compiles,
                    "window_s": now - self.stats.window_started_at,
                    "runtime_cold_window":
                        self.warm.runtime_compiles_window,
                },
            }
            if self.persist is not None:
                out["persist"] = self.persist.stats.as_dict()
            return out


def warm_buckets_for(graphs) -> tuple:
    """The distinct ``(rows, width)`` bucket shapes a graph fleet lands in
    — convenience for building a ``ServerConfig`` from a known workload."""
    return tuple(sorted({bucket_shape(as_graph(g)) for g in graphs}))
