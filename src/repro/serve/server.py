"""The persistent graph service: one process, many callers, zero rebits.

``Server`` accepts concurrent ``mis2`` / ``color`` / ``coarsen`` /
``amg_setup`` requests and serves every one with a result bit-identical
to the direct facade call — batching, caching, and warm executables are
throughput machinery, never semantics (the repo's determinism invariant
is what makes that composition safe).

Request path::

    submit() -> cache lookup (digest-keyed, provably-safe hits)
             -> batcher group (deadline-or-full continuous batching)
    pump()   -> batched dispatch over GraphBatch buckets
                (mis2 through the warm AOT executables; single stragglers
                 through the per-request auto-selected resident engine)
             -> cache insert + future resolution

``pump()`` is the explicit event-loop step (deterministic for tests and
CI); ``start()`` runs it on a daemon thread for real concurrent callers.
Engine auto-selection happens per request at dispatch time via
``api.backend.default_mis2_engine`` / ``default_multilevel_engine`` with
the *request's* backend — a server booted on CPU serves a TPU-placed
request with the resident engine, not a server-global default.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from ..api import facade
from ..api.backend import (
    Backend,
    backend_platform,
    default_mis2_engine,
    default_multilevel_engine,
    resolve_backend,
)
from ..obs import Provenance
from ..obs import metrics as _OBS
from ..obs import span as _obs_span
from ..api.result import Mis2Result
from ..batch.container import bucket_shape
from ..core.mis2 import IN, Mis2Options, is_undecided
from ..core.tuples import id_bits
from ..graphs.handle import as_graph
from .batcher import Batcher, PendingRequest, _freeze
from .cache import ResultCache
from .streaming import StreamSession
from .warm import WarmRegistry, WarmSpec

KINDS = ("mis2", "color", "coarsen", "amg_setup")


@dataclass(frozen=True)
class ServerConfig:
    """Serving policy: batching budget, cache budget, warm shapes.

    ``warm_buckets`` lists ``(rows, width)`` bucket shapes (the
    ``repro.batch`` power-of-two classes) to AOT-compile at startup at
    batch capacity ``max_batch`` for the configured mis2 options; live
    shapes outside the list still work, they just pay a counted runtime
    compile.  ``parity_fraction`` recomputes that fraction of cache hits
    and asserts digest equality; ``delta_check_fraction`` does the same
    for streaming repairs.
    """

    max_batch: int = 8
    max_delay_s: float = 0.01
    cache_bytes: int = 64 << 20
    parity_fraction: float = 0.0
    warm_buckets: tuple = ()
    mis2_options: Optional[Mis2Options] = None
    delta_check_fraction: float = 0.0
    single_fast_path: bool = True
    backend: Optional[Backend] = None
    poll_interval_s: float = 0.002


@dataclass
class ServeStats:
    """Per-server counters, mirrored into the ``repro.obs`` registry
    (``serve.requests`` / ``serve.dispatches`` / ``serve.batched_graphs``
    / ``serve.single_dispatches``).  All timestamps come from
    ``time.perf_counter()`` — the one clock every timing in this repo
    reports on (uptime windows, cache timings, span durations), so
    derived intervals are mutually comparable and monotone."""

    requests: int = 0
    dispatches: int = 0
    batched_graphs: int = 0
    single_dispatches: int = 0
    started_at: float = field(default_factory=time.perf_counter)
    window_started_at: float = field(default_factory=time.perf_counter)

    def bump(self, name: str, n: int = 1) -> None:
        setattr(self, name, getattr(self, name) + n)
        _OBS.counter(f"serve.{name}").inc(n)


class Server:
    """Persistent graph-algorithm service over the ``repro`` facade."""

    def __init__(self, config: Optional[ServerConfig] = None):
        self.config = config if config is not None else ServerConfig()
        self.cache = ResultCache(max_bytes=self.config.cache_bytes,
                                 parity_fraction=self.config.parity_fraction)
        self.batcher = Batcher(max_batch=self.config.max_batch,
                               max_delay_s=self.config.max_delay_s)
        self.warm = WarmRegistry()
        self.stats = ServeStats()
        self._lock = threading.RLock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        opts = self.config.mis2_options or Mis2Options()
        self.warm.warm(WarmSpec(self.config.max_batch, rows, width,
                                opts.priority, opts.max_iters)
                       for rows, width in self.config.warm_buckets)

    # -- request intake -----------------------------------------------------

    def _normalize(self, kind: str, params: dict) -> dict:
        if kind == "mis2":
            options = params.get("options")
            if options is None:
                options = self.config.mis2_options or Mis2Options()
            return {"options": options}
        if kind == "color":
            return {"max_rounds": params.get("max_rounds", 256)}
        if kind == "coarsen":
            return {"method": params.get("method", "two_phase"),
                    "options": params.get("options"),
                    "min_secondary_neighbors":
                        params.get("min_secondary_neighbors", 2)}
        if kind == "amg_setup":
            out = dict(params)
            out.setdefault("aggregation", "two_phase")
            return out
        raise ValueError(f"unknown request kind {kind!r} (one of {KINDS})")

    def submit(self, kind: str, graph, *, engine: Optional[str] = None,
               backend: Optional[Backend] = None, **params):
        """Enqueue one request; returns a ``concurrent.futures.Future``.

        A cache hit resolves the future immediately (optionally parity-
        checked); otherwise the request joins its continuous-batching
        group and resolves at the next full/deadline dispatch.
        """
        gh = as_graph(graph)
        norm = self._normalize(kind, params)
        be = backend if backend is not None else self.config.backend
        engine_token = engine if engine is not None else "auto"
        key = (kind, gh.digest, engine_token, _freeze(norm))
        req = PendingRequest(kind=kind, graph=gh, params=norm, engine=engine,
                             backend=be, cache_key=key)
        with self._lock, _obs_span("serve.submit", kind=kind) as sp:
            self.stats.bump("requests")
            with _obs_span("serve.cache_lookup", kind=kind):
                cached = self.cache.lookup(
                    key, recompute=lambda: self._parity_referent(req))
            if cached is not None:
                sp.annotate(cache="hit")
                req.future.set_result(cached)
                return req.future
            sp.annotate(cache="miss")
            self.batcher.add(req, time.perf_counter())
        return req.future

    def request(self, kind: str, graph, *, engine: Optional[str] = None,
                backend: Optional[Backend] = None, **params):
        """Synchronous convenience: submit, flush, return the Result."""
        fut = self.submit(kind, graph, engine=engine, backend=backend,
                          **params)
        self.flush()
        return fut.result()

    def open_stream(self, graph, *,
                    options: Optional[Mis2Options] = None) -> StreamSession:
        """A streaming MIS-2 session governed by this server's config
        (``delta_check_fraction`` taken from the serving config)."""
        return StreamSession(
            graph, options=options,
            check_fraction=self.config.delta_check_fraction)

    # -- event loop ---------------------------------------------------------

    def pump(self, now: Optional[float] = None, force: bool = False) -> int:
        """Dispatch every due group; returns the number of groups served."""
        with self._lock:
            groups = self.batcher.due(
                time.perf_counter() if now is None else now, force=force)
            for _, reqs in groups:
                self._dispatch(reqs)
            return len(groups)

    def flush(self) -> int:
        """Dispatch everything pending regardless of deadlines."""
        return self.pump(force=True)

    def start(self) -> "Server":
        """Run the pump on a daemon thread (idempotent)."""
        with self._lock:
            if self._thread is not None:
                return self
            self._stop.clear()
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="repro-serve")
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the pump thread and flush whatever is still queued."""
        thread = self._thread
        if thread is None:
            return
        self._stop.set()
        thread.join()
        self._thread = None
        self.flush()

    def _run(self) -> None:
        while not self._stop.is_set():
            self.pump()
            with self._lock:
                delay = self.batcher.next_deadline(time.perf_counter())
            if delay is None:
                delay = self.config.poll_interval_s
            self._stop.wait(min(delay, self.config.poll_interval_s)
                            if delay > 0 else 0.0)

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- dispatch -----------------------------------------------------------

    def _resolve_engine(self, req: PendingRequest) -> Optional[str]:
        """Per-request engine auto-selection (at dispatch time, with the
        request's own backend — never a server-global choice)."""
        if req.engine is not None:
            return req.engine
        be = resolve_backend(req.backend)
        if req.kind == "mis2":
            return default_mis2_engine(be, req.params.get("options"))
        if req.kind == "amg_setup":
            return default_multilevel_engine(be)
        return None     # color/coarsen: the facade default is the engine

    def _direct(self, req: PendingRequest):
        """The direct facade call for one request — the bit-identity
        referent (used for single dispatch and parity recomputation)."""
        kw = dict(req.params)
        kw["backend"] = req.backend
        if req.kind == "mis2":
            return facade.mis2(req.graph, engine=req.engine, **kw)
        if req.kind == "color":
            return facade.color(req.graph, **kw)
        if req.kind == "coarsen":
            if req.engine is not None:
                kw["mis2_engine"] = req.engine
            return facade.coarsen(req.graph, **kw)
        if req.kind == "amg_setup":
            return facade.amg_setup(req.graph, engine=req.engine, **kw)
        raise ValueError(req.kind)

    def _parity_referent(self, req: PendingRequest):
        """Recompute a cache hit for the parity assertion.

        For engine-agnostic mis2 requests the referent is the ``dense``
        engine: every engine is digest-identical (the invariant the cache
        relies on), and dense pads to pow2 buckets, so parity checks over
        arbitrary graph shapes reuse a bounded set of compiled programs
        instead of jit-specializing per exact adjacency shape.
        """
        if req.kind == "mis2" and req.engine is None:
            kw = dict(req.params)
            kw["backend"] = req.backend
            return facade.mis2(req.graph, engine="dense", **kw)
        return self._direct(req)

    def _dispatch(self, reqs: list[PendingRequest]) -> None:
        self.stats.bump("dispatches")
        try:
            with _obs_span("serve.dispatch", kind=reqs[0].kind,
                           group=len(reqs)):
                if len(reqs) == 1 and self.config.single_fast_path:
                    self.stats.bump("single_dispatches")
                    results = [self._direct(reqs[0])]
                else:
                    self.stats.bump("batched_graphs", len(reqs))
                    results = self._batched(reqs)
        except BaseException as err:    # noqa: BLE001 - fan out to callers
            for req in reqs:
                if not req.future.done():
                    req.future.set_exception(err)
            return
        for req, res in zip(reqs, results):
            self.cache.insert(req.cache_key, res)
            req.future.set_result(res)

    def _batched(self, reqs: list[PendingRequest]) -> list:
        """One batched dispatch for a homogeneous group (same kind/params,
        guaranteed by the batcher's group key)."""
        kind, params = reqs[0].kind, reqs[0].params
        graphs = [r.graph for r in reqs]
        backend = reqs[0].backend
        if kind == "mis2":
            return self._mis2_batched(graphs, params["options"])
        if kind == "color":
            batch = facade.color_batch(graphs, backend=backend, **params)
            return list(batch.results)
        if kind == "coarsen":
            batch = facade.coarsen_batch(graphs, backend=backend, **params)
            return list(batch.results)
        if kind == "amg_setup":
            kw = dict(params)
            engine = self._resolve_engine(reqs[0])
            batch = facade.amg_setup_batch(graphs, engine=engine,
                                           backend=backend, **kw)
            return list(batch.results)
        raise ValueError(kind)

    @staticmethod
    def _padded_np(gh, rows: int, width: int) -> np.ndarray:
        """Host copy of the padded ELL adjacency, cached on the handle —
        the request path stacks buckets in numpy (one device transfer per
        dispatch, inside the AOT call) instead of paying eager jnp.stack
        primitive dispatches per request."""
        key = f"serve_padded_np({rows},{width})"
        if key not in gh._cache:
            gh._cache[key] = np.asarray(gh.padded_ell(rows, width).neighbors)
        return gh._cache[key]

    def _mis2_batched(self, graphs: Sequence,
                      options: Mis2Options) -> list[Mis2Result]:
        """Bucketed mis2 dispatch through the warm AOT executables.

        Mirrors ``batch.pipeline._mis2_batch_impl`` — same bucket policy,
        same per-graph ``id_bits``, same fixed point — so per-graph
        results are bit-identical to every single-graph engine; but each
        bucket runs through :class:`WarmRegistry`, so a configured shape
        costs zero request-path compiles at any occupancy.
        """
        t0 = time.perf_counter()
        by_shape: dict[tuple[int, int], list[int]] = {}
        for i, gh in enumerate(graphs):
            by_shape.setdefault(bucket_shape(gh), []).append(i)
        out: list = [None] * len(graphs)
        with _obs_span("serve.batch_mis2", graphs=len(graphs),
                       buckets=len(by_shape)) as sp:
            for (rows, width), idxs in sorted(by_shape.items()):
                nv = [graphs[i].num_vertices for i in idxs]
                nbrs = np.stack([self._padded_np(graphs[i], rows, width)
                                 for i in idxs])
                valid = np.arange(rows)[None, :] < np.asarray(nv)[:, None]
                bits = np.asarray([id_bits(v) for v in nv], dtype=np.uint32)
                t, iters = self.warm.run_mis2_bucket(
                    nbrs, valid, bits, options.priority, options.max_iters)
                t_np, iters_np = np.asarray(t), np.asarray(iters)
                for j, gi in enumerate(idxs):
                    tj = t_np[j, :nv[j]]
                    out[gi] = (tj == np.uint32(IN), int(iters_np[j]),
                               not is_undecided(tj).any())
        per = (time.perf_counter() - t0) / max(1, len(out))
        results = [Mis2Result(in_set, iters, conv, per,
                              engine="dense_batched")
                   for in_set, iters, conv in out]
        span_dict = sp.to_dict()
        platform = backend_platform(resolve_backend(self.config.backend))
        for r in results:
            r.provenance = Provenance("mis2", "dense_batched", platform,
                                      r.digest, span_dict)
        return results

    # -- observability ------------------------------------------------------

    def reset_window(self) -> None:
        """Start a new uptime accounting window (compile churn counters)."""
        with self._lock:
            self.warm.reset_window()
            self.stats.window_started_at = time.perf_counter()

    def server_stats(self) -> dict:
        """Counters for dashboards/tests: requests, batching, cache, jit
        churn (total and since ``reset_window()``).

        Every counter here is also live in the process-wide ``repro.obs``
        registry (``serve.*`` / ``serve.cache.*`` / ``serve.warm.*``) —
        ``obs.snapshot()`` or the Prometheus exporter sees the same
        numbers without going through a ``Server`` reference; this dict
        is the per-instance view.  All intervals are ``perf_counter``
        deltas (monotone, same clock as spans and cache timings)."""
        with self._lock:
            now = time.perf_counter()
            return {
                "requests": self.stats.requests,
                "dispatches": self.stats.dispatches,
                "batched_graphs": self.stats.batched_graphs,
                "single_dispatches": self.stats.single_dispatches,
                "pending": len(self.batcher),
                "uptime_s": now - self.stats.started_at,
                "cache": self.cache.stats.as_dict(),
                "compiles": {
                    "startup_aot": self.warm.startup_compiles,
                    "warmed_shapes": self.warm.num_executables,
                    "runtime_cold": self.warm.runtime_compiles,
                    "window_s": now - self.stats.window_started_at,
                    "runtime_cold_window":
                        self.warm.runtime_compiles_window,
                },
            }


def warm_buckets_for(graphs) -> tuple:
    """The distinct ``(rows, width)`` bucket shapes a graph fleet lands in
    — convenience for building a ``ServerConfig`` from a known workload."""
    return tuple(sorted({bucket_shape(as_graph(g)) for g in graphs}))
