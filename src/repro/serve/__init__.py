"""``repro.serve`` — persistent graph service over the ``repro`` facade.

Continuous batching (deadline-or-full coalescing into ``GraphBatch``
buckets), a digest-keyed LRU result cache whose hits are provably
bit-identical to recomputation, a warm-executable registry that
AOT-compiles configured bucket shapes at startup, and a streaming update
mode with exact incremental MIS-2 repair.  See API.md "Serving".

    from repro.serve import Server, ServerConfig

    srv = Server(ServerConfig(warm_buckets=((256, 8),)))
    fut = srv.submit("mis2", graph)
    srv.flush()                      # or srv.start() for a live pump
    result = fut.result()            # bit-identical to repro.mis2(graph)
"""
from .batcher import Batcher, PendingRequest
from .cache import CacheParityError, CacheStats, ResultCache
from .server import KINDS, Server, ServerConfig, ServeStats, warm_buckets_for
from .streaming import RepairStats, StreamSession
from .warm import WarmRegistry, WarmSpec

__all__ = [
    "Server", "ServerConfig", "ServeStats", "KINDS", "warm_buckets_for",
    "ResultCache", "CacheStats", "CacheParityError",
    "WarmRegistry", "WarmSpec",
    "Batcher", "PendingRequest",
    "StreamSession", "RepairStats",
]
