"""``repro.serve`` — persistent graph service over the ``repro`` facade.

Continuous batching (deadline-or-full coalescing into ``GraphBatch``
buckets), a digest-keyed LRU result cache whose hits are provably
bit-identical to recomputation (with an optional digest-verified disk
tier that survives restarts), a warm-executable registry that
AOT-compiles configured bucket shapes at startup, and a streaming update
mode with exact incremental MIS-2 repair.  See API.md "Serving".

The hardened request path adds in-flight dedup (one compute per unique
``(kind, digest, engine, options)`` key), admission control (bounded
queue, per-caller quotas, deadline-aware shedding with typed errors),
and a retry/fallback policy under deterministic seeded fault injection —
every response is a digest-correct ``Result`` or a typed
:class:`~repro.serve.errors.ServeError`; nothing hangs, nothing lies.

    from repro.serve import Server, ServerConfig

    srv = Server(ServerConfig(warm_buckets=((256, 8),)))
    fut = srv.submit("mis2", graph)
    srv.flush()                      # or srv.start() for a live pump
    result = fut.result()            # bit-identical to repro.mis2(graph)
"""
from .admission import AdmissionController, QuotaConfig, TokenBucket
from .batcher import Batcher, PendingRequest
from .cache import CacheParityError, CacheStats, ResultCache
from .errors import (DeadlineExceeded, DigestMismatch, EngineFailure,
                     LayoutInfeasible, QuotaExceeded, ServeError,
                     ServerClosed, ServerOverloaded)
from .faults import (FALLBACK_ENGINES, Fault, FaultPlan, InjectedFault,
                     RetryPolicy)
from .persist import PersistStats, PersistTier
from .server import KINDS, Server, ServerConfig, ServeStats, warm_buckets_for
from .streaming import RepairStats, StreamSession
from .warm import WarmRegistry, WarmSpec

__all__ = [
    "Server", "ServerConfig", "ServeStats", "KINDS", "warm_buckets_for",
    "ResultCache", "CacheStats", "CacheParityError",
    "PersistTier", "PersistStats",
    "WarmRegistry", "WarmSpec",
    "Batcher", "PendingRequest",
    "StreamSession", "RepairStats",
    "AdmissionController", "QuotaConfig", "TokenBucket",
    "ServeError", "ServerClosed", "ServerOverloaded", "QuotaExceeded",
    "DeadlineExceeded", "EngineFailure", "DigestMismatch",
    "LayoutInfeasible",
    "Fault", "FaultPlan", "InjectedFault", "RetryPolicy",
    "FALLBACK_ENGINES",
]
