"""Warm-executable registry: AOT-compile serving bucket shapes at startup.

``jax.jit`` caches per argument shape, so a server that sees a new
``[B, rows, width]`` bucket mid-traffic pays an XLA compile on the request
path — jit churn, the serving analogue of the per-V recompiles PR 2's
bucketing removed.  The registry front-loads that cost: every bucket shape
named in the serving config is lowered and compiled once at startup
(``jax.jit(...).lower(ShapeDtypeStruct...).compile()``) and the resulting
executables are invoked directly on the hot path, bypassing jit dispatch
entirely.

Dispatch-time bucket membership rarely equals the configured capacity, so
the runner pads each bucket's batch dimension up to the warmed ``B`` with
inert members (all-padding rows: ``active=False``, self-loop adjacency) —
the vmapped fixed point is elementwise across members, so padding cannot
perturb real members' bits (the PR 2 invariant), and one executable serves
every occupancy.  Oversized buckets are served in capacity-sized chunks.

Shapes outside the config fall back to the ordinary jitted kernel; each
*distinct* cold (shape, options) key is counted once as a runtime compile
— the ``num_compiles`` accounting style the resident engines introduced —
and exposed per uptime window so an operator can see config drift
(`runtime_compiles > 0` means the config is missing live shapes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from ..batch.pipeline import _mis2_bucket_run
from ..core.mis2 import MAX_ITERS_DEFAULT
from ..obs import metrics as _OBS


@dataclass(frozen=True)
class WarmSpec:
    """One AOT-compiled serving shape: a mis2 bucket ``[B, rows, width]``."""

    batch: int
    rows: int
    width: int
    priority: str = "xorshift_star"
    max_iters: int = MAX_ITERS_DEFAULT

    @property
    def key(self) -> tuple:
        return (self.batch, self.rows, self.width, self.priority,
                self.max_iters)


def _inert_members(spec_batch: int, fill: int, rows: int, width: int):
    """Adjacency / active / bits rows for padding members: self-loop
    neighbors, nothing active — the fixed point decides them in 0 rounds."""
    nbrs = np.broadcast_to(
        np.arange(rows, dtype=np.int32)[None, :, None],
        (fill, rows, width)).copy()
    act = np.zeros((fill, rows), dtype=bool)
    bits = np.ones(fill, dtype=np.uint32)
    return nbrs, act, bits


@dataclass
class WarmRegistry:
    """Holds AOT executables for configured shapes + jit-churn counters."""

    startup_compiles: int = 0
    _exe: dict = field(default_factory=dict)
    _cold: set = field(default_factory=set)
    _cold_window_base: int = 0

    def warm(self, specs) -> int:
        """AOT-compile every spec not yet registered; returns # compiled."""
        done = 0
        for spec in specs:
            if spec.key in self._exe:
                continue
            shapes = (
                jax.ShapeDtypeStruct((spec.batch, spec.rows, spec.width),
                                     np.int32),
                jax.ShapeDtypeStruct((spec.batch, spec.rows), np.bool_),
                jax.ShapeDtypeStruct((spec.batch,), np.uint32),
            )
            lowered = _mis2_bucket_run.lower(
                *shapes, priority=spec.priority, max_iters=spec.max_iters)
            self._exe[spec.key] = lowered.compile()
            self.startup_compiles += 1
            _OBS.counter("serve.warm.startup_compiles").inc()
            done += 1
        return done

    @property
    def num_executables(self) -> int:
        return len(self._exe)

    @property
    def runtime_compiles(self) -> int:
        """Distinct cold (shape, options) keys dispatched since startup."""
        return len(self._cold)

    @property
    def runtime_compiles_window(self) -> int:
        """Cold keys since the last ``reset_window()``."""
        return len(self._cold) - self._cold_window_base

    def reset_window(self) -> None:
        self._cold_window_base = len(self._cold)

    def _find(self, members: int, rows: int, width: int, priority: str,
              max_iters: int) -> Optional[tuple]:
        """Smallest warmed capacity at (rows, width, options) — warmed
        buckets absorb any occupancy by padding/chunking."""
        best = None
        for (b, r, w, p, mi) in self._exe:
            if (r, w, p, mi) == (rows, width, priority, max_iters):
                if best is None or b < best:
                    best = b
        if best is None:
            return None
        return (best, rows, width, priority, max_iters)

    def run_mis2_bucket(self, neighbors, active, bits, priority: str,
                        max_iters: int):
        """Run one stacked mis2 bucket, preferring a warmed executable.

        ``neighbors`` ``[B, rows, width]`` int32, ``active`` ``[B, rows]``
        bool, ``bits`` ``[B]`` uint32 — exactly the `_mis2_bucket_run`
        calling convention.  Returns ``(t [B, rows], iters [B])``.
        """
        members, rows, width = neighbors.shape
        key = self._find(members, rows, width, priority, max_iters)
        if key is None:
            cold = (members, rows, width, priority, max_iters)
            if cold not in self._cold:
                self._cold.add(cold)
                _OBS.counter("serve.warm.runtime_compiles").inc()
            return _mis2_bucket_run(neighbors, active, bits, priority,
                                    max_iters)
        cap = key[0]
        exe = self._exe[key]
        nbrs_np = np.asarray(neighbors)
        act_np = np.asarray(active)
        bits_np = np.asarray(bits)
        t_parts, it_parts = [], []
        for lo in range(0, members, cap):
            hi = min(members, lo + cap)
            n, a, bb = nbrs_np[lo:hi], act_np[lo:hi], bits_np[lo:hi]
            if hi - lo < cap:
                fn, fa, fb = _inert_members(cap, cap - (hi - lo), rows, width)
                n = np.concatenate([n, fn])
                a = np.concatenate([a, fa])
                bb = np.concatenate([bb, fb])
            t, iters = exe(n, a, bb)
            t_parts.append(np.asarray(t)[: hi - lo])
            it_parts.append(np.asarray(iters)[: hi - lo])
        return np.concatenate(t_parts), np.concatenate(it_parts)
