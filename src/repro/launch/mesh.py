"""Production mesh construction (single pod 16x16 / multi-pod 2x16x16).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
device query).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    devs = np.array(jax.devices())
    n = len(devs)
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch (DP): ('pod','data') or ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
