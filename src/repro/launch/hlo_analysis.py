"""While-aware HLO cost analysis (the dry-run profiler).

``compiled.cost_analysis()`` counts a ``while`` body ONCE, but a scanned
48-layer model executes it 48 times — so XLA's flat numbers undercount
FLOPs, bytes and in-loop collectives by ~L x.  This module parses the
post-optimization HLO text, builds the computation call graph, reads each
loop's ``known_trip_count`` from ``backend_config`` (fallback: the loop
condition's comparison constant), and returns trip-scaled totals:

* ``flops``        — 2 * prod(result dims) * prod(contracting dims) per dot
                     (includes dots inside fusions), x trip counts;
* ``bytes``        — operand + result bytes per instruction (zero-cost ops
                     excluded), x trip counts — the same model XLA's
                     "bytes accessed" uses, but loop-aware;
* ``collectives``  — per-class counts / result bytes / ring wire-byte
                     estimates, x trip counts.

This is the profile the §Perf hillclimbing loop reads (together with
``memory_analysis``), since no real TPU timing exists on this host.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

# TPU v5e hardware constants (EXPERIMENTS.md §Roofline) — shared by every
# dry-run/roofline consumer so the analytic cost model has one source
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link (one direction)
HBM_BYTES = 16e9                # v5e HBM per chip
from functools import lru_cache

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

ZERO_COST_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "iota", "reshape", "broadcast",
                 "partition-id", "replica-id"}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS = re.compile(
    r"replica_groups=(\{\{[^}]*\}[^}]*\}|\[[0-9,]+\]<=\[[0-9,]+\])")


def _parse_shapes(type_str):
    """List of (dtype, dims) in a (possibly tuple) type string."""
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, d))
    return out


def _shape_bytes(type_str) -> int:
    total = 0
    for dt, dims in _parse_shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(1, first.count(",") + 1)
    dims = g[1:g.index("]")].split(",")
    return int(dims[1]) if len(dims) == 2 else default


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)   # raw lines
    shapes: dict = field(default_factory=dict)   # instr name -> type str


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k, self.bytes * k,
            {op: {kk: vv * k for kk, vv in rec.items()}
             for op, rec in self.collectives.items()})

    def add(self, other: "HloCost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for op, rec in other.collectives.items():
            mine = self.collectives.setdefault(
                op, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
            for kk, vv in rec.items():
                mine[kk] += vv

    def as_dict(self):
        return {"flops": self.flops, "bytes": self.bytes,
                "collectives": self.collectives}


def parse_computations(hlo_text: str):
    comps = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            name, type_str, _op = m.groups()
            cur.instrs.append(line)
            cur.shapes[name] = type_str
    return comps, entry


def _instr_parts(line):
    m = _INSTR.match(line)
    return m.groups() if m else (None, None, None)


def analyze(hlo_text: str, num_devices: int) -> dict:
    comps, entry = parse_computations(hlo_text)
    memo: dict[str, HloCost] = {}

    def dot_flops(comp: Computation, line: str, type_str: str) -> float:
        res = _parse_shapes(type_str)
        out_elems = 1
        for _, dims in res[:1]:
            for d in dims:
                out_elems *= d
        cm = _CONTRACT.search(line)
        contract = 1
        if cm:
            cdims = [int(x) for x in cm.group(1).split(",") if x != ""]
            # lhs operand shape: first operand after the opcode
            body = line[line.index("dot(") + 4:]
            ops = _OPERAND.findall(body.split(", metadata")[0])
            if ops:
                lhs_type = comp.shapes.get(ops[0])
                if lhs_type:
                    shp = _parse_shapes(lhs_type)
                    if shp:
                        dims = shp[0][1]
                        for c in cdims:
                            if c < len(dims):
                                contract *= dims[c]
        return 2.0 * out_elems * contract

    def cost_of(comp_name: str) -> HloCost:
        if comp_name in memo:
            return memo[comp_name]
        comp = comps.get(comp_name)
        total = HloCost()
        memo[comp_name] = total          # guard (no true recursion in HLO)
        if comp is None:
            return total
        for line in comp.instrs:
            name, type_str, op = _instr_parts(line)
            if op is None:
                continue
            if op == "while":
                tm = _TRIP.search(line)
                trip = int(tm.group(1)) if tm else _cond_trip(line, comps)
                called = _CALLS.findall(line)
                for c in called:
                    total.add(cost_of(c).scaled(trip))
                # while's own tuple shuffling ~ free
                continue
            if op in ("fusion", "call", "map", "reduce", "reduce-window",
                      "scatter", "sort", "select-and-scatter",
                      "conditional"):
                # count any dots inside called computations (flops only)
                for c in _CALLS.findall(line):
                    total.flops += cost_of(c).flops
            if op in COLLECTIVES or (op.endswith("-start") and
                                     op[:-6] in COLLECTIVES):
                base = op[:-6] if op.endswith("-start") else op
                size = _shape_bytes(type_str)
                n = _group_size(line, num_devices)
                if base == "all-reduce":
                    wire = 2 * size * (n - 1) / max(1, n)
                elif base == "all-gather":
                    wire = size * (n - 1) / max(1, n)
                elif base == "reduce-scatter":
                    wire = size * (n - 1)
                elif base == "all-to-all":
                    wire = size * (n - 1) / max(1, n)
                else:
                    wire = size
                rec = total.collectives.setdefault(
                    base, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
                rec["count"] += 1
                rec["result_bytes"] += size
                rec["wire_bytes"] += wire
            if op == "dot":
                total.flops += dot_flops(comp, line, type_str)
            if op in ZERO_COST_OPS:
                continue
            # bytes: result + operands
            b = _shape_bytes(type_str)
            body = line.split(", metadata")[0]
            paren = body.find(op + "(")
            if paren >= 0:
                args = body[paren + len(op) + 1:]
                for oname in _OPERAND.findall(args):
                    ts = comp.shapes.get(oname)
                    if ts:
                        b += _shape_bytes(ts)
            total.bytes += b
        return total

    def _cond_trip(line, comps) -> int:
        m = re.search(r"condition=%?([\w.\-]+)", line)
        if not m:
            return 1
        cond = comps.get(m.group(1))
        if cond is None:
            return 1
        best = 1
        for li in cond.instrs:
            cm = re.search(r"constant\((\d+)\)", li)
            if cm:
                best = max(best, int(cm.group(1)))
        return best

    result = cost_of(entry)
    return result.as_dict()
