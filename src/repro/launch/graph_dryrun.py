"""Dry-run + roofline for the DISTRIBUTED MIS-2 itself on the production
mesh — the paper-representative §Perf cell.

Lowers the shard_map fixpoint for a Laplace3D-100^3-scale graph on the
16x16 (and 2x16x16) mesh from ShapeDtypeStructs, and compares the two
collective schedules:

* ``two_gather``    — gather T then gather M (the direct port);
* ``single_gather`` — gather T once, recompute M locally (beyond-paper).

    PYTHONPATH=src python -m repro.launch.graph_dryrun [--multi-pod]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.dist import _mis2_local_fixpoint, _shard_map
from repro.launch.hlo_analysis import (
    HBM_BW,
    ICI_LINK_BW,
    PEAK_FLOPS_BF16,
    analyze as hlo_analyze,
)
from repro.launch.mesh import make_production_mesh


def lower_variant(v: int, d: int, mesh, single_gather: bool,
                  max_iters: int = 16):
    nd = int(np.prod(list(mesh.shape.values())))
    axis = mesh.axis_names
    # flatten all mesh axes into one logical partition axis via nested spec
    flat = tuple(mesh.axis_names)
    spec_rows = P(flat)
    vp = ((v + nd - 1) // nd) * nd
    nbrs_spec = jax.ShapeDtypeStruct((vp, d), jnp.int32)
    act_spec = jax.ShapeDtypeStruct((vp,), jnp.bool_)

    if single_gather:
        def fn_core(nbrs, act, nbrs_g):
            return _mis2_local_fixpoint(
                nbrs, act, axis=flat, num_vertices=v,
                priority="xorshift_star", max_iters=max_iters,
                single_gather=True, neighbors_global=nbrs_g)
        in_specs = (spec_rows, spec_rows, P())
        args = (nbrs_spec, act_spec, nbrs_spec)
    else:
        fn_core = functools.partial(
            _mis2_local_fixpoint, axis=flat, num_vertices=v,
            priority="xorshift_star", max_iters=max_iters)
        in_specs = (spec_rows, spec_rows)
        args = (nbrs_spec, act_spec)

    fn = _shard_map(fn_core, mesh=mesh, in_specs=in_specs,
                    out_specs=(spec_rows, P(flat[0])))
    with mesh:
        lowered = jax.jit(fn).lower(*[
            jax.ShapeDtypeStruct(a.shape, a.dtype,
                                 sharding=NamedSharding(mesh, s))
            for a, s in zip(args, in_specs)])
        t0 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0
    hc = hlo_analyze(compiled.as_text(), nd)
    mem = compiled.memory_analysis()
    wire = sum(c["wire_bytes"] for c in hc["collectives"].values())
    rec = {
        "variant": "single_gather" if single_gather else "two_gather",
        "V": v, "D": d, "mesh": "x".join(str(s) for s in mesh.shape.values()),
        "num_devices": nd, "max_iters": max_iters,
        "compile_s": round(compile_s, 2),
        "hlo_flops": hc["flops"], "hlo_bytes": hc["bytes"],
        "collectives": hc["collectives"],
        "wire_bytes_per_device": wire,
        "temp_bytes": int(mem.temp_size_in_bytes),
        "roofline": {
            "t_compute_s": hc["flops"] / PEAK_FLOPS_BF16,
            "t_memory_s": hc["bytes"] / HBM_BW,
            "t_collective_s": wire / ICI_LINK_BW,
        },
    }
    r = rec["roofline"]
    rec["roofline"]["dominant"] = max(r, key=lambda k: r[k])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--v", type=int, default=1_000_000)
    ap.add_argument("--d", type=int, default=7)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="custom mesh shape AxB (scaling curves)")
    ap.add_argument("--out", default="artifacts/dryrun_graph")
    args = ap.parse_args()

    if args.mesh:
        import jax as _jax
        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model")[:len(dims)] if len(dims) == 2 else \
            ("pod", "data", "model")[:len(dims)]
        mesh = _jax.make_mesh(dims, names)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    for single in (False, True):
        rec = lower_variant(args.v, args.d, mesh, single)
        tag = f"mis2_{rec['variant']}__{rec['mesh']}"
        (out / f"{tag}.json").write_text(json.dumps(rec, indent=2))
        r = rec["roofline"]
        print(f"[ok] {tag}: wire/dev={rec['wire_bytes_per_device']/1e6:.1f}MB "
              f"tc={r['t_compute_s']:.3g} tm={r['t_memory_s']:.3g} "
              f"tx={r['t_collective_s']:.3g} dom={r['dominant']} "
              f"compile={rec['compile_s']}s", flush=True)


if __name__ == "__main__":
    main()
