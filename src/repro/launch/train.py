# repro-lint: legacy seed-era LM train driver, exercised only by the fault-tolerance tests
"""Training driver: any --arch, any mesh, checkpoint/restart, preemption
handling, straggler hooks.

Local run (CPU dev, reduced config)::

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault-tolerance contract (designed for 1000+ nodes, exercised in tests):
* checkpoint every ``--ckpt-every`` steps, atomic commit, keep-k;
* SIGTERM/SIGINT (preemption notice) -> synchronous checkpoint, clean exit
  with code 99 so the cluster manager restarts the job;
* restart resumes bit-exact: pipeline is seekable (data/pipeline.py), RNG
  is step-derived, optimizer state restored;
* elastic: --mesh may differ across restarts — restore re-shards leaves via
  device_put (checkpoint/manager.py);
* straggler hook: per-step wall time is tracked; steps slower than
  ``--straggler-factor`` x the running median are logged with the step
  index (on real fleets this feeds the hot-spare controller; here it is a
  log line + counter so the mechanism is testable).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.checkpoint.manager import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.launch.mesh import make_dev_mesh
from repro.launch.sharding import (
    RULE_SETS,
    batch_sharding,
    opt_state_shardings,
    tree_shardings,
)
from repro.models import get_model
from repro.train import AdamWConfig, adamw_init, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU dev)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--total-steps", type=int, default=None,
                    help="schedule horizon (stable across restarts); "
                         "defaults to --steps")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--rules", default="default", choices=sorted(RULE_SETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    mesh = make_dev_mesh(model=args.mesh_model)
    rules = RULE_SETS[args.rules](mesh)

    horizon = args.total_steps or args.steps
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=horizon,
                          warmup_steps=max(1, horizon // 20))
    train_step = make_train_step(model, cfg, opt_cfg)

    param_sh = tree_shardings(mesh, model.param_axes(), rules)
    opt_sh = opt_state_shardings(mesh, param_sh)
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, global_batch=args.batch, seq_len=args.seq,
        seed=args.seed, frames=cfg.family in ("encdec", "audio"),
        frame_seq=cfg.encoder_seq, frame_dim=cfg.d_model)
    pipeline = SyntheticTokens(data_cfg)

    with mesh:
        params = jax.jit(model.init, out_shardings=param_sh)(
            jax.random.PRNGKey(args.seed))
        opt_state = jax.jit(adamw_init, out_shardings=opt_sh)(params)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, (params, opt_state), _ = restore_checkpoint(
            args.ckpt_dir, (params, opt_state),
            shardings=(param_sh, opt_sh))
        print(f"[restore] resumed from step {start}", flush=True)

    step_jit = jax.jit(
        train_step,
        in_shardings=(param_sh, opt_sh,
                      {k: batch_sharding(mesh, rules, np.ndim(v) if hasattr(v, 'ndim') else 2)
                       for k, v in pipeline.batch_at(0).items()}),
        donate_argnums=(0, 1),
    )

    # preemption -> checkpoint + exit(99)
    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True
    signal.signal(signal.SIGTERM, _handler)
    signal.signal(signal.SIGINT, _handler)

    times = []
    stragglers = 0
    with mesh:
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in pipeline.batch_at(step).items()}
            params, opt_state, metrics = step_jit(params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            times.append(dt)
            med = float(np.median(times[-50:]))
            if len(times) > 5 and dt > args.straggler_factor * med:
                stragglers += 1
                print(f"[straggler] step {step} took {dt:.2f}s "
                      f"(median {med:.2f}s)", flush=True)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {metrics['loss']:.4f} "
                      f"ce {metrics['ce']:.4f} lr {metrics['lr']:.2e} "
                      f"gnorm {metrics['grad_norm']:.2f} {dt:.2f}s", flush=True)
            need_ckpt = args.ckpt_dir and (
                (step + 1) % args.ckpt_every == 0 or step == args.steps - 1)
            if preempted["flag"] and args.ckpt_dir:
                save_checkpoint(args.ckpt_dir, step + 1, (params, opt_state),
                                keep=args.keep,
                                metadata={"preempted": True})
                print(f"[preempt] checkpointed step {step + 1}, exiting 99",
                      flush=True)
                sys.exit(99)
            if need_ckpt:
                save_checkpoint(args.ckpt_dir, step + 1, (params, opt_state),
                                keep=args.keep)
    print(f"done: {args.steps} steps, {stragglers} straggler events, "
          f"median step {np.median(times):.3f}s", flush=True)
    return metrics


if __name__ == "__main__":
    main()
