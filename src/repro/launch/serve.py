"""Serving driver: batched prefill + decode for any --arch.

Local run (CPU dev, reduced config)::

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --batch 4 --prompt-len 32 --decode-steps 16

Production shapes are exercised (lower+compile) by the dry-run's
prefill_32k / decode_32k / long_500k cells; this driver runs the same
prefill/decode step functions eagerly with a request batcher:
requests arrive with ragged prompt lengths, are right-aligned into the
fixed prompt window (left-padded), prefilled as one batch, then decoded
in lockstep — the static-shape batching strategy a TPU serving tier uses.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_dev_mesh
from repro.launch.sharding import RULE_SETS, tree_shardings
from repro.models import get_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    mesh = make_dev_mesh()
    rules = RULE_SETS["default"](mesh)
    max_seq = args.max_seq or (args.prompt_len + args.decode_steps)

    rng = np.random.default_rng(args.seed)
    # ragged requests, right-aligned into the static prompt window
    lens = rng.integers(max(1, args.prompt_len // 2), args.prompt_len + 1,
                        size=args.batch)
    tokens = np.zeros((args.batch, args.prompt_len), dtype=np.int32)
    for i, ln in enumerate(lens):
        tokens[i, args.prompt_len - ln:] = rng.integers(
            1, cfg.vocab_size, size=ln)
    print(f"[serve] {args.batch} requests, prompt lens {lens.tolist()}")

    with mesh:
        param_sh = tree_shardings(mesh, model.param_axes(), rules)
        params = jax.jit(model.init, out_shardings=param_sh)(
            jax.random.PRNGKey(args.seed))

        if cfg.family in ("encdec", "audio"):
            frames = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.encoder_seq, cfg.d_model), dtype=np.float32))
            batch = {"frames": frames, "tokens": jnp.asarray(tokens)}
            prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq))
        else:
            batch = jnp.asarray(tokens)
            prefill = jax.jit(lambda p, t: model.prefill(p, t, max_seq))
        decode = jax.jit(model.decode_step)

        t0 = time.time()
        logits, cache = prefill(params, batch)
        logits.block_until_ready()
        prefill_s = time.time() - t0
        print(f"[serve] prefill {args.batch}x{args.prompt_len} tokens "
              f"in {prefill_s:.2f}s (incl. compile)")

        out = [jnp.argmax(logits, -1)[:, None]]
        t0 = time.time()
        for _ in range(args.decode_steps):
            logits, cache = decode(params, cache, out[-1])
            out.append(jnp.argmax(logits, -1)[:, None])
        jax.block_until_ready(out[-1])
        decode_s = time.time() - t0
        tps = args.batch * args.decode_steps / max(1e-9, decode_s)
        print(f"[serve] decoded {args.decode_steps} steps in {decode_s:.2f}s "
              f"(incl. compile) ~ {tps:.0f} tok/s")
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"[serve] sample generations (token ids): {gen[0][:12].tolist()}")
    return gen


if __name__ == "__main__":
    main()
