# repro-lint: legacy deprecation shim for repro.serve
"""Deprecated shim: the serving layer moved to :mod:`repro.serve`.

The seed's LM prefill/decode serving driver lived here; the repo's
serving tier is now the graph service in ``repro.serve`` (continuous
batching over ``GraphBatch`` buckets, digest-keyed result caching,
warm-executable registry, streaming MIS-2 repair).  This module
re-exports that surface and warns on import — see the migration table
in API.md.
"""
from __future__ import annotations

from .._compat import warn_deprecated
from ..serve import (  # noqa: F401 - re-exported surface
    KINDS,
    Batcher,
    CacheParityError,
    CacheStats,
    PendingRequest,
    RepairStats,
    ResultCache,
    Server,
    ServerConfig,
    ServeStats,
    StreamSession,
    WarmRegistry,
    WarmSpec,
    warm_buckets_for,
)

warn_deprecated("repro.launch.serve", "repro.serve", stacklevel=4)

__all__ = [
    "Server", "ServerConfig", "ServeStats", "KINDS", "warm_buckets_for",
    "ResultCache", "CacheStats", "CacheParityError",
    "WarmRegistry", "WarmSpec",
    "Batcher", "PendingRequest",
    "StreamSession", "RepairStats",
]
