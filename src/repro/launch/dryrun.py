# repro-lint: legacy seed-era LM dry-run harness, exercised only by tests
"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell against the production mesh and record memory / while-aware HLO cost /
collective analyses (EXPERIMENTS.md §Dry-run, §Roofline).

MUST set XLA_FLAGS before any jax import — jax locks the device count on
first init.  Run as::

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]

Memory policy (auto, recorded per cell):
* train cells use gradient accumulation — microbatch count doubles until
  the per-device footprint fits HBM (16 GB v5e), starting from a
  tokens-per-device heuristic;
* architectures whose params+optimizer exceed ~25% of HBM under pure TP
  store params in bf16 and shard the fp32 AdamW moments + the fp32 grad
  accumulator ZeRO-1-style over the data axis ('pure bf16 + fp32 moments'
  TPU recipe).  We deliberately do NOT FSDP-shard the scanned weight
  stacks: GSPMD hoists their loop-invariant all-gathers out of the layer
  scan, un-doing the sharding (measured: chameleon-34b temp 18.3 GB with
  FSDP-over-layers vs fitting with the bf16+ZeRO-1 recipe — EXPERIMENTS.md
  §Dry-run notes).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (
    RULE_SETS,
    batch_sharding,
    opt_state_shardings,
    scalar_sharding,
    tree_shardings,
)
from repro.models import get_model
from repro.models.config import LM_SHAPES, cell_applicable
from repro.train import AdamWConfig, adamw_init, make_train_step

# TPU v5e hardware constants — canonical home is hlo_analysis (live);
# re-exported here for the seed-era import surface
from repro.launch.hlo_analysis import (  # noqa: E402, F401
    HBM_BW,
    HBM_BYTES,
    ICI_LINK_BW,
    PEAK_FLOPS_BF16,
)


def count_params(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def active_param_count(cfg, params_spec) -> int:
    total = count_params(params_spec)
    if not cfg.num_experts:
        return total
    expert_per_layer = 3 * cfg.d_model * cfg.moe_d_ff
    routed = cfg.num_layers * cfg.num_experts * expert_per_layer
    active = cfg.num_layers * cfg.num_experts_per_token * expert_per_layer
    return total - routed + active


def input_specs(cfg, cell, microbatches: int = 1):
    """Abstract inputs for one shape cell (no allocation)."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train" and microbatches > 1:
        lead = (microbatches, b // microbatches)
    else:
        lead = (b,)
    if cfg.family in ("encdec", "audio"):
        if cell.kind in ("train", "prefill"):
            return {"frames": jax.ShapeDtypeStruct(
                        lead + (cfg.encoder_seq, cfg.d_model), jnp.float32),
                    "tokens": jax.ShapeDtypeStruct(lead + (s,), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cell.kind in ("train", "prefill"):
        return {"tokens": jax.ShapeDtypeStruct(lead + (s,), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def _with_sharding(sds_tree, shardings):
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        sds_tree, shardings)


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def max_microbatches(cell, mesh) -> int:
    """Largest k with (B/k) divisible by the DP width."""
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    return max(1, cell.global_batch // dp)


def default_microbatches(cfg, cell, mesh) -> int:
    """Start with ~<=8k tokens per device per microbatch."""
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    tokens_per_dev = cell.global_batch * cell.seq_len / dp
    mb = max(1, int(tokens_per_dev // 8192))
    while cell.global_batch % mb and mb > 1:
        mb //= 2
    return min(mb, max_microbatches(cell, mesh))


def wants_zero1(cfg, mesh) -> bool:
    """params+opt under pure TP > ~25% HBM -> bf16 params + ZeRO-1 opt."""
    tp = mesh.shape.get("model", 1)
    n = approx_param_count(cfg)
    return 3 * 4 * n / tp > 0.25 * HBM_BYTES


def approx_param_count(cfg) -> int:
    d, l, v = cfg.d_model, cfg.num_layers, cfg.padded_vocab
    dh = cfg.resolved_head_dim
    n = v * d * (1 if cfg.tie_embeddings else 2)
    att = d * dh * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    if cfg.family == "ssm":
        per = 2 * d * cfg.ssm_d_inner + d * (cfg.ssm_d_inner + 2 * cfg.ssm_state
                                             + cfg.ssm_heads)
    elif cfg.num_experts:
        per = att + 3 * cfg.num_experts * d * cfg.moe_d_ff + \
            3 * cfg.num_shared_experts * d * cfg.moe_d_ff
    else:
        per = att + 3 * d * cfg.d_ff
    return n + l * per


def adapt_rules(rules, cfg, cell, mesh):
    """Per-arch rule adaptation (recorded in the artifact):

    * kv_heads not divisible by TP -> replicate KV projections/caches
      (Megatron practice; the repeat-to-heads happens locally);
    * decode cells with replicated KV heads shard the cache SEQUENCE over
      'model' instead (flash-decoding style partial softmax).
    """
    tp = mesh.shape.get("model", 1)
    rules = dict(rules)
    if cfg.num_experts and cfg.num_experts % tp != 0:
        # qwen2-moe: 60 experts don't divide TP=16 -> shard the per-expert
        # hidden dim (TP-in-expert) instead of the expert axis (EP)
        rules["expert"] = None
        rules["expert_mlp"] = "model"
    if cfg.num_kv_heads and cfg.num_kv_heads % tp != 0:
        rules["kv_heads"] = None
        if cell.kind == "decode":
            rules["cache_seq"] = "model"
    # batch too small for the DP width (long_500k B=1): drop DP axes the
    # batch cannot cover; model-axis (TP/SP) parallelism carries the cell
    dp_axes = rules.get("batch") or ()
    if isinstance(dp_axes, str):
        dp_axes = (dp_axes,)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    if dp and cell.global_batch % dp != 0:
        keep = []
        rem = cell.global_batch
        for a in dp_axes:
            if rem % mesh.shape[a] == 0:
                keep.append(a)
                rem //= mesh.shape[a]
        rules["batch"] = tuple(keep) or None
    return rules


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               rules_name: str = "default", compile_: bool = True,
               microbatches: int | None = None, zero1: bool | None = None,
               max_retries: int = 2):
    cfg = get_config(arch)
    cell = next(c for c in LM_SHAPES if c.shape_name == shape_name)
    ok, why = cell_applicable(cfg, cell)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "rules": rules_name, "applicable": ok}
    if not ok:
        rec.update(skipped=why, ok=True)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    nd = int(np.prod(list(mesh.shape.values())))
    rules = adapt_rules(RULE_SETS[rules_name](mesh), cfg, cell, mesh)
    model = get_model(cfg)
    if zero1 is None:
        zero1 = wants_zero1(cfg, mesh)
    if microbatches is None:
        microbatches = default_microbatches(cfg, cell, mesh) \
            if cell.kind == "train" else 1

    for attempt in range(max_retries + 1):
        rec.update(zero1=zero1, microbatches=microbatches)
        r = _lower_once(cfg, cell, model, mesh, nd, rules, rec.copy(),
                        microbatches, zero1, compile_)
        if not compile_ or not r.get("ok"):
            return r
        cap = max_microbatches(cell, mesh)
        if r["fits_hbm"] or cell.kind != "train" or microbatches >= cap:
            return r
        microbatches = min(cap, microbatches * 2)
        while cell.global_batch % microbatches and microbatches < cap:
            microbatches += 1
    return r


def _lower_once(cfg, cell, model, mesh, nd, rules, rec, microbatches, zero1,
                compile_):
    from repro.models.layers import clear_sharding_context, set_sharding_context
    set_sharding_context(mesh, rules)
    try:
        return _lower_inner(cfg, cell, model, mesh, nd, rules, rec,
                            microbatches, zero1, compile_)
    finally:
        clear_sharding_context()


def _lower_inner(cfg, cell, model, mesh, nd, rules, rec, microbatches, zero1,
                 compile_):
    param_sh = tree_shardings(mesh, model.param_axes(), rules)
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if zero1:   # store params bf16 (fp32 moments carry the precision)
        params_spec = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, jnp.bfloat16 if x.dtype == jnp.float32 else x.dtype),
            params_spec)
    n_params = count_params(params_spec)
    n_active = active_param_count(cfg, params_spec)
    rec.update(n_params=n_params, n_active_params=n_active, num_devices=nd,
               rules_resolved={k: (list(v) if isinstance(v, tuple) else v)
                               for k, v in rules.items()})

    batch = input_specs(cfg, cell, microbatches)
    mb = cell.kind == "train" and microbatches > 1
    batch_sh = {k: batch_sharding(mesh, rules, ndim=len(v.shape),
                                  microbatched=mb and len(v.shape) >= 2)
                for k, v in batch.items()}
    batch_spec = _with_sharding(batch, batch_sh)

    t0 = time.perf_counter()
    if cell.kind == "train":
        opt_spec = jax.eval_shape(adamw_init, params_spec)
        opt_sh = opt_state_shardings(mesh, param_sh,
                                     axes_tree=model.param_axes(),
                                     rules=rules, zero1=zero1,
                                     shapes_tree=params_spec)
        step_fn = make_train_step(
            model, cfg, AdamWConfig(), num_microbatches=microbatches,
            grad_shardings=opt_sh["m"] if zero1 else None)
        scal = scalar_sharding(mesh)
        metrics_sh = {k: scal for k in ("ce", "aux", "loss", "lr",
                                        "grad_norm")}
        with mesh:
            lowered = jax.jit(
                step_fn,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, metrics_sh),
                donate_argnums=(0, 1),
            ).lower(params_spec, opt_spec, batch_spec)
        tokens = cell.global_batch * cell.seq_len
        rec["model_flops"] = 6 * n_active * tokens
    elif cell.kind == "prefill":
        if cfg.family in ("encdec", "audio"):
            fn = lambda p, b: model.prefill(p, b, cell.seq_len)  # noqa: E731
        else:
            fn = lambda p, t: model.prefill(p, t["tokens"], cell.seq_len)  # noqa: E731
        with mesh:
            lowered = jax.jit(
                fn, in_shardings=(param_sh, batch_sh),
            ).lower(params_spec, batch_spec)
        rec["model_flops"] = 2 * n_active * cell.global_batch * cell.seq_len
    else:  # decode
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(cell.global_batch, cell.seq_len))
        cache_sh = tree_shardings(mesh, model.cache_axes(), rules)
        cache_spec = _with_sharding(_sds(cache_struct), cache_sh)

        def fn(p, c, t):
            return model.decode_step(p, c, t["tokens"])
        with mesh:
            lowered = jax.jit(
                fn, in_shardings=(param_sh, cache_sh, batch_sh),
                donate_argnums=(1,),
            ).lower(params_spec, cache_spec, batch_spec)
        rec["model_flops"] = 2 * n_active * cell.global_batch
    rec["lower_s"] = round(time.perf_counter() - t0, 2)

    if not compile_:
        rec["ok"] = True
        return rec

    t0 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t0, 2)

    mem = compiled.memory_analysis()
    rec["memory_analysis"] = {
        k: int(getattr(mem, k)) for k in
        ("argument_size_in_bytes", "output_size_in_bytes",
         "temp_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)}
    # donated args alias outputs; live set ~ max(args, outputs) + temps
    args_b = rec["memory_analysis"].get("argument_size_in_bytes", 0)
    out_b = rec["memory_analysis"].get("output_size_in_bytes", 0)
    temp_b = rec["memory_analysis"].get("temp_size_in_bytes", 0)
    live = max(args_b, out_b) + temp_b
    rec["live_bytes_per_device"] = live
    rec["fits_hbm"] = bool(live <= HBM_BYTES)

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax < 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    rec["cost_analysis_flat"] = {
        k: float(v) for k, v in cost.items()
        if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")}
    hlo = compiled.as_text()
    rec["hlo_bytes"] = len(hlo)
    t0 = time.perf_counter()
    hc = hlo_analyze(hlo, nd)
    rec["analyze_s"] = round(time.perf_counter() - t0, 2)
    rec["hlo_cost"] = {"flops": hc["flops"], "bytes": hc["bytes"]}
    rec["collectives"] = hc["collectives"]

    flops = hc["flops"]
    mem_bytes = hc["bytes"]
    wire = sum(c["wire_bytes"] for c in hc["collectives"].values())
    t_compute = flops / PEAK_FLOPS_BF16
    t_mem = mem_bytes / HBM_BW
    t_coll = wire / ICI_LINK_BW
    bound = max(t_compute, t_mem, t_coll)
    dominant = max((("compute", t_compute), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    rec["roofline"] = {
        "t_compute_s": t_compute, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_over_hlo_flops": rec["model_flops"] / max(1.0, flops * nd),
        "roofline_fraction": (t_compute / bound) if bound else 0.0,
        "wire_bytes_per_device": wire,
    }
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape cell name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default="default", choices=sorted(RULE_SETS))
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--zero1", default=None, choices=(None, "on", "off"))
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch in (None, "all") else [args.arch]
    shapes = [c.shape_name for c in LM_SHAPES] if args.shape in (None, "all") \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    zero1 = None if args.zero1 is None else (args.zero1 == "on")

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if args.rules != "default":
                    tag += f"__{args.rules}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = outdir / f"{tag}.json"
                if path.exists() and not args.force:
                    print(f"[cached] {tag}", flush=True)
                    continue
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp,
                                     rules_name=args.rules,
                                     compile_=not args.no_compile,
                                     microbatches=args.microbatches,
                                     zero1=zero1)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "rules": args.rules, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                if not rec.get("ok"):
                    failures += 1
                path.write_text(json.dumps(rec, indent=2))
                status = "SKIP" if rec.get("skipped") else \
                    ("ok" if rec.get("ok") else "FAIL")
                extra = ""
                if rec.get("ok") and "roofline" in rec:
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" tc={r['t_compute_s']:.3g} tm={r['t_memory_s']:.3g}"
                             f" tx={r['t_collective_s']:.3g}"
                             f" fits={rec['fits_hbm']}"
                             f" mb={rec.get('microbatches')}"
                             f" z1={rec.get('zero1')}"
                             f" compile={rec.get('compile_s')}s")
                print(f"[{status}] {tag}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
