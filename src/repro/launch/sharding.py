# repro-lint: legacy seed-era LM sharding rules, used only by the quarantined LM stack
"""Logical-axis -> mesh-axis sharding rules (GSPMD / pjit).

Every model exposes ``param_axes()``: a tree congruent with its params whose
leaves are tuples of logical axis names.  The rules below map logical axes
to mesh axes; ``None`` replicates.  GSPMD tolerates non-divisible dims by
padding (e.g. 60 experts over 16 — noted per-cell in the roofline).

Rule sets are the primary §Perf hillclimbing lever — variants are defined
here so a dry-run cell can be lowered under each candidate and compared.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# baseline rules: Megatron-style TP on 'model', DP on ('pod','data')
def default_rules(mesh: Mesh) -> Dict[str, Any]:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return {
        "batch": dp,            # activations / inputs
        "vocab": "model",
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "mlp2": None,           # second axis of square RG-LRU gate mats
        "mlp_heads": "model",   # SSM heads (di = heads x headdim)
        "expert": "model",
        "expert_mlp": None,     # per-expert hidden dim (EP shards 'expert')
        "layers": None,
        "seq": None,
        "seq_sp": "model",      # Megatron-SP: residual stream + saved
                                # activations sharded over 'model' between
                                # TP regions (AG on entry, RS on exit)
        "cache_seq": None,      # decode cells may remap to 'model'
    }


# §Perf variant for long-context decode: shard sequence/state over 'data'
def seq_sharded_rules(mesh: Mesh) -> Dict[str, Any]:
    r = default_rules(mesh)
    r["seq"] = "data"
    r["batch"] = tuple(a for a in ("pod",) if a in mesh.axis_names) or None
    return r


RULE_SETS = {
    "default": default_rules,
    "seq_sharded": seq_sharded_rules,
}


def spec_from_axes(axes, rules, shard_free_axis_over: Optional[str] = None,
                   shape: Optional[tuple] = None,
                   mesh: Optional[Mesh] = None) -> P:
    parts = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        parts.append(m)
    if shard_free_axis_over is not None:
        width = mesh.shape[shard_free_axis_over] if mesh is not None else 1
        for i, p in enumerate(parts):
            if p is None and (shape is None or
                              shape[i] % max(1, width) == 0):
                parts[i] = shard_free_axis_over
                break
    return P(*parts)


def tree_shardings(mesh: Mesh, axes_tree, rules,
                   shard_free_axis_over: Optional[str] = None,
                   shapes_tree=None) -> Any:
    """NamedSharding tree congruent with a param/cache tree.

    ``shard_free_axis_over='data'`` additionally shards each leaf's first
    *evenly divisible* unsharded dim over the data axis — ZeRO/FSDP-style
    sharding (argument shardings must divide evenly, so ``shapes_tree``
    provides dims to check; without it any free dim is taken).
    """
    if shapes_tree is None:
        def leaf(axes):
            if not isinstance(axes, tuple):
                raise TypeError(f"axes leaf must be tuple, got {axes!r}")
            return NamedSharding(
                mesh, spec_from_axes(axes, rules, shard_free_axis_over,
                                     mesh=mesh))
        return jax.tree.map(leaf, axes_tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    def leaf2(axes, spec):
        return NamedSharding(
            mesh, spec_from_axes(axes, rules, shard_free_axis_over,
                                 shape=tuple(spec.shape), mesh=mesh))
    return jax.tree.map(leaf2, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_sharding(mesh: Mesh, rules, ndim: int = 2,
                   microbatched: bool = False) -> NamedSharding:
    """Input batch [B, S, ...] (or [k, B, S, ...] when microbatched):
    B over DP axes, rest per rules['seq']."""
    parts = [rules["batch"]] + [rules.get("seq")] + [None] * max(0, ndim - 2)
    if microbatched:
        parts = [None] + parts
    return NamedSharding(mesh, P(*parts[:ndim]))


def scalar_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def opt_state_shardings(mesh: Mesh, param_shardings, *, axes_tree=None,
                        rules=None, zero1: bool = False,
                        shapes_tree=None) -> Dict[str, Any]:
    """AdamW state: m/v shard like params; step replicated.

    ``zero1=True``: m/v additionally shard their first free evenly-dividing
    dim over 'data' (ZeRO-1) — requires axes_tree + rules (+ shapes_tree
    for divisibility checks).
    """
    mv = param_shardings
    if zero1:
        assert axes_tree is not None and rules is not None
        mv = tree_shardings(mesh, axes_tree, rules,
                            shard_free_axis_over="data",
                            shapes_tree=shapes_tree)
    return {
        "m": mv,
        "v": mv,
        "step": scalar_sharding(mesh),
    }
