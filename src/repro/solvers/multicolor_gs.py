"""Point and cluster multicolor Gauss-Seidel (paper Alg. 4 + §III-C).

Point multicolor GS [Deveci et al. 2016, the paper's baseline]: color the
*fine* matrix graph; rows of one color are independent and update in
parallel; colors sweep sequentially.

Cluster multicolor GS (the paper's contribution): coarsen the graph with
MIS-2 aggregation (Alg. 2 or 3), color the *coarse* graph, then within one
coarse color update all clusters in parallel while rows inside a cluster
update sequentially — locally exact Gauss-Seidel, so fewer Krylov
iterations than point multicolor GS, and the (expensive) greedy coloring
runs on the much smaller coarse graph, cutting setup time (Table VI).

Data layout: per color a padded int32 matrix ``rows[c][n_clusters_c,
max_len_c]`` (sentinel = V, scatter-dropped).  The apply sweeps are a single
jitted function per direction; sequential depth = sum_c max_len_c, exactly
the paper's parallelism structure.  Point GS is the cluster structure with
singleton clusters.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.csr import ELLMatrix, csr_to_ell_matrix
from ..graphs.handle import Graph
from ..graphs.ops import extract_diagonal
from ..core.coloring import _color_graph_impl
from ..core.mis2 import Mis2Options
from ..multilevel.packing import pack_clusters_host


@dataclass
class MulticolorGSPreconditioner:
    ell: ELLMatrix
    diag: jnp.ndarray
    color_rows: tuple           # tuple of int32 [n_clusters_c, max_len_c]
    num_colors: int
    num_clusters: int
    setup_seconds: float
    kind: str                   # 'point' | 'cluster'
    timings: dict = field(default_factory=dict)  # setup-phase split
    #                          (aggregate / color / pack seconds)

    def apply(self, b: jnp.ndarray, sweeps: int = 1,
              symmetric: bool = True) -> jnp.ndarray:
        """Approximate A^-1 b by `sweeps` (S)GS sweeps from x0 = 0."""
        return _apply_sweeps(self.ell.cols, self.ell.vals, self.diag,
                             self.color_rows, b, sweeps, symmetric)

    def as_precond(self, sweeps: int = 1, symmetric: bool = True) -> Callable:
        return functools.partial(self.apply, sweeps=sweeps, symmetric=symmetric)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _row_update(cols, vals, diag, x, b, rows):
    """GS update of `rows` (parallel across rows; rows are independent)."""
    v = cols.shape[0]
    safe = jnp.clip(rows, 0, v - 1)
    a_cols = cols[safe]                         # [R, D]
    a_vals = vals[safe]
    ax = jnp.sum(a_vals * x[a_cols], axis=1)    # A_i . x
    xi = x[safe]
    new = xi + (b[safe] - ax) / diag[safe]
    return x.at[rows].set(new, mode="drop")


@functools.partial(jax.jit, static_argnames=("sweeps", "symmetric"))
def _apply_sweeps(cols, vals, diag, color_rows, b, sweeps: int,
                  symmetric: bool):
    x = jnp.zeros_like(b)
    for _ in range(sweeps):
        for rows_c in color_rows:               # forward color sweep
            for s in range(rows_c.shape[1]):    # sequential within cluster
                x = _row_update(cols, vals, diag, x, b, rows_c[:, s])
        if symmetric:
            for rows_c in reversed(color_rows):
                for s in reversed(range(rows_c.shape[1])):
                    x = _row_update(cols, vals, diag, x, b, rows_c[:, s])
    return x


# ---------------------------------------------------------------------------
# setup
# ---------------------------------------------------------------------------

# moved to repro.multilevel.packing; kept under its legacy name because
# callers (and tests) import it from here
_pack_clusters = pack_clusters_host


def setup_cluster_gs(a, aggregation: str = "two_phase",
                     options: Mis2Options | None = None,
                     coarsen_levels: int = 1,
                     engine: str = "host") -> MulticolorGSPreconditioner:
    """Cluster multicolor GS setup through the multilevel subsystem.

    ``engine`` picks the multilevel setup path (``host`` | ``resident``;
    see ``repro.api.cluster_gs_setup`` for the auto-selected facade).
    The returned preconditioner carries the structured setup-phase
    timings (``aggregate`` / ``color`` / ``pack`` seconds) in
    ``.timings``.
    """
    import time

    from ..multilevel.hierarchy import _cluster_gs_setup_impl

    if isinstance(a, Graph):
        a = a.csr_matrix
    t0 = time.perf_counter()
    color_rows, num_colors, nagg, _, _, timings = _cluster_gs_setup_impl(
        a, aggregation=aggregation, options=options,
        coarsen_levels=coarsen_levels, engine=engine)
    ell = csr_to_ell_matrix(a)
    diag = extract_diagonal(a)
    return MulticolorGSPreconditioner(
        ell, diag, color_rows, num_colors, nagg,
        time.perf_counter() - t0, "cluster", timings=timings)


def setup_point_gs(a) -> MulticolorGSPreconditioner:
    import time
    if isinstance(a, Graph):
        a = a.csr_matrix
    t0 = time.perf_counter()
    v = a.num_rows
    t_color = time.perf_counter()
    coloring = _color_graph_impl(a.graph)      # colors the FINE graph
    t_color = time.perf_counter() - t_color
    if not coloring.converged:     # a partial coloring is unusable for GS
        raise RuntimeError("fine-graph coloring did not converge")
    t_pack = time.perf_counter()
    labels = np.arange(v, dtype=np.int32)      # singleton clusters
    color_rows = _pack_clusters(labels, coloring.colors, coloring.num_colors, v)
    t_pack = time.perf_counter() - t_pack
    ell = csr_to_ell_matrix(a)
    diag = extract_diagonal(a)
    return MulticolorGSPreconditioner(
        ell, diag, color_rows, coloring.num_colors, v,
        time.perf_counter() - t0, "point",
        timings={"aggregate": 0.0, "color": t_color, "pack": t_pack})
