"""Point and cluster multicolor Gauss-Seidel (paper Alg. 4 + §III-C).

Point multicolor GS [Deveci et al. 2016, the paper's baseline]: color the
*fine* matrix graph; rows of one color are independent and update in
parallel; colors sweep sequentially.

Cluster multicolor GS (the paper's contribution): coarsen the graph with
MIS-2 aggregation (Alg. 2 or 3), color the *coarse* graph, then within one
coarse color update all clusters in parallel while rows inside a cluster
update sequentially — locally exact Gauss-Seidel, so fewer Krylov
iterations than point multicolor GS, and the (expensive) greedy coloring
runs on the much smaller coarse graph, cutting setup time (Table VI).

Data layout: per color a padded int32 matrix ``rows[c][n_clusters_c,
max_len_c]`` (sentinel = V, scatter-dropped).  The apply sweeps are a single
jitted function per direction; sequential depth = sum_c max_len_c, exactly
the paper's parallelism structure.  Point GS is the cluster structure with
singleton clusters.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.csr import ELLMatrix, csr_to_ell_matrix
from ..graphs.handle import Graph
from ..graphs.ops import coarse_graph_from_labels, extract_diagonal
from ..core.coloring import _color_graph_impl
from ..core.mis2 import Mis2Options


@dataclass
class MulticolorGSPreconditioner:
    ell: ELLMatrix
    diag: jnp.ndarray
    color_rows: tuple           # tuple of int32 [n_clusters_c, max_len_c]
    num_colors: int
    num_clusters: int
    setup_seconds: float
    kind: str                   # 'point' | 'cluster'

    def apply(self, b: jnp.ndarray, sweeps: int = 1,
              symmetric: bool = True) -> jnp.ndarray:
        """Approximate A^-1 b by `sweeps` (S)GS sweeps from x0 = 0."""
        return _apply_sweeps(self.ell.cols, self.ell.vals, self.diag,
                             self.color_rows, b, sweeps, symmetric)

    def as_precond(self, sweeps: int = 1, symmetric: bool = True) -> Callable:
        return functools.partial(self.apply, sweeps=sweeps, symmetric=symmetric)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _row_update(cols, vals, diag, x, b, rows):
    """GS update of `rows` (parallel across rows; rows are independent)."""
    v = cols.shape[0]
    safe = jnp.clip(rows, 0, v - 1)
    a_cols = cols[safe]                         # [R, D]
    a_vals = vals[safe]
    ax = jnp.sum(a_vals * x[a_cols], axis=1)    # A_i . x
    xi = x[safe]
    new = xi + (b[safe] - ax) / diag[safe]
    return x.at[rows].set(new, mode="drop")


@functools.partial(jax.jit, static_argnames=("sweeps", "symmetric"))
def _apply_sweeps(cols, vals, diag, color_rows, b, sweeps: int,
                  symmetric: bool):
    x = jnp.zeros_like(b)
    for _ in range(sweeps):
        for rows_c in color_rows:               # forward color sweep
            for s in range(rows_c.shape[1]):    # sequential within cluster
                x = _row_update(cols, vals, diag, x, b, rows_c[:, s])
        if symmetric:
            for rows_c in reversed(color_rows):
                for s in reversed(range(rows_c.shape[1])):
                    x = _row_update(cols, vals, diag, x, b, rows_c[:, s])
    return x


# ---------------------------------------------------------------------------
# setup
# ---------------------------------------------------------------------------

def _pack_clusters(labels: np.ndarray, cluster_colors: np.ndarray,
                   num_colors: int, v: int):
    """Group rows by (color(cluster), cluster) into padded per-color arrays."""
    order = np.lexsort((np.arange(v), labels))
    sorted_labels = labels[order]
    # row lists per cluster (ascending vertex ids — deterministic)
    starts = np.flatnonzero(np.r_[True, sorted_labels[1:] != sorted_labels[:-1]])
    ends = np.r_[starts[1:], v]
    cluster_ids = sorted_labels[starts]
    color_rows = []
    for c in range(num_colors):
        sel = np.flatnonzero(cluster_colors[cluster_ids] == c)
        if len(sel) == 0:
            continue
        lens = ends[sel] - starts[sel]
        max_len = int(lens.max())
        mat = np.full((len(sel), max_len), v, dtype=np.int32)
        for i, s in enumerate(sel):
            mat[i, : lens[i]] = order[starts[s]:ends[s]]
        color_rows.append(jnp.asarray(mat))
    return tuple(color_rows)


def setup_cluster_gs(a, aggregation: str = "two_phase",
                     options: Mis2Options | None = None,
                     coarsen_levels: int = 1) -> MulticolorGSPreconditioner:
    import time

    from ..api.registry import get_engine  # lazy: engines register on import

    if isinstance(a, Graph):
        a = a.csr_matrix
    t0 = time.perf_counter()
    v = a.num_rows
    agg_fn = get_engine("aggregation", aggregation)
    agg = agg_fn(a.graph, options=options)
    labels = agg.labels
    nagg = agg.num_aggregates
    for _ in range(coarsen_levels - 1):        # optional deeper clustering
        cg = coarse_graph_from_labels(a.graph, labels, nagg)
        agg2 = agg_fn(cg, options=options)
        labels = agg2.labels[labels]
        nagg = agg2.num_aggregates
    coarse = coarse_graph_from_labels(a.graph, labels, nagg)
    coloring = _color_graph_impl(coarse)
    if not coloring.converged:     # a partial coloring is unusable for GS
        raise RuntimeError("coarse-graph coloring did not converge")
    color_rows = _pack_clusters(labels, coloring.colors, coloring.num_colors, v)
    ell = csr_to_ell_matrix(a)
    diag = extract_diagonal(a)
    return MulticolorGSPreconditioner(
        ell, diag, color_rows, coloring.num_colors, nagg,
        time.perf_counter() - t0, "cluster")


def setup_point_gs(a) -> MulticolorGSPreconditioner:
    import time
    if isinstance(a, Graph):
        a = a.csr_matrix
    t0 = time.perf_counter()
    v = a.num_rows
    coloring = _color_graph_impl(a.graph)      # colors the FINE graph
    if not coloring.converged:     # a partial coloring is unusable for GS
        raise RuntimeError("fine-graph coloring did not converge")
    labels = np.arange(v, dtype=np.int32)      # singleton clusters
    color_rows = _pack_clusters(labels, coloring.colors, coloring.num_colors, v)
    ell = csr_to_ell_matrix(a)
    diag = extract_diagonal(a)
    return MulticolorGSPreconditioner(
        ell, diag, color_rows, coloring.num_colors, v,
        time.perf_counter() - t0, "point")
