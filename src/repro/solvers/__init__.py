from .amg import AGGREGATORS, AMGHierarchy, build_hierarchy, v_cycle
from .krylov import SolveResult, cg, gmres
from .multicolor_gs import (
    MulticolorGSPreconditioner,
    setup_cluster_gs,
    setup_point_gs,
)

__all__ = [
    "AGGREGATORS", "AMGHierarchy", "build_hierarchy", "v_cycle",
    "SolveResult", "cg", "gmres",
    "MulticolorGSPreconditioner", "setup_cluster_gs", "setup_point_gs",
]
