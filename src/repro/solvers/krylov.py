"""Krylov solvers (CG, restarted GMRES) with pluggable preconditioners.

Jitted step bodies, host-side convergence control — the solve phase mirrors
the paper's experiments (CG for Table V multigrid, GMRES for Table VI
cluster-SGS preconditioning).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

MatVec = Callable[[jnp.ndarray], jnp.ndarray]
Precond = Callable[[jnp.ndarray], jnp.ndarray]


@dataclass
class SolveResult:
    x: np.ndarray
    iterations: int
    residual_norm: float
    converged: bool
    residual_history: list


def _identity(x):
    return x


def cg(matvec: MatVec, b: jnp.ndarray, x0: Optional[jnp.ndarray] = None,
       precond: Optional[Precond] = None, tol: float = 1e-12,
       maxiter: int = 1000) -> SolveResult:
    """Preconditioned conjugate gradient. Converges when ||r|| <= tol * ||b||."""
    m = precond or _identity
    x = jnp.zeros_like(b) if x0 is None else x0
    b_norm = float(jnp.linalg.norm(b))
    if b_norm == 0.0:
        return SolveResult(np.asarray(x), 0, 0.0, True, [])

    @jax.jit
    def step(x, r, z, p, rz):
        ap = matvec(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = m(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return x, r, z, p, rz_new, jnp.linalg.norm(r)

    r = b - matvec(x)
    z = m(r)
    p = z
    rz = jnp.vdot(r, z)
    hist = []
    it = 0
    rn = float(jnp.linalg.norm(r))
    while rn > tol * b_norm and it < maxiter:
        x, r, z, p, rz, rn_j = step(x, r, z, p, rz)
        rn = float(rn_j)
        hist.append(rn)
        it += 1
    return SolveResult(np.asarray(x), it, rn, rn <= tol * b_norm, hist)


def gmres(matvec: MatVec, b: jnp.ndarray, x0: Optional[jnp.ndarray] = None,
          precond: Optional[Precond] = None, tol: float = 1e-8,
          restart: int = 50, maxiter: int = 800) -> SolveResult:
    """Right-preconditioned restarted GMRES(restart).

    ``maxiter`` counts total inner iterations (matches the paper's GMRES
    iteration counts in Table VI).
    """
    m = precond or _identity
    x = jnp.zeros_like(b) if x0 is None else x0
    b_norm = float(jnp.linalg.norm(b))
    if b_norm == 0.0:
        return SolveResult(np.asarray(x), 0, 0.0, True, [])

    mv = jax.jit(lambda v: matvec(m(v)))
    hist = []
    total_it = 0
    rn = None
    prev_beta = None
    best_x, best_beta = x, None
    while total_it < maxiter:
        r = b - matvec(x)
        beta = float(jnp.linalg.norm(r))
        if best_beta is None or beta < best_beta:
            best_x, best_beta = x, beta
        if rn is None:
            rn = beta
        if beta <= tol * b_norm:
            return SolveResult(np.asarray(x), total_it, beta, True, hist)
        if prev_beta is not None and beta >= prev_beta * 0.999:
            # fp32 accuracy floor reached: restarts stopped helping
            return SolveResult(np.asarray(best_x), total_it, best_beta,
                               best_beta <= tol * b_norm, hist)
        prev_beta = beta
        n = b.shape[0]
        k_max = min(restart, maxiter - total_it)
        v_basis = np.zeros((k_max + 1, n), dtype=np.float64)
        v_basis[0] = np.asarray(r, dtype=np.float64) / beta
        h = np.zeros((k_max + 1, k_max), dtype=np.float64)
        cs = np.zeros(k_max)
        sn = np.zeros(k_max)
        g = np.zeros(k_max + 1)
        g[0] = beta
        k_used = 0
        for k in range(k_max):
            w = np.asarray(mv(jnp.asarray(v_basis[k], dtype=b.dtype)),
                           dtype=np.float64)
            # modified Gram-Schmidt
            for j in range(k + 1):
                h[j, k] = np.dot(v_basis[j], w)
                w = w - h[j, k] * v_basis[j]
            h[k + 1, k] = np.linalg.norm(w)
            if h[k + 1, k] > 1e-300:
                v_basis[k + 1] = w / h[k + 1, k]
            # apply stored Givens rotations
            for j in range(k):
                t = cs[j] * h[j, k] + sn[j] * h[j + 1, k]
                h[j + 1, k] = -sn[j] * h[j, k] + cs[j] * h[j + 1, k]
                h[j, k] = t
            denom = np.hypot(h[k, k], h[k + 1, k])
            cs[k], sn[k] = h[k, k] / denom, h[k + 1, k] / denom
            h[k, k] = denom
            h[k + 1, k] = 0.0
            g[k + 1] = -sn[k] * g[k]
            g[k] = cs[k] * g[k]
            k_used = k + 1
            total_it += 1
            rn = abs(g[k + 1])
            hist.append(rn)
            if rn <= tol * b_norm:
                break
        # solve the small triangular system and update x
        y = np.linalg.solve(h[:k_used, :k_used], g[:k_used])
        update = jnp.asarray((v_basis[:k_used].T @ y), dtype=b.dtype)
        x = x + m(update)
        if rn <= tol * b_norm:
            return SolveResult(np.asarray(x), total_it, float(rn), True, hist)
    r = b - matvec(x)
    rn = float(jnp.linalg.norm(r))
    return SolveResult(np.asarray(x), total_it, rn, rn <= tol * b_norm, hist)
