"""Smoothed-aggregation algebraic multigrid built on MIS-2 aggregation.

Reproduces the paper's Table V setting: a V-cycle SA preconditioner whose
aggregates come from Algorithm 2 ("MIS2 Basic"), Algorithm 3 ("MIS2 Agg"),
or the host-sequential greedy ("Serial Agg" stand-in), used inside CG with
a damped-Jacobi smoother.

Setup now lives in :mod:`repro.multilevel` (engines ``host`` | ``resident``
dispatched through the api registry; see ``repro.amg_setup``); this module
keeps the **solve phase** (fully jitted per level: damped-Jacobi pre/post
smoothing, ELL SpMV residuals, ELL prolong/restrict) plus the legacy
entry points, which re-export the multilevel containers unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .._compat import DeprecatedMapping, warn_deprecated
from ..core.aggregation import (
    _aggregate_basic_impl,
    _aggregate_serial_greedy_impl,
    _aggregate_two_phase_impl,
)
from ..core.mis2 import Mis2Options
from ..graphs.csr import CSRMatrix, ELLMatrix
from ..multilevel.hierarchy import (  # noqa: F401  (compat re-exports)
    AMGHierarchy,
    AMGLevel,
    _build_hierarchy_impl,
)
from ..multilevel.prolongator import rect_ell as _rect_ell  # noqa: F401

# Deprecated: aggregation dispatch moved to the repro.api engine registry
# (register_engine("aggregation", ...)); this mapping warns on access.
AGGREGATORS = DeprecatedMapping(
    {
        "mis2_basic": _aggregate_basic_impl,          # Alg. 2
        "mis2_agg": _aggregate_two_phase_impl,        # Alg. 3
        "serial": lambda graph, options=None, **_:    # Table V "Serial Agg"
            _aggregate_serial_greedy_impl(graph),
    },
    "solvers.amg.AGGREGATORS",
    'repro.api.registry.get_engine("aggregation", name)',
)


def build_hierarchy(a: CSRMatrix, aggregation: str = "mis2_agg",
                    max_levels: int = 10, coarse_size: int = 200,
                    omega: float = 2.0 / 3.0, jacobi_weight: float = 2.0 / 3.0,
                    smoother_sweeps: int = 2,
                    options: Mis2Options | None = None) -> AMGHierarchy:
    """Deprecated entry point — use :func:`repro.api.amg_setup`."""
    warn_deprecated("repro.solvers.amg.build_hierarchy",
                    "repro.api.amg_setup")
    return _build_hierarchy_impl(a, aggregation, max_levels, coarse_size,
                                 omega, jacobi_weight, smoother_sweeps,
                                 options)


# ---------------------------------------------------------------------------
# solve phase (jitted per level)
# ---------------------------------------------------------------------------

def _spmv(ell: ELLMatrix, x):
    return jnp.sum(ell.vals * x[ell.cols], axis=1)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def _jacobi(ell_cols, ell_vals, diag, x, b, weight, sweeps: int):
    for _ in range(sweeps):
        ax = jnp.sum(ell_vals * x[ell_cols], axis=1)
        x = x + weight * (b - ax) / diag
    return x


def v_cycle(h: AMGHierarchy, b: jnp.ndarray, level: int = 0) -> jnp.ndarray:
    lvl = h.levels[level]
    if lvl.p_ell is None:
        return h.coarse_solve(b)
    w = jnp.float32(h.jacobi_weight)
    x = _jacobi(lvl.a_ell.cols, lvl.a_ell.vals, lvl.diag,
                jnp.zeros_like(b), b, w, h.smoother_sweeps)
    r = b - _spmv(lvl.a_ell, x)
    if lvl.r_ell is not None:
        rc = _spmv(lvl.r_ell, r)
    else:
        # matrix-free restriction: R = P^T via the transposed ELL SpMV
        from ..kernels.spmv_ell import ops as spmv_ops

        rc = spmv_ops.spmv_t(lvl.p_ell, r, h.levels[level + 1].n)
    xc = v_cycle(h, rc, level + 1)
    x = x + _spmv(lvl.p_ell, xc)
    x = _jacobi(lvl.a_ell.cols, lvl.a_ell.vals, lvl.diag, x, b, w,
                h.smoother_sweeps)
    return x
