"""Smoothed-aggregation algebraic multigrid built on MIS-2 aggregation.

Reproduces the paper's Table V setting: a V-cycle SA preconditioner whose
aggregates come from Algorithm 2 ("MIS2 Basic"), Algorithm 3 ("MIS2 Agg"),
or the host-sequential greedy ("Serial Agg" stand-in), used inside CG with
a damped-Jacobi smoother.

Setup (host + device, like MueLu's):
  tentative P0[v, agg(v)] = 1/sqrt(|agg|);  P = (I - omega D^-1 A) P0;
  A_{l+1} = P^T A_l P (Galerkin, host scipy); coarsest level is solved
  densely with a cached factorization.
Solve (fully jitted per level): damped-Jacobi pre/post smoothing, ELL SpMV
residuals, ELL prolong/restrict.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from .._compat import DeprecatedMapping, warn_deprecated
from ..graphs.csr import CSRMatrix, ELLMatrix, csr_to_ell_matrix
from ..graphs.handle import Graph
from ..graphs.ops import extract_diagonal, galerkin_coarse_matrix, matrix_to_scipy
from ..core.aggregation import (
    _aggregate_basic_impl,
    _aggregate_serial_greedy_impl,
    _aggregate_two_phase_impl,
)
from ..core.mis2 import Mis2Options

# Deprecated: aggregation dispatch moved to the repro.api engine registry
# (register_engine("aggregation", ...)); this mapping warns on access.
AGGREGATORS = DeprecatedMapping(
    {
        "mis2_basic": _aggregate_basic_impl,          # Alg. 2
        "mis2_agg": _aggregate_two_phase_impl,        # Alg. 3
        "serial": lambda graph, options=None, **_:    # Table V "Serial Agg"
            _aggregate_serial_greedy_impl(graph),
    },
    "solvers.amg.AGGREGATORS",
    'repro.api.registry.get_engine("aggregation", name)',
)


@dataclass
class AMGLevel:
    a_ell: ELLMatrix
    diag: jnp.ndarray
    p_ell: ELLMatrix | None        # prolongator (fine x coarse), None at coarsest
    r_ell: ELLMatrix | None        # restriction = P^T
    n: int
    nnz: int


@dataclass
class AMGHierarchy:
    levels: List[AMGLevel]
    coarse_solve: Callable
    setup_seconds: float
    aggregation_seconds: float
    aggregation: str
    omega: float
    jacobi_weight: float
    smoother_sweeps: int
    level_sizes: list = field(default_factory=list)

    def as_precond(self) -> Callable:
        return functools.partial(v_cycle, self)


def _rect_ell(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
              nrows: int) -> ELLMatrix:
    """Rectangular ELL from COO (for P and R; padding col 0, val 0)."""
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    counts = np.bincount(rows, minlength=nrows)
    d = max(1, int(counts.max()))
    cmat = np.zeros((nrows, d), dtype=np.int32)
    vmat = np.zeros((nrows, d), dtype=np.float32)
    mmat = np.zeros((nrows, d), dtype=bool)
    slot = np.arange(len(rows)) - np.repeat(np.cumsum(counts) - counts, counts)
    cmat[rows, slot] = cols
    vmat[rows, slot] = vals
    mmat[rows, slot] = True
    return ELLMatrix(jnp.asarray(cmat), jnp.asarray(vmat), jnp.asarray(mmat))


def _smoothed_prolongator(a: CSRMatrix, labels: np.ndarray, nagg: int,
                          omega: float):
    """P = (I - omega D^-1 A) P0 in COO (host)."""
    asp = matrix_to_scipy(a)
    import scipy.sparse as sp

    v = a.num_rows
    sizes = np.bincount(labels, minlength=nagg).astype(np.float64)
    p0 = sp.csr_matrix(
        (1.0 / np.sqrt(sizes[labels]), (np.arange(v), labels)), shape=(v, nagg)
    )
    d_inv = 1.0 / asp.diagonal()
    p = p0 - omega * sp.diags(d_inv) @ (asp @ p0)
    p = p.tocoo()
    return p.row, p.col, p.data


def _build_hierarchy_impl(a, aggregation: str = "mis2_agg",
                          max_levels: int = 10, coarse_size: int = 200,
                          omega: float = 2.0 / 3.0,
                          jacobi_weight: float = 2.0 / 3.0,
                          smoother_sweeps: int = 2,
                          options: Mis2Options | None = None,
                          mis2_engine: str | None = None,
                          interpret=None) -> AMGHierarchy:
    # aggregation dispatch via the api engine registry (aliases keep the
    # legacy "mis2_basic" / "mis2_agg" spellings working)
    from ..api.registry import get_engine

    if isinstance(a, Graph):
        a = a.csr_matrix
    t_setup = time.time()
    t_agg = 0.0
    agg_fn = get_engine("aggregation", aggregation)
    levels: List[AMGLevel] = []
    sizes = []
    cur = a
    while len(levels) < max_levels - 1 and cur.num_rows > coarse_size:
        t0 = time.time()
        agg_kwargs = dict(options=options, interpret=interpret)
        if mis2_engine is not None:
            # None = engine's own default; omit so engines registered with
            # any default spelling keep applying theirs (mirrors facade)
            agg_kwargs["mis2_engine"] = mis2_engine
        agg = agg_fn(cur.graph, **agg_kwargs)
        t_agg += time.time() - t0
        if agg.num_aggregates >= cur.num_rows:
            break
        pr, pc, pv = _smoothed_prolongator(cur, agg.labels, agg.num_aggregates,
                                           omega)
        a_next = galerkin_coarse_matrix(cur, pr, pc, pv, agg.num_aggregates)
        p_ell = _rect_ell(pr, pc, pv.astype(np.float32), cur.num_rows)
        r_ell = _rect_ell(pc, pr, pv.astype(np.float32), agg.num_aggregates)
        levels.append(AMGLevel(csr_to_ell_matrix(cur), extract_diagonal(cur),
                               p_ell, r_ell, cur.num_rows, cur.num_entries))
        sizes.append((cur.num_rows, cur.num_entries))
        cur = a_next
    # coarsest level: cached dense factorization
    levels.append(AMGLevel(csr_to_ell_matrix(cur), extract_diagonal(cur),
                           None, None, cur.num_rows, cur.num_entries))
    sizes.append((cur.num_rows, cur.num_entries))
    dense = np.asarray(matrix_to_scipy(cur).todense())
    lu_piv = jax.scipy.linalg.lu_factor(jnp.asarray(dense, dtype=jnp.float32))

    @jax.jit
    def coarse_solve(b):
        return jax.scipy.linalg.lu_solve(lu_piv, b)

    return AMGHierarchy(levels, coarse_solve, time.time() - t_setup, t_agg,
                        aggregation, omega, jacobi_weight, smoother_sweeps,
                        sizes)


def build_hierarchy(a: CSRMatrix, aggregation: str = "mis2_agg",
                    max_levels: int = 10, coarse_size: int = 200,
                    omega: float = 2.0 / 3.0, jacobi_weight: float = 2.0 / 3.0,
                    smoother_sweeps: int = 2,
                    options: Mis2Options | None = None) -> AMGHierarchy:
    """Deprecated entry point — use :func:`repro.api.amg`."""
    warn_deprecated("repro.solvers.amg.build_hierarchy", "repro.api.amg")
    return _build_hierarchy_impl(a, aggregation, max_levels, coarse_size,
                                 omega, jacobi_weight, smoother_sweeps,
                                 options)


# ---------------------------------------------------------------------------
# solve phase (jitted per level)
# ---------------------------------------------------------------------------

def _spmv(ell: ELLMatrix, x):
    return jnp.sum(ell.vals * x[ell.cols], axis=1)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def _jacobi(ell_cols, ell_vals, diag, x, b, weight, sweeps: int):
    for _ in range(sweeps):
        ax = jnp.sum(ell_vals * x[ell_cols], axis=1)
        x = x + weight * (b - ax) / diag
    return x


def v_cycle(h: AMGHierarchy, b: jnp.ndarray, level: int = 0) -> jnp.ndarray:
    lvl = h.levels[level]
    if lvl.p_ell is None:
        return h.coarse_solve(b)
    w = jnp.float32(h.jacobi_weight)
    x = _jacobi(lvl.a_ell.cols, lvl.a_ell.vals, lvl.diag,
                jnp.zeros_like(b), b, w, h.smoother_sweeps)
    r = b - _spmv(lvl.a_ell, x)
    rc = _spmv(lvl.r_ell, r)
    xc = v_cycle(h, rc, level + 1)
    x = x + _spmv(lvl.p_ell, xc)
    x = _jacobi(lvl.a_ell.cols, lvl.a_ell.vals, lvl.diag, x, b, w,
                h.smoother_sweeps)
    return x
