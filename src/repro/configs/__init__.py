# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""Architecture config registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

from importlib import import_module

ARCH_IDS = (
    "chameleon-34b",
    "whisper-tiny",
    "smollm-135m",
    "llama3.2-3b",
    "granite-8b",
    "smollm-360m",
    "recurrentgemma-2b",
    "mamba2-780m",
    "granite-moe-1b-a400m",
    "qwen2-moe-a2.7b",
)

_MODULES = {
    "chameleon-34b": "chameleon_34b",
    "whisper-tiny": "whisper_tiny",
    "smollm-135m": "smollm_135m",
    "llama3.2-3b": "llama32_3b",
    "granite-8b": "granite_8b",
    "smollm-360m": "smollm_360m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-780m": "mamba2_780m",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
}


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
