# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""smollm-360m [hf:HuggingFaceTB/SmolLM-135M family; hf] — llama-arch small dense."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
        d_ff=2560, vocab_size=49152, head_dim=64,
        tie_embeddings=True, rope_theta=10000.0,
    )
