# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""mamba2-780m [arXiv:2405.21060; unverified] — SSD, attention-free."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        num_layers=48, d_model=1536, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    )
