# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""llama3.2-3b [hf:meta-llama/Llama-3.2-*; unverified] — small llama3 dense."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=128256, head_dim=128,
        rope_theta=500000.0,
    )
