# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
— 60 routed experts top-4 + 4 shared experts (shared hidden 4x1408=5632)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=0, vocab_size=151936, head_dim=128,
        num_experts=60, num_experts_per_token=4, num_shared_experts=4,
        moe_d_ff=1408, norm_topk_prob=False,
    )
