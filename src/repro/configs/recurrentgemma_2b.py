# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""recurrentgemma-2b [arXiv:2402.19427; hf] — RG-LRU + local attention 1:2."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        d_ff=7680, vocab_size=256000, head_dim=256,
        block_pattern=("rglru", "rglru", "local"),
        local_window=2048, d_rnn=2560,
    )
