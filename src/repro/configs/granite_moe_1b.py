# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
— 32 experts, top-8."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
        d_ff=0, vocab_size=49155, head_dim=64,
        num_experts=32, num_experts_per_token=8, moe_d_ff=512,
        norm_topk_prob=True,
    )
