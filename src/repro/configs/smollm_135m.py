# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf] — llama-arch small dense."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
        d_ff=1536, vocab_size=49152, head_dim=64,
        tie_embeddings=True, rope_theta=10000.0,
    )
