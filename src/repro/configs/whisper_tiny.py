# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""whisper-tiny [arXiv:2212.04356; unverified] — enc-dec audio backbone.

Modality note (assignment): the conv/mel frontend is a STUB — input_specs
feeds precomputed frame embeddings [B, 1500, 384] to the encoder.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec",
        num_layers=4, encoder_layers=4, d_model=384, num_heads=6,
        num_kv_heads=6, d_ff=1536, vocab_size=51865, head_dim=64,
        encoder_seq=1500, tie_embeddings=True,
    )
