# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""granite-8b [arXiv:2405.04324; hf] — llama-arch dense, code model."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=49152, head_dim=128,
        rope_theta=10000.0,
    )
