# repro-lint: legacy seed-era LM model configs, no graph-facade consumers
"""chameleon-34b [arXiv:2405.09818; unverified] — early-fusion VLM backbone.

Modality note (assignment): the VQ image tokenizer is a STUB — inputs are
token ids over the fused 65536-entry vocabulary (text + VQ image codes).
The backbone below is the full 34B decoder.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22016, vocab_size=65536, head_dim=128,
        rope_theta=10000.0,
    )
