"""Built-in engine registrations.

Imported by ``repro.api.__init__`` so that every process that touches the
facade (or looks an engine up lazily from a lower layer, e.g.
``solvers/amg.py``) sees the full engine table.

Engine call conventions
-----------------------
* ``mis2``:        fn(graph, active, options, backend) -> core Mis2Result
* ``aggregation``: fn(graph, options=None, mis2_engine=None,
                      interpret=None, min_secondary_neighbors=2,
                      backend=None) -> core AggregationResult
                   (``mis2_engine=None`` = the engine's own default inner
                   fixed point; ``backend`` is forwarded by the facade
                   only when the engine declares it, so externally
                   registered engines on the old convention keep working)
* ``misk``:        fn(graph, k, priority, max_iters, backend)
                   -> core Mis2Result
* ``multilevel``:  fn(kind, graph, **kwargs) with kind 'amg' | 'cluster_gs'
                   -> AMGHierarchy | cluster-GS setup tuple (the facade
                   wraps either in its Result type)
* ``coloring``:    fn(graph, max_rounds, backend) -> core ColoringResult
* ``partition``:   fn(graph, num_parts, coarse_target, options, backend)
                   -> core PartitionResult
"""
from __future__ import annotations

from ..batch.pipeline import _mis2_batch_impl
from ..core.aggregation import (
    _aggregate_basic_impl,
    _aggregate_serial_greedy_impl,
    _aggregate_two_phase_impl,
)
from ..core.coloring import _color_graph_impl
from ..core.mis2 import (
    Mis2Options,
    _mis2_compacted_impl,
    _mis2_dense_impl,
    _mis2_resident_impl,
)
from ..core.partition import _partition_impl
from .backend import Backend, default_mis2_engine
from .registry import register_engine


def _opts(options) -> Mis2Options:
    return Mis2Options() if options is None else options


def _dist_mesh_kw(mis2_engine, backend) -> dict:
    """mesh/axis kwargs for aggregation impls whose inner MIS-2 engine is
    distributed — the Backend mesh policy must reach the sharded fixed
    point even through the single-device aggregation drivers."""
    if mis2_engine in ("distributed", "distributed_single_gather"):
        from .backend import get_default_backend

        be = backend if backend is not None else get_default_backend()
        mesh, axis = be.resolve_mesh()
        return {"mesh": mesh, "axis": axis}
    return {}


# -- mis2 -------------------------------------------------------------------

@register_engine("mis2", "dense",
                 doc="single jitted lax.while_loop fixed point (masks, no "
                     "worklist compaction); safe inside larger jitted code")
def _mis2_dense(graph, active, options, backend: Backend):
    return _mis2_dense_impl(graph, active, _opts(options))


@register_engine("mis2", "compacted",
                 doc="host-orchestrated §V-B worklist compaction; the "
                     "production CPU/TPU path behind the Fig. 2 ablation")
def _mis2_compacted(graph, active, options, backend: Backend):
    return _mis2_compacted_impl(graph, active, _opts(options), pallas=False,
                                interpret=backend.resolve_interpret())


@register_engine("mis2", "pallas",
                 doc="compacted driver with the Pallas min-propagation "
                     "kernels on the measured hot loop")
def _mis2_pallas(graph, active, options, backend: Backend):
    return _mis2_compacted_impl(graph, active, _opts(options), pallas=True,
                                interpret=backend.resolve_interpret())


@register_engine("mis2", "compacted_resident",
                 doc="device-resident §V-B fixed point: one jitted "
                     "while_loop per solve, worklists compacted on device "
                     "(cumsum stream compaction), zero host round-trips — "
                     "bit-identical to 'compacted'; the facade default on "
                     "accelerators")
def _mis2_compacted_resident(graph, active, options, backend: Backend):
    return _mis2_resident_impl(graph, active, _opts(options), pallas=False,
                               interpret=backend.resolve_interpret())


@register_engine("mis2", "pallas_resident",
                 doc="resident driver with the FUSED Pallas passes (rank "
                     "packing folded into refresh_columns, row-gather "
                     "folded into decide): each round reads the ELL rows "
                     "once per pass, live counts feed pl.when block "
                     "skipping on device")
def _mis2_pallas_resident(graph, active, options, backend: Backend):
    return _mis2_resident_impl(graph, active, _opts(options), pallas=True,
                               interpret=backend.resolve_interpret())


@register_engine("mis2", "pallas_hybrid",
                 doc="resident driver over the degree-aware hybrid layout "
                     "(sliced-ELL degree buckets + sorted-COO spill for "
                     "heavy hitters): one fused Pallas pass per slice + "
                     "segment reductions for the spill, all inside one "
                     "jitted while_loop — O(E) memory on skewed graphs "
                     "whose padded ELL cannot be allocated, bit-identical "
                     "to 'dense'; auto-selected past the padded-ELL bytes "
                     "threshold")
def _mis2_pallas_hybrid(graph, active, options, backend: Backend):
    from ..core.mis2_hybrid import _mis2_hybrid_impl

    return _mis2_hybrid_impl(graph, active, _opts(options),
                             interpret=backend.resolve_interpret())


@register_engine("mis2", "dense_batched",
                 doc="vmapped dense fixed point over padded size buckets "
                     "(repro.batch); a single-graph call runs as a batch "
                     "of one — bit-identical to 'dense'")
def _mis2_dense_batched(graph, active, options, backend: Backend):
    from ..batch.container import GraphBatch

    actives = None if active is None else [active]
    return _mis2_batch_impl(GraphBatch([graph]), _opts(options), actives)[0]


@register_engine("mis2", "distributed",
                 doc="shard_map vertex partition over Backend(mesh=..., "
                     "axis=...): T and M all-gathered per iteration "
                     "(2·V·4 B collective traffic) — bit-identical to "
                     "'dense' for any device count")
def _mis2_distributed(graph, active, options, backend: Backend):
    from ..core.dist import _mis2_distributed_impl

    mesh, axis = backend.resolve_mesh()
    return _mis2_distributed_impl(graph, active, _opts(options),
                                  mesh=mesh, axis=axis, single_gather=False)


@register_engine("mis2", "distributed_single_gather",
                 doc="distributed variant gathering T once per iteration "
                     "and recomputing M locally (V·4 B collective traffic "
                     "— half of 'distributed'; replicates the ELL "
                     "adjacency)")
def _mis2_distributed_single_gather(graph, active, options, backend: Backend):
    from ..core.dist import _mis2_distributed_impl

    mesh, axis = backend.resolve_mesh()
    return _mis2_distributed_impl(graph, active, _opts(options),
                                  mesh=mesh, axis=axis, single_gather=True)


# -- aggregation (coarsening) ----------------------------------------------

@register_engine("aggregation", "basic", aliases=("mis2_basic",),
                 doc="paper Alg. 2 (Bell-style): MIS-2 roots + neighbors")
def _agg_basic(graph, options=None, mis2_engine=None, interpret=None,
               min_secondary_neighbors=2, backend=None):
    mis2_engine = mis2_engine or default_mis2_engine(backend, options, graph)
    return _aggregate_basic_impl(graph, _opts(options), mis2_engine,
                                 interpret=interpret,
                                 **_dist_mesh_kw(mis2_engine, backend))


@register_engine("aggregation", "two_phase", aliases=("mis2_agg",),
                 doc="paper Alg. 3 (ML-style): two MIS-2 phases + "
                     "max-coupling cleanup")
def _agg_two_phase(graph, options=None, mis2_engine=None,
                   interpret=None, min_secondary_neighbors=2, backend=None):
    mis2_engine = mis2_engine or default_mis2_engine(backend, options, graph)
    return _aggregate_two_phase_impl(graph, _opts(options), mis2_engine,
                                     min_secondary_neighbors,
                                     interpret=interpret,
                                     **_dist_mesh_kw(mis2_engine, backend))


@register_engine("aggregation", "serial",
                 doc="host-sequential greedy reference (Table V 'Serial Agg')")
def _agg_serial(graph, options=None, mis2_engine=None, interpret=None,
                min_secondary_neighbors=2, backend=None):
    return _aggregate_serial_greedy_impl(graph)


@register_engine("aggregation", "two_phase_distributed",
                 doc="paper Alg. 3 sharded over Backend(mesh=...): both "
                     "MIS-2 phases run the distributed fixed point and "
                     "each label-propagation round is one label "
                     "all-gather + local rowwise join — labels "
                     "bit-identical to 'two_phase'")
def _agg_two_phase_distributed(graph, options=None,
                               mis2_engine=None, interpret=None,
                               min_secondary_neighbors=2, backend=None):
    from ..core.aggregation import _aggregate_two_phase_distributed_impl
    from .backend import get_default_backend

    # None = this method's default fixed point; every explicit value must
    # name one of the two distributed engines (a deliberate 'compacted'
    # here is as wrong as 'pallas' and raises rather than being absorbed).
    if mis2_engine in (None, "distributed"):
        single_gather = False
    elif mis2_engine == "distributed_single_gather":
        single_gather = True
    else:
        raise ValueError(
            f"two_phase_distributed runs a distributed MIS-2; got "
            f"mis2_engine={mis2_engine!r} (use 'distributed' | "
            "'distributed_single_gather')")
    be = backend if backend is not None else get_default_backend()
    mesh, axis = be.resolve_mesh()
    return _aggregate_two_phase_distributed_impl(
        graph, _opts(options), min_secondary_neighbors, mesh=mesh, axis=axis,
        single_gather=single_gather)


# -- misk (distance-k MIS) --------------------------------------------------

@register_engine("misk", "dense",
                 doc="single jitted lax.while_loop over masked [V] state "
                     "(k-fold min-propagation)")
def _misk_dense(graph, k, priority, max_iters, backend: Backend):
    from ..core.misk import _mis_k_impl

    return _mis_k_impl(graph, k, priority, max_iters)


@register_engine("misk", "resident",
                 doc="§V-B worklist shape for distance-k: on-device "
                     "compacted worklist feeds the row refresh inside "
                     "the single jitted while_loop — bit-identical to "
                     "'dense' (which is already one dispatch per solve "
                     "and stays the default); kept for ablation")
def _misk_resident(graph, k, priority, max_iters, backend: Backend):
    from ..core.misk import _misk_resident_impl

    return _misk_resident_impl(graph, k, priority, max_iters)


# -- multilevel setup (AMG hierarchy / cluster-GS packing) ------------------

@register_engine("multilevel", "host",
                 doc="legacy host orchestration: scipy smoothed "
                     "prolongator, canonical sorted-COO Galerkin on "
                     "numpy, numpy cluster packing — ~3 matrix-sized "
                     "host round-trips per level (SETUP_STATS.host_syncs)")
def _multilevel_host(kind, graph, **kwargs):
    from ..multilevel.hierarchy import (
        _build_hierarchy_impl,
        _cluster_gs_setup_impl,
    )

    fn = _build_hierarchy_impl if kind == "amg" else _cluster_gs_setup_impl
    return fn(graph, engine="host", **kwargs)


@register_engine("multilevel", "resident",
                 doc="whole per-level setup jitted on device (x64): "
                     "fixed-shape prolongator assembly, padded sorted-COO "
                     "SpGEMM Galerkin, coarse ELL repack, cluster/color "
                     "packing — 7 dispatches per level, zero matrix-sized "
                     "host syncs, digest-identical to 'host'; the facade "
                     "default on accelerators")
def _multilevel_resident(kind, graph, **kwargs):
    from ..multilevel.hierarchy import (
        _build_hierarchy_impl,
        _cluster_gs_setup_impl,
    )

    fn = _build_hierarchy_impl if kind == "amg" else _cluster_gs_setup_impl
    return fn(graph, engine="resident", **kwargs)


# -- coloring ---------------------------------------------------------------

@register_engine("coloring", "luby",
                 doc="Luby-style rounds with xorshift* packed priorities")
def _color_luby(graph, max_rounds, backend: Backend):
    return _color_graph_impl(graph, max_rounds)


@register_engine("coloring", "luby_hybrid",
                 doc="Luby rounds over the degree-aware hybrid layout "
                     "(sliced-ELL + COO spill); bit-identical colors "
                     "without the monolithic padded ELL")
def _color_luby_hybrid(graph, max_rounds, backend: Backend):
    from ..core.coloring import _color_hybrid_impl

    return _color_hybrid_impl(graph, max_rounds)


# -- partition --------------------------------------------------------------

@register_engine("partition", "multilevel",
                 doc="MIS-2 multilevel coarsen + greedy coarse split + "
                     "boundary refinement per level")
def _partition_multilevel(graph, num_parts, coarse_target, options,
                          backend: Backend):
    return _partition_impl(graph, num_parts, coarse_target, _opts(options),
                           interpret=backend.resolve_interpret())
