"""Built-in engine registrations.

Imported by ``repro.api.__init__`` so that every process that touches the
facade (or looks an engine up lazily from a lower layer, e.g.
``solvers/amg.py``) sees the full engine table.

Engine call conventions
-----------------------
* ``mis2``:        fn(graph, active, options, backend) -> core Mis2Result
* ``aggregation``: fn(graph, options=None, mis2_engine="compacted",
                      interpret=None) -> core AggregationResult
* ``coloring``:    fn(graph, max_rounds, backend) -> core ColoringResult
* ``partition``:   fn(graph, num_parts, coarse_target, options, backend)
                   -> core PartitionResult
"""
from __future__ import annotations

from ..batch.pipeline import _mis2_batch_impl
from ..core.aggregation import (
    _aggregate_basic_impl,
    _aggregate_serial_greedy_impl,
    _aggregate_two_phase_impl,
)
from ..core.coloring import _color_graph_impl
from ..core.mis2 import Mis2Options, _mis2_compacted_impl, _mis2_dense_impl
from ..core.partition import _partition_impl
from .backend import Backend
from .registry import register_engine


def _opts(options) -> Mis2Options:
    return Mis2Options() if options is None else options


# -- mis2 -------------------------------------------------------------------

@register_engine("mis2", "dense",
                 doc="single jitted lax.while_loop fixed point (masks, no "
                     "worklist compaction); safe inside larger jitted code")
def _mis2_dense(graph, active, options, backend: Backend):
    return _mis2_dense_impl(graph, active, _opts(options))


@register_engine("mis2", "compacted",
                 doc="host-orchestrated §V-B worklist compaction; the "
                     "production CPU/TPU path behind the Fig. 2 ablation")
def _mis2_compacted(graph, active, options, backend: Backend):
    return _mis2_compacted_impl(graph, active, _opts(options), pallas=False,
                                interpret=backend.resolve_interpret())


@register_engine("mis2", "pallas",
                 doc="compacted driver with the Pallas min-propagation "
                     "kernels on the measured hot loop")
def _mis2_pallas(graph, active, options, backend: Backend):
    return _mis2_compacted_impl(graph, active, _opts(options), pallas=True,
                                interpret=backend.resolve_interpret())


@register_engine("mis2", "dense_batched",
                 doc="vmapped dense fixed point over padded size buckets "
                     "(repro.batch); a single-graph call runs as a batch "
                     "of one — bit-identical to 'dense'")
def _mis2_dense_batched(graph, active, options, backend: Backend):
    from ..batch.container import GraphBatch

    actives = None if active is None else [active]
    return _mis2_batch_impl(GraphBatch([graph]), _opts(options), actives)[0]


# -- aggregation (coarsening) ----------------------------------------------

@register_engine("aggregation", "basic", aliases=("mis2_basic",),
                 doc="paper Alg. 2 (Bell-style): MIS-2 roots + neighbors")
def _agg_basic(graph, options=None, mis2_engine="compacted", interpret=None,
               min_secondary_neighbors=2):
    return _aggregate_basic_impl(graph, _opts(options), mis2_engine,
                                 interpret=interpret)


@register_engine("aggregation", "two_phase", aliases=("mis2_agg",),
                 doc="paper Alg. 3 (ML-style): two MIS-2 phases + "
                     "max-coupling cleanup")
def _agg_two_phase(graph, options=None, mis2_engine="compacted",
                   interpret=None, min_secondary_neighbors=2):
    return _aggregate_two_phase_impl(graph, _opts(options), mis2_engine,
                                     min_secondary_neighbors,
                                     interpret=interpret)


@register_engine("aggregation", "serial",
                 doc="host-sequential greedy reference (Table V 'Serial Agg')")
def _agg_serial(graph, options=None, mis2_engine="compacted", interpret=None,
                min_secondary_neighbors=2):
    return _aggregate_serial_greedy_impl(graph)


# -- coloring ---------------------------------------------------------------

@register_engine("coloring", "luby",
                 doc="Luby-style rounds with xorshift* packed priorities")
def _color_luby(graph, max_rounds, backend: Backend):
    return _color_graph_impl(graph, max_rounds)


# -- partition --------------------------------------------------------------

@register_engine("partition", "multilevel",
                 doc="MIS-2 multilevel coarsen + greedy coarse split + "
                     "boundary refinement per level")
def _partition_multilevel(graph, num_parts, coarse_target, options,
                          backend: Backend):
    return _partition_impl(graph, num_parts, coarse_target, _opts(options),
                           interpret=backend.resolve_interpret())
