"""Public home of the :class:`Graph` handle.

The implementation lives in ``repro.graphs.handle`` (the bottom structural
layer) so that ``core/`` and ``solvers/`` can coerce handles without
importing the facade; this module is the supported import path.
"""
from ..graphs.handle import Graph, as_csr_graph, as_ell_graph, as_graph

__all__ = ["Graph", "as_graph", "as_ell_graph", "as_csr_graph"]
