"""``repro.api`` — the single public surface of the reproduction.

One algorithm, many backends, bit-identical results (the paper's
portability claim) expressed as three orthogonal concepts:

* :class:`Graph`   — cached-format handle (CSR/ELL/COO/bucketed computed
  lazily, exactly once, shared across every pipeline call);
* :class:`Backend` — execution policy (Pallas kernels on/off, interpret
  mode auto-derived from the attached accelerator, device placement),
  threaded down to ``kernels/*/ops.py``;
* engine registry  — ``(pipeline, engine-name)`` dispatch replacing the
  seed's ad-hoc per-engine entry points; all engines of a pipeline return
  the common :class:`Result` protocol (host-numpy payload + iterations +
  convergence + wall time + determinism digest).

Legacy entry points (``repro.core.mis2.mis2``, ``solvers.amg.AGGREGATORS``,
``Mis2Options(use_pallas=...)``, ...) still work but emit
``DeprecationWarning``; the old->new table is in API.md.
"""
from .backend import (
    Backend,
    accelerator_present,
    default_interpret,
    get_default_backend,
    set_default_backend,
    using_backend,
)
from .graph import Graph, as_csr_graph, as_ell_graph, as_graph
from ..batch.container import GraphBatch, as_graph_batch
from .registry import get_engine, get_engine_spec, list_engines, register_engine
from .result import (
    AggregationResult,
    AmgSetup,
    BatchResult,
    ClusterGsSetup,
    ColoringResult,
    Mis2Result,
    PartitionResult,
    Result,
    ResultLike,
    determinism_digest,
)
from . import engines as _engines  # noqa: F401  (registers built-in engines)
from .facade import (
    amg,
    amg_setup,
    amg_setup_batch,
    cluster_gs_setup,
    coarsen,
    coarsen_batch,
    color,
    color_batch,
    mis2,
    mis2_batch,
    misk,
    partition,
)
from ..core.mis2 import ABLATION_CHAIN, Mis2Options
from . import generators  # noqa: F401  (problem generators, re-exported)

__all__ = [
    # facade calls
    "mis2", "misk", "color", "coarsen", "partition", "amg",
    # multilevel setup (repro.multilevel engines)
    "amg_setup", "cluster_gs_setup",
    # batched facade calls (repro.batch)
    "mis2_batch", "color_batch", "coarsen_batch", "amg_setup_batch",
    "GraphBatch", "as_graph_batch", "BatchResult",
    # graph handle
    "Graph", "as_graph", "as_ell_graph", "as_csr_graph",
    # backend policy
    "Backend", "accelerator_present", "default_interpret",
    "get_default_backend", "set_default_backend", "using_backend",
    # engine registry
    "register_engine", "get_engine", "get_engine_spec", "list_engines",
    # problem generators (repro.api.generators.laplace3d, ...)
    "generators",
    # options / results
    "Mis2Options", "ABLATION_CHAIN",
    "Result", "ResultLike", "Mis2Result", "ColoringResult",
    "AggregationResult", "PartitionResult", "AmgSetup", "ClusterGsSetup",
    "determinism_digest",
]
