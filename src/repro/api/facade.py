"""The public facade: one call per pipeline, engines dispatched by name,
results in the common :class:`~repro.api.result.Result` protocol.

    import repro

    g = repro.Graph(repro.graphs.laplace3d(32))
    r = repro.mis2(g, engine="pallas")          # Mis2Result
    agg = repro.coarsen(g, method="two_phase")  # AggregationResult
    parts = repro.partition(g, num_parts=16)    # PartitionResult

Every function accepts a :class:`Graph` handle (conversions cached across
calls) or any bare structural container (``CSRGraph``/``CSRMatrix``/
``ELLGraph``/``ELLMatrix``), and an optional :class:`Backend` controlling
the Pallas/interpret/device policy.
"""
from __future__ import annotations

import time
from typing import Optional

from ..batch.container import GraphBatch, as_graph_batch
from ..batch.pipeline import (
    _coarsen_batch_impl,
    _color_batch_impl,
    _mis2_batch_impl,
)
from ..core.mis2 import Mis2Options
from ..graphs.handle import Graph, as_graph
from ..obs import Provenance
from ..obs import span as _obs_span
from .backend import Backend, backend_platform, resolve_backend
from .registry import get_engine
from .result import (
    AggregationResult,
    AmgSetup,
    BatchResult,
    ClusterGsSetup,
    ColoringResult,
    Mis2Result,
    PartitionResult,
    determinism_digest,
)


def _prepare(graph, backend: Backend) -> Graph:
    gh = as_graph(graph)
    if backend.device is not None:
        gh.place(backend.device)
    return gh


def _traced(kind: str, engine, be: Backend, call, wrap):
    """Run one facade engine call inside an ``obs`` span and attach the
    serializable provenance record to the wrapped Result.

    ``call()`` invokes the engine; ``wrap(core, dt)`` builds the facade
    Result.  The root span (wall time + metric deltas: dispatches, host
    syncs, compiles, cache and conversion traffic inside the call) plus
    engine/backend/digest become ``result.provenance`` — every facade
    answer can explain its own cost.
    """
    platform = backend_platform(be)
    with _obs_span(f"api.{kind}", engine=str(engine),
                   backend=platform) as sp:
        t0 = time.perf_counter()
        core = call()
        dt = time.perf_counter() - t0
        result = wrap(core, dt)
    result.provenance = Provenance(kind, str(engine), platform,
                                   getattr(result, "digest", ""),
                                   sp.to_dict())
    return result


def mis2(graph, *, active=None, options: Optional[Mis2Options] = None,
         engine: Optional[str] = None,
         backend: Optional[Backend] = None) -> Mis2Result:
    """Distance-2 maximal independent set (paper Alg. 1), deterministic
    across engines: ``dense`` | ``compacted`` | ``compacted_resident`` |
    ``pallas`` | ``pallas_resident`` | ``pallas_hybrid`` |
    ``distributed`` | ``distributed_single_gather`` return bit-identical
    sets (equal ``digest``) for equal options.

    ``engine=None`` auto-selects: the device-resident engines (one jitted
    dispatch per solve, worklists compacted on device) on accelerators,
    the host-driven ``compacted`` driver on CPU hosts;
    ``Backend(pallas=True)`` upgrades either to its Pallas variant.  When
    the graph's padded-ELL bytes estimate exceeds
    ``repro.graphs.hybrid.HYBRID_AUTO_BYTES`` (skewed degree distribution
    at scale), auto-selection routes to ``pallas_hybrid`` — the
    sliced-ELL + COO-spill layout that needs O(E) memory instead of
    O(V x max_degree).  The distributed engines shard vertices over
    ``Backend(mesh=..., axis=...)`` and report their collective-byte
    accounting in ``result.collectives``."""
    from .backend import default_mis2_engine

    be = resolve_backend(backend)
    gh = _prepare(graph, be)
    if engine is None:
        engine = default_mis2_engine(be, options, gh)
    elif be.pallas and engine == "compacted":
        engine = "pallas"       # legacy: Backend(pallas=True) upgrade
    fn = get_engine("mis2", engine)
    return _traced(
        "mis2", engine, be,
        lambda: fn(gh, active, options, be),
        lambda r, dt: Mis2Result(
            r.in_set, r.iterations, r.converged, dt, engine=engine,
            collectives=getattr(r, "collectives", None),
            num_compiles=getattr(r, "num_compiles", None)))


def misk(graph, k: int = 2, *, priority: str = "xorshift_star",
         max_iters: int = 256, engine: Optional[str] = None,
         backend: Optional[Backend] = None) -> Mis2Result:
    """Distance-k maximal independent set (k-fold min-propagation),
    deterministic across engines: ``dense`` (masked jitted fixed point)
    and ``resident`` (§V-B worklist compaction on the row refresh)
    return bit-identical sets.

    ``engine=None`` selects ``dense`` — the distance-k fixed point is
    already one jitted dispatch with zero in-loop host syncs, so there
    is no host-driven default to escape; ``resident`` is the worklist
    ablation shape."""
    from .backend import default_misk_engine

    be = resolve_backend(backend)
    gh = _prepare(graph, be)
    if engine is None:
        engine = default_misk_engine(be)
    fn = get_engine("misk", engine)
    return _traced(
        "misk", engine, be,
        lambda: fn(gh, k, priority, max_iters, be),
        lambda r, dt: Mis2Result(
            r.in_set, r.iterations, r.converged, dt,
            engine=f"misk{k}_{engine}",
            num_compiles=getattr(r, "num_compiles", None)))


def color(graph, *, max_rounds: int = 256, engine: str = "luby",
          backend: Optional[Backend] = None) -> ColoringResult:
    """Deterministic parallel greedy distance-1 coloring.  If the round
    limit is hit before every vertex is colored the result comes back with
    ``converged=False`` (uncolored vertices hold ``-1``) instead of
    raising."""
    be = resolve_backend(backend)
    gh = _prepare(graph, be)
    fn = get_engine("coloring", engine)
    return _traced(
        "color", engine, be,
        lambda: fn(gh, max_rounds, be),
        lambda r, dt: ColoringResult(r.colors, r.rounds, r.converged, dt,
                                     num_colors=r.num_colors))


def coarsen(graph, *, method: str = "two_phase",
            options: Optional[Mis2Options] = None,
            mis2_engine: Optional[str] = None,
            min_secondary_neighbors: int = 2,
            backend: Optional[Backend] = None) -> AggregationResult:
    """MIS-2 graph coarsening: ``method`` is ``two_phase`` (paper Alg. 3),
    ``basic`` (Alg. 2), ``serial`` (host-sequential reference) or
    ``two_phase_distributed`` (Alg. 3 sharded over ``Backend(mesh=...)``;
    pass ``mis2_engine="distributed_single_gather"`` for the half-traffic
    gather schedule).  ``mis2_engine=None`` means the method's default
    inner fixed point (``compacted`` for the single-device methods,
    ``distributed`` for the sharded one); an explicit engine a method
    cannot honor raises.

    ``backend`` is forwarded only to engines that declare it, so
    externally registered aggregation engines using the pre-backend call
    convention keep working."""
    import inspect

    be = resolve_backend(backend)
    gh = _prepare(graph, be)
    fn = get_engine("aggregation", method)
    kwargs = dict(options=options, interpret=be.resolve_interpret(),
                  min_secondary_neighbors=min_secondary_neighbors)
    if mis2_engine is not None:
        # None = "engine's own default": omit the kwarg entirely so engines
        # registered with any default spelling (old convention:
        # mis2_engine="compacted") keep applying their own
        kwargs["mis2_engine"] = mis2_engine
    if "backend" in inspect.signature(fn).parameters:
        kwargs["backend"] = be
    return _traced(
        "coarsen", method, be,
        lambda: fn(gh, **kwargs),
        lambda r, dt: AggregationResult(
            r.labels, r.mis2_iterations, r.converged, dt,
            num_aggregates=r.num_aggregates, roots=r.roots, phase=r.phase))


def partition(graph, num_parts: int, *, coarse_target: Optional[int] = None,
              options: Optional[Mis2Options] = None,
              engine: str = "multilevel",
              backend: Optional[Backend] = None) -> PartitionResult:
    """Multilevel graph partitioning via MIS-2 aggregation (paper §VII)."""
    be = resolve_backend(backend)
    gh = _prepare(graph, be)
    fn = get_engine("partition", engine)
    return _traced(
        "partition", engine, be,
        lambda: fn(gh, num_parts, coarse_target, options, be),
        lambda r, dt: PartitionResult(
            r.parts, r.levels, r.converged, dt, num_parts=r.num_parts,
            edge_cut=r.edge_cut, levels=r.levels, history=list(r.history)))


# ---------------------------------------------------------------------------
# batched entry points (repro.batch): many graphs, few compiled shapes
# ---------------------------------------------------------------------------

def _traced_batch(kind: str, engine, be: Backend, call, wrap) -> BatchResult:
    """Batch variant of :func:`_traced`: the batch-level provenance record
    (one span covering every bucket dispatch) is shared by the
    ``BatchResult`` and each per-graph member Result."""
    batch = _traced(kind, engine, be, call, wrap)
    for r in batch.results:
        r.provenance = batch.provenance
    return batch


def _prepare_batch(graphs, backend: Backend) -> GraphBatch:
    if backend.device is not None:
        # honor Backend.device for prebuilt batches too: place every member
        # handle (cached formats move with it) and restack on that device
        members = graphs.graphs if isinstance(graphs, GraphBatch) else graphs
        return GraphBatch([as_graph(g).place(backend.device)
                           for g in members])
    return as_graph_batch(graphs)


def mis2_batch(graphs, *, options: Optional[Mis2Options] = None,
               backend: Optional[Backend] = None) -> BatchResult:
    """Distance-2 MIS over many graphs at once: size-bucketed, vmapped
    dense fixed point — one compilation per bucket shape, ``B`` graphs per
    dispatch.  Each per-graph result (and its determinism digest) is
    bit-identical to ``mis2(g, engine="dense")``; batching is purely a
    throughput optimization.

    ``graphs`` is a sequence of :class:`Graph` handles / structural
    containers, or a prebuilt :class:`~repro.batch.GraphBatch` (reusable
    across calls — stacking is cached on the handles).
    """
    be = resolve_backend(backend)
    batch = _prepare_batch(graphs, be)

    def _wrap(core, dt):
        per = dt / max(1, len(core))
        results = [Mis2Result(r.in_set, r.iterations, r.converged, per,
                              engine="dense_batched") for r in core]
        return BatchResult(results, dt, engine="dense_batched",
                           bucket_shapes=batch.bucket_shapes)

    return _traced_batch("mis2_batch", "dense_batched", be,
                         lambda: _mis2_batch_impl(batch, options), _wrap)


def color_batch(graphs, *, max_rounds: int = 256,
                backend: Optional[Backend] = None) -> BatchResult:
    """Batched deterministic greedy coloring (vmapped Luby rounds); each
    per-graph result matches ``color(g)`` bit-for-bit."""
    be = resolve_backend(backend)
    batch = _prepare_batch(graphs, be)

    def _wrap(core, dt):
        per = dt / max(1, len(core))
        results = [ColoringResult(r.colors, r.rounds, r.converged, per,
                                  num_colors=r.num_colors) for r in core]
        return BatchResult(results, dt, engine="luby_batched",
                           bucket_shapes=batch.bucket_shapes)

    return _traced_batch("color_batch", "luby_batched", be,
                         lambda: _color_batch_impl(batch, max_rounds), _wrap)


def coarsen_batch(graphs, *, method: str = "two_phase",
                  options: Optional[Mis2Options] = None,
                  min_secondary_neighbors: int = 2,
                  backend: Optional[Backend] = None) -> BatchResult:
    """Batched MIS-2 coarsening (paper Alg. 2/3) over the vmapped dense
    MIS-2; per-graph labels match ``coarsen(g, method=...,
    mis2_engine="dense")`` bit-for-bit."""
    be = resolve_backend(backend)
    if method == "serial":
        # host-sequential reference: no fixed point to batch, so skip the
        # bucket padding/stacking entirely
        from ..core.aggregation import _aggregate_serial_greedy_impl

        members = graphs.graphs if isinstance(graphs, GraphBatch) \
            else [as_graph(g) for g in graphs]

        def _wrap_serial(core, dt):
            per = dt / max(1, len(core))
            results = [AggregationResult(
                r.labels, r.mis2_iterations, r.converged, per,
                num_aggregates=r.num_aggregates, roots=r.roots,
                phase=r.phase) for r in core]
            return BatchResult(results, dt, engine="serial_batched")

        return _traced_batch(
            "coarsen_batch", "serial_batched", be,
            lambda: [_aggregate_serial_greedy_impl(g) for g in members],
            _wrap_serial)
    batch = _prepare_batch(graphs, be)

    def _wrap(core, dt):
        per = dt / max(1, len(core))
        results = [AggregationResult(
            r.labels, r.mis2_iterations, r.converged, per,
            num_aggregates=r.num_aggregates, roots=r.roots,
            phase=r.phase) for r in core]
        return BatchResult(results, dt, engine=f"{method}_batched",
                           bucket_shapes=batch.bucket_shapes)

    return _traced_batch(
        "coarsen_batch", f"{method}_batched", be,
        lambda: _coarsen_batch_impl(batch, method, options,
                                    min_secondary_neighbors), _wrap)


def _wrap_hierarchy(h, aggregation: str, engine: str,
                    wall_time: float) -> AmgSetup:
    import numpy as np

    sizes = np.asarray(h.level_sizes, dtype=np.int64).reshape(-1, 2)
    return AmgSetup(sizes, len(h.levels), True, wall_time,
                    hierarchy=h, aggregation=aggregation,
                    setup_seconds=h.setup_seconds,
                    aggregation_seconds=h.aggregation_seconds,
                    engine=engine, timings=dict(h.timings),
                    dispatches=h.dispatches)


def amg_setup(matrix, *, aggregation: str = "two_phase",
              engine: Optional[str] = None, max_levels: int = 10,
              coarse_size: int = 200, omega: float = 2.0 / 3.0,
              jacobi_weight: float = 2.0 / 3.0, smoother_sweeps: int = 2,
              options: Optional[Mis2Options] = None,
              mis2_engine: Optional[str] = None,
              coarse_dtype: Optional[str] = None,
              dense_coarse_cap: Optional[int] = None,
              explicit_restriction: bool = True,
              backend: Optional[Backend] = None) -> AmgSetup:
    """Smoothed-aggregation AMG setup (paper Table V), dispatched through
    the ``multilevel`` engine registry.

    ``engine``: ``host`` (scipy prolongator + canonical numpy Galerkin;
    matrix-sized host round-trips each level) or ``resident`` (the whole
    per-level setup jitted on device — fixed-shape prolongator assembly,
    padded sorted-COO SpGEMM, coarse ELL repack; zero matrix-sized host
    syncs).  Both produce digest-identical hierarchies (per-level ``A_l``
    ELL digests on the result, labels/colors from the shared aggregation
    and coloring fixed points).  ``engine=None`` auto-selects ``resident``
    on accelerators, ``host`` on CPU hosts.

    ``coarse_dtype`` controls the dense coarsest-level factorization
    (default: float64 on CPU hosts, float32 on accelerators);
    ``dense_coarse_cap`` (default: ``coarse_size``) bounds the densified
    size — a coarsest level left above it by a coarsening stall or the
    ``max_levels`` cut falls back to a weighted-Jacobi coarse solve
    instead of an unrequested O(n^2) dense factor.
    ``explicit_restriction=False`` drops the stored ``R = P^T`` matrices;
    the V-cycle then restricts matrix-free through the transposed ELL
    SpMV kernel (``kernels.spmv_ell.spmv_t``), halving transfer-operator
    memory at the cost of a scatter per restriction.
    """
    from .backend import default_multilevel_engine

    be = resolve_backend(backend)
    gh = _prepare(matrix, be)
    if engine is None:
        engine = default_multilevel_engine(be)
    fn = get_engine("multilevel", engine)
    return _traced(
        "amg_setup", engine, be,
        lambda: fn("amg", gh, aggregation=aggregation,
                   max_levels=max_levels, coarse_size=coarse_size,
                   omega=omega, jacobi_weight=jacobi_weight,
                   smoother_sweeps=smoother_sweeps, options=options,
                   mis2_engine=mis2_engine,
                   interpret=be.resolve_interpret(),
                   coarse_dtype=coarse_dtype,
                   dense_coarse_cap=dense_coarse_cap,
                   explicit_restriction=explicit_restriction),
        lambda h, dt: _wrap_hierarchy(h, aggregation, engine, dt))


def amg(matrix, *, aggregation: str = "two_phase", max_levels: int = 10,
        coarse_size: int = 200, omega: float = 2.0 / 3.0,
        jacobi_weight: float = 2.0 / 3.0, smoother_sweeps: int = 2,
        options: Optional[Mis2Options] = None,
        backend: Optional[Backend] = None) -> AmgSetup:
    """Smoothed-aggregation AMG setup (paper Table V).  Returns an
    :class:`AmgSetup` whose ``.as_precond()`` plugs into ``solvers.cg``.

    Equivalent to :func:`amg_setup` with the auto-selected engine; kept
    for source compatibility."""
    return amg_setup(matrix, aggregation=aggregation, max_levels=max_levels,
                     coarse_size=coarse_size, omega=omega,
                     jacobi_weight=jacobi_weight,
                     smoother_sweeps=smoother_sweeps, options=options,
                     backend=backend)


def cluster_gs_setup(matrix, *, aggregation: str = "two_phase",
                     engine: Optional[str] = None,
                     options: Optional[Mis2Options] = None,
                     coarsen_levels: int = 1,
                     mis2_engine: Optional[str] = None,
                     backend: Optional[Backend] = None) -> ClusterGsSetup:
    """Cluster multicolor Gauss-Seidel setup (paper Alg. 4 / Table VI)
    dispatched through the ``multilevel`` engine registry: aggregate with
    MIS-2, color the coarse graph, pack cluster rows per color.

    The ``resident`` engine builds the coarse graph, runs the coloring
    fixed point, and packs the rows on device; ``host`` is the legacy
    numpy path.  Labels, colors, and the packed row matrices are
    bit-identical across engines; the result carries the structured
    setup-phase timings (``aggregate`` / ``color`` / ``pack``).
    """
    from ..graphs.ops import extract_diagonal
    from ..solvers.multicolor_gs import MulticolorGSPreconditioner
    from .backend import default_multilevel_engine

    be = resolve_backend(backend)
    gh = _prepare(matrix, be)
    if engine is None:
        engine = default_multilevel_engine(be)
    fn = get_engine("multilevel", engine)

    def _build(out, dt):
        color_rows, num_colors, nagg, labels, colors, timings = out
        ell = gh.ell_matrix
        diag = extract_diagonal(gh.csr_matrix)
        pre = MulticolorGSPreconditioner(ell, diag, color_rows, num_colors,
                                         nagg, dt, "cluster",
                                         timings=timings)
        return ClusterGsSetup(labels, 0, True, dt, preconditioner=pre,
                              num_colors=num_colors, num_clusters=nagg,
                              colors=colors, engine=engine, timings=timings)

    return _traced(
        "cluster_gs_setup", engine, be,
        lambda: fn("cluster_gs", gh, aggregation=aggregation,
                   options=options, coarsen_levels=coarsen_levels,
                   mis2_engine=mis2_engine),
        _build)


def amg_setup_batch(matrices, *, aggregation: str = "two_phase",
                    engine: Optional[str] = None,
                    options: Optional[Mis2Options] = None,
                    backend: Optional[Backend] = None,
                    **hierarchy_kwargs) -> BatchResult:
    """Batched AMG setup: every member's finest-level aggregation — the
    dominant setup cost — runs through the vmapped bucketed coarsening
    (one dispatch per bucket shape); each hierarchy is then finished with
    the selected multilevel engine.  Per-graph hierarchies are
    digest-identical to ``amg_setup(g, ...)``."""
    from ..batch.pipeline import _amg_setup_batch_impl
    from .backend import default_multilevel_engine

    be = resolve_backend(backend)
    batch = _prepare_batch(matrices, be)
    if engine is None:
        engine = default_multilevel_engine(be)

    def _wrap(hierarchies, dt):
        per = dt / max(1, len(hierarchies))
        results = [_wrap_hierarchy(h, aggregation, engine, per)
                   for h in hierarchies]
        return BatchResult(results, dt, engine=f"{engine}_batched",
                           bucket_shapes=batch.bucket_shapes)

    return _traced_batch(
        "amg_setup_batch", f"{engine}_batched", be,
        lambda: _amg_setup_batch_impl(batch, aggregation, options,
                                      engine=engine, **hierarchy_kwargs),
        _wrap)


__all__ = [
    "mis2", "misk", "color", "coarsen", "partition", "amg",
    "amg_setup", "cluster_gs_setup",
    "mis2_batch", "color_batch", "coarsen_batch", "amg_setup_batch",
    "Graph", "GraphBatch", "Backend", "Mis2Options", "determinism_digest",
]
