"""Engine registry: backend dispatch as a first-class layer.

The seed encoded each execution strategy as a separate ad-hoc entry point
(``mis2`` vs ``mis2_dense`` vs ``mis2_compacted``, a ``use_pallas`` bool, a
string-keyed ``AGGREGATORS`` dict in ``solvers/amg.py``).  The registry
makes the (pipeline kind, engine name) pair the single dispatch mechanism:

    @register_engine("mis2", "dense", doc="single jitted while_loop")
    def _dense(graph, active, options, backend): ...

    get_engine("mis2", "dense")(graph, None, opts, backend)

Engines are registered in ``repro.api.engines`` at import time; callers in
lower layers (e.g. ``solvers/amg.py``) look engines up lazily so importing
``repro.api`` anywhere in the process is sufficient.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass(frozen=True)
class EngineSpec:
    kind: str
    name: str
    fn: Callable
    doc: str = ""
    aliases: tuple = field(default_factory=tuple)


_ENGINES: dict[tuple[str, str], EngineSpec] = {}
_ALIASES: dict[tuple[str, str], str] = {}


def register_engine(kind: str, name: str, *, aliases: tuple = (),
                    doc: str = "") -> Callable:
    """Decorator registering ``fn`` as the engine ``name`` for pipeline
    ``kind``.  ``aliases`` keep legacy spellings routable (e.g. the old
    ``AGGREGATORS`` keys ``mis2_basic``/``mis2_agg``)."""

    def deco(fn: Callable) -> Callable:
        key = (kind, name)
        if key in _ENGINES:
            raise ValueError(f"engine {key} already registered")
        _ENGINES[key] = EngineSpec(kind, name, fn, doc, tuple(aliases))
        for alias in aliases:
            _ALIASES[(kind, alias)] = name
        return fn

    return deco


def _canonical(kind: str, name: str) -> str:
    return _ALIASES.get((kind, name), name)


def get_engine(kind: str, name: str) -> Callable:
    """Resolve an engine callable; raises with the available names listed."""
    spec = _ENGINES.get((kind, _canonical(kind, name)))
    if spec is None:
        avail = ", ".join(sorted(n for k, n in _ENGINES if k == kind)) or "none"
        raise ValueError(
            f"unknown {kind!r} engine {name!r} (available: {avail})")
    return spec.fn


def get_engine_spec(kind: str, name: str) -> EngineSpec:
    get_engine(kind, name)  # raise uniformly on unknown names
    return _ENGINES[(kind, _canonical(kind, name))]


def list_engines(kind: Optional[str] = None) -> dict[str, list[str]]:
    """Mapping kind -> sorted engine names (optionally one kind only)."""
    out: dict[str, list[str]] = {}
    for k, n in _ENGINES:
        if kind is None or k == kind:
            out.setdefault(k, []).append(n)
    for names in out.values():
        names.sort()
    return out
