"""Backend execution policy: one object that answers "where and how do the
hot loops run", threaded from the facade down to ``kernels/*/ops.py``.

Replaces the seed's scattered knobs — ``Mis2Options.use_pallas``, per-call
``interpret=True`` kwargs — with a single config:

* ``pallas``     route the measured hot loops through the Pallas kernels
  (``kernels/minprop_ell``); the XLA fallback otherwise.
* ``interpret``  tri-state.  ``None`` (default) = *auto*: run the Pallas
  interpreter only when no accelerator is attached (CPU hosts); compile
  for real on TPU/GPU.  The seed hard-coded ``interpret=True``, which
  silently ran the interpreter even on accelerators.
* ``device``     optional JAX device for graph/array placement.
* ``mesh`` / ``axis``  device mesh + partition axis for the distributed
  (shard_map) engines.  ``None`` (default) = one flat axis over every
  attached device; a multi-axis mesh with ``axis=None`` flattens all its
  axes into the vertex partition.

This module is import-cycle-safe by construction: it depends only on
``jax`` so both ``kernels/`` (below ``core``) and the facade (above it)
can consult the same policy.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, replace
from typing import Any, Optional

import jax


def accelerator_present() -> bool:
    """True iff the default JAX backend is an accelerator (TPU/GPU)."""
    return jax.default_backend() not in ("cpu",)


def backend_platform(backend: Optional["Backend"] = None) -> str:
    """The platform a request under ``backend`` actually executes on.

    ``Backend(device=...)`` pins placement per request, so engine
    auto-selection must consult *that* device's platform — not the
    process-global default backend.  A server coalescing requests from
    callers with different placements (the ``repro.serve`` dispatch path)
    would otherwise resolve every request against whatever platform the
    process booted with.
    """
    be = backend if backend is not None else _DEFAULT
    if be.device is not None:
        return be.device.platform
    return jax.default_backend()


def backend_accelerator(backend: Optional["Backend"] = None) -> bool:
    """True iff requests under ``backend`` run on an accelerator.

    With an explicit ``Backend(device=...)`` that device's platform
    decides; otherwise this defers to :func:`accelerator_present` (the
    process-global check — and the seam tests monkeypatch to simulate
    accelerators on the CPU CI runner).
    """
    be = backend if backend is not None else _DEFAULT
    if be.device is not None:
        return be.device.platform not in ("cpu",)
    return accelerator_present()


def default_interpret() -> bool:
    """The auto policy: interpret Pallas kernels only off-accelerator."""
    return not accelerator_present()


def default_mis2_engine(backend: Optional["Backend"] = None,
                        options=None, graph=None) -> str:
    """The facade's engine auto-selection rule (``engine=None``).

    On accelerators the fixed point runs device-resident — host-driven
    worklist rebuilds serialize the hot loop on dispatch + transfer
    latency, which is exactly the overhead §V-B exists to remove.  On CPU
    hosts the host-driven driver keeps the default (per-iteration numpy
    worklists are cheap there, and it is the Fig. 2 ablation baseline).
    ``Backend(pallas=True)`` upgrades either choice to its Pallas variant.
    All four engines produce bit-identical sets.

    ``options`` (a ``Mis2Options``) keeps the rule total: the resident
    engines implement §V-B worklists by construction, so the
    ``worklists=False`` ablation auto-selects the host-driven driver
    instead of raising even on accelerators.

    ``graph`` (a ``repro.Graph`` handle) enables the degree-aware rule:
    when the monolithic padded-ELL bytes estimate exceeds
    ``repro.graphs.hybrid.HYBRID_AUTO_BYTES`` (a skewed graph at paper
    scale), every ELL-monolith engine above is off the table — the rule
    returns ``'pallas_hybrid'`` (sliced-ELL + COO spill, O(E) memory,
    bit-identical results).  The threshold is read at call time so tests
    and operators can tune it.

    The platform is resolved **per request**: ``Backend(device=...)``
    selects by that device's platform, falling back to the process
    default backend only when no device is pinned (see
    :func:`backend_platform`).
    """
    be = backend if backend is not None else _DEFAULT
    resident_ok = options is None or getattr(options, "worklists", True)
    hybrid_ok = resident_ok and (
        options is None or (getattr(options, "packed", True)
                            and getattr(options, "layout", "ell") == "ell"))
    if hybrid_ok and graph is not None \
            and hasattr(graph, "ell_bytes_estimate"):
        from ..graphs import hybrid as _hybrid

        if graph.ell_bytes_estimate() > _hybrid.HYBRID_AUTO_BYTES:
            return "pallas_hybrid"
    if backend_accelerator(be) and resident_ok:
        return "pallas_resident" if be.pallas else "compacted_resident"
    return "pallas" if be.pallas else "compacted"


def default_misk_engine(backend: Optional["Backend"] = None) -> str:
    """``misk`` auto-selection (``engine=None``): always ``dense`` — the
    distance-k fixed point was born device-resident (one jitted
    ``while_loop``, zero in-loop host syncs), so unlike ``mis2`` there is
    no host-driven default to escape.  The ``resident`` engine (worklist
    compaction on the row refresh, the §V-B execution shape) exists for
    ablation and produces bit-identical sets."""
    return "dense"


def default_multilevel_engine(backend: Optional["Backend"] = None) -> str:
    """``multilevel`` auto-selection (``engine=None``): the device-resident
    setup (on-device prolongator/Galerkin/packing, zero matrix-sized host
    syncs) on accelerators; the host scipy/numpy path on CPU hosts, where
    the round-trips are address-space copies.  Both engines produce
    digest-identical hierarchies.  Like :func:`default_mis2_engine`, the
    rule honors ``Backend(device=...)`` per request (the device's platform
    wins over the process default)."""
    return "resident" if backend_accelerator(backend) else "host"


@dataclass(frozen=True)
class Backend:
    """Execution policy for one pipeline invocation (hashable, reusable)."""

    pallas: bool = False
    interpret: Optional[bool] = None   # None = auto (interpret iff no accel)
    device: Any = None                 # optional jax.Device for placement
    mesh: Any = None                   # optional jax.sharding.Mesh (sharding)
    axis: Any = None                   # mesh axis name (or tuple) to shard on

    def resolve_interpret(self) -> bool:
        if self.interpret is not None:
            return bool(self.interpret)
        return default_interpret()

    def resolve_mesh(self):
        """(mesh, axis) for the distributed engines.

        ``Backend(mesh=..., axis=...)`` is honored as-is (``axis=None`` on
        a multi-axis mesh flattens every axis into the vertex partition);
        the default is one flat ``"x"`` axis over every attached device.
        The actual defaulting lives in ``core.dist._resolve_mesh`` so the
        facade path and direct core calls can never diverge.
        """
        from ..core.dist import _resolve_mesh

        mesh, axis, _ = _resolve_mesh(self.mesh, self.axis)
        return mesh, axis

    def with_(self, **changes) -> "Backend":
        return replace(self, **changes)


_DEFAULT = Backend()


def get_default_backend() -> Backend:
    return _DEFAULT


def set_default_backend(backend: Backend) -> Backend:
    """Install ``backend`` as the process-wide default; returns the old one."""
    global _DEFAULT
    old, _DEFAULT = _DEFAULT, backend
    return old


@contextmanager
def using_backend(backend: Backend):
    """Scoped default backend (restores the previous default on exit)."""
    old = set_default_backend(backend)
    try:
        yield backend
    finally:
        set_default_backend(old)


def resolve_backend(backend: Optional[Backend]) -> Backend:
    return backend if backend is not None else _DEFAULT
