"""Problem generators re-exported on the facade (`repro.api.generators`).

Implementation lives in ``repro.graphs.generators``; this module keeps
examples/benchmarks importable against the ``repro.api`` surface alone.
"""
from ..graphs.generators import (
    elasticity3d,
    er_laplacian,
    laplace3d,
    paper_suite,
    path_graph,
    powerlaw_graph,
    random_skewed_graph,
    random_uniform_graph,
)

__all__ = [
    "elasticity3d", "er_laplacian", "laplace3d", "paper_suite", "path_graph",
    "powerlaw_graph", "random_skewed_graph", "random_uniform_graph",
]
