"""Common Result protocol shared by every pipeline in ``repro.api``.

Every facade call returns a :class:`Result` subclass with the same core
contract:

* ``payload``      the primary output as a **host** ``np.ndarray`` (the
  seed mixed ``np.ndarray``/``jnp.ndarray`` depending on engine; the
  protocol normalizes in ``__post_init__`` so downstream numpy code never
  trips on device arrays),
* ``iterations``   fixed-point / setup iteration count,
* ``converged``    whether the pipeline reached its fixed point,
* ``wall_time_s``  facade-measured wall time of the engine call,
* ``digest``       a determinism digest of the payload — two runs (or two
  engines) produced bit-identical output iff their digests match, which is
  the paper's portability claim made checkable in one string compare,
* ``provenance``   a serializable :class:`~repro.obs.Provenance` record
  (engine, backend, span tree with wall times and metric deltas, digest)
  attached by the facade — any answer can explain its own cost.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np


def determinism_digest(arr: np.ndarray) -> str:
    """Stable 16-hex digest over dtype, shape and raw bytes."""
    arr = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()[:16]


@runtime_checkable
class ResultLike(Protocol):
    payload: np.ndarray
    iterations: int
    converged: bool
    wall_time_s: float
    digest: str


@dataclass
class Result:
    payload: np.ndarray
    iterations: int = 0
    converged: bool = True
    wall_time_s: float = 0.0
    digest: str = ""
    # facade-attached repro.obs.Provenance (set after construction so the
    # record can embed the payload digest computed in __post_init__)
    provenance: object | None = None

    def __post_init__(self):
        # protocol guarantee: host numpy payload, digest always present
        self.payload = np.asarray(self.payload)
        if not self.digest:
            self.digest = determinism_digest(self.payload)


@dataclass
class Mis2Result(Result):
    """Distance-2 (or -k) MIS: ``payload`` is the bool membership mask."""

    engine: str = ""
    collectives: dict | None = None   # distributed engines: per-run §V-C
    #                                   collective-byte accounting
    num_compiles: int | None = None   # distinct jitted step shapes the solve
    #                                   required (resident engines: 1; legacy
    #                                   compacted: pow2 worklist-bucket pairs)

    @property
    def in_set(self) -> np.ndarray:
        return self.payload

    @property
    def size(self) -> int:
        return int(self.payload.sum())


@dataclass
class ColoringResult(Result):
    """Distance-1 coloring: ``payload`` is the int32 color per vertex."""

    num_colors: int = 0

    @property
    def colors(self) -> np.ndarray:
        return self.payload

    @property
    def rounds(self) -> int:
        return self.iterations


@dataclass
class AggregationResult(Result):
    """MIS-2 coarsening: ``payload`` is the int32 aggregate label per vertex."""

    num_aggregates: int = 0
    roots: np.ndarray | None = None
    phase: np.ndarray | None = None

    def __post_init__(self):
        super().__post_init__()
        if self.roots is not None:
            self.roots = np.asarray(self.roots)
        if self.phase is not None:
            self.phase = np.asarray(self.phase)

    @property
    def labels(self) -> np.ndarray:
        return self.payload

    @property
    def mis2_iterations(self) -> int:
        return self.iterations

    @property
    def coarsening_ratio(self) -> float:
        return len(self.payload) / max(1, self.num_aggregates)


@dataclass
class PartitionResult(Result):
    """Multilevel partition: ``payload`` is the int32 part id per vertex."""

    num_parts: int = 0
    edge_cut: int = 0
    levels: int = 0
    history: list = field(default_factory=list)

    @property
    def parts(self) -> np.ndarray:
        return self.payload


@dataclass
class BatchResult:
    """Result of one batched facade call (``mis2_batch`` / ``color_batch``
    / ``coarsen_batch``): the per-graph :class:`Result`\\ s in **input
    order**, each carrying its own determinism digest — so batching can be
    checked graph-by-graph against the single-graph engines in one string
    compare per member.

    ``wall_time_s`` is the whole batched dispatch (all buckets);
    ``bucket_shapes`` records the compilation footprint as
    ``(rows, width, member_count)`` triples.
    """

    results: list = field(default_factory=list)
    wall_time_s: float = 0.0
    engine: str = ""
    bucket_shapes: list = field(default_factory=list)
    provenance: object | None = None   # shared batch-level obs.Provenance

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, i):
        return self.results[i]

    @property
    def num_graphs(self) -> int:
        return len(self.results)

    @property
    def num_buckets(self) -> int:
        return len(self.bucket_shapes)

    @property
    def digests(self) -> list:
        """Per-graph determinism digests, input order."""
        return [r.digest for r in self.results]

    @property
    def converged(self) -> bool:
        return all(r.converged for r in self.results)

    @property
    def graphs_per_second(self) -> float:
        return len(self.results) / self.wall_time_s if self.wall_time_s else 0.0


@dataclass
class AmgSetup(Result):
    """AMG hierarchy setup: ``payload`` is the [levels, 2] (n, nnz) table;
    the usable hierarchy hangs off ``.hierarchy`` / ``.as_precond()``.

    ``engine`` names the multilevel engine that built it (``host`` |
    ``resident``); ``timings`` is the structured setup-phase split
    (``aggregate`` / ``prolongator`` / ``galerkin`` / ``pack`` seconds);
    ``dispatches`` counts the resident engine's jitted dispatches for the
    build (0 on the host engine).  ``level_digests`` exposes the
    per-level ``A_l`` ELL digests — the bit-identity surface the
    ``multilevel`` engines are gated on.
    """

    hierarchy: object | None = None
    aggregation: str = ""
    setup_seconds: float = 0.0
    aggregation_seconds: float = 0.0
    engine: str = ""
    timings: dict = field(default_factory=dict)
    dispatches: int = 0

    @property
    def level_sizes(self) -> list:
        return [tuple(int(x) for x in row) for row in self.payload]

    @property
    def num_levels(self) -> int:
        return int(self.payload.shape[0])

    @property
    def level_digests(self) -> list:
        return self.hierarchy.level_digests() if self.hierarchy else []

    def as_precond(self):
        return self.hierarchy.as_precond()


@dataclass
class ClusterGsSetup(Result):
    """Cluster multicolor GS setup: ``payload`` is the int32 cluster label
    per vertex (so ``digest`` gates the aggregation); ``colors`` carries
    the coarse coloring with its own digest, and ``preconditioner`` is the
    ready :class:`~repro.solvers.multicolor_gs.MulticolorGSPreconditioner`.
    ``timings`` is the structured setup split (``aggregate`` / ``color`` /
    ``pack`` seconds)."""

    preconditioner: object | None = None
    num_colors: int = 0
    num_clusters: int = 0
    colors: np.ndarray | None = None
    engine: str = ""
    timings: dict = field(default_factory=dict)

    def __post_init__(self):
        super().__post_init__()
        if self.colors is not None:
            self.colors = np.asarray(self.colors)

    @property
    def labels(self) -> np.ndarray:
        return self.payload

    @property
    def colors_digest(self) -> str:
        return determinism_digest(self.colors)

    def as_precond(self, sweeps: int = 1, symmetric: bool = True):
        return self.preconditioner.as_precond(sweeps, symmetric)
