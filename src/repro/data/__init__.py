# repro-lint: legacy seed-era LM data pipeline, test-only surface
from .pipeline import DataConfig, SyntheticTokens

__all__ = ["DataConfig", "SyntheticTokens"]
