# repro-lint: legacy seed-era LM data pipeline, test-only surface
"""Deterministic, seekable synthetic data pipeline.

``batch_at(step)`` is a pure function of (seed, step) via counter-based
Philox bit generation — so checkpoint/restart is bit-exact with *zero*
pipeline state to save, and elastic re-runs (different DP width) slice the
same global batch differently but identically.  This is the fault-tolerance
contract a 1000-node data loader must meet (DESIGN.md §7); a real corpus
loader would implement the same ``batch_at`` interface over a tokenized
shard index.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    # encdec extras
    frames: bool = False
    frame_seq: int = 0
    frame_dim: int = 0


class SyntheticTokens:
    """Markov-ish synthetic token stream (not uniform noise, so losses move)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=self.cfg.seed, counter=step))

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng(step)
        # low-entropy structure: repeat short motifs so a model can learn
        motifs = rng.integers(0, cfg.vocab_size,
                              size=(cfg.global_batch, 64), dtype=np.int32)
        reps = int(np.ceil(cfg.seq_len / 64))
        tokens = np.tile(motifs, (1, reps))[:, :cfg.seq_len]
        noise = rng.random((cfg.global_batch, cfg.seq_len)) < 0.1
        tokens = np.where(
            noise,
            rng.integers(0, cfg.vocab_size, size=tokens.shape, dtype=np.int32),
            tokens)
        batch = {"tokens": tokens}
        if cfg.frames:
            batch["frames"] = rng.standard_normal(
                (cfg.global_batch, cfg.frame_seq, cfg.frame_dim),
                dtype=np.float32)
        return batch

    def host_slice(self, batch: dict, host_index: int, num_hosts: int) -> dict:
        """Per-host shard of the global batch (multi-host data loading)."""
        def sl(x):
            per = x.shape[0] // num_hosts
            return x[host_index * per:(host_index + 1) * per]
        return {k: sl(v) for k, v in batch.items()}
