"""Shared interpret-mode resolution for the kernel ops wrappers.

``interpret=None`` means "defer to the Backend policy": run the Pallas
interpreter only when no accelerator is attached.  The import of the
policy is lazy so that ``kernels`` (below ``core``) never triggers the
``repro.api`` package import at module-import time.
"""
from __future__ import annotations


def resolve_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return bool(interpret)
    from ..api.backend import default_interpret  # lazy: avoids import cycle

    return default_interpret()
