"""Jitted wrappers wiring the Pallas min-propagation kernels into the
compacted MIS-2 driver (core/mis2.py, engine ``"pallas"``).

The XLA side does the irregular parts (worklist row gather, scatter-back);
the Pallas kernels fuse the neighbor-tuple gather + reductions, which is
the paper's measured hot loop.

``interpret=None`` (the default) defers to the :class:`repro.api.Backend`
policy: interpret only when no accelerator is attached.  The seed
hard-coded ``interpret=True``, silently running the Pallas interpreter
even on TPU/GPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .._interpret import resolve_interpret as _resolve_interpret
from .kernel import (
    _refresh_inline,
    decide_pallas,
    fused_decide_pallas,
    fused_refresh_columns_pallas,
    refresh_columns_pallas,
    slice_block_rows,
    sliced_decide_pallas,
    sliced_refresh_columns_pallas,
)

IN = np.uint32(0)
OUT = np.uint32(0xFFFFFFFF)

# ---------------------------------------------------------------------------
# ELL row-traffic model (asserted by tests/test_resident.py)
#
# HBM movements of one live worklist row's ELL entries per per-round pass:
# the host-driven path gathers the row in XLA (1 read), materializes the
# [W, D] worklist copy (1 write), and the kernel reads the copy back
# (1 read) — 3 movements.  The fused resident kernels gather the row
# in-kernel from the flat [V*D] adjacency: 1 read, no copy.
# ---------------------------------------------------------------------------

ELL_ROW_TRAFFIC = {
    "pallas": {"reads": 2, "writes": 1},
    "pallas_resident": {"reads": 1, "writes": 0},
    # hybrid slices reuse the fused in-kernel gather (1 read of W_i ids per
    # live worklist row per pass, no materialized copy); the spill segment
    # is COO, accounted per entry, not per padded row
    "pallas_hybrid": {"reads": 1, "writes": 0},
}


def ell_row_movements(engine: str) -> int:
    """Total HBM movements of one worklist row's ELL entries per pass."""
    t = ELL_ROW_TRAFFIC[engine]
    return t["reads"] + t["writes"]


def hybrid_row_traffic_bytes(slice_widths, slice_rows_processed,
                             spill_entries: int, spill_passes: int) -> int:
    """Analytic adjacency traffic of one hybrid MIS-2 solve, in bytes.

    ``slice_rows_processed[i]`` is the total live worklist rows slice ``i``
    processed across every pass of every round (refresh + decide); each
    such row moves its ``W_i`` int32 neighbor ids through HBM exactly
    ``ell_row_movements('pallas_hybrid')`` times.  The spill segment has no
    worklist: every pass reads all ``spill_entries`` int32 column ids once.
    The hybrid engine accumulates the same quantities *on device* inside
    the while_loop; the ``hybrid_traffic`` check_shape gate asserts
    registry == this model == the result's own accounting.
    """
    moves = ell_row_movements("pallas_hybrid")
    total = 0
    for w, rows in zip(slice_widths, slice_rows_processed):
        total += int(rows) * int(w) * 4 * moves
    total += int(spill_passes) * int(spill_entries) * 4
    return total


@jax.jit
def _gather_rows(neighbors, wl):
    v = neighbors.shape[0]
    return neighbors[jnp.clip(wl, 0, v - 1)]


def refresh_columns(t, m, wl2, neighbors, count, *, interpret=None):
    """M.at[wl2] <- poisoned min of T over wl2 rows' closed neighborhoods."""
    wl_nbrs = _gather_rows(neighbors, wl2)
    mv = refresh_columns_pallas(t, wl_nbrs, jnp.asarray(count, jnp.int32),
                                interpret=_resolve_interpret(interpret))
    return m.at[wl2].set(mv, mode="drop")


def decide(t, m, wl1, neighbors, active, count, *, interpret=None):
    """T.at[wl1] <- IN/OUT decision for wl1 rows."""
    v = neighbors.shape[0]
    wl_nbrs = _gather_rows(neighbors, wl1)
    t_rows = t[jnp.clip(wl1, 0, v - 1)]
    newt = decide_pallas(t_rows, m, active, wl_nbrs,
                         jnp.asarray(count, jnp.int32),
                         interpret=_resolve_interpret(interpret))
    return t.at[wl1].set(newt, mode="drop")


# ---------------------------------------------------------------------------
# fused wrappers for the device-resident driver: worklist *indices* go in
# (no pre-gathered [W, D] row copies), counts may be traced (they feed the
# pl.when block skipping via scalar prefetch inside a lax.while_loop)
# ---------------------------------------------------------------------------

def fused_refresh_columns(t, m, wl2, count, neighbors, it, *, priority: str,
                          b: int, interpret=None):
    """M.at[wl2] <- poisoned min over wl2 rows' closed neighborhoods, with
    the §V-A row refresh applied to the gathered tuples on the fly."""
    mv = fused_refresh_columns_pallas(
        t, neighbors.reshape(-1), wl2, jnp.asarray(count, jnp.int32),
        jnp.asarray(it, jnp.uint32), priority=priority, b=b,
        interpret=_resolve_interpret(interpret))
    return m.at[wl2].set(mv, mode="drop")


def fused_decide(t, m, wl1, count, neighbors, active, it, *, priority: str,
                 b: int, interpret=None):
    """T.at[wl1] <- IN/OUT decision, row tuple gather + refresh in-kernel.

    Because still-undecided rows get their *refreshed* tuple written back,
    this single scatter leaves T exactly as the host pipeline's
    refresh_rows + decide pair would."""
    newt = fused_decide_pallas(
        t, m, active, neighbors.reshape(-1), wl1,
        jnp.asarray(count, jnp.int32), jnp.asarray(it, jnp.uint32),
        priority=priority, b=b, interpret=_resolve_interpret(interpret))
    return t.at[wl1].set(newt, mode="drop")


# ---------------------------------------------------------------------------
# hybrid-layout passes (``pallas_hybrid``): per-slice fused kernels over the
# sliced-ELL slabs + XLA segment reductions over the sorted-COO spill.  All
# of these trace inside the hybrid resident while_loop; the slice worklists
# are slice-local (sentinel R_i) and every write back into the global [V]
# state goes through a global-id scatter with drop semantics.
# ---------------------------------------------------------------------------

def _slice_gids(slice_rows, wl, v: int):
    """Worklist slots -> global scatter targets (sentinel slots -> V,
    dropped by ``mode='drop'``)."""
    r = slice_rows.shape[0]
    return jnp.where(wl < r, slice_rows[jnp.clip(wl, 0, r - 1)],
                     jnp.int32(v))


def sliced_refresh_columns(t, m, slice_rows, nbrs_flat, wl, count, it, *,
                           priority: str, b: int, d: int, interpret=None,
                           block_rows=None):
    """M.at[slice rows on the worklist] <- poisoned closed-neighborhood min
    (the fused refresh, restricted to one degree-bucket slab)."""
    interp = _resolve_interpret(interpret)
    if block_rows is None:
        block_rows = slice_block_rows(slice_rows.shape[0], d, interp)
    mv = sliced_refresh_columns_pallas(
        t, nbrs_flat, wl, jnp.asarray(count, jnp.int32),
        jnp.asarray(it, jnp.uint32), priority=priority, b=b, d=d,
        interpret=interp, block_rows=block_rows)
    gids = _slice_gids(slice_rows, wl, t.shape[0])
    return m.at[gids].set(mv, mode="drop")


def sliced_decide(t, m, active, slice_rows, nbrs_flat, wl, count, it, *,
                  priority: str, b: int, d: int, interpret=None,
                  block_rows=None):
    """T.at[slice rows on the worklist] <- IN/OUT decision (fused decide,
    restricted to one slab; global row ids ride alongside the worklist)."""
    interp = _resolve_interpret(interpret)
    if block_rows is None:
        block_rows = slice_block_rows(slice_rows.shape[0], d, interp)
    gids = _slice_gids(slice_rows, wl, t.shape[0])
    newt = sliced_decide_pallas(
        t, m, active, nbrs_flat, wl, gids, jnp.asarray(count, jnp.int32),
        jnp.asarray(it, jnp.uint32), priority=priority, b=b, d=d,
        interpret=interp, block_rows=block_rows)
    return t.at[gids].set(newt, mode="drop")


def spill_refresh_columns(t, m, spill_rows, spill_seg, spill_cols, live, it,
                          *, priority: str, b: int):
    """M over the heavy (COO-spill) rows via segment_min — same closed
    min + IN->OUT poison as the slab kernels, with the §V-A refresh applied
    to every gathered tuple on the fly.  ``live`` is the [V] round mask;
    rows off the worklist keep their previous M (the worklist contract)."""
    h = spill_rows.shape[0]
    it = jnp.asarray(it, jnp.uint32)
    te = _refresh_inline(t[spill_cols], spill_cols.astype(jnp.uint32), it,
                         priority, b)
    mv = jax.ops.segment_min(te, spill_seg, num_segments=h)
    tself = _refresh_inline(t[spill_rows], spill_rows.astype(jnp.uint32), it,
                            priority, b)
    mv = jnp.minimum(mv, tself)                # closed neighborhood
    mv = jnp.where(mv == IN, OUT, mv)
    newm = jnp.where(live[spill_rows], mv, m[spill_rows])
    return m.at[spill_rows].set(newm)


def spill_decide(t, m, active, spill_rows, spill_seg, spill_cols, it, *,
                 priority: str, b: int):
    """IN/OUT decision over the heavy rows via segment reductions,
    bit-matching the fused slab decide: neighbor terms gated by ``active``
    (padding-slot semantics), the self term folded in explicitly, and
    still-undecided rows written with their refreshed tuple."""
    h = spill_rows.shape[0]
    it = jnp.asarray(it, jnp.uint32)
    tv_old = t[spill_rows]
    tv = _refresh_inline(tv_old, spill_rows.astype(jnp.uint32), it,
                         priority, b)
    mn = m[spill_cols]
    an = active[spill_cols]
    tv_e = tv[spill_seg]
    any_out = jax.ops.segment_max(
        (an & (mn == OUT)).astype(jnp.int32), spill_seg, num_segments=h) > 0
    neq = jax.ops.segment_max(
        (an & (mn != tv_e)).astype(jnp.int32), spill_seg, num_segments=h) > 0
    m_self = m[spill_rows]
    a_self = active[spill_rows]
    any_out = any_out | (a_self & (m_self == OUT))
    neq = neq | (a_self & (m_self != tv))
    newt = jnp.where(any_out, OUT, jnp.where(~neq, IN, tv))
    und = (tv_old != IN) & (tv_old != OUT)
    return t.at[spill_rows].set(jnp.where(und, newt, tv_old))
