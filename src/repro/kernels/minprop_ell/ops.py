"""Jitted wrappers wiring the Pallas min-propagation kernels into the
compacted MIS-2 driver (core/mis2.py, engine ``"pallas"``).

The XLA side does the irregular parts (worklist row gather, scatter-back);
the Pallas kernels fuse the neighbor-tuple gather + reductions, which is
the paper's measured hot loop.

``interpret=None`` (the default) defers to the :class:`repro.api.Backend`
policy: interpret only when no accelerator is attached.  The seed
hard-coded ``interpret=True``, silently running the Pallas interpreter
even on TPU/GPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .._interpret import resolve_interpret as _resolve_interpret
from .kernel import decide_pallas, refresh_columns_pallas

OUT = np.uint32(0xFFFFFFFF)


@jax.jit
def _gather_rows(neighbors, wl):
    v = neighbors.shape[0]
    return neighbors[jnp.clip(wl, 0, v - 1)]


def refresh_columns(t, m, wl2, neighbors, count, *, interpret=None):
    """M.at[wl2] <- poisoned min of T over wl2 rows' closed neighborhoods."""
    wl_nbrs = _gather_rows(neighbors, wl2)
    mv = refresh_columns_pallas(t, wl_nbrs, jnp.asarray(count, jnp.int32),
                                interpret=_resolve_interpret(interpret))
    return m.at[wl2].set(mv, mode="drop")


def decide(t, m, wl1, neighbors, active, count, *, interpret=None):
    """T.at[wl1] <- IN/OUT decision for wl1 rows."""
    v = neighbors.shape[0]
    wl_nbrs = _gather_rows(neighbors, wl1)
    t_rows = t[jnp.clip(wl1, 0, v - 1)]
    newt = decide_pallas(t_rows, m, active, wl_nbrs,
                         jnp.asarray(count, jnp.int32),
                         interpret=_resolve_interpret(interpret))
    return t.at[wl1].set(newt, mode="drop")
