"""Jitted wrappers wiring the Pallas min-propagation kernels into the
compacted MIS-2 driver (core/mis2.py, engine ``"pallas"``).

The XLA side does the irregular parts (worklist row gather, scatter-back);
the Pallas kernels fuse the neighbor-tuple gather + reductions, which is
the paper's measured hot loop.

``interpret=None`` (the default) defers to the :class:`repro.api.Backend`
policy: interpret only when no accelerator is attached.  The seed
hard-coded ``interpret=True``, silently running the Pallas interpreter
even on TPU/GPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .._interpret import resolve_interpret as _resolve_interpret
from .kernel import (
    decide_pallas,
    fused_decide_pallas,
    fused_refresh_columns_pallas,
    refresh_columns_pallas,
)

OUT = np.uint32(0xFFFFFFFF)

# ---------------------------------------------------------------------------
# ELL row-traffic model (asserted by tests/test_resident.py)
#
# HBM movements of one live worklist row's ELL entries per per-round pass:
# the host-driven path gathers the row in XLA (1 read), materializes the
# [W, D] worklist copy (1 write), and the kernel reads the copy back
# (1 read) — 3 movements.  The fused resident kernels gather the row
# in-kernel from the flat [V*D] adjacency: 1 read, no copy.
# ---------------------------------------------------------------------------

ELL_ROW_TRAFFIC = {
    "pallas": {"reads": 2, "writes": 1},
    "pallas_resident": {"reads": 1, "writes": 0},
}


def ell_row_movements(engine: str) -> int:
    """Total HBM movements of one worklist row's ELL entries per pass."""
    t = ELL_ROW_TRAFFIC[engine]
    return t["reads"] + t["writes"]


@jax.jit
def _gather_rows(neighbors, wl):
    v = neighbors.shape[0]
    return neighbors[jnp.clip(wl, 0, v - 1)]


def refresh_columns(t, m, wl2, neighbors, count, *, interpret=None):
    """M.at[wl2] <- poisoned min of T over wl2 rows' closed neighborhoods."""
    wl_nbrs = _gather_rows(neighbors, wl2)
    mv = refresh_columns_pallas(t, wl_nbrs, jnp.asarray(count, jnp.int32),
                                interpret=_resolve_interpret(interpret))
    return m.at[wl2].set(mv, mode="drop")


def decide(t, m, wl1, neighbors, active, count, *, interpret=None):
    """T.at[wl1] <- IN/OUT decision for wl1 rows."""
    v = neighbors.shape[0]
    wl_nbrs = _gather_rows(neighbors, wl1)
    t_rows = t[jnp.clip(wl1, 0, v - 1)]
    newt = decide_pallas(t_rows, m, active, wl_nbrs,
                         jnp.asarray(count, jnp.int32),
                         interpret=_resolve_interpret(interpret))
    return t.at[wl1].set(newt, mode="drop")


# ---------------------------------------------------------------------------
# fused wrappers for the device-resident driver: worklist *indices* go in
# (no pre-gathered [W, D] row copies), counts may be traced (they feed the
# pl.when block skipping via scalar prefetch inside a lax.while_loop)
# ---------------------------------------------------------------------------

def fused_refresh_columns(t, m, wl2, count, neighbors, it, *, priority: str,
                          b: int, interpret=None):
    """M.at[wl2] <- poisoned min over wl2 rows' closed neighborhoods, with
    the §V-A row refresh applied to the gathered tuples on the fly."""
    mv = fused_refresh_columns_pallas(
        t, neighbors.reshape(-1), wl2, jnp.asarray(count, jnp.int32),
        jnp.asarray(it, jnp.uint32), priority=priority, b=b,
        interpret=_resolve_interpret(interpret))
    return m.at[wl2].set(mv, mode="drop")


def fused_decide(t, m, wl1, count, neighbors, active, it, *, priority: str,
                 b: int, interpret=None):
    """T.at[wl1] <- IN/OUT decision, row tuple gather + refresh in-kernel.

    Because still-undecided rows get their *refreshed* tuple written back,
    this single scatter leaves T exactly as the host pipeline's
    refresh_rows + decide pair would."""
    newt = fused_decide_pallas(
        t, m, active, neighbors.reshape(-1), wl1,
        jnp.asarray(count, jnp.int32), jnp.asarray(it, jnp.uint32),
        priority=priority, b=b, interpret=_resolve_interpret(interpret))
    return t.at[wl1].set(newt, mode="drop")
