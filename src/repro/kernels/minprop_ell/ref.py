"""Pure-jnp oracle for the min-propagation / decide kernels.

These are exactly the XLA-path bodies from core/mis2.py, restated standalone
so kernel tests have an independent reference.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

IN = np.uint32(0)
OUT = np.uint32(0xFFFFFFFF)


def refresh_columns_ref(t: jnp.ndarray, wl_neighbors: jnp.ndarray,
                        count: int) -> jnp.ndarray:
    """M for each worklist row: closed-neighborhood min of T, IN poisoned.

    wl_neighbors: int32 [W, D] — pre-gathered ELL rows of the worklist.
    Rows at index >= count are don't-care (returned as OUT).
    """
    tn = t[wl_neighbors]                    # [W, D]
    m = jnp.min(tn, axis=1)
    m = jnp.where(m == IN, OUT, m)
    w = wl_neighbors.shape[0]
    live = jnp.arange(w) < count
    return jnp.where(live, m, OUT)


def decide_ref(t_rows: jnp.ndarray, m: jnp.ndarray, active: jnp.ndarray,
               wl_neighbors: jnp.ndarray, count: int) -> jnp.ndarray:
    """New T for each worklist row (IN / OUT / unchanged).

    t_rows: uint32 [W] current tuples of worklist rows.
    m, active: full [V] arrays.
    """
    mn = m[wl_neighbors]                    # [W, D]
    an = active[wl_neighbors]
    any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
    all_eq = jnp.all(jnp.where(an, mn, t_rows[:, None]) == t_rows[:, None],
                     axis=1)
    newt = jnp.where(any_out, OUT, jnp.where(all_eq, IN, t_rows))
    und = (t_rows != IN) & (t_rows != OUT)
    newt = jnp.where(und, newt, t_rows)
    w = wl_neighbors.shape[0]
    live = jnp.arange(w) < count
    return jnp.where(live, newt, t_rows)
