"""Pure-jnp oracle for the min-propagation / decide kernels.

These are exactly the XLA-path bodies from core/mis2.py, restated standalone
so kernel tests have an independent reference.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

IN = np.uint32(0)
OUT = np.uint32(0xFFFFFFFF)


def refresh_columns_ref(t: jnp.ndarray, wl_neighbors: jnp.ndarray,
                        count: int) -> jnp.ndarray:
    """M for each worklist row: closed-neighborhood min of T, IN poisoned.

    wl_neighbors: int32 [W, D] — pre-gathered ELL rows of the worklist.
    Rows at index >= count are don't-care (returned as OUT).
    """
    tn = t[wl_neighbors]                    # [W, D]
    m = jnp.min(tn, axis=1)
    m = jnp.where(m == IN, OUT, m)
    w = wl_neighbors.shape[0]
    live = jnp.arange(w) < count
    return jnp.where(live, m, OUT)


def decide_ref(t_rows: jnp.ndarray, m: jnp.ndarray, active: jnp.ndarray,
               wl_neighbors: jnp.ndarray, count: int) -> jnp.ndarray:
    """New T for each worklist row (IN / OUT / unchanged).

    t_rows: uint32 [W] current tuples of worklist rows.
    m, active: full [V] arrays.
    """
    mn = m[wl_neighbors]                    # [W, D]
    an = active[wl_neighbors]
    any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
    all_eq = jnp.all(jnp.where(an, mn, t_rows[:, None]) == t_rows[:, None],
                     axis=1)
    newt = jnp.where(any_out, OUT, jnp.where(all_eq, IN, t_rows))
    und = (t_rows != IN) & (t_rows != OUT)
    newt = jnp.where(und, newt, t_rows)
    w = wl_neighbors.shape[0]
    live = jnp.arange(w) < count
    return jnp.where(live, newt, t_rows)


# ---------------------------------------------------------------------------
# fused-pass oracles (``pallas_resident``): refresh folded into the gathers
# ---------------------------------------------------------------------------

def _refresh_ref(t_vals, ids, it, priority: str, b: int):
    from ...core.hashing import PRIORITY_FNS
    from ...core.tuples import pack

    fresh = pack(PRIORITY_FNS[priority](it, ids.astype(jnp.uint32)), ids, b)
    und = (t_vals != IN) & (t_vals != OUT)
    return jnp.where(und, fresh, t_vals)


def fused_refresh_columns_ref(t, neighbors, wl, count, it, priority: str,
                              b: int) -> jnp.ndarray:
    """M for each worklist slot with the §V-A refresh applied on the fly.

    neighbors: int32 [V, D] (NOT pre-gathered); wl: sentinel-padded [W]."""
    v = neighbors.shape[0]
    rows = jnp.clip(wl, 0, v - 1)
    tn = _refresh_ref(t, jnp.arange(v, dtype=jnp.uint32), it, priority,
                      b)[neighbors[rows]]
    m = jnp.min(tn, axis=1)
    m = jnp.where(m == IN, OUT, m)
    live = jnp.arange(wl.shape[0]) < count
    return jnp.where(live, m, OUT)


def fused_decide_ref(t, m, active, neighbors, wl, count, it, priority: str,
                     b: int) -> jnp.ndarray:
    """New T for each worklist slot, row gather + refresh folded in."""
    v = neighbors.shape[0]
    rows = jnp.clip(wl, 0, v - 1)
    tv_old = t[rows]
    tv = _refresh_ref(tv_old, rows.astype(jnp.uint32), it, priority, b)
    nb = neighbors[rows]
    mn = m[nb]
    an = active[nb]
    any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
    all_eq = jnp.all(jnp.where(an, mn, tv[:, None]) == tv[:, None], axis=1)
    newt = jnp.where(any_out, OUT, jnp.where(all_eq, IN, tv))
    und = (tv_old != IN) & (tv_old != OUT)
    newt = jnp.where(und, newt, tv_old)
    live = jnp.arange(wl.shape[0]) < count
    return jnp.where(live, newt, jnp.zeros_like(newt))
