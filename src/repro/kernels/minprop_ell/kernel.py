"""Pallas TPU kernels for the MIS-2 hot loops (paper §V-D, TPU-adapted).

The paper's SIMD optimization reads each vertex's adjacency row with a
warp so loads coalesce.  The TPU analogue (DESIGN.md §3): rows live in
ELL layout, a *block* of rows ``[BLOCK_ROWS, D]`` is one VMEM tile, and the
neighbor-tuple gather + min-reduce runs on the VPU across lanes.  The
paper's *worklist* optimization maps to block-granular work skipping: the
live worklist length is scalar-prefetched into SMEM and grid blocks whose
row range lies entirely past ``count`` exit via ``pl.when`` without touching
VMEM/HBM — the TPU equivalent of launching fewer thread blocks.

Tiling:
* ``wl_neighbors [W, D]`` — blocked ``[BLOCK_ROWS, D]`` along the grid.
* ``t / m / active [V]``  — resident as a single VMEM block (uint32; 4 MB at
  V = 1M).  For V beyond VMEM, the banded variant would block T by the
  graph bandwidth (RCM-ordered meshes have O(V^(2/3)) bands); the tests
  exercise the resident variant, which is the paper's problem regime.
* gathers ``t[idx]`` inside the kernel are 1-D VMEM vector gathers
  (``jnp.take``), the Mosaic-supported form.

Validated with ``interpret=True`` on CPU against ref.py (bit-exact — all
integer math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# hashing/tuples are leaf modules (jax/numpy only) — importing them here
# keeps the §V-A rank packing bit-identical to the XLA path without
# touching the core drivers (no import cycle: core imports kernels lazily)
from ...core.hashing import PRIORITY_FNS
from ...core.tuples import pack

IN = np.uint32(0)
OUT = np.uint32(0xFFFFFFFF)

BLOCK_ROWS = 256
# The fused resident kernels run their whole grid inside a lax.while_loop,
# so per-grid-step overhead is paid every round; a larger block amortizes
# it (and still fits VMEM: 512 rows x D neighbor ids x 4 B is ~16 KB at
# D=8, beside the [V]-resident T/M vectors).
FUSED_BLOCK_ROWS = 512


def _refresh_columns_kernel(count_ref, nbrs_ref, t_ref, m_ref):
    """One grid step: M[block] = poisoned closed-neighborhood min of T."""
    i = pl.program_id(0)
    block = nbrs_ref.shape[0]

    @pl.when(i * block < count_ref[0])          # §V-B: skip dead blocks
    def _():
        nbrs = nbrs_ref[...]                    # [B, D] int32
        t = t_ref[...]                          # [V] uint32 (VMEM-resident)
        tn = jnp.take(t, nbrs.reshape(-1), axis=0).reshape(nbrs.shape)
        mv = jnp.min(tn, axis=1)
        mv = jnp.where(mv == IN, OUT, mv)
        m_ref[...] = mv

    @pl.when(i * block >= count_ref[0])
    def _():
        m_ref[...] = jnp.full((block,), OUT, dtype=jnp.uint32)


def _decide_kernel(count_ref, nbrs_ref, trow_ref, m_ref, act_ref, out_ref):
    """One grid step: decide IN/OUT for a block of worklist rows."""
    i = pl.program_id(0)
    block = nbrs_ref.shape[0]

    @pl.when(i * block < count_ref[0])
    def _():
        nbrs = nbrs_ref[...]                    # [B, D]
        tv = trow_ref[...]                      # [B]
        m = m_ref[...]                          # [V]
        act = act_ref[...]                      # [V]
        flat = nbrs.reshape(-1)
        mn = jnp.take(m, flat, axis=0).reshape(nbrs.shape)
        an = jnp.take(act, flat, axis=0).reshape(nbrs.shape)
        any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
        all_eq = jnp.all(jnp.where(an, mn, tv[:, None]) == tv[:, None], axis=1)
        newt = jnp.where(any_out, OUT, jnp.where(all_eq, IN, tv))
        und = (tv != IN) & (tv != OUT)
        out_ref[...] = jnp.where(und, newt, tv)

    @pl.when(i * block >= count_ref[0])
    def _():
        out_ref[...] = trow_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def refresh_columns_pallas(t: jnp.ndarray, wl_neighbors: jnp.ndarray,
                           count: jnp.ndarray, *, interpret: bool = True,
                           block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """M values for the (padded) worklist rows. Rows >= count return OUT."""
    w, d = wl_neighbors.shape
    block = min(block_rows, w)
    grid = pl.cdiv(w, block)
    v = t.shape[0]
    return pl.pallas_call(
        _refresh_columns_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((block, d), lambda i, *_: (i, 0)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=interpret,
    )(count.reshape(1), wl_neighbors, t)


# ===========================================================================
# fused passes for the device-resident engine (``pallas_resident``)
#
# The host-driven round moves each live row's ELL entries through HBM three
# times: the XLA ``_gather_rows`` reads the row and writes a ``[W, D]``
# worklist copy, then the kernel reads that copy back — per pass.  The
# fused kernels below take the worklist *indices* plus the flat ``[V*D]``
# adjacency and do the row gather in-kernel (one read, no materialized
# copy).  The §V-A rank packing is folded into the same gather: an
# undecided tuple's refreshed value is a pure function of (vertex id,
# round), so instead of a separate refresh_rows scatter pass the kernels
# recompute it on the fly for every gathered neighbor.  The stored T is
# only written once per round, by the decide scatter — and because decide
# writes the refreshed tuple for still-undecided rows, the stored state
# after each round is bit-identical to the three-pass host pipeline.
# ===========================================================================

def _refresh_inline(t_vals, ids, it, priority: str, b: int):
    """T after the §V-A row refresh, recomputed from ids instead of memory.

    ``wl1`` is exactly the undecided set, so ``refresh_rows`` is the pure
    map ``t -> undecided(t) ? pack(prio(it, id), id) : t`` — no pass
    over stored T needed.
    """
    fresh = pack(PRIORITY_FNS[priority](it, ids), ids, b)
    und = (t_vals != IN) & (t_vals != OUT)
    return jnp.where(und, fresh, t_vals)


def _gather_rows_inkernel(nbrs_flat, rows, d: int):
    """[B] row ids -> [B, d] neighbor ids via a 1-D VMEM vector gather."""
    block = rows.shape[0]
    idx = rows[:, None] * d + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block, d), 1)
    return jnp.take(nbrs_flat, idx.reshape(-1), axis=0).reshape(block, d)


def _fused_refresh_columns_kernel(count_ref, it_ref, wl_ref, nbrs_ref,
                                  t_ref, m_ref, *, priority: str, b: int,
                                  d: int):
    """One grid step: M[wl block] from ONE in-kernel read of the ELL rows,
    with the §V-A rank packing applied to the gathered tuples on the fly."""
    i = pl.program_id(0)
    block = wl_ref.shape[0]

    @pl.when(i * block < count_ref[0])          # §V-B: skip dead blocks
    def _():
        v = t_ref.shape[0]
        rows = jnp.clip(wl_ref[...], 0, v - 1)  # sentinel slots: dropped later
        nbrs = _gather_rows_inkernel(nbrs_ref[...], rows, d)
        t = t_ref[...]
        tn = jnp.take(t, nbrs.reshape(-1), axis=0).reshape(nbrs.shape)
        tn = _refresh_inline(tn, nbrs.astype(jnp.uint32), it_ref[0],
                             priority, b)
        mv = jnp.min(tn, axis=1)
        m_ref[...] = jnp.where(mv == IN, OUT, mv)

    @pl.when(i * block >= count_ref[0])
    def _():
        m_ref[...] = jnp.full((block,), OUT, dtype=jnp.uint32)


def _fused_decide_kernel(count_ref, it_ref, wl_ref, nbrs_ref, t_ref, m_ref,
                         act_ref, out_ref, *, priority: str, b: int, d: int):
    """One grid step: IN/OUT decision for a block of worklist rows, with
    the row tuple gather + refresh folded in (no pre-gathered T rows)."""
    i = pl.program_id(0)
    block = wl_ref.shape[0]

    @pl.when(i * block < count_ref[0])
    def _():
        v = t_ref.shape[0]
        rows = jnp.clip(wl_ref[...], 0, v - 1)
        t = t_ref[...]
        tv_old = jnp.take(t, rows, axis=0)
        tv = _refresh_inline(tv_old, rows.astype(jnp.uint32), it_ref[0],
                             priority, b)
        nbrs = _gather_rows_inkernel(nbrs_ref[...], rows, d)
        flat = nbrs.reshape(-1)
        mn = jnp.take(m_ref[...], flat, axis=0).reshape(nbrs.shape)
        an = jnp.take(act_ref[...], flat, axis=0).reshape(nbrs.shape)
        any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
        all_eq = jnp.all(jnp.where(an, mn, tv[:, None]) == tv[:, None], axis=1)
        newt = jnp.where(any_out, OUT, jnp.where(all_eq, IN, tv))
        und = (tv_old != IN) & (tv_old != OUT)
        out_ref[...] = jnp.where(und, newt, tv_old)

    @pl.when(i * block >= count_ref[0])
    def _():
        # every slot of a dead block holds the sentinel V: the scatter back
        # into T drops all of them, so the fill value is never observed
        out_ref[...] = jnp.zeros((block,), dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("priority", "b", "interpret",
                                             "block_rows"))
def fused_refresh_columns_pallas(t: jnp.ndarray, nbrs_flat: jnp.ndarray,
                                 wl: jnp.ndarray, count: jnp.ndarray,
                                 it: jnp.ndarray, *, priority: str, b: int,
                                 interpret: bool = True,
                                 block_rows: int = FUSED_BLOCK_ROWS) -> jnp.ndarray:
    """Fused refresh_rows+refresh_columns: M values for the worklist slots.

    ``wl`` is a full ``[V]`` sentinel-padded index buffer (the resident
    driver's fixed-shape worklist); ``count`` may be traced — it reaches
    the kernel via scalar prefetch, so block skipping follows the *live*
    worklist length with no host involvement.
    """
    v = t.shape[0]
    w = wl.shape[0]
    d = nbrs_flat.shape[0] // v
    block = min(block_rows, w)
    grid = pl.cdiv(w, block)
    kernel = functools.partial(_fused_refresh_columns_kernel,
                               priority=priority, b=b, d=d)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((block,), lambda i, *_: (i,)),
                pl.BlockSpec((v * d,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=interpret,
    )(count.reshape(1), it.reshape(1), wl, nbrs_flat, t)


@functools.partial(jax.jit, static_argnames=("priority", "b", "interpret",
                                             "block_rows"))
def fused_decide_pallas(t: jnp.ndarray, m: jnp.ndarray, active: jnp.ndarray,
                        nbrs_flat: jnp.ndarray, wl: jnp.ndarray,
                        count: jnp.ndarray, it: jnp.ndarray, *,
                        priority: str, b: int, interpret: bool = True,
                        block_rows: int = FUSED_BLOCK_ROWS) -> jnp.ndarray:
    """Fused row-gather+decide: new T values for the worklist slots."""
    v = t.shape[0]
    w = wl.shape[0]
    d = nbrs_flat.shape[0] // v
    block = min(block_rows, w)
    grid = pl.cdiv(w, block)
    kernel = functools.partial(_fused_decide_kernel, priority=priority,
                               b=b, d=d)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((block,), lambda i, *_: (i,)),
                pl.BlockSpec((v * d,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=interpret,
    )(count.reshape(1), it.reshape(1), wl, nbrs_flat, t, m, active)


# ===========================================================================
# sliced passes for the hybrid layout (``pallas_hybrid``)
#
# Same fused shape as above, but the adjacency block is one degree-bucket
# slab ``[R_i, W_i]`` instead of the monolithic ``[V, max_degree]`` ELL:
# the worklist indices are *slice-local* (sentinel R_i), the slab gather
# resolves them locally, and only the T/M/active state vectors stay
# global ``[V]``.  The decide kernel additionally receives the worklist
# slots' *global* row ids (precomputed by the driver from ``slice.rows``)
# because the refresh packing and the T read are keyed by global id.
# One pallas_call per slice per pass — compile count O(#slices).
# ===========================================================================

def _sliced_refresh_columns_kernel(count_ref, it_ref, wl_ref, nbrs_ref,
                                   t_ref, m_ref, *, priority: str, b: int,
                                   d: int):
    """One grid step: M[wl block] for one slice slab.  ``wl`` holds
    slice-local row positions; the gathered neighbor ids are global."""
    i = pl.program_id(0)
    block = wl_ref.shape[0]

    @pl.when(i * block < count_ref[0])          # §V-B: skip dead blocks
    def _():
        r = nbrs_ref.shape[0] // d              # rows in THIS slab
        rows = jnp.clip(wl_ref[...], 0, r - 1)  # sentinel slots: dropped later
        nbrs = _gather_rows_inkernel(nbrs_ref[...], rows, d)
        t = t_ref[...]
        tn = jnp.take(t, nbrs.reshape(-1), axis=0).reshape(nbrs.shape)
        tn = _refresh_inline(tn, nbrs.astype(jnp.uint32), it_ref[0],
                             priority, b)
        mv = jnp.min(tn, axis=1)
        m_ref[...] = jnp.where(mv == IN, OUT, mv)

    @pl.when(i * block >= count_ref[0])
    def _():
        # dead-block slots scatter to the sentinel target and are dropped
        m_ref[...] = jnp.full((block,), OUT, dtype=jnp.uint32)


def _sliced_decide_kernel(count_ref, it_ref, wl_ref, gid_ref, nbrs_ref,
                          t_ref, m_ref, act_ref, out_ref, *, priority: str,
                          b: int, d: int):
    """One grid step: IN/OUT decision for a block of one slice's worklist.
    ``wl`` indexes the slab; ``gid`` carries the matching global ids."""
    i = pl.program_id(0)
    block = wl_ref.shape[0]

    @pl.when(i * block < count_ref[0])
    def _():
        r = nbrs_ref.shape[0] // d
        v = t_ref.shape[0]
        rows = jnp.clip(wl_ref[...], 0, r - 1)
        gids = jnp.clip(gid_ref[...], 0, v - 1)
        t = t_ref[...]
        tv_old = jnp.take(t, gids, axis=0)
        tv = _refresh_inline(tv_old, gids.astype(jnp.uint32), it_ref[0],
                             priority, b)
        nbrs = _gather_rows_inkernel(nbrs_ref[...], rows, d)
        flat = nbrs.reshape(-1)
        mn = jnp.take(m_ref[...], flat, axis=0).reshape(nbrs.shape)
        an = jnp.take(act_ref[...], flat, axis=0).reshape(nbrs.shape)
        any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
        all_eq = jnp.all(jnp.where(an, mn, tv[:, None]) == tv[:, None], axis=1)
        newt = jnp.where(any_out, OUT, jnp.where(all_eq, IN, tv))
        und = (tv_old != IN) & (tv_old != OUT)
        out_ref[...] = jnp.where(und, newt, tv_old)

    @pl.when(i * block >= count_ref[0])
    def _():
        # dead-block slots scatter to the sentinel target and are dropped
        out_ref[...] = jnp.zeros((block,), dtype=jnp.uint32)


# VMEM budget per worklist block in slab entries (rows x width); the block
# row count adapts to the slice width so wide slices don't blow the tile.
SLICE_BLOCK_ENTRIES = FUSED_BLOCK_ROWS * 8


def slice_block_rows(num_rows: int, width: int, interpret: bool) -> int:
    """Worklist block size for one slice.  Interpret mode executes grid
    steps as a sequential host scan, so it takes the whole slice as one
    block; compiled mode tiles to ~SLICE_BLOCK_ENTRIES slab entries."""
    if interpret:
        return max(1, num_rows)
    return max(8, min(num_rows, SLICE_BLOCK_ENTRIES // max(width, 1)))


@functools.partial(jax.jit, static_argnames=("priority", "b", "d",
                                             "interpret", "block_rows"))
def sliced_refresh_columns_pallas(t: jnp.ndarray, nbrs_flat: jnp.ndarray,
                                  wl: jnp.ndarray, count: jnp.ndarray,
                                  it: jnp.ndarray, *, priority: str, b: int,
                                  d: int, interpret: bool = True,
                                  block_rows: int = FUSED_BLOCK_ROWS) -> jnp.ndarray:
    """Fused refresh for one slice: M values per slice-local worklist slot.

    ``nbrs_flat`` is the slab ``[R*d]``; ``wl`` is ``[R]`` sentinel-padded
    with slice-local positions; ``t`` stays the global ``[V]`` state."""
    v = t.shape[0]
    r = nbrs_flat.shape[0] // d
    block = min(block_rows, max(r, 1))
    grid = pl.cdiv(r, block)
    kernel = functools.partial(_sliced_refresh_columns_kernel,
                               priority=priority, b=b, d=d)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((block,), lambda i, *_: (i,)),
                pl.BlockSpec((r * d,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.uint32),
        interpret=interpret,
    )(count.reshape(1), it.reshape(1), wl, nbrs_flat, t)


@functools.partial(jax.jit, static_argnames=("priority", "b", "d",
                                             "interpret", "block_rows"))
def sliced_decide_pallas(t: jnp.ndarray, m: jnp.ndarray, active: jnp.ndarray,
                         nbrs_flat: jnp.ndarray, wl: jnp.ndarray,
                         gids: jnp.ndarray, count: jnp.ndarray,
                         it: jnp.ndarray, *, priority: str, b: int, d: int,
                         interpret: bool = True,
                         block_rows: int = FUSED_BLOCK_ROWS) -> jnp.ndarray:
    """Fused decide for one slice: new T values per slice-local worklist
    slot (``gids`` maps each slot to its global row; the driver scatters
    the output back into T at those ids with drop semantics)."""
    v = t.shape[0]
    r = nbrs_flat.shape[0] // d
    block = min(block_rows, max(r, 1))
    grid = pl.cdiv(r, block)
    kernel = functools.partial(_sliced_decide_kernel, priority=priority,
                               b=b, d=d)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((block,), lambda i, *_: (i,)),
                pl.BlockSpec((block,), lambda i, *_: (i,)),
                pl.BlockSpec((r * d,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.uint32),
        interpret=interpret,
    )(count.reshape(1), it.reshape(1), wl, gids, nbrs_flat, t, m, active)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def decide_pallas(t_rows: jnp.ndarray, m: jnp.ndarray, active: jnp.ndarray,
                  wl_neighbors: jnp.ndarray, count: jnp.ndarray, *,
                  interpret: bool = True,
                  block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    w, d = wl_neighbors.shape
    block = min(block_rows, w)
    grid = pl.cdiv(w, block)
    v = m.shape[0]
    return pl.pallas_call(
        _decide_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((block, d), lambda i, *_: (i, 0)),
                pl.BlockSpec((block,), lambda i, *_: (i,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=interpret,
    )(count.reshape(1), wl_neighbors, t_rows, m, active)
