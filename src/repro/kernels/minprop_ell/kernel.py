"""Pallas TPU kernels for the MIS-2 hot loops (paper §V-D, TPU-adapted).

The paper's SIMD optimization reads each vertex's adjacency row with a
warp so loads coalesce.  The TPU analogue (DESIGN.md §3): rows live in
ELL layout, a *block* of rows ``[BLOCK_ROWS, D]`` is one VMEM tile, and the
neighbor-tuple gather + min-reduce runs on the VPU across lanes.  The
paper's *worklist* optimization maps to block-granular work skipping: the
live worklist length is scalar-prefetched into SMEM and grid blocks whose
row range lies entirely past ``count`` exit via ``pl.when`` without touching
VMEM/HBM — the TPU equivalent of launching fewer thread blocks.

Tiling:
* ``wl_neighbors [W, D]`` — blocked ``[BLOCK_ROWS, D]`` along the grid.
* ``t / m / active [V]``  — resident as a single VMEM block (uint32; 4 MB at
  V = 1M).  For V beyond VMEM, the banded variant would block T by the
  graph bandwidth (RCM-ordered meshes have O(V^(2/3)) bands); the tests
  exercise the resident variant, which is the paper's problem regime.
* gathers ``t[idx]`` inside the kernel are 1-D VMEM vector gathers
  (``jnp.take``), the Mosaic-supported form.

Validated with ``interpret=True`` on CPU against ref.py (bit-exact — all
integer math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

IN = np.uint32(0)
OUT = np.uint32(0xFFFFFFFF)

BLOCK_ROWS = 256


def _refresh_columns_kernel(count_ref, nbrs_ref, t_ref, m_ref):
    """One grid step: M[block] = poisoned closed-neighborhood min of T."""
    i = pl.program_id(0)
    block = nbrs_ref.shape[0]

    @pl.when(i * block < count_ref[0])          # §V-B: skip dead blocks
    def _():
        nbrs = nbrs_ref[...]                    # [B, D] int32
        t = t_ref[...]                          # [V] uint32 (VMEM-resident)
        tn = jnp.take(t, nbrs.reshape(-1), axis=0).reshape(nbrs.shape)
        mv = jnp.min(tn, axis=1)
        mv = jnp.where(mv == IN, OUT, mv)
        m_ref[...] = mv

    @pl.when(i * block >= count_ref[0])
    def _():
        m_ref[...] = jnp.full((block,), OUT, dtype=jnp.uint32)


def _decide_kernel(count_ref, nbrs_ref, trow_ref, m_ref, act_ref, out_ref):
    """One grid step: decide IN/OUT for a block of worklist rows."""
    i = pl.program_id(0)
    block = nbrs_ref.shape[0]

    @pl.when(i * block < count_ref[0])
    def _():
        nbrs = nbrs_ref[...]                    # [B, D]
        tv = trow_ref[...]                      # [B]
        m = m_ref[...]                          # [V]
        act = act_ref[...]                      # [V]
        flat = nbrs.reshape(-1)
        mn = jnp.take(m, flat, axis=0).reshape(nbrs.shape)
        an = jnp.take(act, flat, axis=0).reshape(nbrs.shape)
        any_out = jnp.any(jnp.where(an, mn, IN) == OUT, axis=1)
        all_eq = jnp.all(jnp.where(an, mn, tv[:, None]) == tv[:, None], axis=1)
        newt = jnp.where(any_out, OUT, jnp.where(all_eq, IN, tv))
        und = (tv != IN) & (tv != OUT)
        out_ref[...] = jnp.where(und, newt, tv)

    @pl.when(i * block >= count_ref[0])
    def _():
        out_ref[...] = trow_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def refresh_columns_pallas(t: jnp.ndarray, wl_neighbors: jnp.ndarray,
                           count: jnp.ndarray, *, interpret: bool = True,
                           block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """M values for the (padded) worklist rows. Rows >= count return OUT."""
    w, d = wl_neighbors.shape
    block = min(block_rows, w)
    grid = pl.cdiv(w, block)
    v = t.shape[0]
    return pl.pallas_call(
        _refresh_columns_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((block, d), lambda i, *_: (i, 0)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=interpret,
    )(count.reshape(1), wl_neighbors, t)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def decide_pallas(t_rows: jnp.ndarray, m: jnp.ndarray, active: jnp.ndarray,
                  wl_neighbors: jnp.ndarray, count: jnp.ndarray, *,
                  interpret: bool = True,
                  block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    w, d = wl_neighbors.shape
    block = min(block_rows, w)
    grid = pl.cdiv(w, block)
    v = m.shape[0]
    return pl.pallas_call(
        _decide_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((block, d), lambda i, *_: (i, 0)),
                pl.BlockSpec((block,), lambda i, *_: (i,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
                pl.BlockSpec((v,), lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=interpret,
    )(count.reshape(1), wl_neighbors, t_rows, m, active)
