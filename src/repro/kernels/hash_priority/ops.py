"""Jitted wrapper for the hash+pack kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import hash_pack_pallas


def hash_pack(iteration, vertex_ids: jnp.ndarray, b: int, *,
              interpret: bool = True) -> jnp.ndarray:
    return hash_pack_pallas(iteration, vertex_ids.astype(jnp.uint32), b,
                            interpret=interpret)
