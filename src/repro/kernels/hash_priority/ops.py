"""Jitted wrapper for the hash+pack kernel.

``interpret=None`` defers to the :class:`repro.api.Backend` policy
(interpret only off-accelerator) instead of the seed's hard ``True``.
"""
from __future__ import annotations

import jax.numpy as jnp

from .._interpret import resolve_interpret as _resolve_interpret
from .kernel import hash_pack_pallas


def hash_pack(iteration, vertex_ids: jnp.ndarray, b: int, *,
              interpret: bool | None = None) -> jnp.ndarray:
    return hash_pack_pallas(iteration, vertex_ids.astype(jnp.uint32), b,
                            interpret=_resolve_interpret(interpret))
