"""Pallas TPU kernel: xorshift64* priorities + tuple packing (paper §V-A/C).

Elementwise over a block of vertex ids.  The interesting TPU detail: there
is no native 64-bit integer lane, so the xorshift* state is a pair of
uint32 VREGs and the multiply is four 16-bit partial products — the limb
emulation from core/hashing.py runs unchanged *inside* the kernel (it is
pure jnp), demonstrating that the production hash lowers to plain VPU ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.hashing import priorities_xorshift_star
from ...core.tuples import pack

BLOCK = 1024


# repro-lint: ignore[RL106] elementwise, no gathered indexing; tail lanes drop at BlockSpec write
def _hash_pack_kernel(it_ref, ids_ref, out_ref, *, b: int):
    ids = ids_ref[...]
    prio = priorities_xorshift_star(it_ref[0], ids)
    out_ref[...] = pack(prio, ids, b)


@functools.partial(jax.jit, static_argnames=("b", "interpret", "block"))
def hash_pack_pallas(iteration, vertex_ids: jnp.ndarray, b: int, *,
                     interpret: bool = True, block: int = BLOCK) -> jnp.ndarray:
    n = vertex_ids.shape[0]
    blk = min(block, n)
    grid = pl.cdiv(n, blk)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        functools.partial(_hash_pack_kernel, b=b),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(grid,),
            in_specs=[pl.BlockSpec((blk,), lambda i, *_: (i,))],
            out_specs=pl.BlockSpec((blk,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(jnp.asarray(iteration, jnp.uint32).reshape(1), vertex_ids)
